"""Serving example: batched greedy decoding with the ServeEngine
(+ optional int8 KV cache, the production decode configuration).

Run:  PYTHONPATH=src python examples/serve_lm.py [--int8-kv]
"""

import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", help="smoke-config arch id")
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_quant_decode=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=128)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, rng.integers(3, 9)).tolist()
               for _ in range(args.batch)]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total_new = args.batch * args.max_new
    print(f"arch={cfg.name} int8_kv={args.int8_kv}")
    for i, o in enumerate(outs):
        print(f"req{i}: prompt={o[:len(prompts[i])]} -> {o[len(prompts[i]):]}")
    print(f"{total_new} tokens in {dt:.2f}s = {total_new / dt:.1f} tok/s (batched)")


if __name__ == "__main__":
    main()
