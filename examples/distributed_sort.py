"""Terasort-style distributed sort (Sample-Shuffle-Compute at its purest),
with pivots, partition sizes, and the cost-model's predicted vs measured
scaling printed.

Run:  PYTHONPATH=src python examples/distributed_sort.py --devices 8
"""

import os
import sys
import time

if "--devices" in sys.argv:
    n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import DDF, DDFContext
from repro.core.cost_model import CostParams, pattern_cost
from repro.data.synthetic import uniform_table, zipf_table


def main():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    P = ctx.nworkers
    n = 200_000

    for name, data in (("uniform", uniform_table(n, 0.99, seed=3)),
                       ("zipf-skewed", zipf_table(n, a=1.3, seed=3))):
        d = DDF.from_numpy(data, ctx, capacity=4 * (n // P + 1))
        t0 = time.time()
        s, info = d.sort_values("c0")
        out = s.to_numpy()["c0"]
        dt = time.time() - t0
        assert np.array_equal(out, np.sort(data["c0"])), "sort mismatch!"
        counts = np.asarray(s.counts)
        skew = counts.max() / max(counts.mean(), 1)
        print(f"{name:12s}: {n} rows sorted in {dt:.2f}s on P={P}; "
              f"partition skew={skew:.2f} "
              f"(overflow={int(np.asarray(info['overflow_shuffle']).sum())})")

    est = pattern_cost("sample_shuffle_compute", P=P, n_rows=n / P, row_bytes=8,
                       params=CostParams())
    print(f"cost model estimate (host fabric): {est['total'] * 1e3:.2f} ms "
          f"[core={est['core'] * 1e3:.2f} aux={est['aux'] * 1e3:.2f} "
          f"comm={est['comm'] * 1e3:.2f}]")


if __name__ == "__main__":
    main()
