"""DLRM-style preprocessing pipeline (the paper's motivating workload class:
TPC/DLRM preprocessing dominated by join/groupby — §6.3).

clicks x users join -> per-user aggregates -> quality filter -> rebalance,
each stage one of the paper's parallel patterns, with the planner choosing
strategies from sampled statistics.

Run:  PYTHONPATH=src python examples/dlrm_preprocess.py [--devices 8]
"""

import os
import sys

if "--devices" in sys.argv:
    n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import DDF, DDFContext
from repro.core.patterns import sampled_cardinality
from repro.expr import col


def main():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    rng = np.random.default_rng(0)

    n_clicks, n_users = 80_000, 2_000
    clicks = {
        "user_id": rng.integers(0, n_users, n_clicks).astype(np.int32),
        "item_id": rng.integers(0, 10_000, n_clicks).astype(np.int32),
        "dwell_ms": rng.integers(10, 60_000, n_clicks).astype(np.int32),
    }
    users = {
        "user_id": np.arange(n_users, dtype=np.int32),
        "region": rng.integers(0, 40, n_users).astype(np.int32),
    }
    dclicks = DDF.from_numpy(clicks, ctx, capacity=2 * (n_clicks // ctx.nworkers + 1))
    dusers = DDF.from_numpy(users, ctx, capacity=2 * (n_users // ctx.nworkers + 1))

    # 1. enrich clicks with user features — users is small, so the cost
    #    model picks BROADCAST join (paper §5.3.7)
    joined, info = dclicks.join(dusers, on=("user_id",))
    print(f"join -> {joined.num_rows()} rows")

    # 2. per-user engagement aggregates — cardinality ~ n_users/n_clicks is
    #    low, so Combine-Shuffle-Reduce wins (paper §5.4.1)
    C = sampled_cardinality(clicks["user_id"][:5000])
    agg, _ = joined.groupby(("user_id",), {"dwell_ms": ("sum", "count", "mean")},
                            cardinality_hint=C)
    print(f"groupby (C-hat={C:.3f}, pre_combine={C < 0.5}) -> {agg.num_rows()} users")

    # 3. embarrassingly-parallel filter + 4. rebalance (partitioned I/O)
    active = agg.select(col("dwell_ms_count") >= 20, name="active")
    balanced, _ = active.rebalance()
    counts = np.asarray(balanced.counts)
    print(f"filter -> {active.num_rows()} active users; "
          f"rebalanced partitions: max-min={counts.max() - counts.min()}")

    # 5. global stats (Globally-Reduce)
    print(f"mean dwell over active users: {float(balanced.agg('dwell_ms_mean', 'mean')):.0f} ms")


if __name__ == "__main__":
    main()
