"""End-to-end training driver: DDF data pipeline -> LM trainer.

The pipeline stages (dedup / filter / length-sort / rebalance) are the
paper's parallel patterns; the trainer is the framework's pjit path with
checkpointing + the straggler watchdog.

Run (tiny, CPU-friendly):
  PYTHONPATH=src python examples/train_lm.py --steps 200
Run the ~100M-param preset (same code; sized for a real accelerator):
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DDFContext
from repro.data.pipeline import TokenPipeline
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.train.checkpoint import save
from repro.train.elastic import StepGuard
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainHParams, init_train_state, make_train_step

PRESETS = {
    # ~1M params: fast on this CPU container
    "tiny": ModelConfig(name="tiny-lm", family="dense", n_layers=4, d_model=128,
                        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=512,
                        vocab_size=2048, norm="rmsnorm", mlp="swiglu"),
    # ~100M params: the task-spec example config (runs identically; sized
    # for accelerators)
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12, d_model=768,
                        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
                        vocab_size=32000, norm="rmsnorm", mlp="swiglu"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = build_model(cfg)

    # ---- DDF data pipeline (the paper's technique as the data path) -------
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    pipe = TokenPipeline(ctx, n_docs=4000, vocab=cfg.vocab_size,
                         seq_len=args.seq, batch=args.batch)
    print(f"pipeline: {pipe.n_docs} docs after dedup+filter, "
          f"{pipe.total_tokens} tokens budget")

    # ---- trainer ------------------------------------------------------------
    hp = TrainHParams(opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps))
    step = jax.jit(make_train_step(model, hp), donate_argnums=(0,))
    state = init_train_state(model, jax.random.key(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"model: {cfg.name}, {n_params:,} params")

    guard = StepGuard(args.ckpt_dir)
    t0 = time.time()
    for i, batch in zip(range(args.steps), pipe):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = guard.step(i, step, state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            toks = args.batch * args.seq * (i + 1)
            print(f"step {i:4d}  loss={float(metrics['loss']):.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}  "
                  f"tok/s={toks / (time.time() - t0):.0f}")
        if i and i % args.ckpt_every == 0:
            save(args.ckpt_dir, i, state)
    save(args.ckpt_dir, args.steps, state)
    print(f"done in {time.time() - t0:.1f}s; final checkpoint at "
          f"{args.ckpt_dir}/step_{args.steps:08d} "
          f"(emergency saves: {guard.emergency_saves})")


if __name__ == "__main__":
    main()
