"""Quickstart: the paper's Fig 2b program on the repro DDF engine.

    df1 = read_csv_dist(...); df2 = read_csv_dist(...)
    df_j = df1.merge(df2); df_s = df_j.sort_values(...); df_s.iloc[:10]

Run:  PYTHONPATH=src python examples/quickstart.py [--devices 8]
"""

import os
import sys

if "--devices" in sys.argv:
    n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import DDF, DDFContext
from repro.data.synthetic import uniform_table
from repro.expr import col


def main():
    # env = execution environment (paper's `env=env`)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    print(f"workers: {ctx.nworkers}")

    # partitioned input (synthetic stands in for read_csv_dist)
    df1 = DDF.from_numpy(uniform_table(50_000, cardinality=0.9, seed=0), ctx)
    df2 = DDF.from_numpy(uniform_table(50_000, cardinality=0.9, seed=1), ctx)

    # join — the planner picks hash-shuffle vs broadcast from the cost model
    df_j, info = df1.join(df2, on=("c0",))
    print(f"join: {df_j.num_rows()} rows "
          f"(overflow={int(np.asarray(info.get('overflow_join', 0)).sum())})")

    # sort (sample-shuffle-compute) then global head(10)
    df_s, _ = df_j.sort_values("c1")
    top = df_s.head(10).to_numpy()
    print("top10 by c1:", top["c1"].tolist())

    # groupby (combine-shuffle-reduce) + global aggregate
    g, _ = df1.groupby(("c0",), {"c1": ("mean", "count")})
    print(f"groups: {g.num_rows()}, global mean(c1) = {float(df1.agg('c1', 'mean')):.1f}")

    # the same filter->join->groupby as ONE lazy plan over expression
    # operators (docs/EXPRESSIONS.md): the optimizer sees the whole
    # pipeline, pushes the predicate below the join shuffle, elides the
    # groupby shuffle (co-partition reuse) and compiles a single shard_map
    # program (docs/LAZY_PLANS.md)
    lz = (df1.lazy().select(col("c1") > 0.25)
          .join(df2.lazy(), on=("c0",), strategy="shuffle")
          .groupby(("c0",), [col("c1").count()]))
    print("lazy plan:")
    print(lz.explain())
    print(f"lazy groups: {lz.collect().num_rows()}")


if __name__ == "__main__":
    main()
