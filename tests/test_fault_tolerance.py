"""Fault tolerance: deterministic chaos tests for the streaming engine.

Every test here is reproducible from a seed (``repro.testing.FaultPlan``):
transient faults exercise the in-place retry path and must leave results
bit-identical; persistent faults kill the query at a chosen site/ordinal
and the resumed run must produce output bit-identical to an uninterrupted
one. ``REPRO_CHAOS_SEED`` (CI matrix) offsets every plan seed so different
legs walk different failure schedules over the same assertions.

Also covers the trainer-checkpoint crash-debris edge cases, StepGuard's
straggler emergency checkpoint (fake clock), elastic rescale onto
smaller/larger meshes (subprocess, 8 host devices), and the prefetch
thread's error-propagation regression.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import stream
from repro.core import DDF, DDFContext
from repro.data.dataset import write_dataset
from repro.stream import (
    RETRYABLE_EXCEPTIONS,
    RetryPolicy,
    StreamCheckpoint,
    call_with_retry,
    classify_error,
)
from repro.testing import FAULT_SITES, FaultPlan, InjectedFault, fault_scope

CHAOS_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def _table(n, nkeys, seed):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, nkeys, n).astype(np.int64),
            "v": rng.standard_normal(n).astype(np.float32)}


@pytest.fixture(scope="module")
def ds(tmp_path_factory):
    """Three chunked datasets; 4096 rows / batch_rows=512 -> 8 morsels.
    ``sleft`` keys are dict-encoded strings (same key distribution as
    ``left``) so the matrix also covers vocab state in carry tables."""
    root = tmp_path_factory.mktemp("faultds")
    left = write_dataset(_table(4096, 50, CHAOS_SEED), str(root / "left"),
                         chunk_rows=256)
    rng = np.random.default_rng(CHAOS_SEED + 1)
    right = write_dataset(
        {"k": rng.integers(0, 50, 1536).astype(np.int64),
         "w": rng.standard_normal(1536).astype(np.float32)},
        str(root / "right"), chunk_rows=192)
    t = _table(4096, 50, CHAOS_SEED + 2)
    words = np.asarray([f"city{i:02d}" for i in range(50)])
    sleft = write_dataset({"k": words[t["k"]], "v": t["v"]},
                          str(root / "sleft"), chunk_rows=256)
    return left, right, sleft


def _pipeline(name, ctx, ds):
    """Named 8+-morsel pipelines covering every blocking-tail strategy."""
    left, right, sleft = ds
    scan = lambda m: stream.scan_dataset(m, ctx, batch_rows=512)
    if name == "groupby":        # device carry table
        return scan(left).groupby(("k",), {"v": ("sum", "count")})
    if name == "strgroupby":     # carry table keyed by dict-encoded strings
        return scan(sleft).groupby(("k",), {"v": ("sum", "count")})
    if name == "unique":         # device carry table (distinct rows)
        return scan(left).unique(("k",))
    if name == "sort":           # host spill + stable merge
        return scan(left).sort_values("v")
    if name == "join":           # scan x scan: bucket spill + bucket joins
        return (scan(left).join(scan(right), on=("k",))
                .groupby(("k",), {"v": ("sum",), "w": ("sum",)}))
    if name == "multi":          # staged materialization: unique below sort
        return scan(left).unique(("k",)).sort_values("k")
    raise ValueError(name)


PIPELINES = ("groupby", "strgroupby", "unique", "sort", "join", "multi")


def _run(name, ctx, ds, **opts):
    lz = _pipeline(name, ctx, ds)
    out = lz.collect_stream(**opts).to_numpy()
    return out, lz.last_info


def _assert_same(ref, out):
    assert set(ref) == set(out)
    for k in ref:
        assert np.array_equal(ref[k], out[k]), f"column {k} diverged"


# -- classification / retry units ----------------------------------------------

def test_classify_error():
    assert classify_error(InjectedFault("device_op", 0)) == "retryable"
    assert classify_error(OSError("disk")) == "retryable"
    assert classify_error(EOFError()) == "retryable"
    assert classify_error(RuntimeError("overflow")) == "fatal"
    assert classify_error(ValueError("schema")) == "fatal"
    assert all(issubclass(t, Exception) for t in RETRYABLE_EXCEPTIONS)


def test_retry_policy_backoff_bounded():
    p = RetryPolicy(max_retries=3, backoff_s=0.1, backoff_factor=2.0,
                    max_backoff_s=0.25)
    assert [p.delay(i) for i in range(4)] == [0.1, 0.2, 0.25, 0.25]


def test_call_with_retry_exhausts_then_raises():
    calls, slept = [], []
    def fn():
        calls.append(1)
        raise OSError("transient")
    with pytest.raises(OSError):
        call_with_retry(fn, RetryPolicy(max_retries=2, backoff_s=0.0),
                        "chunk_decode", sleep=slept.append)
    assert len(calls) == 3 and len(slept) == 2


def test_call_with_retry_fatal_not_retried():
    calls = []
    def fn():
        calls.append(1)
        raise ValueError("deterministic")
    with pytest.raises(ValueError):
        call_with_retry(fn, RetryPolicy(max_retries=5, backoff_s=0.0),
                        "device_op", sleep=lambda s: None)
    assert len(calls) == 1


def test_fault_plan_deterministic():
    a, b = FaultPlan(seed=9, rates={"device_op": 0.5}), \
           FaultPlan(seed=9, rates={"device_op": 0.5})
    fires_a = [bool(_fires(a, "device_op")) for _ in range(40)]
    fires_b = [bool(_fires(b, "device_op")) for _ in range(40)]
    assert fires_a == fires_b and any(fires_a) and not all(fires_a)
    a.reset()
    assert [bool(_fires(a, "device_op")) for _ in range(40)] == fires_a


def _fires(plan, site):
    try:
        plan.check(site)
        return False
    except InjectedFault:
        return True


def test_fault_plan_rejects_unknown_site():
    with pytest.raises(ValueError):
        FaultPlan(rates={"nonsense": 0.5})
    with pytest.raises(ValueError):
        from repro.testing import check
        check("nonsense")
    assert set(("chunk_decode", "prefetch", "device_op", "spill_write",
                "checkpoint_publish")) == set(FAULT_SITES)


# -- trainer checkpoint edge cases ----------------------------------------------

def test_latest_step_empty_and_missing_dir(tmp_path):
    from repro.train.checkpoint import latest_step
    assert latest_step(str(tmp_path)) is None
    assert latest_step(str(tmp_path / "never_created")) is None


def test_latest_step_ignores_debris_and_partials(tmp_path):
    from repro.train.checkpoint import latest_step, list_steps
    good = tmp_path / "step_00000005"
    good.mkdir()
    (good / "manifest.json").write_text("{}")
    (tmp_path / "step_00000007").mkdir()              # partial: no manifest
    (tmp_path / "step_00000006.tmp_0").mkdir()        # crashed publish
    (tmp_path / "step_00000008.tmp_1").mkdir()        # multi-process staging
    (tmp_path / "not_a_step").mkdir()
    assert latest_step(str(tmp_path)) == 5
    # stale staging dirs were cleaned as a side effect
    names = {p.name for p in tmp_path.iterdir()}
    assert not any(".tmp_" in n for n in names)
    # clean_stale=False leaves debris alone
    (tmp_path / "step_00000009.tmp_0").mkdir()
    assert list_steps(str(tmp_path), clean_stale=False) == [5]
    assert (tmp_path / "step_00000009.tmp_0").is_dir()


def test_restore_missing_step_raises(tmp_path):
    from repro.train.checkpoint import restore
    (tmp_path / "step_00000002").mkdir()  # partial, no manifest
    with pytest.raises(FileNotFoundError, match="valid steps"):
        restore(str(tmp_path), 2, {})


def test_step_guard_emergency_checkpoint(tmp_path):
    """A straggler step (fake clock) triggers an atomic emergency save."""
    from repro.train.checkpoint import latest_step
    from repro.train.elastic import StepGuard
    # each step consumes two clock reads; 6 normal steps of dt=1, then a
    # straggler of dt=50 (> 3x trailing mean) triggers the emergency save
    times = []
    for i in range(6):
        times += [float(i), float(i) + 1.0]
    times += [100.0, 150.0]
    clock = iter(times)
    guard = StepGuard(str(tmp_path), threshold_factor=3.0, min_history=5,
                      time_fn=lambda: next(clock))
    state = jax.numpy.zeros((4,))
    fn = lambda s: s + 1
    for i in range(6):
        state = guard.step(i, fn, state)
    assert guard.emergency_saves == 0 and latest_step(str(tmp_path)) is None
    state = guard.step(6, fn, state)
    assert guard.emergency_saves == 1
    assert guard.last_emergency_step == 6
    assert latest_step(str(tmp_path)) == 6


@pytest.mark.slow
def test_rescale_state_across_mesh_sizes(tmp_path):
    """Restore one checkpoint onto smaller AND larger meshes (8 forced host
    devices in a subprocess, keeping this pytest process at 1 device)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax
import numpy as np
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.train.checkpoint import save
from repro.train.elastic import rescale_state
from repro.train.train_step import init_train_state

ckpt = sys.argv[1]
cfg = get_smoke_config("olmo-1b")
model = build_model(cfg)
state = init_train_state(model, jax.random.key(0))
save(ckpt, 11, state)
specs = jax.eval_shape(lambda: state)
for shape in ((2, 1), (8, 1), (4, 2)):
    mesh = jax.make_mesh(shape, ("data", "model"))
    restored, step_no = rescale_state(ckpt, 11, specs, mesh)
    assert step_no == 11
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "rescale mismatch"
    del restored
print("RESCALE OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    res = subprocess.run([sys.executable, "-c", code, str(tmp_path)],
                         capture_output=True, text=True, timeout=600, env=env)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "RESCALE OK" in res.stdout


# -- prefetch propagation (regression) -------------------------------------------

def test_prefetch_propagates_decode_fault(ctx, ds):
    """A decoder failure inside the prefetch thread must surface on the
    consumer thread (historically the thread died and q.get() hung)."""
    plan = FaultPlan(seed=CHAOS_SEED, kill_after={"chunk_decode": 0})
    with fault_scope(plan):
        with pytest.raises(InjectedFault):
            _run("groupby", ctx, ds, prefetch=True, max_retries=0)
    assert plan.invocations("chunk_decode") >= 1


def test_prefetch_site_kill_propagates(ctx, ds):
    plan = FaultPlan(seed=CHAOS_SEED, kill_after={"prefetch": 2})
    with fault_scope(plan):
        with pytest.raises(InjectedFault):
            _run("sort", ctx, ds, prefetch=True)


def test_real_io_error_is_retried(ctx, ds, monkeypatch):
    """A genuine OSError from the chunk reader retries in place and the
    stream still finishes bit-identically."""
    from repro.stream import runner as runner_mod
    ref, _ = _run("groupby", ctx, ds)
    real = runner_mod.read_rows
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 3:
            raise OSError("simulated torn read")
        return real(*a, **kw)

    monkeypatch.setattr(runner_mod, "read_rows", flaky)
    out, info = _run("groupby", ctx, ds)
    _assert_same(ref, out)
    assert info.get("retries:chunk_decode", 0) == 1


# -- seeded chaos: transparent retry ---------------------------------------------

@pytest.mark.parametrize("name", ["groupby", "sort", "join"])
def test_chaos_transparent_retry_bit_identical(ctx, ds, name):
    """Transient faults under the retry budget never change the result.

    ``max_failures <= max_retries`` makes completion certain: a unit of
    work can never see more consecutive fires than the whole plan allows."""
    ref, _ = _run(name, ctx, ds)
    plan = FaultPlan(seed=CHAOS_SEED + 13, max_failures=4,
                     rates={"chunk_decode": 0.5, "device_op": 0.5})
    with fault_scope(plan):
        out, info = _run(name, ctx, ds, max_retries=4)
    _assert_same(ref, out)
    assert len(plan.fired) >= 1
    assert sum(v for k, v in info.items()
               if k.startswith("retries:")) == len(plan.fired)


# -- seeded chaos: kill + resume -------------------------------------------------

KILL_CASES = [
    ("groupby", "device_op", 5),
    ("groupby", "chunk_decode", 5),
    ("unique", "device_op", 4),
    ("sort", "spill_write", 3),
    ("sort", "chunk_decode", 6),
    ("join", "prefetch", 8),
    # the join spills ~16 bucket appends per morsel: ordinal 40 lands a few
    # morsels in, after at least one periodic snapshot has been published
    ("join", "spill_write", 40),
    ("multi", "chunk_decode", 6),
    # string-keyed carry table: the snapshot must persist vocab state and
    # the resumed codes must decode to the same strings
    ("strgroupby", "device_op", 5),
    ("strgroupby", "chunk_decode", 5),
]


@pytest.mark.parametrize("name,site,after", KILL_CASES)
def test_chaos_kill_then_resume_bit_identical(ctx, ds, tmp_path, name, site,
                                              after):
    """Kill the query at a registered fault site, resume from the last
    snapshot, and require output bit-identical to an uninterrupted run —
    while proving the resume actually skipped work (fewer chunk decodes
    than a fresh run)."""
    counter = FaultPlan(seed=CHAOS_SEED)  # no faults: pure invocation counts
    with fault_scope(counter):
        ref, _ = _run(name, ctx, ds)
    full_decodes = counter.invocations("chunk_decode")
    assert full_decodes >= 8, "pipeline must stream 8+ morsels"

    ck = str(tmp_path / "ck")
    plan = FaultPlan(seed=CHAOS_SEED + 7, kill_after={site: after})
    with fault_scope(plan):
        with pytest.raises(InjectedFault):
            _run(name, ctx, ds, checkpoint_dir=ck, checkpoint_every=2)
    assert plan.invocations(site) > after
    store = StreamCheckpoint(ck)
    assert store.steps(), "the killed run must have published a snapshot"

    recount = FaultPlan(seed=CHAOS_SEED)
    with fault_scope(recount):
        out, info = _run(name, ctx, ds, checkpoint_dir=ck, resume=True)
    _assert_same(ref, out)
    assert recount.invocations("chunk_decode") < full_decodes, \
        "resume re-decoded every morsel: it did not restart from the cursor"
    assert store.steps() == [], "store must be cleared on success"


def test_publish_crash_preserves_previous_snapshot(ctx, ds, tmp_path):
    """A crash *during* checkpoint publication must leave the previous
    snapshot restorable: only a ``*.tmp_*`` staging dir may remain, and it
    is cleaned on the next listing."""
    ref, _ = _run("groupby", ctx, ds)
    ck = str(tmp_path / "ck")
    plan = FaultPlan(seed=CHAOS_SEED, kill_after={"checkpoint_publish": 1})
    with fault_scope(plan):
        with pytest.raises(InjectedFault):
            _run("groupby", ctx, ds, checkpoint_dir=ck, checkpoint_every=2)
    names = os.listdir(ck)
    assert any(".tmp_" in n for n in names), "crashed publish leaves staging"
    store = StreamCheckpoint(ck)
    assert store.steps() == [0]
    assert not any(".tmp_" in n for n in os.listdir(ck)), "debris cleaned"
    manifest, _arrays = store.load()
    assert manifest["step"] == 0
    out, _ = _run("groupby", ctx, ds, checkpoint_dir=ck, resume=True)
    _assert_same(ref, out)


def test_resume_rejects_different_query(ctx, ds, tmp_path):
    ck = str(tmp_path / "ck")
    plan = FaultPlan(seed=CHAOS_SEED, kill_after={"device_op": 5})
    with fault_scope(plan):
        with pytest.raises(InjectedFault):
            _run("groupby", ctx, ds, checkpoint_dir=ck, checkpoint_every=2)
    with pytest.raises(ValueError, match="different query"):
        _run("sort", ctx, ds, checkpoint_dir=ck, resume=True)


def test_resume_rejects_different_vocab(ctx, tmp_path):
    """Two datasets with IDENTICAL plan shape, chunk layout, and code
    streams but different string vocabularies: a checkpoint from one must
    refuse to resume the other — carried codes would silently decode to
    the wrong strings."""
    t = _table(4096, 50, CHAOS_SEED + 3)
    qs = {}
    for stem in ("city", "town"):
        words = np.asarray([f"{stem}{i:02d}" for i in range(50)])
        man = write_dataset({"k": words[t["k"]], "v": t["v"]},
                            str(tmp_path / stem), chunk_rows=256)
        qs[stem] = lambda m=man: stream.scan_dataset(
            m, ctx, batch_rows=512).groupby(("k",), {"v": ("sum",)})
    ck = str(tmp_path / "ck")
    plan = FaultPlan(seed=CHAOS_SEED, kill_after={"device_op": 5})
    with fault_scope(plan):
        with pytest.raises(InjectedFault):
            qs["city"]().collect_stream(checkpoint_dir=ck,
                                        checkpoint_every=2)
    with pytest.raises(ValueError, match="different query"):
        qs["town"]().collect_stream(checkpoint_dir=ck, resume=True)
    # the same query still resumes fine
    out = qs["city"]().collect_stream(checkpoint_dir=ck,
                                      resume=True).to_numpy()
    assert sorted(out["k"].tolist()) == sorted(
        f"city{i:02d}" for i in range(50))


def test_resume_requires_checkpoint_dir(ctx, ds):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        _run("groupby", ctx, ds, resume=True)


def test_resume_with_empty_store_runs_fresh(ctx, ds, tmp_path):
    ref, _ = _run("groupby", ctx, ds)
    out, _ = _run("groupby", ctx, ds, checkpoint_dir=str(tmp_path / "ck"),
                  resume=True)
    _assert_same(ref, out)


def test_checkpointing_without_faults_is_transparent(ctx, ds, tmp_path):
    """Snapshots change nothing about the result and are cleared on
    success (they are crash artifacts, not outputs)."""
    for name in ("groupby", "join"):
        ref, _ = _run(name, ctx, ds)
        ck = str(tmp_path / f"ck_{name}")
        out, info = _run(name, ctx, ds, checkpoint_dir=ck, checkpoint_every=2)
        _assert_same(ref, out)
        assert info.get("checkpoints", 0) >= 1
        assert StreamCheckpoint(ck).steps() == []
        assert not os.path.exists(os.path.join(ck, "spill")) or \
            not os.listdir(os.path.join(ck, "spill"))


def test_to_batches_resume_re_yields_from_cursor(ctx, ds, tmp_path):
    """to_batches: a killed iteration resumes from the snapshotted cursor;
    stitching consumed-before-snapshot + resumed batches rebuilds the
    fault-free result exactly."""
    lz = _pipeline("groupby", ctx, ds)  # finalized: single post-carry yield
    ref = [b for b in lz.to_batches()]
    ck = str(tmp_path / "ck")
    plan = FaultPlan(seed=CHAOS_SEED, kill_after={"device_op": 5})
    got = []
    with fault_scope(plan):
        with pytest.raises(InjectedFault):
            for b in _pipeline("groupby", ctx, ds).to_batches(
                    checkpoint_dir=ck, checkpoint_every=2):
                got.append(b)
    resumed = [b for b in _pipeline("groupby", ctx, ds).to_batches(
        checkpoint_dir=ck, resume=True)]
    # groupby finalizes before yielding, so the kill happened pre-yield and
    # the resumed iterator carries the complete result
    assert got == []
    assert len(resumed) == len(ref)
    for a, b in zip(ref, resumed):
        _assert_same(a, b)
    assert StreamCheckpoint(ck).steps() == []


# -- property test: resume == uninterrupted, across seeds ------------------------

def _kill_resume_property(seed):
    """One chaos draw: random pipeline x site x ordinal; killed-and-resumed
    output must equal the fault-free output bit-for-bit."""
    rng = np.random.default_rng(seed)
    name = PIPELINES[int(rng.integers(0, len(PIPELINES)))]
    site = ("chunk_decode", "device_op", "spill_write")[int(rng.integers(0, 3))]
    after = int(rng.integers(2, 8))
    ctx = _kill_resume_property.ctx
    ds = _kill_resume_property.ds
    tmp = _kill_resume_property.tmp
    ck = os.path.join(tmp, f"ck_{seed}")
    ref, _ = _run(name, ctx, ds)
    plan = FaultPlan(seed=seed, kill_after={site: after})
    died = False
    try:
        with fault_scope(plan):
            out, _ = _run(name, ctx, ds, checkpoint_dir=ck,
                          checkpoint_every=2)
    except InjectedFault:
        died = True
        out, _ = _run(name, ctx, ds, checkpoint_dir=ck, resume=True)
    _assert_same(ref, out)
    # sites not exercised by this pipeline (e.g. spill_write under groupby)
    # simply never fire — the run completes and must still be identical
    assert died == (plan.invocations(site) > after)
    assert StreamCheckpoint(ck).steps() == []


@pytest.fixture()
def _property_env(ctx, ds, tmp_path):
    _kill_resume_property.ctx = ctx
    _kill_resume_property.ds = ds
    _kill_resume_property.tmp = str(tmp_path)
    yield


def test_kill_resume_property_seeded(_property_env):
    for seed in (CHAOS_SEED * 100 + 1, CHAOS_SEED * 100 + 2,
                 CHAOS_SEED * 100 + 3):
        _kill_resume_property(seed)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_kill_resume_property_hypothesis(_property_env):
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def prop(seed):
        _kill_resume_property(seed)

    prop()
