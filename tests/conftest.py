import os
import sys

# NOTE (task spec): no XLA_FLAGS here — tests must see the real single CPU
# device. Multi-device DDF semantics are tested via subprocess re-exec in
# test_ddf_multidevice.py.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
