"""Trainer: loss decreases, checkpoint roundtrip, elastic restore,
chunked-xent equivalence, gradient compression parity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.train.checkpoint import latest_step, restore, save
from repro.train.compress import compressed_psum, init_error_feedback
from repro.train.loss import chunked_cross_entropy
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainHParams, init_train_state, make_train_step, train_state_specs


def _toy_batch(cfg, B=4, S=16, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    return {
        "tokens": jnp.asarray(tokens),
        "labels": jnp.asarray(np.roll(tokens, -1, 1)),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }


def test_loss_decreases_over_steps():
    cfg = get_smoke_config("olmo-1b")
    model = build_model(cfg)
    hp = TrainHParams(opt=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=100))
    step = jax.jit(make_train_step(model, hp))
    state = init_train_state(model, jax.random.key(0))
    batch = _toy_batch(cfg)
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]
    assert np.isfinite(losses).all()


def test_microbatch_accumulation_matches_single():
    cfg = get_smoke_config("olmo-1b")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    batch = _toy_batch(cfg, B=4)
    s1 = jax.jit(make_train_step(model, TrainHParams()))
    s2 = jax.jit(make_train_step(model, TrainHParams(microbatches=2)))
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    # same data -> nearly identical update (fp accumulation differences only)
    l1 = jax.tree.leaves(st1["params"])
    l2 = jax.tree.leaves(st2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-3)


def test_chunked_xent_matches_dense():
    rng = np.random.default_rng(0)
    B, S, d, V = 2, 32, 16, 64
    hidden = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    emb = jnp.asarray(rng.normal(size=(V, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (B, S)), jnp.float32)
    nll, ntok = chunked_cross_entropy(hidden, emb, labels, mask, chunk=8)
    logits = hidden @ emb.T
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    dense = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1)
    np.testing.assert_allclose(float(nll), float(dense), rtol=1e-5)
    assert float(ntok) == float(mask.sum())


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("granite-moe-1b-a400m")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, TrainHParams()))
    state, _ = step(state, _toy_batch(cfg))
    path = save(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    specs = train_state_specs(model)
    # opt.step scalar: eval_shape of adamw_init on specs
    restored, step_no = restore(str(tmp_path), 7, jax.eval_shape(lambda: state))
    assert step_no == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "restore mismatch"


def test_checkpoint_atomicity(tmp_path):
    """Second save of the same step replaces cleanly; interrupted tmp dirs
    are ignored by latest_step."""
    cfg = get_smoke_config("olmo-1b")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    save(str(tmp_path), 1, state)
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp_0"), exist_ok=True)
    assert latest_step(str(tmp_path)) == 1
    save(str(tmp_path), 1, state)  # overwrite OK
    assert latest_step(str(tmp_path)) == 1


def test_compressed_psum_parity():
    """int8+error-feedback all-reduce ~ exact mean over workers (single
    device: P=1 exactness + error feedback plumbing)."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(32, 32)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    err = init_error_feedback(grads)

    def run(g, e):
        return compressed_psum(g, "data", e)

    from repro.compat import shard_map
    out, new_err = jax.jit(shard_map(
        run, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False))(grads, err)
    for k in grads:
        scale = float(jnp.max(jnp.abs(grads[k]))) / 127.0
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(grads[k]),
                                   atol=scale)
        # residual = quantization error, bounded by half a quantum-ish
        assert float(jnp.max(jnp.abs(new_err[k]))) <= scale + 1e-6


def test_elastic_rescale_roundtrip(tmp_path):
    """Restore a checkpoint onto a (trivially different) mesh — exercises
    the device_put path used by real rescale."""
    from repro.train.elastic import rescale_state
    cfg = get_smoke_config("olmo-1b")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    save(str(tmp_path), 3, state)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    restored, step_no = rescale_state(str(tmp_path), 3, jax.eval_shape(lambda: state), mesh)
    assert step_no == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
