"""Unified observability layer (tracing + metrics + cost-model checks):
span nesting and thread safety, the disabled fast path, Chrome-trace JSON
schema, per-pattern predicted-vs-observed records across eager/streaming
paths, bit-identity of profiled runs, and the admission controller's
learned working-set corrections."""

import json
import threading

import jax
import numpy as np
import pytest

from repro import obs, stream
from repro.core import DDF, DDFContext
from repro.expr import col
from repro.data.dataset import write_dataset
from repro.obs import metrics, model_check, trace
from repro.service import QueryService
from repro.service.admission import AdmissionController, query_learn_key


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def _table(n, nkeys=100, seed=0):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, nkeys, n).astype(np.int32),
            "v": rng.integers(0, 1000, n).astype(np.int32)}


@pytest.fixture(scope="module")
def tables(ctx):
    L = DDF.from_numpy(_table(400, seed=1), ctx, capacity=800)
    R = {"k": np.arange(100, dtype=np.int32),
         "w": (np.arange(100, dtype=np.int32) % 7).astype(np.int32)}
    return L, DDF.from_numpy(R, ctx, capacity=200)


@pytest.fixture(scope="module")
def dataset(tmp_path_factory):
    root = tmp_path_factory.mktemp("obs")
    return write_dataset(_table(4000, seed=2), str(root / "ds"),
                         chunk_rows=512)


@pytest.fixture()
def traced():
    """Enable tracing for one test, restoring prior state after."""
    with trace.tracing():
        trace_mark, model_mark = trace.mark(), model_check.mark()
        yield trace_mark, model_mark


# -- span mechanics -----------------------------------------------------------

def test_span_nesting_and_attrs(traced):
    mark, _ = traced
    with trace.span("outer", layer="test") as so:
        with trace.span("inner") as si:
            si.set(rows=7)
    t = trace.get_trace(since=mark)
    by_name = {sp.name: sp for sp in t.spans}
    assert set(by_name) >= {"outer", "inner"}
    assert by_name["inner"].parent == by_name["outer"].sid
    assert by_name["inner"].attrs["rows"] == 7
    assert by_name["outer"].attrs["layer"] == "test"
    assert by_name["outer"].t1 >= by_name["inner"].t1 >= by_name["inner"].t0


def test_retroactive_complete_and_instant(traced):
    mark, _ = traced
    t0 = trace.now()
    trace.complete("retro", t0, t0 + 0.5, kind="stage")
    trace.instant("marker", site="here")
    spans = trace.get_trace(since=mark).spans
    retro = next(sp for sp in spans if sp.name == "retro")
    assert retro.duration_s == pytest.approx(0.5)
    assert any(sp.name == "marker" and sp.t0 == sp.t1 for sp in spans)


def test_span_thread_safety(traced):
    """Concurrent spans from many threads: no misnesting across threads
    (parents resolve per-thread), no lost events."""
    mark, _ = traced
    n_threads, per_thread = 8, 25

    def work(i):
        for j in range(per_thread):
            with trace.span(f"t{i}", j=j):
                with trace.span(f"t{i}.child", j=j):
                    pass

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = trace.get_trace(since=mark).spans
    assert len(spans) == n_threads * per_thread * 2
    by_sid = {sp.sid: sp for sp in spans}
    for sp in spans:
        if sp.name.endswith(".child"):
            parent = by_sid[sp.parent]
            assert parent.name == sp.name[:-len(".child")]
            assert parent.tid == sp.tid


def test_disabled_mode_null_span():
    """Disabled tracing hands out one shared null span — no allocation,
    no recording — and records nothing."""
    assert not trace.enabled()
    mark = trace.mark()
    a = trace.span("x", big=list(range(100)))
    b = trace.span("y")
    assert a is b  # the singleton
    with a as sp:
        sp.set(rows=1)
    trace.instant("z")
    trace.complete("w", 0.0, 1.0)
    model_check.record("shuffle_compute", "op", 1.0, 2.0)
    assert len(trace.get_trace(since=mark).spans) == 0
    assert trace.summary()["enabled"] is False


def test_chrome_trace_schema(tmp_path, traced):
    mark, _ = traced
    with trace.span("parent", bytes=123):
        with trace.span("kid"):
            pass
    trace.instant("blip")
    path = tmp_path / "trace.json"
    trace.get_trace(since=mark).save(str(path))
    doc = json.loads(path.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in xs} >= {"parent", "kid", "blip"}
    for e in xs:
        # required Chrome trace_event fields, all JSON-able
        assert {"name", "ph", "ts", "dur", "pid", "tid", "args"} <= set(e)
        assert e["dur"] >= 0
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in events)


# -- metrics registry ---------------------------------------------------------

def test_metrics_parent_chaining_and_restore():
    root = metrics.MetricsRegistry()
    child = metrics.MetricsRegistry(parent=root, prefix="run.")
    child.counter("batches").add(3)
    child.counter("batches").add(2)
    assert child.counter("batches").value == 5
    assert root.counter("run.batches").value == 5
    # restore is local-only: resumed checkpoint counts must not re-count
    # in the process totals
    child.counter("batches").restore(50)
    assert child.counter("batches").value == 50
    assert root.counter("run.batches").value == 5
    g = child.gauge("peak")
    g.max(10.0)
    g.max(4.0)
    assert g.value == 10.0
    assert root.gauge("run.peak").value == 10.0
    g.restore(100.0)
    assert root.gauge("run.peak").value == 10.0
    with pytest.raises(TypeError):
        child.gauge("batches")


def test_timing_summary():
    reg = metrics.MetricsRegistry()
    t = reg.timing("op")
    for s in (0.1, 0.3, 0.2):
        t.observe(s)
    summ = t.summary()
    assert summ["count"] == 3
    assert summ["total_s"] == pytest.approx(0.6)
    assert summ["min_s"] == pytest.approx(0.1)
    assert summ["max_s"] == pytest.approx(0.3)


# -- predicted-vs-observed accounting ------------------------------------------

def _four_op(ctx, tables):
    L, R = tables
    return (L.lazy().select((col("v") % 2).eq(0))
            .project(["k", "v"])
            .join(R.lazy(), on=("k",), strategy="shuffle", capacity=2000)
            .groupby(("k",), {"v": ("sum", "count")}))


def test_profiled_collect_bit_identical(ctx, tables):
    lz = _four_op(ctx, tables)
    base = lz.collect().to_numpy()
    got = lz.collect(profile=True).to_numpy()
    assert set(base) == set(got)
    for k in base:
        assert np.array_equal(base[k], got[k]), k
    prof = lz.last_profile
    assert prof is not None and prof.records
    report = prof.report()["model"]
    assert "shuffle_compute" in report
    for d in report.values():
        assert d["count"] >= 1 and d["observed_s"] >= 0.0
    text = prof.render()
    assert "predicted" in text and "per-pattern model error" in text


def test_explain_analyze(ctx, tables):
    lz = _four_op(ctx, tables)
    plain = lz.explain()
    analyzed = lz.explain(analyze=True)
    assert analyzed.startswith(plain)
    assert "per-pattern model error" in analyzed
    assert lz.last_info is not None  # it really executed


def test_stream_records_scan_and_shuffle_patterns(ctx, dataset, traced):
    """A streamed scan->groupby run while tracing records the paper's
    partitioned_io pattern per decoded batch plus the groupby's shuffle
    pattern per device dispatch."""
    _, mark = traced
    lz = (stream.scan_dataset(dataset, ctx, batch_rows=512)
          .groupby(("k",), {"v": ("sum",)}))
    out = lz.collect()
    assert int(np.asarray(out.counts).sum()) == 100
    recs = model_check.records(since=mark)
    patterns = {r.pattern for r in recs}
    assert "partitioned_io" in patterns  # one per decoded scan batch
    assert patterns & {"combine_shuffle_reduce", "shuffle_compute"}
    scans = [r for r in recs if r.pattern == "partitioned_io"]
    assert len(scans) == 8  # 4000 rows / 512-row batches
    for r in scans:
        assert r.observed_s >= 0.0 and r.observed_rows is not None
    report = model_check.model_report(recs)
    for d in report.values():
        assert {"count", "predicted_s", "observed_s", "mean_abs_rel_err",
                "bias"} <= set(d)


def test_stream_profiled_bit_identical_and_info_stable(ctx, dataset):
    lz = (stream.scan_dataset(dataset, ctx, batch_rows=512)
          .groupby(("k",), {"v": ("sum", "count")}))
    base = lz.collect().to_numpy()
    info_base = dict(lz.last_info)
    got = lz.collect(profile=True).to_numpy()
    info_prof = dict(lz.last_info)
    for k in base:
        assert np.array_equal(base[k], got[k]), k
    assert info_base["batches"] == info_prof["batches"] == 8
    assert info_base["peak_working_set_bytes"] > 0


def test_record_program_apportions_by_share(traced):
    preds = [
        {"node_index": 1, "op": "n1:Join", "pattern": "shuffle_compute",
         "predicted_s": 0.03, "predicted_rows": 10.0,
         "predicted_bytes": 80.0},
        {"node_index": 2, "op": "n2:GroupBy",
         "pattern": "combine_shuffle_reduce", "predicted_s": 0.01,
         "predicted_rows": 5.0, "predicted_bytes": 40.0},
    ]
    _, mark = traced
    model_check.record_program(preds, 0.4, observed_rows=5)
    recs = model_check.records(since=mark)
    assert len(recs) == 2
    total = sum(r.observed_s for r in recs)
    assert total == pytest.approx(0.4)
    join = next(r for r in recs if r.op == "n1:Join")
    gb = next(r for r in recs if r.op == "n2:GroupBy")
    assert join.observed_s == pytest.approx(0.3)
    assert join.meta["share"] == pytest.approx(0.75)
    assert join.observed_rows is None  # output attaches to the last op
    assert gb.observed_rows == 5


# -- kernel-dispatch + engine snapshot ----------------------------------------

def test_kernel_dispatch_counted(ctx, tables):
    before = metrics.registry().counters()
    lz = _four_op(ctx, tables)
    lz.collect()
    after = metrics.registry().counters()
    dispatched = {k: v - before.get(k, 0) for k, v in after.items()
                  if k.startswith("kernels.dispatch.")}
    assert sum(dispatched.values()) >= 0  # counters exist and are sane
    snap = obs.engine_snapshot()
    assert {"metrics", "caches", "kernel_backend"} <= set(snap)
    assert "plan" in snap["caches"] and "op" in snap["caches"]


# -- admission feedback (satellite: learned working-set corrections) ----------

def test_admission_learns_from_observed_peak(ctx, dataset):
    def q():
        return (stream.scan_dataset(dataset, ctx, batch_rows=512)
                .groupby(("k",), {"v": ("sum",)}))

    assert query_learn_key(q()) == query_learn_key(q())
    assert query_learn_key(lambda: None) is None
    with QueryService(max_running=2) as svc:
        s1 = svc.submit(q())
        s1.result()
        # the finished run taught the controller its shape's real peak
        stats1 = svc.admission.stats()
        assert stats1["observed_total"] >= 1
        assert stats1["learned_keys"] >= 1
        ratio = svc.admission.learned_ratio(q())
        assert ratio is not None and 0.125 <= ratio <= 8.0
        s2 = svc.submit(q())
        s2.result()
        # the second submission was costed with the learned correction
        assert s2.cost_bytes == pytest.approx(s2.cost_base * ratio, rel=0.6)
        assert np.array_equal(
            np.asarray(s1.result().to_numpy()["v_sum"]),
            np.asarray(s2.result().to_numpy()["v_sum"]))


def test_admission_ratio_clamped():
    ac = AdmissionController()

    class FakeSession:
        admission_key = "k1"
        cost_base = 100.0
        info = {"peak_working_set_bytes": 1e12}  # absurd observation

    ac.observe(FakeSession())
    with ac._lock:
        assert ac._learned["k1"] == 8.0  # clamped at the upper bound


def test_service_stats_include_trace(ctx, tables):
    with trace.tracing():
        with QueryService(max_running=2) as svc:
            h = svc.submit(_four_op(ctx, tables))
            h.result()
            st = svc.stats()
    assert st["trace"]["enabled"] is True
    assert "service.morsel" in st["trace"]["by_name"]
    assert "service.query" in st["trace"]["by_name"]
