"""Serving: engine generation, int8 KV-cache accuracy, decode state shapes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import ServeEngine


def test_engine_generates():
    cfg = get_smoke_config("olmo-1b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, max_len=64)
    outs = eng.generate([[1, 2, 3], [4, 5, 6, 7]], max_new=8)
    assert len(outs) == 2
    assert len(outs[0]) == 3 + 8 and len(outs[1]) == 4 + 8
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


def test_engine_uneven_prompts_match_solo():
    """Regression: a batch of different-length prompts must produce exactly
    what each prompt produces alone. The old prefill fed padding zeros to
    short lanes past their end and took every lane's first token from the
    logits at the longest prompt's final position, so short prompts'
    continuations were computed from padding."""
    cfg = get_smoke_config("olmo-1b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(3))
    eng = ServeEngine(model, params, max_len=64)
    prompts = [[5], [1, 2, 3], [9, 8, 7, 6, 5, 4]]
    batched = eng.generate(prompts, max_new=6)
    for p, got in zip(prompts, batched):
        solo = eng.generate([p], max_new=6)[0]
        assert got == solo


def test_engine_rejects_empty_prompt():
    cfg = get_smoke_config("olmo-1b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(4))
    eng = ServeEngine(model, params, max_len=64)
    with pytest.raises(ValueError, match="at least one token"):
        eng.generate([[1, 2], []], max_new=2)


def test_engine_deterministic():
    cfg = get_smoke_config("gemma2-9b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(1))
    eng = ServeEngine(model, params, max_len=64)
    a = eng.generate([[1, 2, 3]], max_new=6)
    b = eng.generate([[1, 2, 3]], max_new=6)
    assert a == b


@pytest.mark.parametrize("arch", ["olmo-1b", "granite-moe-1b-a400m"])
def test_int8_kv_cache_close_to_bf16(arch):
    """int8 KV (production decode default in the dry-run) must track the
    fp32-cache decode logits closely."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model_fp = build_model(cfg)
    model_q = build_model(dataclasses.replace(cfg, kv_quant_decode=True))
    params = model_fp.init_params(jax.random.key(2))

    B, S = 2, 10
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)

    st_fp = model_fp.init_decode_state(B, 32, dtype=jnp.float32)
    st_q = model_q.init_decode_state(B, 32, dtype=jnp.float32)
    assert st_q["kv"].k.dtype == jnp.int8
    step_fp = jax.jit(model_fp.decode_step)
    step_q = jax.jit(model_q.decode_step)
    errs = []
    for t in range(S):
        batch = {"token": jnp.asarray(toks[:, t: t + 1])}
        lf, st_fp = step_fp(params, st_fp, batch)
        lq, st_q = step_q(params, st_q, batch)
        scale = float(jnp.max(jnp.abs(lf))) + 1e-6
        errs.append(float(jnp.max(jnp.abs(lf - lq))) / scale)
    assert max(errs) < 0.05, errs  # <5% relative logit error
    # and the argmax decisions should essentially agree
    agree = float(jnp.mean((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).astype(jnp.float32)))
    assert agree >= 0.5
