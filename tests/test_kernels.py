"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(task spec deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,H,KV,hd", [(256, 4, 2, 64), (128, 2, 2, 128), (256, 8, 1, 64)])
def test_flash_attention_sweep(S, H, KV, hd, dtype):
    rng = np.random.default_rng(0)
    B = 2
    q = _rand(rng, (B, S, H, hd), dtype)
    k = _rand(rng, (B, S, KV, hd), dtype)
    v = _rand(rng, (B, S, KV, hd), dtype)
    got = ops.flash_attention(q, k, v, force="interpret", causal=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("kwargs", [
    dict(causal=True, window=64),
    dict(causal=True, softcap=50.0),
    dict(causal=False),
    dict(causal=True, window=32, softcap=30.0),
])
def test_flash_attention_variants(kwargs):
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 256, 4, 64), jnp.float32)
    k = _rand(rng, (1, 256, 2, 64), jnp.float32)
    v = _rand(rng, (1, 256, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, force="interpret", **kwargs)
    exp = ref.flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("chunk", [32, 64])
@pytest.mark.parametrize("H,dh,G,ds", [(4, 32, 2, 16), (2, 64, 1, 32)])
def test_ssd_scan_sweep(chunk, H, dh, G, ds):
    rng = np.random.default_rng(2)
    b, L = 2, 128
    x = _rand(rng, (b, L, H, dh), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B = _rand(rng, (b, L, G, ds), jnp.float32)
    C = _rand(rng, (b, L, G, ds), jnp.float32)
    D = _rand(rng, (H,), jnp.float32)
    got = ops.ssd_scan(x, dt, A, B, C, D, chunk=chunk, force="interpret")
    exp = ref.ssd_scan_ref(x, dt, A, B, C, D, chunk=chunk)
    scale = float(jnp.max(jnp.abs(exp))) + 1e-6
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(exp) / scale,
                               atol=3e-5)


def test_ssd_kernel_matches_model_layer():
    """The kernel must agree with the model's SSD reference (same math used
    in training), including the D skip term."""
    from repro.models.ssm import ssd_scan_ref as model_ssd
    rng = np.random.default_rng(3)
    b, L, H, dh, ds = 1, 64, 2, 32, 16
    x = _rand(rng, (b, L, H, dh), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.3, (b, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 1.5, (H,)), jnp.float32)
    B = _rand(rng, (b, L, 1, ds), jnp.float32)
    C = _rand(rng, (b, L, 1, ds), jnp.float32)
    D = _rand(rng, (H,), jnp.float32)
    y_model, _ = model_ssd(x, dt, A, B, C, chunk=32)
    y_model = y_model + x * D[None, None, :, None]
    y_kernel = ops.ssd_scan(x, dt, A, B, C, D, chunk=32, force="interpret")
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model), atol=3e-5)


@pytest.mark.parametrize("dtype", [np.int32, np.uint32])
@pytest.mark.parametrize("P", [4, 16, 64])
@pytest.mark.parametrize("ncols", [1, 2])
def test_hash_partition_sweep(P, ncols, dtype):
    rng = np.random.default_rng(4)
    keys = jnp.asarray(rng.integers(0, 1 << 31, size=(2048, ncols)).astype(dtype))
    dest, hist = ops.hash_partition(keys, P, block=512, force="interpret")
    dref, href = ref.hash_partition_ref(keys, P)
    assert jnp.array_equal(dest, dref)
    assert jnp.array_equal(hist, href)
    assert int(hist.sum()) == 2048


def test_hash_partition_matches_engine_hash():
    """Kernel hash must equal core.partition.hash_columns (the DDF engine's
    partitioner) bit-for-bit."""
    from repro.core.dataframe import from_arrays
    from repro.core.partition import hash_columns
    rng = np.random.default_rng(5)
    k0 = rng.integers(0, 1 << 31, 1024).astype(np.int32)
    k1 = rng.integers(0, 1 << 31, 1024).astype(np.int32)
    t = from_arrays({"a": jnp.asarray(k0), "b": jnp.asarray(k1)})
    h_engine = hash_columns(t, ["a", "b"])
    dest, _ = ops.hash_partition(jnp.stack([jnp.asarray(k0), jnp.asarray(k1)], 1),
                                 1 << 16, block=512, force="interpret")
    assert jnp.array_equal(dest, (h_engine % jnp.uint32(1 << 16)).astype(jnp.int32))


@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize("nseg,block", [(100, 512), (13, 256)])
def test_segment_reduce_sweep(op, nseg, block):
    rng = np.random.default_rng(6)
    N, W = 2048, 4
    seg = np.sort(rng.integers(0, nseg, N)).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(N, W)), jnp.float32)
    got = ops.segment_reduce(vals, jnp.asarray(seg), nseg, op=op,
                             max_segments=128, block=block, force="interpret")
    exp = ref.segment_reduce_ref(vals, jnp.asarray(seg), nseg, op=op)
    mask = np.isfinite(np.asarray(exp))
    np.testing.assert_allclose(np.asarray(got)[mask], np.asarray(exp)[mask], atol=1e-4)
