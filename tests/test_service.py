"""Concurrent query service (ISSUE 7): session lifecycle, admission
control, morsel scheduling under both policies, shared-cache telemetry,
and the headline property — N interleaved mixed queries bit-identical to
running each serially."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core import DDF, DDFContext
from repro.core.api import _LRUCache
from repro.data.dataset import write_dataset
from repro.expr import col
from repro import stream
from repro.service import (
    AdmissionController,
    AdmissionError,
    CacheManager,
    MorselScheduler,
    QueryCancelled,
    QueryService,
    QuerySession,
    QueryState,
    SessionManager,
    estimate_query_bytes,
)


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def _table(n, nkeys=120, seed=0):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, nkeys, n).astype(np.int32),
            "v": rng.integers(0, 1000, n).astype(np.int32)}


@pytest.fixture(scope="module")
def dataset(tmp_path_factory):
    root = tmp_path_factory.mktemp("svc")
    return write_dataset(_table(4000, seed=1), str(root / "ds"), chunk_rows=512)


@pytest.fixture(scope="module")
def tables(ctx):
    L = DDF.from_numpy(_table(240, seed=2), ctx, capacity=480)
    R = {"k": np.arange(120, dtype=np.int32),
         "w": np.arange(120, dtype=np.int32) % 9}
    return L, DDF.from_numpy(R, ctx, capacity=240)


def _same(a: DDF, b: DDF) -> bool:
    an, bn = a.to_numpy(), b.to_numpy()
    return set(an) == set(bn) and all(np.array_equal(an[c], bn[c]) for c in an)


def _mixed_queries(ctx, dataset, tables):
    """8 queries across all three submission kinds."""
    L, R = tables
    aggs = {"v": ("sum", "count")}
    qs = []
    for _ in range(3):
        qs.append(("stream",
                   stream.scan_dataset(dataset, ctx, batch_rows=500)
                   .groupby(("k",), aggs)))
    for _ in range(3):
        qs.append(("lazy", L.lazy().join(R.lazy(), on=("k",))
                   .groupby(("k",), aggs)))
    qs.append(("eager", lambda: L.sort_values("k")[0]))
    qs.append(("lazy", L.lazy().select(col("v") > 500)))
    return qs


def _serial(kind, q) -> DDF:
    if kind == "eager":
        return q()
    if kind == "stream":
        return stream.collect(q)[0]
    return q.collect()


# -- the headline property: interleaved == serial, bit for bit ------------------

@pytest.mark.parametrize("policy", ["fair", "round_robin"])
def test_interleaved_bit_identical_to_serial(ctx, dataset, tables, policy):
    queries = _mixed_queries(ctx, dataset, tables)
    assert len(queries) >= 8
    serial = [_serial(k, q) for k, q in queries]
    with QueryService(policy=policy, max_running=4) as svc:
        handles = [svc.submit(q) for _, q in queries]
        results = [h.result(timeout=300) for h in handles]
        stats = svc.stats()
    for ref, got in zip(serial, results):
        assert _same(ref, got)
    assert stats["sessions"]["DONE"] == len(queries)
    assert stats["sessions"]["FAILED"] == 0
    # interleaving actually happened: more morsels than queries means the
    # streaming queries went through multiple scheduler-driven quanta
    assert stats["scheduler"]["morsels_total"] > len(queries)


def test_cross_query_cache_reuse(ctx, dataset, tables):
    """Queries sharing a plan shape hit the shared plan/compiled-op caches."""
    queries = _mixed_queries(ctx, dataset, tables)
    _ = [_serial(k, q) for k, q in queries[:1]]  # ensure at least one warm
    with QueryService(max_running=8) as svc:
        for _, q in queries:
            svc.submit(q)
        # drain via shutdown, then read the window
        svc.shutdown()
        caches = svc.stats()["caches"]
    assert caches["op"]["window"]["hits"] > 0
    assert caches["plan"]["window"]["hits"] > 0


def test_submit_weight_and_labels(ctx, tables):
    L, _ = tables
    with QueryService() as svc:
        h = svc.submit(L.lazy().select(col("v") > 500), weight=2.5,
                       label="filter")
        h.result(timeout=120)
        desc = [d for d in svc.stats()["queries"] if d["qid"] == h.qid][0]
    assert desc["label"] == "filter"
    assert desc["weight"] == 2.5
    assert desc["state"] == QueryState.DONE
    assert desc["morsels"] >= 1


# -- cancellation ---------------------------------------------------------------

def test_cancel_mid_stream(ctx, dataset):
    aggs = {"v": ("sum", "count")}
    # warm the compile caches so the query is mid-stream quickly
    stream.collect(stream.scan_dataset(dataset, ctx, batch_rows=300)
                   .groupby(("k",), aggs))
    svc = QueryService()
    try:
        h = svc.submit(stream.scan_dataset(dataset, ctx, batch_rows=300)
                       .groupby(("k",), aggs))
        deadline = time.monotonic() + 60
        while h.morsels < 1 and not h.done() and time.monotonic() < deadline:
            time.sleep(0.002)
        assert svc.cancel(h.qid) or h.done()
        if not h.state == QueryState.DONE:
            with pytest.raises(QueryCancelled):
                h.result(timeout=60)
            assert h.state == QueryState.CANCELLED
    finally:
        svc.shutdown(cancel=True, timeout=30)


def test_cancel_pending_resolves_immediately():
    mgr = SessionManager()
    s = mgr.create(lambda: None, {})
    assert s.cancel() is True
    assert s.state == QueryState.CANCELLED
    with pytest.raises(QueryCancelled):
        s.result(timeout=1)
    # terminal sessions can't be re-cancelled
    assert s.cancel() is False


def test_failed_query_propagates_error(ctx):
    def boom():
        raise RuntimeError("exploded in the query")
    with QueryService() as svc:
        h = svc.submit(boom)
        with pytest.raises(RuntimeError, match="exploded"):
            h.result(timeout=60)
        assert h.state == QueryState.FAILED
        # one bad query never poisons the service
        h2 = svc.submit(lambda: 42)
        assert h2.result(timeout=60) == 42


# -- admission control ----------------------------------------------------------

def _mk_session(cost=0.0):
    s = SessionManager().create(lambda: None, {})
    s.cost_bytes = cost
    return s


def test_admission_concurrency_and_backlog():
    adm = AdmissionController(max_running=2, max_backlog=2,
                             memory_budget_bytes=1e9)
    a, b, c, d = (_mk_session() for _ in range(4))
    assert adm.offer(a) == "admitted" and adm.offer(b) == "admitted"
    assert adm.offer(c) == "queued" and adm.offer(d) == "queued"
    # backlog full -> shed with AdmissionError, session fails
    e = _mk_session()
    with pytest.raises(AdmissionError, match="backlog full"):
        adm.offer(e)
    assert e.state == QueryState.FAILED
    assert adm.stats()["rejected_total"] == 1
    # releasing a slot admits the FIFO head
    a._transition(QueryState.RUNNING)
    a._finish(QueryState.DONE)
    admitted = adm.release(a)
    assert admitted == [c]
    assert c.state == QueryState.ADMITTED


def test_admission_memory_budget():
    adm = AdmissionController(max_running=8, max_backlog=8,
                             memory_budget_bytes=100.0)
    big = _mk_session(cost=1000.0)   # over the whole budget, but alone: runs
    assert adm.offer(big) == "admitted"
    small = _mk_session(cost=10.0)   # doesn't fit next to `big`
    assert adm.offer(small) == "queued"
    big._transition(QueryState.RUNNING)
    big._finish(QueryState.DONE)
    assert adm.release(big) == [small]


def test_admission_skips_cancelled_backlog():
    adm = AdmissionController(max_running=1, max_backlog=4)
    a, b, c = (_mk_session() for _ in range(3))
    adm.offer(a), adm.offer(b), adm.offer(c)
    b.cancel()  # cancelled while queued
    a._transition(QueryState.RUNNING)
    a._finish(QueryState.DONE)
    assert adm.release(a) == [c]
    assert adm.backlog_depth() == 0


def test_estimate_query_bytes(ctx, dataset, tables):
    L, R = tables
    assert estimate_query_bytes(lambda: None) == 0.0
    scan_q = stream.scan_dataset(dataset, ctx, batch_rows=500).groupby(
        ("k",), {"v": ("sum",)})
    lazy_q = L.lazy().join(R.lazy(), on=("k",))
    assert estimate_query_bytes(scan_q) > 0.0
    assert estimate_query_bytes(lazy_q) > 0.0
    # factor scales the estimate linearly
    assert estimate_query_bytes(lazy_q, working_set_factor=8.0) == pytest.approx(
        2 * estimate_query_bytes(lazy_q, working_set_factor=4.0))


def test_shed_on_overflow_from_service(ctx, tables):
    L, _ = tables
    q = L.lazy().select(col("v") > 500)
    svc = QueryService(max_running=1, max_backlog=0,
                       memory_budget_bytes=1.0)
    try:
        # block the single slot with a slow eager thunk
        gate = threading.Event()
        h = svc.submit(lambda: gate.wait(timeout=30))
        with pytest.raises(AdmissionError):
            svc.submit(q)
        gate.set()
        h.result(timeout=60)
    finally:
        svc.shutdown(cancel=True, timeout=30)


def test_submit_after_shutdown_rejected(ctx, tables):
    L, _ = tables
    svc = QueryService()
    svc.shutdown()
    with pytest.raises(AdmissionError, match="shut down"):
        svc.submit(L.lazy().select(col("v") > 500))


# -- session state machine ------------------------------------------------------

def test_session_lifecycle_transitions():
    mgr = SessionManager()
    s = mgr.create(lambda: None, {}, label="t")
    assert s.state == QueryState.PENDING
    s._transition(QueryState.ADMITTED)
    s._transition(QueryState.RUNNING)
    with pytest.raises(RuntimeError, match="illegal transition"):
        s._transition(QueryState.PENDING)
    s._finish(QueryState.DONE, result=7)
    assert s.result(timeout=1) == 7
    assert s.done()
    # unique, monotonic-ish ids
    ids = {mgr.create(lambda: None, {}).qid for _ in range(10)}
    assert len(ids) == 10


def test_scheduler_rejects_bad_inputs(ctx, tables):
    L, _ = tables
    with pytest.raises(ValueError, match="policy"):
        MorselScheduler(policy="nope")
    with QueryService() as svc:
        # materialized DDFs must come in as .lazy()
        h = svc.submit(L)
        with pytest.raises(TypeError, match="lazy"):
            h.result(timeout=60)
        # stream options on a scan-free query are a user error
        h2 = svc.submit(L.lazy().select(col("v") > 500), batch_rows=64)
        with pytest.raises(ValueError, match="stream options"):
            h2.result(timeout=60)


# -- shared cache managers (satellite: thread-safe _LRUCache) -------------------

def test_lru_cache_counters():
    c = _LRUCache(maxsize=2)
    assert c.get("a") is None
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1
    c.put("c", 3)  # evicts b (a was touched more recently)
    assert c.get("b") is None
    st = c.stats()
    assert st["hits"] == 1 and st["misses"] == 2 and st["evictions"] == 1
    assert st["size"] == 2 and st["maxsize"] == 2


def test_lru_cache_thread_safety():
    c = _LRUCache(maxsize=64)
    errs = []

    def work(seed):
        try:
            rng = np.random.default_rng(seed)
            for _ in range(500):
                k = int(rng.integers(0, 128))
                if rng.random() < 0.5:
                    c.put(k, k)
                else:
                    v = c.get(k)
                    assert v is None or v == k
        except BaseException as e:  # surfaced below
            errs.append(e)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    st = c.stats()
    assert st["hits"] + st["misses"] > 0
    assert len(c) <= 64


def test_cache_manager_window(ctx, tables):
    L, _ = tables
    mgr = CacheManager()
    before = mgr.stats()["op"]["window"]
    L.lazy().select(col("v") > 500).collect()
    L.lazy().select(col("v") > 500).collect()
    after = mgr.stats()["op"]["window"]
    assert after["hits"] + after["misses"] > before["hits"] + before["misses"]
    assert mgr.hit_rate("op") is not None
    mgr.mark()
    reset = mgr.stats()["op"]["window"]
    assert reset["hits"] == 0 and reset["misses"] == 0
