"""Property test: lazy ``.collect()`` is bit-identical to eager execution
for random pipelines over the supported operators.

Pipelines are drawn as op sequences over integer tables (integer aggregation
is order-independent, so "bit-identical" is exact, not approximate) and run
twice: once through the eager per-op ``DDF`` path, once as a single lazy
plan through the full optimizer (pushdown + elision + fusion + cost-model
planning). Join strategy is pinned to "shuffle" inside random pipelines —
eager auto-planning reads *actual* intermediate row counts while the lazy
planner uses estimates, and the broadcast variants emit rows in a different
(equally valid) order; strategy choice itself is covered by unit tests.

Runs hypothesis-driven when hypothesis is installed, and always runs a
deterministic seeded variant of the same property.
"""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import DDF, DDFContext

N = 96
CAP = 4 * N  # headroom so no pipeline overflows (overflow truncation is
             # order-dependent and excluded from the bit-exactness contract)
OP_KINDS = ("select", "project", "map", "join", "groupby", "unique", "sort",
            "rebalance", "difference")


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


@pytest.fixture(scope="module")
def base(ctx):
    rng = np.random.default_rng(3)
    L = {"k": rng.integers(0, 24, N).astype(np.int32),
         "v": rng.integers(0, 1000, N).astype(np.int32)}
    R = {"k": rng.integers(0, 24, N).astype(np.int32),
         "w": rng.integers(0, 1000, N).astype(np.int32)}
    return (DDF.from_numpy(L, ctx, capacity=CAP),
            DDF.from_numpy(R, ctx, capacity=CAP))


def _map_fn(col):
    def fn(c):
        return {"k": c["k"], col: c[col], f"m_{col}": c[col] * 2 + 1}
    return fn


def _sel_fn(col, m):
    return lambda c: c[col] % m != 0


def _value_col(names):
    """First non-key numeric column, by a deterministic preference order."""
    for c in ("v", "w", "v_sum", "w_sum", "v_count", "w_count", "m_v", "m_w"):
        if c in names:
            return c
    return None


def _apply(frame, right, op, eager: bool):
    """Apply one drawn op to either an eager DDF or a LazyDDF; ops missing
    their required columns degrade to a no-op (deterministically in both
    modes, since schemas match)."""
    names = set(frame.column_names)
    kind, p1, p2 = op
    col = _value_col(names)
    if kind == "select" and col is not None:
        return frame.select(_sel_fn(col, 2 + p1 % 5), name=f"s_{col}_{p1 % 5}")
    if kind == "project" and col is not None:
        return frame.project(["k", col])
    if kind == "map" and col in ("v", "w"):
        return frame.map_columns(_map_fn(col), name=f"m_{col}")
    if kind == "join" and "w" not in names:
        out = frame.join(right, on=("k",), strategy="shuffle", capacity=CAP * 8)
        return out[0] if eager else out
    if kind == "groupby" and col is not None:
        aggs = {col: ("sum", "count") if p1 % 2 else ("sum",)}
        out = frame.groupby(("k",), aggs)
        return out[0] if eager else out
    if kind == "unique":
        out = frame.unique(("k",))
        return out[0] if eager else out
    if kind == "sort":
        by = "k" if p1 % 2 or col is None else col
        out = frame.sort_values(by, descending=bool(p2 % 2))
        return out[0] if eager else out
    if kind == "rebalance":
        out = frame.rebalance()
        return out[0] if eager else out
    if kind == "difference":
        out = frame.difference(right.project(["k"]), on=("k",))
        return out[0] if eager else out
    return frame


def _check_pipeline(base, ops):
    dl, dr = base
    e = dl
    for op in ops:
        e = _apply(e, dr, op, eager=True)
    lz = dl.lazy()
    lzr = dr.lazy()
    for op in ops:
        lz = _apply(lz, lzr, op, eager=False)
    ref = e.to_numpy()
    got = lz.to_numpy()
    assert sorted(ref) == sorted(got)
    for k in ref:
        assert ref[k].dtype == got[k].dtype, k
        assert np.array_equal(ref[k], got[k]), (k, ops, ref[k][:8], got[k][:8])
    # no silent truncation on either path
    if lz.last_info:
        assert all(int(np.asarray(v).sum()) == 0 for v in lz.last_info.values())


def test_lazy_collect_bit_identical_seeded(base):
    """Deterministic variant of the property (runs without hypothesis)."""
    rng = np.random.default_rng(2024)
    for _ in range(8):
        n_ops = int(rng.integers(1, 5))
        ops = [(OP_KINDS[int(rng.integers(len(OP_KINDS)))],
                int(rng.integers(8)), int(rng.integers(8)))
               for _ in range(n_ops)]
        _check_pipeline(base, ops)


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.tuples(st.sampled_from(OP_KINDS),
                  st.integers(0, 7), st.integers(0, 7)),
        min_size=1, max_size=4)

    @settings(max_examples=10, deadline=None)
    @given(_ops)
    def test_lazy_collect_bit_identical_to_eager(ctx, base, ops):
        _check_pipeline(base, ops)
