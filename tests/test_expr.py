"""Columnar expression API (ISSUE 4): unit + equivalence tests.

Covers the tree itself (folding, AND-split, structural-hash non-aliasing,
rendering), the eager/lazy/streaming integration (bit-identical to the
equivalent callable pipelines), scan absorption without the numpy probe
path, the deprecation shim, and KeyError wording parity. A property test
drives random expr-vs-callable pipelines through eager and lazy execution.
"""

import warnings

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import repro.expr as ex
import repro.plan.optimizer as optimizer
from repro.core import DDF, DDFContext
from repro.expr import col, lit, when
from repro.plan.logical import Scan, Select, WithColumn, walk

N = 96
CAP = 4 * N


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


@pytest.fixture(scope="module")
def base(ctx):
    rng = np.random.default_rng(7)
    L = {"k": rng.integers(0, 24, N).astype(np.int32),
         "v": rng.integers(0, 1000, N).astype(np.int32),
         "junk": rng.integers(0, 5, N).astype(np.int32)}
    R = {"k": rng.integers(0, 24, N).astype(np.int32),
         "w": rng.integers(0, 1000, N).astype(np.int32)}
    return (DDF.from_numpy(L, ctx, capacity=CAP),
            DDF.from_numpy(R, ctx, capacity=CAP))


SCHEMA = (("a", "int32", ()), ("b", "int32", ()), ("f", "float32", ()))


# -- tree unit tests -----------------------------------------------------------

def test_rendering():
    assert str((col("a") > 3) & (col("b") < lit(7))) == "((a > 3) & (b < 7))"
    assert str(col("a") + col("b")) == "(a + b)"
    assert str((col("a") % 2).eq(0)) == "((a % 2) == 0)"
    assert str(when(col("a") > 0).then(1).otherwise(-1)) == \
        "when((a > 0), 1, -1)"
    assert str(col("v").mean().alias("avg")) == "v.mean() as 'avg'"


def test_referenced_columns_exact():
    e = when(col("a") > 0).then(col("b")).otherwise(col("f") * 2)
    assert ex.referenced_columns(e) == frozenset({"a", "b", "f"})
    assert ex.referenced_columns(lit(3)) == frozenset()


def test_fold_constants():
    assert ex.fold_constants(col("a") > lit(1) + lit(2)) == (col("a") > 3)
    assert ex.fold_constants((col("a") > 3) & lit(True)) == (col("a") > 3)
    assert ex.fold_constants((col("a") > 3) | lit(False)) == (col("a") > 3)
    sel = when(lit(True)).then(col("a")).otherwise(col("b"))
    assert ex.fold_constants(sel) == col("a")
    # no literal subtree: unchanged (and identical object where possible)
    e = col("a") + col("b")
    assert ex.fold_constants(e) == e


def test_fold_constants_is_semantics_preserving():
    # `x & True` is bitwise `x & 1` when x is an integer column: the
    # boolean identity must NOT fire unless x provably produces booleans
    e = col("v") & lit(True)
    assert ex.fold_constants(e) == e
    assert ex.fold_constants(col("v") | lit(False)) == (col("v") | lit(False))
    cols = {"v": np.array([5, 4, 7], np.int32)}
    assert np.array_equal(ex.to_numpy_fn(ex.fold_constants(e))(cols),
                          np.array([1, 0, 1]))
    # dtype-pinned literals drive promotion of the unfolded tree and are
    # never collapsed into a dtype-less weak literal
    pinned = lit(1, "float64") + lit(2, "float64")
    assert ex.fold_constants(pinned) == pinned
    assert ex.fold_constants(-lit(3, "int64")) == -lit(3, "int64")


def test_split_conjuncts_boolean_only():
    parts = ex.split_conjuncts((col("a") > 3) & (col("b") < 7), SCHEMA)
    assert parts == (col("a") > 3, col("b") < 7)
    # nested conjunction flattens
    e3 = (col("a") > 1) & (col("b") > 2) & (col("f") > 0.5)
    assert len(ex.split_conjuncts(e3, SCHEMA)) == 3
    # int & int is bitwise, never split
    assert ex.split_conjuncts(col("a") & col("b"), SCHEMA) == \
        (col("a") & col("b"),)


def test_structural_hash_non_aliasing():
    assert (col("a") > 3) == (col("a") > lit(3))
    assert hash(col("a") > 3) == hash(col("a") > lit(3))
    # different literal values never alias, even hash-equal (-1/-2) or
    # numerically-equal-but-differently-typed (3 vs 3.0) ones
    assert (col("a") > -1) != (col("a") > -2)
    assert (col("a") > 3) != (col("a") > 3.0)
    assert (col("a") > 3) != (col("b") > 3)
    assert lit(3) != lit(3, dtype="int32")


def test_expr_guardrails():
    with pytest.raises(TypeError):
        bool(col("a") > 3)
    with pytest.raises(TypeError):
        ex.ensure_row_expr(col("a").sum(), "select")
    with pytest.raises(KeyError, match="available schema"):
        ex.ensure_columns(col("zz") > 1, ("a", "b"), "select")
    with pytest.raises(TypeError):
        ex.to_jax_fn(col("a").sum())({"a": np.ones(2)})


def test_incomplete_when_builder_guidance(base):
    """An unfinished when(...).then(...) gets the guidance TypeError from
    every public entry point, never the legacy-callable fallback."""
    dl, _ = base
    half = when(col("v") > 1).then(1)
    for call in (lambda: dl.select(half),
                 lambda: dl.with_column("c", half),
                 lambda: dl.lazy().select(half),
                 lambda: dl.lazy().with_column("c", half)):
        with pytest.raises(TypeError, match="incomplete when"):
            with warnings.catch_warnings():
                warnings.simplefilter("error", DeprecationWarning)
                call()


def test_host_portable():
    schema = (("a", "int32", ()), ("f", "float32", ()), ("z", "bool", ()))
    assert ex.host_portable((col("a") % 2).eq(0), schema)
    assert ex.host_portable((col("a") > 3) & (col("f") < 0.5), schema)
    assert ex.host_portable(col("f") > 0.05, schema)  # raw-col comparison
    assert ex.host_portable(~col("z"), schema)
    # float arithmetic promotes differently on numpy (float64) vs jax
    # (float32): not portable, must stay a device SELECT
    assert not ex.host_portable((col("a") / 2) <= 16777216.0, schema)
    assert not ex.host_portable(col("f") * 2 > 1.0, schema)
    assert not ex.host_portable(col("a") > 3.0 * col("f"), schema)
    # 64-bit columns are truncated to 32 bits on device (x64 disabled):
    # host-side evaluation would see different values
    wide = (("d", "float64", ()), ("i", "int64", ()))
    assert not ex.host_portable(col("d") > 0.1, wide)
    assert not ex.host_portable((col("i") % 2).eq(0), wide)
    assert not ex.host_portable(col("a").eq(lit(3, "int64")), schema)
    # mixed int-column vs float comparisons promote through float64 on
    # numpy but float32 on jax (flip above 2^24): rejected
    assert not ex.host_portable(col("a") > 16777216.5, schema)
    assert not ex.host_portable(col("f") < col("a"), schema)
    assert not ex.host_portable(col("a").eq(lit(1.0, "float32")), schema)
    # unsigned columns: numpy compares out-of-range literals exactly
    # (uint32 > -1 is all-True) while jax wraps them (all-False)
    assert not ex.host_portable(col("u") > -1, (("u", "uint32", ()),))
    assert not ex.host_portable((col("u") % 2).eq(0), (("u", "uint16", ()),))


def test_bare_bool_predicate_rejected(base):
    """`col("a") == 3` is structural equality returning a Python bool;
    predicate positions reject it with .eq() guidance instead of silently
    folding to a constant."""
    dl, _ = base
    mistake = col("v") == 3  # structural: a plain bool
    assert mistake is False
    for call in (lambda: dl.select(mistake),
                 lambda: dl.lazy().select(mistake),
                 lambda: dl.with_column("flag", mistake),
                 lambda: dl.lazy().with_column("flag", mistake),
                 lambda: when(mistake),
                 # compound-operand variants: the bool hides inside &/|
                 lambda: (col("v") > 0) & mistake,
                 lambda: mistake & (col("v") > 0),
                 lambda: (col("v") > 0) | mistake,
                 lambda: (col("v") > 0) ^ mistake):
        with pytest.raises(TypeError, match=r"\.eq\(\)"):
            call()
    # an intentional boolean constant stays expressible
    assert ((col("v") > 0) & lit(True)) is not None
    # explicit literals remain available
    assert np.array_equal(dl.with_column("t", lit(True)).to_numpy()["t"],
                          np.ones(N, bool))


def test_jax_numpy_parity():
    e = ((col("a") * 3 - col("b")) % 5).eq(0) & (col("f") > 0.25)
    rng = np.random.default_rng(0)
    cols = {"a": rng.integers(0, 100, 64).astype(np.int32),
            "b": rng.integers(0, 100, 64).astype(np.int32),
            "f": rng.random(64).astype(np.float32)}
    host = ex.to_numpy_fn(e)(cols)
    dev = np.asarray(ex.to_jax_fn(e)({k: np.asarray(v) for k, v in cols.items()}))
    assert host.dtype == np.dtype(bool)
    assert np.array_equal(host, dev)


def test_infer_schema_entry():
    assert ex.infer_schema_entry(col("a") + col("b"), SCHEMA) == ("int32", ())
    assert ex.infer_schema_entry(col("a") > 3, SCHEMA) == ("bool", ())
    assert ex.infer_schema_entry(col("a").cast("float32") / 2, SCHEMA) == \
        ("float32", ())


def test_parse_agg_specs():
    aggs, renames = ex.parse_agg_specs(
        [col("v").sum(), col("v").mean().alias("avg"), col("w").count()])
    assert aggs == {"v": ("sum", "mean"), "w": ("count",)}
    assert renames == (("v_mean", "avg"),)
    with pytest.raises(TypeError):
        ex.parse_agg_specs([col("v")])
    with pytest.raises(TypeError):
        ex.parse_agg_specs([(col("a") + col("b")).sum()])
    with pytest.raises(ValueError):
        ex.parse_agg_specs([col("v").sum().alias("x"),
                            col("v").sum().alias("y")])
    with pytest.raises(ValueError, match="duplicate output"):
        ex.parse_agg_specs([col("v").sum().alias("x"),
                            col("w").sum().alias("x")])
    with pytest.raises(ValueError, match="duplicate output"):
        ex.parse_agg_specs([col("v").sum(), col("w").count().alias("v_sum")])
    with pytest.raises(ValueError):
        ex.parse_agg_specs([])


# -- eager integration ---------------------------------------------------------

def test_eager_select_expr_matches_callable(base):
    dl, _ = base
    ref = dl.select(lambda c: (c["v"] % 3 == 0) & (c["k"] > 5)).to_numpy()
    got = dl.select((col("v") % 3).eq(0) & (col("k") > 5)).to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


def test_eager_with_column(base):
    dl, _ = base
    got = dl.with_column("c", col("v") * 2 + col("k")).to_numpy()
    host = dl.to_numpy()
    assert np.array_equal(got["c"], host["v"] * 2 + host["k"])
    # overwrite keeps schema, literal broadcast fills rows
    lit7 = dl.with_column("v", lit(7)).to_numpy()
    assert np.array_equal(lit7["v"], np.full(N, 7))
    cond = dl.with_column("s", when(col("v") > 500).then(1).otherwise(-1))
    assert np.array_equal(cond.to_numpy()["s"],
                          np.where(host["v"] > 500, 1, -1))


def test_eager_groupby_agg_exprs(base):
    dl, _ = base
    ref, _ = dl.groupby(("k",), {"v": ("sum", "mean")})
    ref = ref.rename({"v_mean": "avg"}).to_numpy()
    got, _ = dl.groupby(("k",), [col("v").sum(), col("v").mean().alias("avg")])
    got = got.to_numpy()
    assert sorted(ref) == sorted(got)
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


def test_unknown_column_wording_matches_eager(base):
    dl, _ = base
    with pytest.raises(KeyError) as e_eager:
        dl.select(col("zz") > 1)
    with pytest.raises(KeyError) as e_lazy:
        dl.lazy().select(col("zz") > 1)
    assert str(e_eager.value) == str(e_lazy.value)
    assert "available schema" in str(e_eager.value)
    with pytest.raises(KeyError, match="with_column"):
        dl.with_column("c", col("zz") + 1)
    with pytest.raises(KeyError, match="with_column"):
        dl.lazy().with_column("c", col("zz") + 1)


def test_callable_deprecation_warned_once(base):
    dl, _ = base
    ex._WARNED.discard("select")
    ex._WARNED.discard("map_columns")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dl.select(lambda c: c["v"] > 0)
        dl.select(lambda c: c["v"] > 1)
        dl.lazy().select(lambda c: c["v"] > 2)
        dl.map_columns(lambda c: dict(c))
        dl.lazy().map_columns(lambda c: dict(c))
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 2  # one for select, one for map_columns
    # expressions never warn
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        dl.select(col("v") > 0)
    assert not [x for x in w2 if issubclass(x.category, DeprecationWarning)]


# -- lazy integration ----------------------------------------------------------

def test_lazy_explain_renders_exprs(base):
    dl, dr = base
    lz = (dl.lazy()
          .select((col("v") > 3) & (col("k") < 20))
          .with_column("c", col("v") + col("k")))
    raw = lz.explain(optimized=False)
    assert "SELECT[((v > 3) & (k < 20))]" in raw
    assert "WITH_COLUMN c = (v + k)" in raw
    opt = lz.explain()
    # AND-split: the conjuncts appear as separate fused select steps
    assert "select[(v > 3)]" in opt and "select[(k < 20)]" in opt


def test_lazy_and_split_pushes_to_both_join_sides(base):
    dl, dr = base
    lz = (dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle")
          .select((col("v") > 100) & (col("w") > 100)))
    opt = lz.explain()
    join_at = opt.index("JOIN")
    # both conjuncts sank below the join (each to its own side)
    assert opt.index("(v > 100)") > join_at
    assert opt.index("(w > 100)") > join_at
    ref, _ = dl.join(dr, on=("k",), strategy="shuffle")
    ref = ref.select(lambda c: (c["v"] > 100) & (c["w"] > 100)).to_numpy()
    got = lz.to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


def test_lazy_with_column_dead_column_eliminated(base):
    dl, _ = base
    lz = (dl.lazy()
          .with_column("dead", col("v") * 1000)
          .project(["k", "v"]))
    plan = optimizer.optimize(lz.plan, 1, {0: N})
    assert not any(isinstance(n, WithColumn) for n in walk(plan))
    got = lz.to_numpy()
    ref = dl.project(["k", "v"]).to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


def test_lazy_select_sinks_below_with_column(base):
    dl, _ = base
    lz = (dl.lazy()
          .with_column("c", col("v") + 1)
          .select(col("k") > 5))
    opt = lz.explain()
    # the filter does not read c: it runs before the column is computed
    assert opt.index("(k > 5)") < opt.index("with_column") \
        or opt.index("select[(k > 5)]") < opt.index("with_column:c")
    ref = dl.select(col("k") > 5).with_column("c", col("v") + 1).to_numpy()
    got = lz.to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


def test_plan_cache_structural_identity(base):
    dl, _ = base
    a = dl.lazy().select((col("v") > 3) & (col("k") < lit(1) + lit(19)))
    b = dl.lazy().select((col("v") > 3) & (col("k") < 20))
    assert a.plan == b.plan  # folded at build: same structural identity
    c = dl.lazy().select((col("v") > 3) & (col("k") < 21))
    assert a.plan != c.plan


# -- streaming integration -----------------------------------------------------

def _write_ds(tmp_path, n=640):
    from repro.data.dataset import write_dataset
    rng = np.random.default_rng(11)
    data = {"k": rng.integers(0, 16, n).astype(np.int32),
            "v": rng.integers(0, 1000, n).astype(np.int32),
            "q": rng.integers(0, 7, n).astype(np.int32)}
    return data, write_dataset(data, str(tmp_path / "ds"), chunk_rows=80)


def test_scan_absorbs_expr_pred_without_probe(ctx, base, tmp_path,
                                              monkeypatch):
    from repro.stream import scan_dataset
    data, man = _write_ds(tmp_path)

    def boom(fn, schema):
        raise AssertionError("numpy probe invoked for an expression pred")

    monkeypatch.setattr(optimizer, "_host_pred_ok", boom)
    lz = (scan_dataset(man, ctx, batch_rows=160)
          .select((col("v") % 2).eq(0))
          .project(["k", "v"])
          .groupby(("k",), [col("v").sum()]))
    opt = lz.explain()
    assert "absorbed preds=[((v % 2) == 0)]" in opt
    scan = next(n for n in walk(optimizer.optimize(
        lz.plan, ctx.nworkers, {next(iter(lz._scans)): 640})) if isinstance(n, Scan))
    assert scan.columns == ("k", "v")
    got = lz.collect_stream().to_numpy()
    assert lz.last_info["batches"] >= 4
    dd = DDF.from_numpy(data, ctx)
    ref, _ = dd.select((col("v") % 2).eq(0)).groupby(("k",), {"v": ("sum",)})
    ref = ref.to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


def test_scan_float_arith_pred_stays_on_device(ctx, tmp_path):
    """A non-host-portable (float-arithmetic) expression predicate is NOT
    absorbed into the SCAN; it runs as a device SELECT and the streamed
    result still matches eager exactly."""
    from repro.stream import scan_dataset
    data, man = _write_ds(tmp_path)
    pred = (col("v") / 2) <= 250.0
    lz = scan_dataset(man, ctx, batch_rows=160).select(pred)
    sid = next(iter(lz._scans))
    plan = optimizer.optimize(lz.plan, ctx.nworkers, {sid: man.num_rows})
    scan = next(n for n in walk(plan) if isinstance(n, Scan))
    assert not scan.pred_sigs  # not absorbed
    assert any(isinstance(n, Select) and n.expr == pred for n in walk(plan))
    got = lz.collect_stream().to_numpy()
    ref = DDF.from_numpy(data, ctx).select(pred).to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


def test_scan_pred_decode_superset(ctx, tmp_path):
    """A scan predicate on a column outside the projected set decodes the
    column transiently and drops it before admission."""
    from repro.stream import scan_dataset
    data, man = _write_ds(tmp_path)
    lz = scan_dataset(man, ctx, batch_rows=160, columns=["k", "v"],
                      predicate=col("q") > 3)
    got = lz.collect_stream().to_numpy()
    assert sorted(got) == ["k", "v"]
    m = data["q"] > 3
    assert np.array_equal(got["k"], data["k"][m])
    assert np.array_equal(got["v"], data["v"][m])
    with pytest.raises(KeyError, match="scan"):
        scan_dataset(man, ctx, predicate=col("zz") > 1)
    with pytest.raises(TypeError):
        scan_dataset(man, ctx, predicate=lambda c: c["q"] > 3)


def test_scan_predicate_param_non_portable_goes_to_device(ctx, tmp_path):
    """scan_dataset(predicate=) stays exactly equivalent to .select():
    a non-host-portable predicate becomes a device SELECT, never a
    host-numpy filter with different float semantics."""
    from repro.stream import scan_dataset
    data, man = _write_ds(tmp_path)
    pred = (col("v") / 2) <= 250.0
    lz = scan_dataset(man, ctx, batch_rows=160, predicate=pred)
    assert isinstance(lz.plan, Select) and lz.plan.expr == pred
    assert not next(n for n in walk(lz.plan)
                    if isinstance(n, Scan)).pred_sigs
    got = lz.collect_stream().to_numpy()
    ref = scan_dataset(man, ctx, batch_rows=160).select(pred) \
        .collect_stream().to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k
    # narrowed decode set cannot feed a device predicate on other columns
    with pytest.raises(ValueError, match="not host-portable"):
        scan_dataset(man, ctx, columns=["k"], predicate=(col("v") / 2) <= 1.0)


def test_stream_expr_matches_callable_end_to_end(ctx, tmp_path):
    from repro.stream import scan_dataset
    data, man = _write_ds(tmp_path)

    def build(lz, use_expr):
        if use_expr:
            return (lz.select((col("v") % 2).eq(0) & (col("q") < 5))
                    .with_column("s", col("v") + col("q"))
                    .groupby(("k",), [col("s").sum(), col("s").count()]))
        return (lz.select(lambda c: (c["v"] % 2 == 0) & (c["q"] < 5))
                .map_columns(lambda c: {**c, "s": c["v"] + c["q"]},
                             name="add_s")
                .groupby(("k",), {"s": ("sum", "count")}))

    got = build(scan_dataset(man, ctx, batch_rows=160), True) \
        .collect_stream().to_numpy()
    ref = build(scan_dataset(man, ctx, batch_rows=160), False) \
        .collect_stream().to_numpy()
    eager = build(DDF.from_numpy(data, ctx).lazy(), True).collect().to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k
        assert np.array_equal(eager[k], got[k]), k


# -- property test: expr pipelines == callable pipelines -----------------------

OP_KINDS = ("select", "with_column", "project", "join", "groupby", "sort",
            "unique")


def _value_col(names):
    for c in ("v", "w", "v_sum", "w_sum", "c_sum", "v_count", "c"):
        if c in names:
            return c
    return None


def _apply(frame, right, op, use_expr, eager):
    names = set(frame.column_names)
    kind, p1, p2 = op
    c = _value_col(names)
    if kind == "select" and c is not None:
        m = 2 + p1 % 5
        if use_expr:
            return frame.select((col(c) % m).ne(0), name=f"s{m}")
        return frame.select(lambda cc: cc[c] % m != 0, name=f"s{m}")
    if kind == "with_column" and c in ("v", "w"):
        if use_expr:
            return frame.with_column("c", col(c) * 2 + p1)
        if eager:  # eager has no callable with_column; expr is the only form
            return frame.with_column("c", col(c) * 2 + p1)
        return frame.map_columns(
            lambda cc, _c=c, _p=p1: {**cc, "c": cc[_c] * 2 + _p},
            name=f"wc{p1}")
    if kind == "project" and c is not None and "k" in names:
        return frame.project(sorted({"k", c}))
    if kind == "join" and "w" not in names and "k" in names:
        out = frame.join(right, on=("k",), strategy="shuffle", capacity=CAP * 8)
        return out[0] if eager else out
    if kind == "groupby" and c is not None and "k" in names:
        if use_expr:
            specs = [col(c).sum()]
            if p1 % 2:
                specs.append(col(c).count().alias(f"{c}_n"))
            out = frame.groupby(("k",), specs)
            return out[0] if eager else out
        aggs = {c: ("sum", "count") if p1 % 2 else ("sum",)}
        out = frame.groupby(("k",), aggs)
        out = out[0] if eager else out
        if p1 % 2:
            out = out.rename({f"{c}_count": f"{c}_n"})
        return out
    if kind == "sort" and c is not None:
        out = frame.sort_values(c if p2 % 2 else ("k" if "k" in names else c),
                                descending=bool(p1 % 2))
        return out[0] if eager else out
    if kind == "unique" and "k" in names:
        out = frame.unique(("k",))
        return out[0] if eager else out
    return frame


def _check(base, ops):
    dl, dr = base
    results = {}
    for use_expr in (True, False):
        e = dl
        for op in ops:
            e = _apply(e, dr, op, use_expr, eager=True)
        lz = dl.lazy()
        lzr = dr.lazy()
        for op in ops:
            lz = _apply(lz, lzr, op, use_expr, eager=False)
        results[(use_expr, "eager")] = e.to_numpy()
        results[(use_expr, "lazy")] = lz.to_numpy()
    ref = results[(False, "eager")]
    for key, got in results.items():
        assert sorted(ref) == sorted(got), (key, ops)
        for k in ref:
            assert ref[k].dtype == got[k].dtype, (key, k, ops)
            assert np.array_equal(ref[k], got[k]), (key, k, ops)


def test_expr_pipelines_bit_identical_seeded(base):
    """Random expr pipelines == their callable equivalents, eager and lazy
    (deterministic variant; runs without hypothesis)."""
    rng = np.random.default_rng(4040)
    for _ in range(8):
        n_ops = int(rng.integers(1, 5))
        ops = [(OP_KINDS[int(rng.integers(len(OP_KINDS)))],
                int(rng.integers(8)), int(rng.integers(8)))
               for _ in range(n_ops)]
        _check(base, ops)


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.tuples(st.sampled_from(OP_KINDS),
                  st.integers(0, 7), st.integers(0, 7)),
        min_size=1, max_size=4)

    @settings(max_examples=8, deadline=None)
    @given(_ops)
    def test_expr_pipelines_bit_identical(ctx, base, ops):
        _check(base, ops)
