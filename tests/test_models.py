"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.launch.shapes import SHAPES, cell_applicable, input_specs
from repro.models import build_model


def _batch_for(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    total = S
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
        total += cfg.n_patches
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_positions, cfg.d_model)), jnp.float32)
    return batch, total


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 16
    batch, total = _batch_for(cfg, B, S)
    h, aux = jax.jit(model.forward)(params, batch)
    assert h.shape == (B, total, cfg.d_model)
    logits = model.unembed(params, h)
    assert logits.shape == (B, total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    state = model.init_decode_state(B, 32)
    if cfg.family == "encdec":
        state["enc_out"] = batch["enc_frames"].astype(jnp.bfloat16)
    dl, state2 = jax.jit(model.decode_step)(params, state, {"token": batch["tokens"][:, :1]})
    assert dl.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(dl.astype(jnp.float32)).all())
    assert int(state2["length"]) == 1


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma2-9b", "mamba2-1.3b",
                                  "granite-moe-1b-a400m", "llava-next-mistral-7b"])
def test_prefill_decode_consistency(arch):
    """Token-by-token decode must reproduce the training-forward logits
    (same positions, same caches) — catches cache/rope/mask bugs."""
    cfg = get_smoke_config(arch)
    # fp32 for a tight comparison
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(1))
    B, S = 2, 12
    batch, total = _batch_for(cfg, B, S, seed=3)
    h, _ = model.forward(params, batch)
    full_logits = model.unembed(params, h)  # (B, total, V)

    state = model.init_decode_state(B, 32, dtype=jnp.float32)
    if cfg.family == "encdec":
        state["enc_out"] = batch["enc_frames"].astype(jnp.float32)
    step = jax.jit(model.decode_step)
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts after image prefix; covered by smoke")
    outs = []
    for t in range(S):
        logits, state = step(params, state, {"token": batch["tokens"][:, t: t + 1]})
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)  # (B, S, V)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    }[cfg.name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    if "moe" in cfg.name:
        assert (cfg.n_experts, cfg.top_k) == ((40, 8) if "3b" in cfg.name else (32, 8))
    if cfg.name == "mamba2-1.3b":
        assert cfg.ssm_state == 128
    if cfg.name == "zamba2-1.2b":
        assert cfg.ssm_state == 64


def test_long500k_applicability_matches_design():
    runs = {a for a in ARCHS if cell_applicable(get_config(a), "long_500k")[0]}
    assert runs == {"llava_next_mistral_7b", "zamba2_1p2b", "mamba2_1p3b", "gemma2_9b"} \
        or runs == {"llava-next-mistral-7b", "zamba2-1.2b", "mamba2-1.3b", "gemma2-9b"}


def test_input_specs_cover_all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = cell_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs or "token" in specs
            for v in jax.tree.leaves(specs):
                assert isinstance(v, jax.ShapeDtypeStruct)
