"""HLO cost analyzer: trip-count scaling + collective census correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost
from repro.launch.roofline import collective_bytes_from_hlo, roofline_terms, model_flops
from repro.launch.shapes import SHAPES


def test_scan_trip_count_scaling():
    def step(c, w):
        return jnp.tanh(c @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(step, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    c = hlo_cost.analyze(comp.as_text())
    assert c.flops == 2 * 64 * 64 * 64 * 5  # 5 trips, not 1


def test_nested_scan_scaling():
    def inner(c, w):
        return c @ w, None

    def outer(c, ws):
        c, _ = jax.lax.scan(inner, c, ws)
        return c, None

    def f(x, ws):
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 4, 32, 32), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    c = hlo_cost.analyze(comp.as_text())
    assert c.flops == 2 * 32 * 32 * 32 * 3 * 4


def test_model_flops_formula():
    from repro.configs import get_config
    cfg = get_config("olmo-1b")
    cell = SHAPES["train_4k"]
    mf = model_flops(cfg, cell)
    # 6 * N * D with D = 256*4096 tokens
    assert mf == pytest.approx(6 * cfg.num_params() * 256 * 4096)
    moe = get_config("granite-moe-3b-a800m")
    assert moe.num_active_params() < moe.num_params()


def test_roofline_dominant_term():
    from repro.configs import get_config
    cfg = get_config("olmo-1b")
    r = roofline_terms(cfg, SHAPES["train_4k"], flops=1e12, bytes_accessed=1e9,
                       collective={"total_bytes": 1e13}, n_chips=256)
    assert r["dominant"] == "collective"
    r = roofline_terms(cfg, SHAPES["train_4k"], flops=1e15, bytes_accessed=1e9,
                       collective={"total_bytes": 1e6}, n_chips=256)
    assert r["dominant"] == "compute"
