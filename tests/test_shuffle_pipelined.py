"""Pipelined chunked shuffle: bit-exact equivalence + cost-model units.

The equivalence contract (ISSUE 1 acceptance): ``shuffle_table_pipelined``
produces bit-identical output buffers, nvalid, and overflow counters vs the
monolithic ``shuffle_table`` for every chunk count, including non-dividing
chunk counts, overflow-forcing quotas, and capacity overrides. In-process
tests run at P=1 (the pytest process owns a single CPU device); the
multi-worker case runs on 8 host devices in a subprocess.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import DDF, DDFContext
from repro.core import cost_model, patterns
from repro.core.comm import collectives
from repro.core.dataframe import Table
from repro.core.partition import hash_partition_ids


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def _run_shuffle(ctx, cols_np, counts_np, quota, num_chunks, capacity=None):
    nw = ctx.nworkers
    mesh = ctx.mesh

    def run(cols, counts):
        t = Table(dict(cols), counts.reshape(()))
        dest = hash_partition_ids(t, ("k",), nw)
        if num_chunks == 0:  # monolithic reference
            out, ov = collectives.shuffle_table(t, dest, ctx.axis, quota,
                                                capacity=capacity)
        else:
            out, ov = collectives.shuffle_table_pipelined(
                t, dest, ctx.axis, quota, num_chunks, capacity=capacity)
        return dict(out.columns), out.nvalid.reshape(1), ov.reshape(1)

    spec = {name: P("data") for name in cols_np}
    sm = shard_map(run, mesh=mesh, in_specs=(spec, P("data")),
                   out_specs=P("data"), check_vma=False)
    cols = {k: jnp.asarray(v.reshape(-1)) for k, v in cols_np.items()}
    return jax.jit(sm)(cols, jnp.asarray(counts_np))


def _table_inputs(nw, cap, n_per, seed=0):
    rng = np.random.default_rng(seed)
    cols = {
        "k": rng.integers(0, 500, size=(nw, cap)).astype(np.int32),
        "v": rng.integers(-1000, 1000, size=(nw, cap)).astype(np.int32),
    }
    counts = np.full((nw,), n_per, np.int32)
    return cols, counts


@pytest.mark.parametrize("num_chunks", [1, 2, 3, 4, 7])
def test_pipelined_bit_exact(ctx, num_chunks):
    cols, counts = _table_inputs(ctx.nworkers, cap=64, n_per=50)
    mono = _run_shuffle(ctx, cols, counts, quota=64, num_chunks=0)
    pipe = _run_shuffle(ctx, cols, counts, quota=64, num_chunks=num_chunks)
    assert np.array_equal(np.asarray(mono[1]), np.asarray(pipe[1]))
    assert np.array_equal(np.asarray(mono[2]), np.asarray(pipe[2]))
    assert int(np.asarray(pipe[2]).sum()) == 0  # well-sized quota: no overflow
    for name in cols:
        assert np.array_equal(np.asarray(mono[0][name]),
                              np.asarray(pipe[0][name])), f"column {name}"


@pytest.mark.parametrize("quota,capacity", [(8, None), (13, 40), (64, 500)])
def test_pipelined_bit_exact_overflow_and_capacity(ctx, quota, capacity):
    """Equivalence must hold when quotas overflow and capacities truncate/pad."""
    cols, counts = _table_inputs(ctx.nworkers, cap=64, n_per=60, seed=1)
    mono = _run_shuffle(ctx, cols, counts, quota, 0, capacity)
    for num_chunks in (2, 3, 5):
        pipe = _run_shuffle(ctx, cols, counts, quota, num_chunks, capacity)
        assert np.array_equal(np.asarray(mono[1]), np.asarray(pipe[1]))
        assert np.array_equal(np.asarray(mono[2]), np.asarray(pipe[2]))
        for name in cols:
            assert np.array_equal(np.asarray(mono[0][name]),
                                  np.asarray(pipe[0][name]))


def test_communicator_shuffle_pipelined_method(ctx):
    """Communicator.shuffle_pipelined (always-chunked, even K=1) matches
    Communicator.shuffle's monolithic output bit-exactly."""
    cols_np, counts_np = _table_inputs(ctx.nworkers, cap=32, n_per=24, seed=3)
    nw = ctx.nworkers

    def run(method_chunks):
        def f(cols, counts):
            t = Table(dict(cols), counts.reshape(()))
            dest = hash_partition_ids(t, ("k",), nw)
            comm = ctx.comm()
            if method_chunks is None:
                out, ov = comm.shuffle(t, dest, quota=32)
            else:
                out, ov = comm.shuffle_pipelined(t, dest, quota=32,
                                                 num_chunks=method_chunks)
            return dict(out.columns), out.nvalid.reshape(1), ov.reshape(1)

        spec = {name: P("data") for name in cols_np}
        sm = shard_map(f, mesh=ctx.mesh, in_specs=(spec, P("data")),
                       out_specs=P("data"), check_vma=False)
        cols = {k: jnp.asarray(v.reshape(-1)) for k, v in cols_np.items()}
        return jax.jit(sm)(cols, jnp.asarray(counts_np))

    mono = run(None)
    for k in (1, 2, 4):
        pipe = run(k)
        assert np.array_equal(np.asarray(mono[1]), np.asarray(pipe[1]))
        assert np.array_equal(np.asarray(mono[2]), np.asarray(pipe[2]))
        for name in cols_np:
            assert np.array_equal(np.asarray(mono[0][name]),
                                  np.asarray(pipe[0][name]))


def test_pipelined_operators_match_monolithic(ctx):
    """DDF join/groupby/sort give identical results with num_chunks > 1."""
    rng = np.random.default_rng(2)
    n = 400
    L = {"k": rng.integers(0, 80, size=n).astype(np.int32),
         "v": rng.integers(0, 1000, size=n).astype(np.int32)}
    R = {"k": rng.integers(0, 80, size=n).astype(np.int32),
         "w": rng.integers(0, 1000, size=n).astype(np.int32)}
    dl = DDF.from_numpy(L, ctx, capacity=2 * n)
    dr = DDF.from_numpy(R, ctx, capacity=2 * n)

    j1, _ = dl.join(dr, on=("k",), strategy="shuffle", capacity=16 * n, num_chunks=1)
    j3, _ = dl.join(dr, on=("k",), strategy="shuffle", capacity=16 * n, num_chunks=3)
    for c in j1.column_names:
        assert np.array_equal(j1.to_numpy()[c], j3.to_numpy()[c])

    g1, _ = dl.groupby(("k",), {"v": ("sum", "count")}, pre_combine=True, num_chunks=1)
    g4, _ = dl.groupby(("k",), {"v": ("sum", "count")}, pre_combine=True, num_chunks=4)
    for c in g1.column_names:
        assert np.array_equal(g1.to_numpy()[c], g4.to_numpy()[c])

    s1, _ = dl.sort_values("v", num_chunks=1)
    s2, _ = dl.sort_values("v", num_chunks=2)
    assert np.array_equal(s1.to_numpy()["v"], s2.to_numpy()["v"])
    assert np.array_equal(s1.to_numpy()["v"], np.sort(L["v"]))


@pytest.mark.slow
def test_pipelined_bit_exact_8_devices():
    """The real multi-worker all-to-all: bit-exactness on 8 host devices."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.dataframe import Table
from repro.core.partition import hash_partition_ids
from repro.core.comm import collectives

mesh = jax.make_mesh((8,), ("data",))
nw, cap, quota = 8, 64, 16
rng = np.random.default_rng(0)
cols_np = {"k": rng.integers(0, 500, size=(nw, cap)).astype(np.int32),
           "v": rng.integers(-1000, 1000, size=(nw, cap)).astype(np.int32)}
counts_np = np.full((nw,), 50, np.int32)

def run_shuffle(num_chunks):
    def f(cols, cnt):
        t = Table(dict(cols), cnt.reshape(()))
        dest = hash_partition_ids(t, ("k",), nw)
        if num_chunks == 0:
            out, ov = collectives.shuffle_table(t, dest, "data", quota)
        else:
            out, ov = collectives.shuffle_table_pipelined(t, dest, "data", quota, num_chunks)
        return dict(out.columns), out.nvalid.reshape(1), ov.reshape(1)
    sm = shard_map(f, mesh=mesh, in_specs=({"k": P("data"), "v": P("data")}, P("data")),
                   out_specs=P("data"), check_vma=False)
    return jax.jit(sm)({k: jnp.asarray(v.reshape(-1)) for k, v in cols_np.items()},
                       jnp.asarray(counts_np))

mono = run_shuffle(0)
for K in (2, 3, 4, 8):
    pipe = run_shuffle(K)
    assert np.array_equal(np.asarray(mono[1]), np.asarray(pipe[1])), f"K={K} nvalid"
    assert np.array_equal(np.asarray(mono[2]), np.asarray(pipe[2])), f"K={K} overflow"
    for name in ("k", "v"):
        assert np.array_equal(np.asarray(mono[0][name]), np.asarray(pipe[0][name])), f"K={K} {name}"
print("PIPELINED-8DEV-BITEXACT-OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=env)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "PIPELINED-8DEV-BITEXACT-OK" in res.stdout


def test_shuffle_rejects_non_native_algorithm_with_chunks():
    """algorithm='bruck' + num_chunks>1 is a contradiction, not a fallback."""
    from repro.core.comm.communicator import make_communicator

    comm = make_communicator("data")
    t = Table({"k": jnp.zeros(4, jnp.int32)}, jnp.asarray(4, jnp.int32))
    with pytest.raises(ValueError, match="monolithic"):
        comm.shuffle(t, jnp.zeros(4, jnp.int32), quota=4,
                     algorithm="bruck", num_chunks=2)


# -- cost model / planner units -------------------------------------------------

def test_pipelined_cost_degenerates_at_k1():
    p = cost_model.CostParams()
    for nb in (1e3, 1e6, 1e9):
        mono = sum(cost_model.t_shuffle(8, nb, p))
        assert cost_model.t_shuffle_pipelined(8, nb, 1, p) == pytest.approx(mono)


def test_pipelined_cost_overlap_beats_monolithic_when_balanced():
    """With comm ~ core, pipelining hides most of the smaller term."""
    p = cost_model.CostParams()
    nb = 1e8
    core = sum(cost_model.t_shuffle(8, nb, p))  # core == comm exactly
    mono = core + sum(cost_model.t_shuffle(8, nb, p))
    piped = cost_model.t_shuffle_pipelined(8, nb, 16, p, core_s=core)
    assert piped < 0.6 * mono  # ideal overlap approaches 0.5x


def test_choose_chunk_count_bounds():
    p = cost_model.CostParams()
    # tiny payload: startup dominates -> monolithic
    assert cost_model.choose_chunk_count(8, 1e3, p) == 1
    # large payload: pipelining wins
    k = cost_model.choose_chunk_count(8, 1e9, p, core_s=0.1)
    assert k > 1
    assert k <= 32
    # chosen K is the argmin over the scanned candidates
    cands = [1] + [2 ** i for i in range(1, 6) if 1e9 / 2 ** i >= 4096]
    best = min(cands, key=lambda c: cost_model.t_shuffle_pipelined(8, 1e9, c, p, core_s=0.1))
    assert k == best


def test_plan_join_and_groupby_carry_num_chunks():
    plan = patterns.plan_join(10_000_000, 10_000_000, 8, 2_500_000)
    assert plan.strategy == "shuffle"
    assert plan.num_chunks >= 1
    small = patterns.plan_join(1_000, 1_000, 8, 250)
    assert small.num_chunks == 1 or small.strategy == "broadcast"
    g = patterns.plan_groupby(0.2, 8, 1_000_000, n_rows=8_000_000)
    assert g.num_chunks >= 1
    # no size info -> stays monolithic
    assert patterns.plan_groupby(0.2, 8, 1_000).num_chunks == 1
    # cardinality 0.0 = "unknown" sentinel: must size for the full payload,
    # not a zero-byte shuffle (which would never pipeline)
    g0 = patterns.plan_groupby(0.0, 8, 1_000_000, n_rows=80_000_000)
    assert g0.num_chunks > 1
    # a pinned pre_combine=False must size the payload at full n (no C
    # shrink): at this scale the full payload picks K>1 while a wrongly
    # C-shrunk payload (the bug this guards) would pick K=1
    gf = patterns.plan_groupby(0.1, 8, 1_000_000, n_rows=200_000,
                               pre_combine=False)
    g1 = patterns.plan_groupby(1.0, 8, 1_000_000, n_rows=200_000,
                               pre_combine=False)
    assert gf.strategy == "shuffle_compute"
    assert gf.num_chunks == g1.num_chunks  # cardinality must not shrink payload
    assert gf.num_chunks > 1


def test_pattern_cost_pipelined_total_not_worse():
    for pat, op in (("shuffle_compute", "hash_join"),
                    ("combine_shuffle_reduce", "groupby")):
        mono = cost_model.pattern_cost(pat, P=8, n_rows=1e7, row_bytes=16.0,
                                       cardinality=0.5, core_op=op)
        piped = cost_model.pattern_cost(pat, P=8, n_rows=1e7, row_bytes=16.0,
                                        cardinality=0.5, core_op=op, num_chunks=8)
        assert piped["total"] <= mono["total"] + 1e-12
