"""Differential oracle tests: eager == lazy == streaming == numpy oracle.

Random pipelines over mixed numeric + dict-encoded string tables run four
ways — the eager per-op ``DDF`` path, one lazy plan through the full
optimizer, the out-of-core streaming engine over on-disk chunked datasets
(scan leaves, so vocab unification happens at Recode boundaries), and the
pure-numpy reference in ``tests/oracle.py`` — and every result must agree
as a multiset of rows (hash/tie order is an engine detail; explicit sorts
additionally assert monotonicity).

Pipelines are drawn from a seeded generator (deterministic: the suite
replays bit-identically); when hypothesis is installed an extra
hypothesis-driven variant of the same property runs too. String predicates
exercise both vocab-present and vocab-absent literals, joins/set-ops run
over *divergent* per-side vocabularies, and string-keyed groupbys cover
ordered aggregation (min/max) of dict columns.
"""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import DDF, DDFContext
from repro.data.dataset import DatasetWriter
from repro.expr import col
from repro.stream import scan_dataset

import oracle as O

N = 48
CAP = 8 * N
WORDS = ("atl", "bos", "den", "dfw", "iad", "jfk", "lax", "ord",
         "sea", "sfo")
TAGS = ("blue", "green", "red")
OP_KINDS = ("select", "project", "join", "groupby", "unique", "sort",
            "difference", "union")


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(11)
    # divergent key vocabularies: the two sides only share WORDS[2:8], so
    # every join/union/difference crosses a real vocab-unification boundary
    L = {"k": np.asarray(WORDS[:8])[rng.integers(0, 8, N)],
         "g": np.asarray(TAGS)[rng.integers(0, 3, N)],
         "v": rng.integers(0, 1000, N).astype(np.int32)}
    R = {"k": np.asarray(WORDS[2:])[rng.integers(0, 8, N)],
         "w": rng.integers(0, 1000, N).astype(np.int32)}
    return L, R


@pytest.fixture(scope="module")
def datasets(data, tmp_path_factory):
    L, R = data
    out = []
    for name, tbl in (("L", L), ("R", R)):
        d = tmp_path_factory.mktemp(f"diff{name}")
        schema = {c: ("dict" if tbl[c].dtype.kind == "U" else str(tbl[c].dtype))
                  for c in tbl}
        w = DatasetWriter(str(d), schema, chunk_rows=16)
        w.append(tbl)
        out.append(w.close())
    return tuple(out)


def _value_col(names):
    for c in ("v", "w", "v_sum", "v_count", "v_min", "v_max", "w_sum",
              "w_count", "g_min", "g_max"):
        if c in names:
            return c
    return None


def _names(frame, mode):
    if mode == "oracle":
        return set(frame)
    return set(frame.column_names)


def _select_pred(p1, p2, vcol):
    """(expr for engines, numpy mask fn for the oracle). Words are drawn
    from the full pool, so some literals are absent from a side's vocab."""
    word = WORDS[p2 % len(WORDS)]
    kind = p1 % 5
    if kind == 0:
        return col("k").eq(word), lambda t: np.asarray(t["k"]) == word
    if kind == 1:
        return col("k").ne(word), lambda t: np.asarray(t["k"]) != word
    if kind == 2:
        return col("k") < word, lambda t: np.asarray(t["k"]) < word
    if kind == 3:
        return col("k") >= word, lambda t: np.asarray(t["k"]) >= word
    m = 2 + p2 % 5
    if vcol is None or vcol.startswith("g_"):
        return None, None
    return (col(vcol) % m).ne(0), \
        lambda t: (np.asarray(t[vcol]) % m) != 0


def _apply(frame, rights, op, mode):
    """Apply one drawn op in one execution mode; ops whose requirements
    are unmet degrade to a no-op (identically in every mode, because the
    four modes always hold the same schema)."""
    names = _names(frame, mode)
    kind, p1, p2 = op
    vcol = _value_col(names)
    right = rights[mode]
    eager = mode == "eager"
    if kind == "select" and "k" in names:
        pred, mask = _select_pred(p1, p2, vcol)
        if pred is None:
            return frame
        if mode == "oracle":
            return O.o_select(frame, mask(frame))
        return frame.select(pred, name=f"p{p1 % 5}_{p2 % 10}")
    if kind == "project" and "k" in names and vcol is not None:
        keep = ["k", vcol] + (["g"] if "g" in names and p1 % 2 else [])
        if mode == "oracle":
            return O.o_project(frame, keep)
        return frame.project(keep)
    if kind == "join" and "k" in names and "w" not in names:
        if mode == "oracle":
            return O.o_join(frame, right, ("k",))
        out = frame.join(right, on=("k",), strategy="shuffle",
                         capacity=CAP * 8)
        return out[0] if eager else out
    if kind == "groupby" and "k" in names and vcol is not None:
        by = ("k", "g") if "g" in names and p2 % 2 else ("k",)
        if p1 % 4 == 3 and "g" in names and "g" not in by:
            aggs = {"g": ("min", "max")}
        elif vcol.startswith("g_"):
            aggs = {vcol: ("min", "max")}
        else:
            aggs = {vcol: ("sum", "count") if p1 % 2 else ("min", "max")}
        if mode == "oracle":
            return O.o_groupby(frame, by, aggs)
        out = frame.groupby(by, aggs)
        return out[0] if eager else out
    if kind == "unique" and "k" in names:
        keys = ("k", "g") if "g" in names and p1 % 2 else ("k",)
        if mode == "oracle":
            return O.o_unique(O.o_project(frame, keys), keys)
        out = frame.project(list(keys)).unique(keys)
        return out[0] if eager else out
    if kind == "sort" and names:
        by = "k" if (p1 % 2 or vcol is None) and "k" in names else vcol
        if by is None:
            return frame
        if mode == "oracle":
            return O.o_sort(frame, by, descending=bool(p2 % 2))
        out = frame.sort_values(by, descending=bool(p2 % 2))
        return out[0] if eager else out
    if kind == "difference" and "k" in names:
        # the engine's difference is a SET op (left is deduplicated by
        # key), so run it key-only to keep non-key survivors unambiguous
        if mode == "oracle":
            return O.o_unique(
                O.o_difference(O.o_project(frame, ["k"]),
                               O.o_project(right, ["k"]), ("k",)), ("k",))
        out = frame.project(["k"]).difference(right.project(["k"]),
                                              on=("k",))
        return out[0] if eager else out
    if kind == "union" and "k" in names:
        if mode == "oracle":
            return O.o_union(O.o_project(frame, ["k"]),
                             O.o_project(right, ["k"]), ("k",))
        out = frame.project(["k"]).union(right.project(["k"]), on=("k",))
        return out[0] if eager else out
    return frame


def _final_sort(ops, result):
    """(by, descending) when the pipeline's last op is a sort; a sort
    changes no columns, so its key resolves against the final schema."""
    if not ops or ops[-1][0] != "sort":
        return None
    _, p1, p2 = ops[-1]
    names = set(result)
    vcol = _value_col(names)
    by = "k" if (p1 % 2 or vcol is None) and "k" in names else vcol
    return (by, bool(p2 % 2)) if by is not None else None


def _check_pipeline(ctx, data, datasets, ops):
    L, R = data
    manL, manR = datasets
    dl = DDF.from_numpy(L, ctx, capacity=CAP)
    dr = DDF.from_numpy(R, ctx, capacity=CAP)
    frames = {
        "eager": dl,
        "lazy": dl.lazy(),
        "stream": scan_dataset(manL, ctx, batch_rows=16),
        "oracle": {c: np.asarray(v) for c, v in L.items()},
    }
    rights = {
        "eager": dr,
        "lazy": dr.lazy(),
        "stream": scan_dataset(manR, ctx, batch_rows=16),
        "oracle": {c: np.asarray(v) for c, v in R.items()},
    }
    for mode in frames:
        f = frames[mode]
        for op in ops:
            f = _apply(f, rights, op, mode)
        frames[mode] = f
    results = {
        "eager": frames["eager"].to_numpy(),
        "lazy": frames["lazy"].to_numpy(),
        "stream": frames["stream"].collect_stream().to_numpy(),
        "oracle": frames["oracle"],
    }
    want = O.canonical(results["oracle"])
    for mode in ("eager", "lazy", "stream"):
        got = O.canonical(results[mode])
        assert got[0] == want[0], (mode, ops, got[0], want[0])
        assert got[1] == want[1], (mode, ops, got[1][:4], want[1][:4])
    srt = _final_sort(ops, results["eager"])
    if srt is not None:
        by, desc = srt
        for mode in ("eager", "lazy", "stream"):
            assert O.is_sorted_by(results[mode], by, desc), (mode, ops)


def _draw_ops(rng, max_ops=3):
    n_ops = int(rng.integers(1, max_ops + 1))
    return [(OP_KINDS[int(rng.integers(len(OP_KINDS)))],
             int(rng.integers(8)), int(rng.integers(10)))
            for _ in range(n_ops)]


# 200+ seeded pipelines split into chunks so a failure names its block and
# the whole sweep shows progress under -v
@pytest.mark.parametrize("block", range(10))
def test_differential_seeded(ctx, data, datasets, block):
    """Deterministic sweep: 10 blocks x 20 pipelines = 200 pipelines."""
    rng = np.random.default_rng(7000 + block)
    for _ in range(20):
        _check_pipeline(ctx, data, datasets, _draw_ops(rng))


def test_differential_string_heavy(ctx, data, datasets):
    """Hand-picked worst cases: every op touches a dict column."""
    cases = [
        [("select", 0, 4), ("groupby", 3, 1), ("sort", 1, 0)],
        [("join", 0, 0), ("select", 2, 7), ("groupby", 0, 0)],
        [("union", 0, 0), ("sort", 1, 1)],
        [("difference", 0, 0), ("unique", 1, 0), ("sort", 1, 0)],
        [("select", 0, 9), ("join", 0, 0)],  # literal absent on one side
        [("groupby", 3, 0), ("sort", 0, 0)],  # g_min/g_max keep vocab
    ]
    for ops in cases:
        _check_pipeline(ctx, data, datasets, ops)


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.tuples(st.sampled_from(OP_KINDS),
                  st.integers(0, 7), st.integers(0, 9)),
        min_size=1, max_size=3)

    @settings(max_examples=10, deadline=None)
    @given(_ops)
    def test_differential_hypothesis(ctx, data, datasets, ops):
        _check_pipeline(ctx, data, datasets, ops)
