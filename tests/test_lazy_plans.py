"""Lazy logical-plan layer: rewrite passes (via structure and ``.explain()``),
build-time validation, caches, and lazy-vs-eager execution equivalence."""

import jax
import numpy as np
import pytest

from repro.core import DDF, DDFContext
from repro.core import api
from repro.plan import LazyDDF, logical, optimizer
from repro.plan.logical import (
    Fused, GroupBy, Join, Project, Select, Source, format_plan,
)


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


@pytest.fixture(scope="module")
def tables(ctx):
    rng = np.random.default_rng(7)
    n = 240
    L = {"k": rng.integers(0, 120, n).astype(np.int32),
         "v": rng.integers(0, 1000, n).astype(np.int32),
         "junk": rng.integers(0, 5, n).astype(np.int32)}
    R = {"k": rng.integers(0, 120, n).astype(np.int32),
         "w": rng.integers(0, 1000, n).astype(np.int32),
         "junk2": rng.integers(0, 5, n).astype(np.int32)}
    return (DDF.from_numpy(L, ctx, capacity=2 * n),
            DDF.from_numpy(R, ctx, capacity=2 * n), L, R)


# -- pass 1: predicate pushdown -------------------------------------------------

def test_predicate_pushdown_left_side(tables):
    dl, dr, _, _ = tables
    lz = (dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle")
          .select(lambda c: c["v"] > 500, name="vbig"))
    root = optimizer.pushdown_predicates(lz.plan)
    assert isinstance(root, Join)
    assert isinstance(root.left, Select) and root.left.name == "vbig"
    ex = lz.explain()
    assert ex.index("JOIN") < ex.index("SELECT vbig")  # printed below the join


def test_predicate_pushdown_right_side(tables):
    dl, dr, _, _ = tables
    lz = (dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle")
          .select(lambda c: c["w"] > 500, name="wbig"))
    root = optimizer.pushdown_predicates(lz.plan)
    assert isinstance(root, Join)
    assert isinstance(root.right, Select) and root.right.name == "wbig"


def test_predicate_pushdown_blocked_on_suffixed_column(ctx):
    rng = np.random.default_rng(0)
    n = 64
    A = DDF.from_numpy({"k": rng.integers(0, 9, n).astype(np.int32),
                        "x": rng.integers(0, 9, n).astype(np.int32)}, ctx)
    B = DDF.from_numpy({"k": rng.integers(0, 9, n).astype(np.int32),
                        "x": rng.integers(0, 9, n).astype(np.int32)}, ctx)
    lz = (A.lazy().join(B.lazy(), on=("k",), strategy="shuffle")
          .select(lambda c: c["x_r"] > 4, name="xr"))
    root = optimizer.pushdown_predicates(lz.plan)
    assert isinstance(root, Select)  # x_r only exists above the join


def test_predicate_pushdown_below_sort(tables):
    dl, _, _, _ = tables
    lz = dl.lazy().sort_values("v").select(lambda c: c["v"] % 2 == 0, name="even")
    root = optimizer.pushdown_predicates(lz.plan)
    assert isinstance(root, logical.Sort)
    assert isinstance(root.child, Select)


# -- pass 2: projection pushdown ------------------------------------------------

def test_projection_pushdown_below_join(tables):
    dl, dr, _, _ = tables
    lz = (dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle")
          .groupby(("k",), {"v": ("sum",)}))
    root = optimizer.pushdown_projections(lz.plan)
    gp = root
    assert isinstance(gp, GroupBy)
    join = gp.child.child if isinstance(gp.child, Project) else gp.child
    assert isinstance(join, Join)
    assert isinstance(join.left, Project) and join.left.synthetic
    assert set(join.left.names) == {"k", "v"}       # junk dropped pre-shuffle
    assert isinstance(join.right, Project) and set(join.right.names) == {"k"}
    ex = lz.explain()
    assert ex.index("JOIN") < ex.index("PROJECT")   # pushed below the shuffle


def test_projection_pushdown_keeps_root_schema(tables):
    dl, dr, _, _ = tables
    lz = dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle")
    root = optimizer.pushdown_projections(lz.plan)
    assert logical.schema_names(logical.schema_of(root)) == lz.column_names


def test_projection_pushdown_below_sort_and_rebalance(tables):
    dl, _, _, _ = tables
    lz = dl.lazy().sort_values("v").project(["k", "v"])
    root = optimizer.pushdown_projections(lz.plan)
    sort = root.child if isinstance(root, Project) else root
    assert isinstance(sort, logical.Sort)
    assert isinstance(sort.child, Project) and sort.child.synthetic
    assert "junk" not in sort.child.names  # junk not shipped through the range shuffle
    lz2 = dl.lazy().rebalance().project(["k"])
    root2 = optimizer.pushdown_projections(lz2.plan)
    rb = root2.child if isinstance(root2, Project) else root2
    assert isinstance(rb, logical.Rebalance)
    assert isinstance(rb.child, Project) and rb.child.names == ("k",)


def test_difference_right_side_projected_to_keys(tables):
    dl, dr, _, _ = tables
    lz = dl.lazy().difference(dr.lazy(), on=("k",))
    root = optimizer.pushdown_projections(lz.plan)
    assert isinstance(root.right, Project)
    assert root.right.names == ("k",)  # anti-join only reads the keys


# -- pass 3: cost-model planning -------------------------------------------------

def test_plan_shuffles_concretizes_everything(tables):
    dl, dr, _, _ = tables
    lz = (dl.lazy().join(dr.lazy(), on=("k",))
          .groupby(("k",), {"v": ("sum",)}).sort_values("v_sum"))
    root = optimizer.plan_shuffles(lz.plan, ctx_nw := dl.ctx.nworkers,
                                   {s: d.num_rows() for s, d in lz._sources.items()})
    for node in logical.walk(root):
        if isinstance(node, (Join, GroupBy, logical.Sort)):
            assert node.quota is not None and node.capacity is not None
            assert node.num_chunks is not None and node.num_chunks >= 1
        if isinstance(node, Join):
            assert node.strategy != "auto"
        if isinstance(node, GroupBy):
            assert node.pre_combine is not None


def test_single_planning_pass_single_sync(tables):
    """A lazy collect must sync source row counts at most once, and repeats
    reuse the memoized counts (zero further syncs)."""
    dl, dr, _, _ = tables
    lz = dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle")
    rows1 = lz._rows()
    assert set(rows1.values()) == {240}
    assert all(sources._nrows is not None for sources in lz._sources.values())


# -- pass 4: shuffle elision ------------------------------------------------------

def test_groupby_after_join_elides_shuffle(tables):
    dl, dr, _, _ = tables
    lz = (dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle")
          .groupby(("k",), {"v": ("sum",)}))
    ex = lz.explain()
    assert "elide_shuffle" in ex
    assert ex.strip().endswith("shuffles: 1")  # only the join shuffles


def test_unique_after_join_elides_shuffle(tables):
    dl, dr, _, _ = tables
    lz = dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle").unique(("k",))
    ex = lz.explain()
    assert "UNIQUE" in ex and "elide_shuffle" in ex
    assert ex.strip().endswith("shuffles: 1")


def test_no_elision_on_different_key(tables):
    dl, dr, _, _ = tables
    lz = (dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle")
          .groupby(("v",), {"w": ("sum",)}))
    ex = lz.explain()
    assert "elide_shuffle" not in ex
    assert ex.strip().endswith("shuffles: 2")


def test_elided_groupby_matches_eager(tables):
    dl, dr, L, R = tables
    lz = (dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle", capacity=4000)
          .groupby(("k",), {"v": ("sum", "count")}))
    got = lz.to_numpy()
    EJ, _ = dl.join(dr, on=("k",), strategy="shuffle", capacity=4000)
    EG, _ = EJ.groupby(("k",), {"v": ("sum", "count")})
    ref = EG.to_numpy()
    assert sorted(ref) == sorted(got)
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


# -- pass 5: EP fusion -------------------------------------------------------------

def test_elementwise_chain_fuses_to_one_stage(tables):
    dl, _, _, _ = tables
    lz = (dl.lazy().select(lambda c: c["v"] % 2 == 0, name="even")
          .map_columns(lambda c: {"k": c["k"], "v": c["v"], "v2": c["v"] * 2},
                       name="double")
          .project(["k", "v2"]))
    root = optimizer.fuse_elementwise(optimizer.pushdown_predicates(lz.plan))
    assert isinstance(root, Fused)
    assert len(root.steps) == 3
    assert isinstance(root.child, Source)
    assert "EP[" in lz.explain()


# -- terminals / equivalence --------------------------------------------------------

def test_four_op_pipeline_bit_exact(tables):
    """The benchmark pipeline (select -> project -> join -> groupby) in
    miniature: lazy-optimized collect is bit-identical to eager."""
    dl, dr, _, _ = tables
    lz = (dl.lazy().select(lambda c: c["v"] % 2 == 0, name="even")
          .project(["k", "v"])
          .join(dr.lazy(), on=("k",), strategy="shuffle", capacity=4000)
          .groupby(("k",), {"v": ("sum", "count")}))
    got = lz.to_numpy()
    E = dl.select(lambda c: c["v"] % 2 == 0, name="even").project(["k", "v"])
    EJ, _ = E.join(dr, on=("k",), strategy="shuffle", capacity=4000)
    EG, _ = EJ.groupby(("k",), {"v": ("sum", "count")})
    ref = EG.to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k
    # overflow counters surface through last_info and are all zero
    assert all(int(np.asarray(v).sum()) == 0 for v in lz.last_info.values())


def test_optimized_equals_plan_only(tables):
    """The rewrite passes never change results, only cost."""
    dl, dr, _, _ = tables
    lz = (dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle", capacity=4000)
          .select(lambda c: c["v"] > 500, name="vbig")
          .groupby(("k",), {"v": ("sum",)}))
    a = lz.collect(level="all").to_numpy()
    b = lz.collect(level="plan-only").to_numpy()
    for k in a:
        assert np.array_equal(a[k], b[k]), k


def test_explain_does_not_execute(tables):
    dl, dr, _, _ = tables
    lz = dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle")
    ex = lz.explain()
    assert "JOIN" in ex and "shuffles:" in ex and "rows~" in ex
    assert lz.last_info is None  # no execution happened


def test_eager_escape_hatches(tables):
    dl, _, _, _ = tables
    assert dl.eager() is dl
    out = dl.lazy().select(lambda c: c["v"] % 2 == 0, name="even").eager()
    assert isinstance(out, DDF)


def test_default_mode_switch(ctx):
    import repro.plan as rplan
    data = {"k": np.arange(16, dtype=np.int32)}
    try:
        rplan.set_default_mode("lazy")
        assert isinstance(DDF.from_numpy(data, ctx), LazyDDF)
        with pytest.raises(ValueError):
            rplan.set_default_mode("nope")
    finally:
        rplan.set_default_mode("eager")
    assert isinstance(DDF.from_numpy(data, ctx), DDF)
    assert isinstance(DDF.from_numpy(data, ctx, mode="lazy"), LazyDDF)


# -- validation ----------------------------------------------------------------------

def test_eager_project_rename_drop_validation(tables):
    dl, _, L, _ = tables
    with pytest.raises(KeyError, match="available schema"):
        dl.project(["nope"])
    with pytest.raises(KeyError, match="available schema"):
        dl.drop(["nope"])
    with pytest.raises(KeyError, match="available schema"):
        dl.rename({"nope": "x"})
    with pytest.raises(ValueError, match="duplicate target"):
        dl.rename({"v": "junk"})
    got = dl.drop(["junk"])
    assert sorted(got.column_names) == ["k", "v"]
    assert np.array_equal(got.to_numpy()["v"], dl.to_numpy()["v"])


def test_lazy_validation_at_build_time(tables):
    dl, dr, _, _ = tables
    lz = dl.lazy()
    for bad in (lambda: lz.project(["nope"]),
                lambda: lz.drop(["nope"]),
                lambda: lz.rename({"nope": "x"}),
                lambda: lz.groupby(("nope",), {"v": ("sum",)}),
                lambda: lz.groupby(("k",), {"nope": ("sum",)}),
                lambda: lz.sort_values("nope"),
                lambda: lz.join(dr.lazy(), on=("nope",))):
        with pytest.raises(KeyError, match="available schema"):
            bad()
    # drop is project's inverse and stays lazy
    assert lz.drop(["junk"]).column_names == ("k", "v")


def test_same_name_different_predicates_do_not_alias(tables):
    """Two selects with the default name but different predicates must not
    share a compiled op or plan-cache entry (callable fingerprint)."""
    dl, _, L, _ = tables
    lo = dl.lazy().select(lambda c: c["v"] < 500).to_numpy()
    hi = dl.lazy().select(lambda c: c["v"] >= 500).to_numpy()
    assert sorted(lo["v"]) == sorted(L["v"][L["v"] < 500])
    assert sorted(hi["v"]) == sorted(L["v"][L["v"] >= 500])
    # same-line lambdas differing only in a captured constant, eager path
    outs = [dl.select(lambda c: c["v"] % m == 0).num_rows() for m in (2, 3)]
    assert outs[0] == int((L["v"] % 2 == 0).sum())
    assert outs[1] == int((L["v"] % 3 == 0).sum())


def test_same_line_lambdas_with_different_consts_do_not_alias(tables):
    """Lambdas sharing one source line (same co_code) but differing in a
    literal or referenced column must get distinct cache signatures."""
    dl, _, L, _ = tables
    preds = [lambda c: c["v"] > 0, lambda c: c["v"] > 500]
    assert api.callable_signature(preds[0]) != api.callable_signature(preds[1])
    a = dl.select(preds[0]).num_rows()
    b = dl.select(preds[1]).num_rows()
    assert a == int((L["v"] > 0).sum()) and b == int((L["v"] > 500).sum())


def test_pushdown_preserves_join_suffix(ctx):
    """Pruning the left side must not un-suffix a right column an ancestor
    references as '<name>_r'."""
    rng = np.random.default_rng(13)
    n = 64
    A = DDF.from_numpy({"k": np.arange(n, dtype=np.int32),
                        "x": rng.integers(0, 9, n).astype(np.int32)}, ctx)
    B = DDF.from_numpy({"k": np.arange(n, dtype=np.int32),
                        "x": (rng.integers(0, 9, n) + 100).astype(np.int32)}, ctx)
    lz = (A.lazy().join(B.lazy(), on=("k",), strategy="shuffle", capacity=256)
          .project(["x_r"]))
    assert "x_r" in lz.explain()  # optimized schema still carries the suffix
    got = lz.to_numpy()
    EJ, _ = A.join(B, on=("k",), strategy="shuffle", capacity=256)
    ref = EJ.project(["x_r"]).to_numpy()
    assert np.array_equal(ref["x_r"], got["x_r"])


def test_membership_probe_disables_pushdown(tables):
    """A predicate branching on `'col' in c` depends on the full column set;
    the probe must report used=None so pushdown keeps every column."""
    from repro.plan.logical import probe_columns
    used, _ = probe_columns(lambda c: (c["v"] > 0) if "junk" in c else (c["v"] < 0),
                            tables[0].lazy().schema)
    assert used is None


def test_lazy_rename_duplicate_target_raises(tables):
    dl, _, _, _ = tables
    with pytest.raises(ValueError, match="duplicate target"):
        dl.lazy().rename({"v": "junk"})


def test_hash_equal_closure_values_do_not_alias(tables):
    """hash(-1) == hash(-2) in CPython: fingerprints keep raw values so
    cache-key equality (not hash) decides, and the ops stay distinct."""
    dl, _, L, _ = tables

    def make(t):
        return lambda c: c["v"] > t

    assert api.callable_signature(make(-1)) != api.callable_signature(make(-2))
    a = dl.select(make(-1)).num_rows()
    b = dl.select(make(-2)).num_rows()
    assert a == int((L["v"] > -1).sum()) and b == int((L["v"] > -2).sum())
    lz_a = dl.lazy().select(make(-1)).collect().num_rows()
    assert lz_a == a


def test_internal_pipeline_immune_to_lazy_default(ctx):
    """set_default_mode('lazy') must not change internal library callers
    that pin mode='eager' (e.g. the data pipeline)."""
    import repro.plan as rplan
    try:
        rplan.set_default_mode("lazy")
        d = DDF.from_numpy({"k": np.arange(16, dtype=np.int32)}, ctx,
                           mode="eager")
        assert isinstance(d, DDF)
        out, _ = d.unique(("k",))  # eager tuple-returning API still works
        assert isinstance(out, DDF)
    finally:
        rplan.set_default_mode("eager")


def test_unknown_column_in_predicate_raises_at_build(tables):
    dl, _, _, _ = tables
    with pytest.raises(KeyError, match="available schema"):
        dl.lazy().select(lambda c: c["typo"] > 0)
    with pytest.raises(KeyError, match="available schema"):
        dl.lazy().map_columns(lambda c: {"x": c["typo"]})


def test_broadcast_join_keeps_column_roles(ctx):
    """Eager broadcast no longer swaps join sides: colliding non-key columns
    keep left-values in 'x' and right-values in 'x_r' whichever side is
    gathered, matching shuffle joins and the lazy executor."""
    rng = np.random.default_rng(11)
    n = 64
    A = DDF.from_numpy({"k": np.arange(n, dtype=np.int32),
                        "x": rng.integers(0, 9, n).astype(np.int32)}, ctx)
    B = DDF.from_numpy({"k": np.arange(n, dtype=np.int32),
                        "x": (rng.integers(0, 9, n) + 100).astype(np.int32)}, ctx)
    small = DDF.from_numpy({"k": np.arange(8, dtype=np.int32),
                            "x": np.full(8, 100, np.int32)}, ctx)
    for left, right in ((A, small), (small, B)):
        bc, _ = left.join(right, on=("k",), strategy="broadcast", capacity=256)
        sh, _ = left.join(right, on=("k",), strategy="shuffle", capacity=256)
        gb, gs = bc.to_numpy(), sh.to_numpy()
        assert sorted(gb) == sorted(gs)
        for col in gs:
            assert sorted(gb[col].tolist()) == sorted(gs[col].tolist()), col
        lzb = left.lazy().join(right.lazy(), on=("k",), strategy="broadcast",
                               capacity=256).to_numpy()
        for col in gs:
            assert sorted(lzb[col].tolist()) == sorted(gs[col].tolist()), col


# -- caches ---------------------------------------------------------------------------

def test_op_cache_lru_bound_and_stable_keys():
    c = api._LRUCache(maxsize=2)
    c.put("a", 1), c.put("b", 2)
    assert c.get("a") == 1
    c.put("c", 3)  # evicts "b" (least recently used)
    assert c.get("b") is None and c.get("a") == 1 and c.get("c") == 3
    assert len(c) == 2


def test_mesh_signature_is_stable_across_instances():
    m1 = jax.make_mesh((len(jax.devices()),), ("data",))
    m2 = jax.make_mesh((len(jax.devices()),), ("data",))
    assert m1 is not m2 or id(m1) == id(m2)
    assert api.mesh_signature(m1) == api.mesh_signature(m2)


def test_repeated_collect_hits_plan_and_op_caches(tables):
    dl, dr, _, _ = tables
    def build():
        return (dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle",
                               capacity=4000)
                .groupby(("k",), {"v": ("sum",)}))
    build().collect()
    n_ops = len(api._OP_CACHE)
    build().collect()  # rebuilt pipeline over the same DDFs: full cache hit
    assert len(api._OP_CACHE) == n_ops
