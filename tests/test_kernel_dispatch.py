"""Kernel dispatch registry + Pallas/jnp parity (ISSUE 5).

Three layers of coverage:

1. **registry/cost-model units** — ``set_backend`` validation and restore,
   ``resolve`` honoring the ``kernel_params`` thresholds / dtype gates /
   native flag, cache keys separating backends;
2. **kernel parity properties** — pallas(interpret) == jnp bit-exactness
   for ``hash_partition`` and ``segment_reduce`` across dtypes
   (int32/int64-folded/float32), uneven segment runs, empty and
   all-invalid tables. Float test values are integer-valued so sums are
   exact under any association (the kernel's partials tree reassociates
   float addition; see docs/KERNELS.md) — min/max and all integer ops are
   exact for arbitrary values;
3. **end-to-end equivalence** — groupby/join/shuffle results bit-identical
   between ``set_backend("pallas")`` (interpret on CPU) and
   ``set_backend("jnp")`` across the eager and lazy layers (the streaming
   layer is covered on 8 devices by the CI kernel smoke leg).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import kernels
from repro.core import DDF, DDFContext, cost_model
from repro.core.dataframe import Table, from_numpy as table_from_numpy
from repro.core.local_ops import local_groupby
from repro.core.partition import hash_partition_ids, u32_normalize
from repro.expr import col
from repro.kernels import ops, ref, registry


@pytest.fixture(autouse=True)
def _restore_backend():
    prev = registry.get_backend()
    yield
    registry.set_backend(prev)


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


# -- registry / cost model units ------------------------------------------------

def test_set_backend_validates_and_restores():
    prev = registry.set_backend("pallas")
    assert registry.get_backend() == "pallas"
    with pytest.raises(ValueError):
        registry.set_backend("cuda")
    assert registry.get_backend() == "pallas"
    with registry.use_backend("jnp"):
        assert registry.get_backend() == "jnp"
    assert registry.get_backend() == "pallas"
    registry.set_backend(prev)


def test_resolve_modes_per_backend():
    params = registry.current_params()
    registry.set_backend("jnp")
    assert registry.resolve("hash_partition", 1 << 20) == "jnp"
    registry.set_backend("pallas")
    expected = "pallas" if params.native else "interpret"
    assert registry.resolve("hash_partition", 4) == expected
    # forced pallas still falls back to jnp for unsupported dtypes (the jnp
    # path IS the kernel semantics there, so parity holds trivially)
    assert registry.resolve("segment_reduce", 1 << 20, "float64") == "jnp"
    registry.set_backend("auto")
    decision = registry.resolve("hash_partition", 1 << 20)
    if params.native:
        assert decision == "pallas"
    else:
        assert decision == "jnp"  # interpret never profitable off-TPU


def test_kernel_params_thresholds_and_dtypes():
    kp = cost_model.kernel_params("tpu")
    assert kp.native
    assert kp.profitable("hash_partition", kp.min_rows["hash_partition"])
    assert not kp.profitable("hash_partition",
                             kp.min_rows["hash_partition"] - 1)
    assert not kp.profitable("segment_reduce", 1 << 30, "float64")
    assert kp.dtype_supported("segment_reduce", jnp.int32)
    assert kp.dtype_supported("hash_partition", "float64")  # unrestricted
    host = cost_model.kernel_params("cpu")
    assert not host.native
    assert not host.profitable("hash_partition", 1 << 30)


def test_explain_matches_resolve():
    registry.set_backend("auto")
    e = registry.explain("segment_reduce", 1024, jnp.int32)
    assert e["decision"] == registry.resolve("segment_reduce", 1024, jnp.int32)
    assert e["min_rows"] == registry.current_params().min_rows["segment_reduce"]


def test_backend_flip_retraces_not_aliases(ctx):
    """Flipping set_backend must compile a distinct cache entry — the
    dispatch signature is part of the key, so a program traced under one
    backend never serves the other. Asserted via the cache's miss counter
    (entry count is not monotone: a full LRU evicts on insert)."""
    from repro.core.api import _OP_CACHE

    rng = np.random.default_rng(0)
    d = DDF.from_numpy({"k": rng.integers(0, 9, 64).astype(np.int32),
                        "v": rng.integers(0, 99, 64).astype(np.int32)}, ctx)
    registry.set_backend("jnp")
    d.groupby(("k",), {"v": ("sum",)}, pre_combine=True)
    n_miss = _OP_CACHE.stats()["misses"]
    registry.set_backend("pallas")
    d.groupby(("k",), {"v": ("sum",)}, pre_combine=True)
    assert _OP_CACHE.stats()["misses"] > n_miss  # retraced, not aliased
    n_miss = _OP_CACHE.stats()["misses"]
    d.groupby(("k",), {"v": ("sum",)}, pre_combine=True)
    assert _OP_CACHE.stats()["misses"] == n_miss  # same backend: cache hit


# -- kernel parity: hash_partition ---------------------------------------------

def _hash_parity(keys_np, P):
    keys = jnp.asarray(keys_np)
    if keys.ndim == 1:
        keys = keys[:, None]
    ku = jnp.stack([u32_normalize(keys[:, c]) for c in range(keys.shape[1])],
                   axis=1)
    dest_i, hist_i = ops.hash_partition(ku, P, force="interpret")
    dest_j, hist_j = ref.hash_partition_ref(ku, P)
    assert jnp.array_equal(dest_i, dest_j)
    assert jnp.array_equal(hist_i, hist_j)


@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.float32])
@pytest.mark.parametrize("n", [1, 7, 1024, 1500])
@pytest.mark.parametrize("P", [2, 8, 64])
def test_hash_partition_parity_sweep(n, P, dtype):
    rng = np.random.default_rng(42)
    if dtype == np.float32:
        keys = rng.normal(size=(n, 2)).astype(np.float32)
    else:
        keys = rng.integers(0, 1 << 31, size=(n, 2)).astype(dtype)
    _hash_parity(keys, P)


def test_hash_partition_dest_only_variant():
    """with_hist=False (the hash_partition_ids shape) returns identical
    destinations and no histogram."""
    rng = np.random.default_rng(9)
    keys = jnp.asarray(rng.integers(0, 1 << 31, size=(1300, 2)).astype(np.uint32))
    d_full, h_full = ops.hash_partition(keys, 16, force="interpret")
    d_only, h_none = ops.hash_partition(keys, 16, force="interpret",
                                        with_hist=False)
    assert h_none is None
    assert jnp.array_equal(d_full, d_only)
    assert int(h_full.sum()) == 1300


def test_hash_partition_parity_int64_folding():
    """64-bit keys fold hi^lo in u32_normalize before either path."""
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(7)
        keys = rng.integers(-(1 << 62), 1 << 62, size=(512,)).astype(np.int64)
        _hash_parity(keys, 16)


def test_hash_partition_ids_backend_parity():
    """The engine entry point (invalid rows -> drop bucket) is identical
    under both backends, including the forced-interpret one."""
    rng = np.random.default_rng(3)
    t = table_from_numpy({"a": rng.integers(0, 1 << 30, 700).astype(np.int32),
                          "b": rng.normal(size=700).astype(np.float32)},
                         capacity=1000)
    registry.set_backend("jnp")
    dj = hash_partition_ids(t, ["a", "b"], 8)
    registry.set_backend("pallas")
    dp = hash_partition_ids(t, ["a", "b"], 8)
    assert jnp.array_equal(dj, dp)
    assert int(jnp.sum(dp == 8)) == 300  # invalid tail in the drop bucket


# -- kernel parity: segment_reduce ----------------------------------------------

def _seg_parity(vals_np, seg_np, nseg, op):
    vals = jnp.asarray(vals_np)
    seg = jnp.asarray(seg_np, dtype=jnp.int32)
    got = ops.segment_reduce(vals, seg, nseg, op=op, force="interpret")
    exp = ref.segment_reduce_ref(vals, seg, nseg, op=op)
    assert got.dtype == exp.dtype
    # compare only segments that contain rows: empty-segment defaults are
    # backend identities (never observed by local_groupby, which compacts
    # to the live group count)
    present = np.zeros(nseg, bool)
    present[np.asarray(seg_np)[np.asarray(seg_np) < nseg]] = True
    assert np.array_equal(np.asarray(got)[present], np.asarray(exp)[present])


@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
@pytest.mark.parametrize("n,nseg", [(1, 1), (255, 3), (1024, 100), (1300, 7)])
def test_segment_reduce_parity_sweep(n, nseg, op, dtype):
    rng = np.random.default_rng(11)
    # integer-valued floats: exact under any summation order
    vals = rng.integers(-1000, 1000, size=(n, 2)).astype(dtype)
    seg = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    _seg_parity(vals, seg, nseg, op)


@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_segment_reduce_parity_int_overflow_wraps_identically(op):
    """int32 sums that overflow wrap the same way on both paths."""
    rng = np.random.default_rng(13)
    vals = rng.integers(1 << 30, (1 << 31) - 1, size=(512, 1)).astype(np.int32)
    seg = np.sort(rng.integers(0, 4, 512)).astype(np.int32)
    _seg_parity(vals, seg, 4, op)


def test_local_groupby_parity_empty_and_all_invalid():
    """Empty tables and tables whose rows are all invalid produce identical
    groupby output under both backends."""
    for nvalid in (0, 5):
        cols = {"k": jnp.zeros((600,), jnp.int32).at[:5].set(
                    jnp.arange(5, dtype=jnp.int32)),
                "v": jnp.ones((600,), jnp.int32)}
        t = Table(cols, jnp.asarray(nvalid, jnp.int32))
        outs = {}
        for b in ("jnp", "pallas"):
            registry.set_backend(b)
            g = local_groupby(t, ["k"], {"v": ("sum", "min", "max", "count")})
            n = int(g.nvalid)
            outs[b] = {k: np.asarray(v)[:n] for k, v in g.columns.items()}
        assert int(outs["jnp"]["k"].shape[0]) == nvalid
        for k in outs["jnp"]:
            assert np.array_equal(outs["jnp"][k], outs["pallas"][k]), k


def _groupby_parity_case(keys, vals):
    n = len(keys)
    t = table_from_numpy({"k": keys, "v": vals}, capacity=max(n, 1))
    outs = {}
    for b in ("jnp", "pallas"):
        registry.set_backend(b)
        g = local_groupby(t, ["k"], {"v": ("sum", "min", "max", "count")})
        nv = int(g.nvalid)
        outs[b] = {k: np.asarray(v)[:nv] for k, v in g.columns.items()}
    for k in outs["jnp"]:
        assert outs["jnp"][k].dtype == outs["pallas"][k].dtype
        assert np.array_equal(outs["jnp"][k], outs["pallas"][k]), k


def test_local_groupby_parity_seeded():
    rng = np.random.default_rng(17)
    for n in (1, 3, 257, 1024, 2000):
        for card in (1, 2, max(n // 3, 1)):
            keys = rng.integers(0, card, n).astype(np.int32)
            vals = rng.integers(-(1 << 20), 1 << 20, n).astype(np.int32)
            _groupby_parity_case(keys, vals)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=700),
        card=st.integers(min_value=1, max_value=64),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        as_float=st.booleans(),
    )
    def test_local_groupby_parity_property(n, card, seed, as_float):
        rng = np.random.default_rng(seed)
        keys = rng.integers(0, card, n).astype(np.int32)
        vals = rng.integers(-(1 << 16), 1 << 16, n)
        vals = vals.astype(np.float32 if as_float else np.int32)
        _groupby_parity_case(keys, vals)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=900),
        P=st.sampled_from([2, 5, 8, 32]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hash_partition_parity_property(n, P, seed):
        rng = np.random.default_rng(seed)
        keys = rng.integers(0, 1 << 31, size=(n, 1)).astype(np.int32)
        _hash_parity(keys, P)


# -- end-to-end equivalence across layers ---------------------------------------

def _pipeline_outputs(ctx):
    rng = np.random.default_rng(23)
    n = 3000
    d1 = DDF.from_numpy({"k": rng.integers(0, 40, n).astype(np.int32),
                         "v": rng.integers(-500, 500, n).astype(np.int32)},
                        ctx)
    d2 = DDF.from_numpy({"k": np.arange(40, dtype=np.int32),
                         "w": rng.integers(0, 50, 40).astype(np.int32)}, ctx)
    g, _ = d1.groupby(("k",), {"v": ("sum", "min", "max", "count")})
    j, _ = d1.join(d2, on=("k",), strategy="shuffle")
    u, _ = d1.unique(("k",))
    lz = (d1.lazy().select(col("v") > -400)
          .join(d2.lazy(), on=("k",), strategy="shuffle")
          .groupby(("k",), {"v": ("sum", "count"), "w": ("max",)}))
    return {"groupby": g.to_numpy(), "join": j.to_numpy(),
            "unique": u.to_numpy(), "lazy": lz.collect().to_numpy()}


def test_end_to_end_pallas_vs_jnp_bit_identical(ctx):
    registry.set_backend("jnp")
    base = _pipeline_outputs(ctx)
    registry.set_backend("pallas")
    forced = _pipeline_outputs(ctx)
    for op in base:
        for k in base[op]:
            assert base[op][k].dtype == forced[op][k].dtype
            assert np.array_equal(base[op][k], forced[op][k]), (op, k)
