"""Statistics subsystem (ISSUE 9): chunk sketches, selectivity-driven
planning, conservative chunk skipping, and adaptive mid-stream re-planning.

The load-bearing properties: skipping never drops a row that a full decode
would admit (skip-set is a subset of the truly-empty set); adaptive
re-planning is result-invariant (bit-identical to non-adaptive streaming
and to the reference aggregation) including across a checkpoint/resume
taken mid-correction; old manifests without sketches keep loading.
"""

import dataclasses
import json
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import expr as E
from repro import stream
from repro.core import DDFContext
from repro.core.patterns import quota_from_histogram, sampled_quota
from repro.data.dataset import (
    DatasetManifest,
    DatasetWriter,
    csv_to_dataset,
    open_dataset,
    read_chunk,
    write_dataset,
)
from repro.stats import (
    AdaptiveController,
    ChunkStats,
    DEFAULT_KMV_K,
    PlanStats,
    backfill_stats,
    chunk_skip_mask,
    expr_interval,
    hash32,
    key_cardinality,
    merge_chunk_stats,
    plan_stats,
    predicate_selectivity,
    scan_row_estimate,
)
from repro.stats.estimate import Interval
from repro.testing import FaultPlan, InjectedFault, fault_scope


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def _canon(host):
    order = np.lexsort(tuple(host[k] for k in sorted(host)))
    return {k: v[order] for k, v in host.items()}


# -- sketches ------------------------------------------------------------------

def test_kmv_exact_below_k():
    vals = np.arange(100, dtype=np.int64)  # 100 distinct < k=128
    cs = ChunkStats.from_columns({"a": vals})
    col = cs.column("a")
    assert col.distinct() == 100
    assert col.min == 0 and col.max == 99


def test_kmv_accuracy_large():
    rng = np.random.default_rng(7)
    vals = rng.integers(0, 5000, 100_000)
    cs = ChunkStats.from_columns({"a": vals})
    true = len(np.unique(vals))
    est = cs.column("a").distinct()
    assert abs(est - true) / true < 0.25  # ~1/sqrt(128) ≈ 0.09 expected


def test_sketch_merge_equals_concat():
    rng = np.random.default_rng(3)
    a = {"x": rng.integers(0, 900, 4000), "y": rng.standard_normal(4000)}
    b = {"x": rng.integers(400, 1500, 3000), "y": rng.standard_normal(3000)}
    both = {k: np.concatenate([a[k], b[k]]) for k in a}
    merged = merge_chunk_stats(
        [ChunkStats.from_columns(a), ChunkStats.from_columns(b)])
    whole = ChunkStats.from_columns(both)
    assert merged.count == whole.count == 7000
    for name in ("x", "y"):
        m, w = merged.column(name), whole.column(name)
        assert m.min == w.min and m.max == w.max
        assert m.distinct() == w.distinct()  # KMV union == sketch-of-union


def test_sketch_json_roundtrip():
    cs = ChunkStats.from_columns(
        {"a": np.array([3, 1, 4, 1, 5]), "b": np.array([0.5, -2.0])})
    again = ChunkStats.from_json(json.loads(json.dumps(cs.to_json())))
    assert again == cs


def test_hash32_matches_runner_mirror():
    from repro.stream.runner import _np_hash32
    vals = np.arange(1000, dtype=np.int64) * 2654435761
    assert np.array_equal(hash32(vals), _np_hash32(vals))


# -- manifest persistence ------------------------------------------------------

def test_manifest_stats_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    data = {"a": rng.integers(0, 100, 777).astype(np.int32)}
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=200)
    assert man.stats is not None and len(man.stats) == len(man.chunks)
    again = open_dataset(str(tmp_path / "ds"))
    assert again.stats == man.stats
    assert again.stats_k == man.stats_k


def test_old_manifest_without_stats_loads(tmp_path):
    data = {"a": np.arange(100, dtype=np.int32)}
    write_dataset(data, str(tmp_path / "ds"), chunk_rows=50)
    path = str(tmp_path / "ds" / "manifest.json")
    with open(path) as f:
        payload = json.load(f)
    del payload["stats"]  # simulate a pre-ISSUE-9 manifest
    with open(path, "w") as f:
        json.dump(payload, f)
    man = open_dataset(str(tmp_path / "ds"))
    assert man.stats is None
    assert man.num_rows == 100  # everything else intact
    # unknown future stats_version is ignored, not fatal
    payload["stats"] = {"stats_version": 999, "k": 4, "chunks": []}
    with open(path, "w") as f:
        json.dump(payload, f)
    assert open_dataset(str(tmp_path / "ds")).stats is None


def test_writer_stats_flag(tmp_path):
    data = {"a": np.arange(300, dtype=np.int32)}
    w = DatasetWriter(str(tmp_path / "off"), chunk_rows=100, stats=False)
    w.append(data)
    assert w.close().stats is None
    w2 = DatasetWriter(str(tmp_path / "on"), chunk_rows=100)
    w2.append(data)
    man = w2.close()
    assert man.stats is not None
    assert [cs.count for cs in man.stats] == [100, 100, 100]


def test_csv_to_dataset_has_stats(tmp_path):
    import csv as _csv
    path = str(tmp_path / "in.csv")
    with open(path, "w", newline="") as f:
        wr = _csv.writer(f)
        wr.writerow(["a", "b"])
        for i in range(50):
            wr.writerow([i, i * 0.5])
    man = csv_to_dataset([path], {"a": np.int32, "b": np.float32},
                         str(tmp_path / "ds"), chunk_rows=20)
    assert man.stats is not None and len(man.stats) == 3
    assert man.stats[0].column("a").min == 0


def test_backfill_matches_write_time(tmp_path):
    rng = np.random.default_rng(5)
    data = {"a": rng.integers(0, 500, 640).astype(np.int64)}
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=128)
    ref = man.stats
    # strip stats on disk, then backfill
    stripped = dataclasses.replace(man, stats=None)
    stripped.save()
    assert open_dataset(str(tmp_path / "ds")).stats is None
    back = backfill_stats(str(tmp_path / "ds"))
    assert back.stats == ref  # identical to write-time sketching
    # idempotent without force; script entry point agrees
    assert backfill_stats(str(tmp_path / "ds")).stats == ref


# -- interval arithmetic / estimation ------------------------------------------

def test_expr_interval_basics():
    r = {"a": Interval(0.0, 10.0), "b": Interval(-5.0, 5.0)}
    assert expr_interval(E.col("a") + E.col("b"), r) == Interval(-5.0, 15.0)
    iv = expr_interval(E.col("a") > 20, r)
    assert (iv.lo, iv.hi, iv.boolish) == (0.0, 0.0, True)   # certainly false
    iv = expr_interval(E.col("a") >= 0, r)
    assert (iv.lo, iv.hi) == (1.0, 1.0)                     # certainly true
    # sound short-circuit: False AND unknown is still certainly false
    iv = expr_interval((E.col("a") > 20) & (E.col("c") > 0), r)
    assert (iv.lo, iv.hi) == (0.0, 0.0)
    assert expr_interval(E.col("c") * 2, r) is None          # unknown column


def test_chunk_skip_mask_never_skips_matching(tmp_path):
    """Seeded sweep: a skipped chunk must contain zero passing rows."""
    rng = np.random.default_rng(11)
    preds = [E.col("a") > 800, E.col("a") <= 10, (E.col("a") >= 100) & (E.col("b") < 50),
             E.col("b") == 999, (E.col("a") + E.col("b")) > 1500]
    for trial in range(5):
        data = {"a": np.sort(rng.integers(0, 1000, 2000)).astype(np.int64),
                "b": rng.integers(0, 1000, 2000).astype(np.int64)}
        man = write_dataset(data, str(tmp_path / f"ds{trial}"), chunk_rows=250)
        for pred in preds:
            fn = E.to_numpy_fn(pred)
            mask = chunk_skip_mask(man, (pred,))
            assert mask.shape == (len(man.chunks),)
            for i, skip in enumerate(mask):
                if skip:
                    chunk = read_chunk(man, i)
                    assert not np.asarray(fn(chunk)).any(), \
                        f"skipped chunk {i} has matching rows for {pred}"


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(-50, 1050),
           st.sampled_from(["gt", "lt", "ge", "le", "eq", "ne"]))
    def test_skip_mask_property(seed, threshold, op):
        """Property: skip-set ⊆ true-empty-set, any data, any threshold."""
        import tempfile
        rng = np.random.default_rng(seed)
        data = {"a": rng.integers(0, 1000, 600).astype(np.int64)}
        ops = {"gt": lambda c, v: c > v, "lt": lambda c, v: c < v,
               "ge": lambda c, v: c >= v, "le": lambda c, v: c <= v,
               "eq": lambda c, v: c == v, "ne": lambda c, v: c != v}
        pred = ops[op](E.col("a"), int(threshold))
        fn = E.to_numpy_fn(pred)
        with tempfile.TemporaryDirectory() as d:
            man = write_dataset(data, d, chunk_rows=97)
            mask = chunk_skip_mask(man, (pred,))
            for i, skip in enumerate(mask):
                if skip:
                    assert not np.asarray(fn(read_chunk(man, i))).any()


def test_selectivity_and_cardinality_estimates(tmp_path):
    rng = np.random.default_rng(2)
    data = {"a": np.arange(10_000, dtype=np.int32),
            "k": rng.integers(0, 40, 10_000).astype(np.int32)}
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=1000)
    merged = merge_chunk_stats(man.stats)
    sel = predicate_selectivity(E.col("a") >= 9000, merged, man.schema)
    assert 0.05 < sel < 0.2  # true 0.1
    card = key_cardinality(man, ("k",))
    assert card is not None and abs(card - 40 / 10_000) / (40 / 10_000) < 0.5
    est = scan_row_estimate(man, _scan_of(
        stream.scan_dataset(man, _ctx8(), predicate=E.col("a") >= 9000)))
    assert est is not None and 500 <= est <= 2000  # true 1000


def _ctx8():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def _scan_of(lazy):
    from repro.plan.logical import Scan, walk
    return next(n for n in walk(lazy._root) if isinstance(n, Scan))


def test_plan_stats_cache_key_stable(tmp_path):
    data = {"a": np.arange(100, dtype=np.int32)}
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=50)
    lazy = stream.scan_dataset(man, _ctx8())
    ps1 = plan_stats(lazy._scans)
    ps2 = plan_stats(lazy._scans)
    assert isinstance(ps1, PlanStats)
    assert ps1.cache_key == ps2.cache_key
    assert plan_stats({1: dataclasses.replace(man, stats=None)}) is None


# -- end-to-end: skipping, explain, admission ----------------------------------

def test_stream_chunk_skipping_bit_identical(ctx, tmp_path):
    rng = np.random.default_rng(0)
    n = 4000
    data = {"a": np.arange(n, dtype=np.int32),
            "v": rng.standard_normal(n).astype(np.float32)}
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=500)
    q = stream.scan_dataset(man, ctx, batch_rows=1000,
                            predicate=E.col("a") >= 3500)
    out = q.collect_stream().to_numpy()
    info = q.last_info
    assert info["chunks_skipped"] > 0
    assert info["chunks_decoded"] < len(man.chunks)
    # identical to the stats-less (decode-everything) run
    q2 = stream.scan_dataset(dataclasses.replace(man, stats=None), ctx,
                             batch_rows=1000, predicate=E.col("a") >= 3500)
    ref = q2.collect_stream().to_numpy()
    assert q2.last_info["chunks_skipped"] == 0
    for c in ref:
        assert np.array_equal(out[c], ref[c])


def test_explain_shows_estimated_selectivity(ctx, tmp_path):
    data = {"a": np.arange(2000, dtype=np.int32)}
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=500)
    q = stream.scan_dataset(man, ctx, predicate=E.col("a") >= 1900)
    txt = q.explain()
    assert "sel~" in txt and "fixed" in txt
    # stats never leak into the process-stable plan identity
    from repro.plan.logical import plan_signature
    assert "sel~" not in plan_signature(q._root)
    # without sketches the annotation disappears
    q2 = stream.scan_dataset(dataclasses.replace(man, stats=None), ctx,
                             predicate=E.col("a") >= 1900)
    assert "sel~" not in q2.explain()


def test_admission_estimate_tighter_with_stats(ctx, tmp_path):
    from repro.service.admission import estimate_query_bytes
    data = {"a": np.arange(50_000, dtype=np.int32)}
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=5000)
    # highly selective scan: sketches prove ~50 surviving rows
    sel = stream.scan_dataset(man, ctx, predicate=E.col("a") >= 49_950)
    legacy = stream.scan_dataset(dataclasses.replace(man, stats=None), ctx,
                                 predicate=E.col("a") >= 49_950)
    with_stats = estimate_query_bytes(sel)
    without = estimate_query_bytes(legacy)
    assert with_stats < without  # row-count evidence tightens the reserve
    assert with_stats > 0


# -- adaptive re-planning ------------------------------------------------------

def _skewed_ds(tmp_path, n=6000, seed=0):
    """First half uniform keys, second half one hot key: the static quota
    derived from uniform assumptions drifts badly once the hot key
    dominates the shuffle histogram."""
    rng = np.random.default_rng(seed)
    k = np.concatenate([rng.integers(0, 300, n // 2),
                        np.full(n - n // 2, 7)]).astype(np.int64)
    v = rng.integers(0, 100, n).astype(np.int64)
    return write_dataset({"k": k, "v": v}, str(tmp_path / "skewed"),
                         chunk_rows=500)


def _gq(man, ctx):
    return stream.scan_dataset(man, ctx, batch_rows=750) \
        .groupby(("k",), {"v": ("sum", "count")})


def test_adaptive_bit_identical_and_replans(ctx, tmp_path):
    man = _skewed_ds(tmp_path)
    base = _canon(_gq(man, ctx).collect_stream().to_numpy())
    qa = _gq(man, ctx)
    adpt = _canon(qa.collect_stream(adaptive=True, replan_every=2).to_numpy())
    if jax.device_count() > 1:
        # At P=1 the static quota is already clamped to capacity and the
        # histogram-implied quota clamps to the same value, so zero replans
        # is the correct decision; skew only drifts the quota across >1
        # partitions (the 8-device CI legs exercise the replan itself).
        assert qa.last_info.get("replans", 0) >= 1
    assert set(base) == set(adpt)
    for c in base:
        assert np.array_equal(base[c], adpt[c])
    # matches the eager (non-streaming) engine exactly
    from repro.core import DDF
    from repro.data.dataset import read_rows
    host = read_rows(man, 0, man.num_rows)
    ref = _canon(DDF.from_numpy(host, ctx)
                 .groupby(("k",), {"v": ("sum", "count")})[0].to_numpy())
    for c in ref:
        assert np.array_equal(ref[c], adpt[c]), c


def test_adaptive_checkpoint_resume_mid_correction(ctx, tmp_path):
    man = _skewed_ds(tmp_path, seed=3)
    base = _canon(_gq(man, ctx).collect_stream().to_numpy())
    ck = str(tmp_path / "ck")
    plan = FaultPlan(seed=0, kill_after={"device_op": 5})
    with fault_scope(plan):
        with pytest.raises(InjectedFault):
            _gq(man, ctx).collect_stream(adaptive=True, replan_every=2,
                                         checkpoint_dir=ck,
                                         checkpoint_every=1)
    # the snapshot carries the controller's decision state
    ckpt = stream.StreamCheckpoint(ck)
    manifest, _ = ckpt.load()
    assert _find_adaptive(manifest) is not None
    qr = _gq(man, ctx)
    res = _canon(qr.collect_stream(adaptive=True, replan_every=2,
                                   checkpoint_dir=ck, resume=True).to_numpy())
    for c in base:
        assert np.array_equal(base[c], res[c])


def _find_adaptive(obj):
    """Locate the serialized AdaptiveController state in a snapshot."""
    if isinstance(obj, dict):
        if "adaptive" in obj and isinstance(obj["adaptive"], dict):
            return obj["adaptive"]
        for v in obj.values():
            found = _find_adaptive(v)
            if found is not None:
                return found
    elif isinstance(obj, list):
        for v in obj:
            found = _find_adaptive(v)
            if found is not None:
                return found
    return None


def test_adaptive_controller_state_roundtrip():
    c = AdaptiveController(8, plan_quota=100, plan_capacity=1000)
    c.observe(500, hist=np.array([10, 200, 30, 5, 0, 0, 0, 0]),
              groups_out=240, max_worker_groups=80)
    c.observe(500, hist=np.array([400, 0, 0, 0, 0, 0, 0, 0]),
              groups_out=10, max_worker_groups=10)
    r = AdaptiveController.restore(c.state_dict())
    assert r.state_dict() == c.state_dict()
    assert r.current_quota == c.current_quota
    assert r.should_replan() == c.should_replan()


def test_quota_from_histogram_matches_sampled_quota():
    rng = np.random.default_rng(4)
    dest = (hash32(rng.integers(0, 1000, 5000)) % 8).astype(np.int64)
    hist = np.bincount(dest, minlength=8)
    assert quota_from_histogram(hist, 4096, 8) == \
        sampled_quota(dest, 4096, 8, sample_fraction=1.0)
    # empty histogram falls back to the static default, never 0
    assert quota_from_histogram(np.zeros(8, np.int64), 4096, 8) > 0


def test_kernel_partition_histogram_matches_host():
    import jax.numpy as jnp
    from repro.kernels import partition_histogram
    from repro.stream.runner import _np_hash_columns
    rng = np.random.default_rng(6)
    keys = rng.integers(0, 10_000, 2048).astype(np.int64)
    host = {"k": keys}
    expect = np.bincount(_np_hash_columns(host, ("k",)) % np.uint32(8),
                         minlength=8)
    from repro.core.partition import u32_normalize
    ku = np.asarray(u32_normalize(jnp.asarray(keys)))
    hist = np.asarray(partition_histogram(jnp.asarray(ku), 8))
    assert np.array_equal(hist, expect)
