"""Satellite CI check: every exported core symbol has a docstring and the
pattern docs cover the full registry (scripts/check_docs.py)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))

import check_docs


def test_core_exports_have_docstrings():
    assert check_docs.missing_docstrings() == []


def test_docs_cover_every_pattern():
    assert check_docs.missing_pattern_docs() == []
