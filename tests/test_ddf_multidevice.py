"""Re-runs the full DDF smoke suite on 8 host devices (real collectives) in
a subprocess, keeping this pytest process at 1 device (task spec)."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_ddf_smoke_on_8_devices():
    script = os.path.join(os.path.dirname(__file__), "..", "scripts", "smoke_ddf.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, script, "--devices", "8"],
        capture_output=True, text=True, timeout=900, env=env)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "ALL DDF SMOKE TESTS PASSED" in res.stdout
