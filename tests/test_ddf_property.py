"""Property-based tests (hypothesis) for DDF invariants."""

import collections

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import DDF, DDFContext
from repro.core.cost_model import (
    CostParams, choose_groupby_strategy, choose_join_strategy,
    choose_shuffle_algorithm, pattern_cost, t_allreduce, t_shuffle,
)


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


_small_tables = st.integers(2, 120).flatmap(
    lambda n: st.tuples(
        st.lists(st.integers(0, 30), min_size=n, max_size=n),
        st.lists(st.integers(-1000, 1000), min_size=n, max_size=n),
    ))


@settings(max_examples=12, deadline=None)
@given(_small_tables)
def test_groupby_sum_matches_oracle(ctx, data):
    keys, vals = data
    L = {"k": np.asarray(keys, np.int32), "v": np.asarray(vals, np.int32)}
    d = DDF.from_numpy(L, ctx, capacity=2 * len(keys))
    G, _ = d.groupby(("k",), {"v": ("sum",)}, pre_combine=True)
    gg = G.to_numpy()
    exp = collections.Counter()
    for k, v in zip(keys, vals):
        exp[k] += v
    got = dict(zip(gg["k"].tolist(), gg["v_sum"].tolist()))
    assert got == dict(exp)


@settings(max_examples=12, deadline=None)
@given(_small_tables)
def test_sort_is_permutation_and_ordered(ctx, data):
    keys, vals = data
    L = {"k": np.asarray(keys, np.int32), "v": np.asarray(vals, np.int32)}
    d = DDF.from_numpy(L, ctx, capacity=2 * len(keys))
    S, _ = d.sort_values("v")
    out = S.to_numpy()["v"]
    assert np.array_equal(out, np.sort(L["v"]))


@settings(max_examples=12, deadline=None)
@given(_small_tables)
def test_unique_is_set(ctx, data):
    keys, _ = data
    L = {"k": np.asarray(keys, np.int32)}
    d = DDF.from_numpy(L, ctx, capacity=2 * len(keys))
    U, _ = d.unique(("k",))
    assert sorted(U.to_numpy()["k"].tolist()) == sorted(set(keys))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 4096), st.floats(1.0, 1e9), st.floats(1e-9, 1e-3))
def test_shuffle_cost_monotone_in_bytes(P, n_bytes, beta):
    p = CostParams()
    t1 = sum(t_shuffle(P, n_bytes, p))
    t2 = sum(t_shuffle(P, 2 * n_bytes, p))
    assert t2 >= t1


@settings(max_examples=20, deadline=None)
@given(st.floats(0.0, 1.0))
def test_groupby_strategy_crossover(C):
    """Low cardinality -> combine-shuffle-reduce; high -> plain shuffle
    (paper §5.4.1)."""
    pre = choose_groupby_strategy(C)
    assert pre == (C < 0.5)


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 1024), st.integers(100_000, 10_000_000))
def test_join_strategy_small_side_broadcast(P, n_big):
    """A tiny relation must broadcast; relations too large to replicate must
    shuffle regardless of comm cost (paper §5.3.7: Modin's broadcast-only
    joins OOM on same-order relations — a memory failure)."""
    s_small = choose_join_strategy(n_big, max(n_big // 10000, 1), P, 16.0)
    assert s_small == "broadcast"
    # memory guard: replicating >256MB/worker is rejected outright
    s_huge = choose_join_strategy(1e9, 1e9, P, 16.0)
    assert s_huge == "shuffle"
    # and transfer-dominated equal-size relations shuffle on cost too
    s_equal = choose_join_strategy(n_big, n_big, 8, 16.0)
    if n_big / 8 * 16.0 > 1e6:
        assert s_equal == "shuffle"


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8192))
def test_bruck_wins_at_latency_bound(P):
    """Tiny messages, many workers -> Bruck (log P startup); paper §6.1.1."""
    alg = choose_shuffle_algorithm(P, n_bytes=64.0)
    if P >= 64:
        assert alg == "bruck"


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 512), st.floats(0.001, 1.0))
def test_combine_shuffle_reduce_beats_shuffle_at_low_C(P, C):
    lo = pattern_cost("combine_shuffle_reduce", P=P, n_rows=1e6, row_bytes=16,
                      cardinality=C, core_op="groupby")
    hi = pattern_cost("shuffle_compute", P=P, n_rows=1e6, row_bytes=16,
                      cardinality=C, core_op="groupby")
    if C < 0.05:
        assert lo["comm"] < hi["comm"]  # combine shrinks the shuffle payload


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 512), st.floats(0.05, 1.0))
def test_sampled_quota_covers_skew(P, frac):
    """Quota planned from a sampled destination histogram must cover the
    true per-destination maximum for the sampled distribution (paper §5.4.2)."""
    import numpy as np
    from repro.core.patterns import sampled_quota
    rng = np.random.default_rng(P)
    n = 4000
    dest = rng.zipf(1.4, size=n).astype(np.int64) % P  # skewed destinations
    k = max(int(n * frac), 1)
    sample = dest[rng.choice(n, size=k, replace=False)]
    q = sampled_quota(sample.astype(np.int32), capacity=n, num_partitions=P,
                      sample_fraction=frac, safety=2.0)
    true_max = np.bincount(dest, minlength=P).max()
    # full-sample plans always cover; sub-samples cover within safety slack
    if frac >= 0.99:
        assert q >= true_max
    assert q <= n
