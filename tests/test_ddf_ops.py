"""DDF operator correctness vs numpy oracles (single device; the same suite
re-runs on 8 host devices via test_ddf_multidevice.py)."""

import collections

import jax
import numpy as np
import pytest

from repro.core import DDF, DDFContext


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


@pytest.fixture(scope="module")
def tables(ctx):
    rng = np.random.default_rng(42)
    n = 600
    L = {"k": rng.integers(0, 500, n).astype(np.int32),
         "v": rng.integers(0, 1000, n).astype(np.int32)}
    R = {"k": rng.integers(0, 500, n).astype(np.int32),
         "w": rng.integers(0, 1000, n).astype(np.int32)}
    return (DDF.from_numpy(L, ctx, capacity=2 * n),
            DDF.from_numpy(R, ctx, capacity=2 * n), L, R)


def _join_oracle(L, R):
    ridx = collections.defaultdict(list)
    for i, k in enumerate(R["k"]):
        ridx[int(k)].append(i)
    out = []
    for i, k in enumerate(L["k"]):
        for j in ridx.get(int(k), []):
            out.append((int(k), int(L["v"][i]), int(R["w"][j])))
    return sorted(out)


def test_join_shuffle(tables):
    dl, dr, L, R = tables
    J, info = dl.join(dr, on=("k",), strategy="shuffle", capacity=8 * 600)
    got = J.to_numpy()
    assert int(np.asarray(info["overflow_join"]).sum()) == 0
    assert sorted(zip(got["k"], got["v"], got["w"])) == _join_oracle(L, R)


def test_join_broadcast(tables):
    dl, dr, L, R = tables
    J, _ = dl.join(dr, on=("k",), strategy="broadcast", capacity=8 * 600)
    got = J.to_numpy()
    assert sorted(zip(got["k"], got["v"], got["w"])) == _join_oracle(L, R)


def test_join_auto_picks_broadcast_for_small_side(ctx):
    rng = np.random.default_rng(0)
    big = DDF.from_numpy({"k": rng.integers(0, 50, 5000).astype(np.int32)}, ctx)
    small = DDF.from_numpy({"k": np.arange(10, dtype=np.int32),
                            "w": np.arange(10, dtype=np.int32)}, ctx)
    from repro.core.patterns import plan_join
    plan = plan_join(big.num_rows(), small.num_rows(), 64, big.capacity)
    assert plan.strategy == "broadcast"


def test_groupby_both_strategies(tables):
    dl, _, L, _ = tables
    exp_sum = collections.Counter()
    exp_cnt = collections.Counter()
    for k, v in zip(L["k"], L["v"]):
        exp_sum[int(k)] += int(v)
        exp_cnt[int(k)] += 1
    for pre in (True, False):
        G, _ = dl.groupby(("k",), {"v": ("sum", "count")}, pre_combine=pre)
        gg = G.to_numpy()
        assert sorted(gg["k"]) == sorted(exp_sum)
        m = dict(zip(gg["k"].tolist(), gg["v_sum"].tolist()))
        assert all(m[k] == exp_sum[k] for k in exp_sum), f"pre_combine={pre}"
        c = dict(zip(gg["k"].tolist(), gg["v_count"].tolist()))
        assert all(c[k] == exp_cnt[k] for k in exp_cnt)


def test_sort_global_order(tables):
    dl, _, L, _ = tables
    S, info = dl.sort_values("v")
    assert int(np.asarray(info["overflow_shuffle"]).sum()) == 0
    assert np.array_equal(S.to_numpy()["v"], np.sort(L["v"]))


def test_sort_descending(tables):
    dl, _, L, _ = tables
    S, _ = dl.sort_values("v", descending=True)
    assert np.array_equal(S.to_numpy()["v"], np.sort(L["v"])[::-1])


def test_unique_union_difference(tables):
    dl, dr, L, R = tables
    U, _ = dl.unique(("k",))
    assert sorted(U.to_numpy()["k"]) == sorted(set(L["k"].tolist()))
    UN, _ = dl.project(["k"]).union(dr.project(["k"]), on=("k",))
    assert sorted(UN.to_numpy()["k"]) == sorted(set(L["k"]) | set(R["k"]))
    DF, _ = dl.project(["k"]).difference(dr.project(["k"]), on=("k",))
    assert sorted(DF.to_numpy()["k"]) == sorted(set(L["k"]) - set(R["k"]))


def test_column_agg_and_length(tables):
    dl, _, L, _ = tables
    assert int(dl.agg("v", "sum")) == int(L["v"].sum())
    assert int(dl.agg("v", "max")) == int(L["v"].max())
    assert abs(float(dl.agg("v", "mean")) - float(L["v"].mean())) < 1e-3
    assert dl.length() == len(L["v"])


def test_rolling_window(tables):
    dl, _, L, _ = tables
    W, info = dl.rolling_sum("v", window=7)
    assert not np.asarray(info["halo_short"]).any()
    ww = W.to_numpy()
    ref = np.convolve(L["v"].astype(np.float64), np.ones(7))[6: len(L["v"])]
    assert np.allclose(ww["v_rollsum"][ww["window_valid"]], ref)


def test_select_map_head_rebalance(tables):
    dl, _, L, _ = tables
    S = dl.select(lambda c: c["v"] % 2 == 0, name="even")
    assert sorted(S.to_numpy()["v"]) == sorted(L["v"][L["v"] % 2 == 0])
    M = dl.map_columns(lambda c: {**c, "v2": c["v"] * 2}, name="double")
    assert np.array_equal(M.to_numpy()["v2"], M.to_numpy()["v"] * 2)
    RB, _ = S.rebalance()
    cnts = np.asarray(RB.counts)
    assert cnts.max() - cnts.min() <= 1
    srt, _ = dl.sort_values("v")
    H = srt.head(5)
    assert np.array_equal(H.to_numpy()["v"], np.sort(L["v"])[:5])


def test_overflow_accounting(ctx):
    """Quota too small -> overflow counted, never wrong results silently."""
    rng = np.random.default_rng(1)
    n = 512
    # all rows share one key -> they all hash to one destination
    data = {"k": np.zeros(n, np.int32), "v": rng.integers(0, 9, n).astype(np.int32)}
    d = DDF.from_numpy(data, ctx, capacity=n)
    # pre_combine=False ships raw rows: every row hashes to ONE destination,
    # so quota 8 must overflow (the combine variant dedups first — that IS
    # the paper's point about Combine-Shuffle-Reduce)
    _, info = d.groupby(("k",), {"v": ("sum",)}, pre_combine=False, quota=8)
    assert int(np.asarray(info["overflow_shuffle"]).sum()) >= n - 8 * ctx.nworkers
    # and the combine variant needs no headroom at all
    _, info2 = d.groupby(("k",), {"v": ("sum",)}, pre_combine=True, quota=8)
    assert int(np.asarray(info2["overflow_shuffle"]).sum()) == 0
