"""Out-of-core streaming engine (ISSUE 3): chunked dataset format, SCAN
pushdown, morsel-driven execution with carry/spill finalization, distributed
I/O round-trips, and the read_csv_dist overflow regression."""

import jax
import numpy as np
import pytest

from repro.core import DDF, DDFContext
from repro.core.cost_model import CostParams, choose_batch_rows
from repro.data.dataset import (
    DatasetWriter,
    csv_to_dataset,
    open_dataset,
    read_chunk,
    read_rows,
    write_dataset,
)
from repro.data.io import read_csv_dist, write_csv_dist
from repro import stream


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def _table(n, nkeys=150, seed=0):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, nkeys, n).astype(np.int32),
            "v": rng.integers(0, 1000, n).astype(np.int32),
            "junk": rng.integers(0, 5, n).astype(np.int32)}


def _canon(host):
    order = np.lexsort(tuple(host[k] for k in sorted(host)))
    return {k: v[order] for k, v in host.items()}


# -- chunked dataset format ----------------------------------------------------

def test_dataset_roundtrip(tmp_path):
    data = _table(1111)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=256)
    assert man.num_rows == 1111
    assert len(man.chunks) == -(-1111 // 256)
    again = open_dataset(str(tmp_path / "ds"))
    assert again == man
    host = read_rows(man, 0, man.num_rows)
    for k in data:
        assert np.array_equal(host[k], data[k])
    # arbitrary row ranges, chunk-straddling
    part = read_rows(man, 200, 700)
    for k in data:
        assert np.array_equal(part[k], data[k][200:700])
    # projection decodes only requested columns
    proj = read_chunk(man, 0, columns=["v"])
    assert list(proj) == ["v"]
    with pytest.raises(KeyError):
        read_chunk(man, 0, columns=["nope"])


def test_dataset_writer_incremental(tmp_path):
    w = DatasetWriter(str(tmp_path / "ds"), chunk_rows=100)
    a, b = _table(130, seed=1), _table(45, seed=2)
    w.append(a)
    w.append(b)
    man = w.close()
    assert man.num_rows == 175
    assert [r for _, r in man.chunks] == [100, 75]
    host = read_rows(man, 0, 175)
    for k in a:
        assert np.array_equal(host[k], np.concatenate([a[k], b[k]]))
    with pytest.raises(ValueError):
        w.append(a)  # closed


def test_csv_to_dataset_and_schema_mismatch(tmp_path):
    import csv as _csv
    data = _table(300, seed=3)
    path = str(tmp_path / "in.csv")
    with open(path, "w", newline="") as f:
        wr = _csv.writer(f)
        wr.writerow(["k", "v", "junk"])
        for i in range(300):
            wr.writerow([data["k"][i], data["v"][i], data["junk"][i]])
    schema = {"k": np.int32, "v": np.int32, "junk": np.int32}
    man = csv_to_dataset([path], schema, str(tmp_path / "ds"), chunk_rows=64)
    host = read_rows(man, 0, man.num_rows)
    for k in data:
        assert np.array_equal(host[k], data[k])
    with pytest.raises(ValueError, match="schema mismatch"):
        csv_to_dataset([path], {"missing_col": np.int32},
                       str(tmp_path / "ds2"))


# -- batch sizing --------------------------------------------------------------

def test_choose_batch_rows_bounds():
    p = CostParams()
    # memory ceiling binds: huge rows ask, small budget
    r = choose_batch_rows(8, row_bytes=1000.0, p=p,
                          memory_budget_bytes=1e6, working_set_factor=4.0)
    assert r <= 8 * 1e6 / (1000.0 * 4.0)
    # amortization floor: cheap rows want big batches, memory permits
    r2 = choose_batch_rows(8, row_bytes=8.0, p=p, memory_budget_bytes=1e9)
    assert r2 > r
    # clamped to the dataset
    assert choose_batch_rows(8, 8.0, p, total_rows=100) == 100
    assert choose_batch_rows(1, 8.0, p, total_rows=1) >= 1


# -- streaming vs eager bit-exactness ------------------------------------------

def test_stream_ep_pipeline_bit_identical(ctx, tmp_path):
    data = _table(4000, seed=4)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=700)
    lz = (stream.scan_dataset(man, ctx, batch_rows=512)
          .select(lambda c: c["v"] % 2 == 0, name="even")
          .project(["k", "v"]))
    got = lz.collect().to_numpy()
    ref = (DDF.from_numpy(data, ctx)
           .select(lambda c: c["v"] % 2 == 0).project(["k", "v"])).to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k
    assert lz.last_info["batches"] == 8  # 4000 rows / 512-row morsels


def test_scan_pushdown_in_plan(ctx, tmp_path):
    man = write_dataset(_table(1000, seed=5), str(tmp_path / "ds"),
                        chunk_rows=300)
    lz = (stream.scan_dataset(man, ctx, batch_rows=256)
          .select(lambda c: c["v"] > 10, name="gt")
          .project(["k", "v"]))
    plan = lz.explain()
    # projection narrowed into the scan, predicate absorbed host-side
    assert "SCAN" in plan and "cols=('k', 'v')" in plan
    assert "absorbed preds=[gt]" in plan
    assert "SELECT" not in plan and "PROJECT" not in plan


def test_stream_groupby_carry_bit_identical(ctx, tmp_path):
    data = _table(4000, seed=6)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=600)
    aggs = {"v": ("sum", "count", "mean", "min", "max")}
    lz = stream.scan_dataset(man, ctx, batch_rows=500).groupby(("k",), aggs)
    got = lz.collect().to_numpy()
    ref = DDF.from_numpy(data, ctx).groupby(("k",), aggs)[0].to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k
    assert lz.last_info["batches"] == 8


def test_stream_unique_carry(ctx, tmp_path):
    base = _table(1500, seed=7)
    dup = {k: np.concatenate([v, v[:400]]) for k, v in base.items()}
    man = write_dataset(dup, str(tmp_path / "ds"), chunk_rows=333)
    got = (stream.scan_dataset(man, ctx, batch_rows=300)
           .unique(("k",)).collect().to_numpy())
    ref = DDF.from_numpy(dup, ctx).unique(("k",))[0].to_numpy()
    # full-duplicate rows: survivor identical -> bitwise equality
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


def test_stream_sort_spill_bit_identical(ctx, tmp_path):
    data = _table(3000, seed=8)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=500)
    for desc in (False, True):
        got = (stream.scan_dataset(man, ctx, batch_rows=400)
               .sort_values("v", descending=desc).collect().to_numpy())
        ref = DDF.from_numpy(data, ctx).sort_values(
            "v", descending=desc)[0].to_numpy()
        for k in ref:
            assert np.array_equal(ref[k], got[k]), (k, desc)


def test_stream_4op_pipeline_8x_capacity(ctx, tmp_path):
    """Acceptance: select -> project -> join -> groupby streamed over a
    dataset 8x the per-batch device footprint, bit-identical to eager."""
    data = _table(4000, seed=9)
    rng = np.random.default_rng(10)
    R = {"k": rng.integers(0, 150, 900).astype(np.int32),
         "w": rng.integers(0, 50, 900).astype(np.int32)}
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=700)
    dr = DDF.from_numpy(R, ctx)
    lz = (stream.scan_dataset(man, ctx, batch_rows=500)  # 8 batches
          .select(lambda c: c["v"] % 2 == 0, name="even")
          .project(["k", "v"])
          # capacity pinned: join multiplicity (~6 rows/key) exceeds the
          # default 2x bound; strict_overflow would catch the truncation
          .join(dr.lazy(), on=("k",), strategy="shuffle", capacity=2000)
          .groupby(("k",), {"v": ("sum", "count")}))
    got = lz.collect().to_numpy()
    ref = (DDF.from_numpy(data, ctx)
           .select(lambda c: c["v"] % 2 == 0).project(["k", "v"])
           .join(dr, on=("k",), strategy="shuffle", capacity=16000)[0]
           .groupby(("k",), {"v": ("sum", "count")})[0]).to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k
    assert lz.last_info["batches"] == 8


def test_stream_join_spill_both_scans(ctx, tmp_path):
    data = _table(2500, seed=11)
    rng = np.random.default_rng(12)
    R = {"k": rng.integers(0, 150, 700).astype(np.int32),
         "w": rng.integers(0, 50, 700).astype(np.int32)}
    man_l = write_dataset(data, str(tmp_path / "l"), chunk_rows=400)
    man_r = write_dataset(R, str(tmp_path / "r"), chunk_rows=200)
    got = (stream.scan_dataset(man_l, ctx, batch_rows=400)
           .join(stream.scan_dataset(man_r, ctx, batch_rows=400), on=("k",))
           .collect().to_numpy())
    ref = DDF.from_numpy(data, ctx).join(
        DDF.from_numpy(R, ctx), on=("k",), strategy="shuffle",
        capacity=30000)[0].to_numpy()
    cg, cr = _canon(got), _canon(ref)
    assert len(cg["k"]) == len(cr["k"])
    for k in cr:
        assert np.array_equal(cr[k], cg[k]), k


def test_stream_staged_blocking_below_sort(ctx, tmp_path):
    """unique (carry) below sort (spill): staged materialization."""
    data = _table(2000, seed=13)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=300)
    got = (stream.scan_dataset(man, ctx, batch_rows=256)
           .unique(("k",)).sort_values("k").collect().to_numpy())
    ref = DDF.from_numpy(data, ctx).unique(("k",))[0] \
        .sort_values("k")[0].to_numpy()
    assert np.array_equal(ref["k"], got["k"])


def test_to_batches_matches_collect(ctx, tmp_path):
    data = _table(3000, seed=14)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=500)
    lz = stream.scan_dataset(man, ctx, batch_rows=400).select(
        lambda c: c["v"] > 500, name="gt")
    parts = list(lz.to_batches())
    assert len(parts) == 8
    cat = {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}
    ref = DDF.from_numpy(data, ctx).select(lambda c: c["v"] > 500).to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], cat[k]), k


def test_stream_prefetch_off_identical(ctx, tmp_path):
    data = _table(2000, seed=15)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=300)
    lz = stream.scan_dataset(man, ctx, batch_rows=256).groupby(
        ("k",), {"v": ("sum",)})
    a = lz.collect_stream(prefetch=True).to_numpy()
    b = lz.collect_stream(prefetch=False).to_numpy()
    for k in a:
        assert np.array_equal(a[k], b[k]), k


def test_scan_pushdown_project_keeps_pred_columns(ctx, tmp_path):
    """Regression: projecting away a column a pushed-down scan predicate
    reads must not narrow the decode set (KeyError at stream time)."""
    data = _table(1000, seed=21)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=300)
    lz = (stream.scan_dataset(man, ctx, batch_rows=256)
          .select(lambda c: c["v"] > 300, name="gt")
          .project(["k"]))
    got = lz.collect().to_numpy()
    ref = (DDF.from_numpy(data, ctx)
           .select(lambda c: c["v"] > 300).project(["k"])).to_numpy()
    assert np.array_equal(ref["k"], got["k"])


def test_stream_carry_overflow_raises(ctx, tmp_path):
    """Regression: carry-state truncation must trip strict_overflow, not
    silently drop groups."""
    data = _table(1000, nkeys=200, seed=22)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=300)
    lz = stream.scan_dataset(man, ctx, batch_rows=256).groupby(
        ("k",), {"v": ("sum",)})
    with pytest.raises(RuntimeError, match="overflow"):
        lz.collect_stream(carry_capacity=10)
    # and the same plan with room succeeds
    out = lz.collect_stream(carry_capacity=1000)
    ref = DDF.from_numpy(data, ctx).groupby(("k",), {"v": ("sum",)})[0]
    got, expect = out.to_numpy(), ref.to_numpy()
    for k in expect:
        assert np.array_equal(expect[k], got[k]), k


def test_to_batches_overflow_raises_before_yield(ctx, tmp_path):
    """Regression: strict_overflow must fire on the FIRST truncated batch,
    not after the whole stream was consumed (or never, on early abandon)."""
    n = 1000
    data = {"k": np.zeros(n, np.int32), "v": np.arange(n, dtype=np.int32)}
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=200)
    right = DDF.from_numpy({"k": np.zeros(600, np.int32),
                            "w": np.arange(600, dtype=np.int32)}, ctx)
    gen = (stream.scan_dataset(man, ctx, batch_rows=200)
           .join(right.lazy(), on=("k",), capacity=64)
           .to_batches())
    with pytest.raises(RuntimeError, match="overflow"):
        next(gen)


def test_read_csv_dist_zero_byte_file(ctx, tmp_path):
    """Regression: a zero-byte shard is an empty partition, not an error."""
    data = _table(60, seed=24)
    ddf = DDF.from_numpy(data, ctx)
    paths = write_csv_dist(ddf, str(tmp_path / "out"))
    empty = str(tmp_path / "out" / "part-empty.csv")
    open(empty, "w").close()
    schema = {"junk": np.int32, "k": np.int32, "v": np.int32}
    back = read_csv_dist(paths + [empty], schema, ctx)
    assert back.num_rows() == 60


def test_to_batches_early_abandon(ctx, tmp_path):
    """Breaking out of a streamed iterator must not deadlock or error."""
    data = _table(2000, seed=23)
    man = write_dataset(data, str(tmp_path / "ds"), chunk_rows=200)
    gen = stream.scan_dataset(man, ctx, batch_rows=200).to_batches()
    first = next(gen)
    assert len(first["k"]) == 200
    gen.close()  # abandon: prefetch thread must unblock and exit


def test_stream_empty_and_tiny_datasets(ctx, tmp_path):
    empty = {"k": np.zeros((0,), np.int32), "v": np.zeros((0,), np.int32)}
    man = write_dataset(empty, str(tmp_path / "e"))
    out = stream.scan_dataset(man, ctx, batch_rows=128).groupby(
        ("k",), {"v": ("sum",)}).collect()
    assert out.num_rows() == 0
    tiny = {"k": np.arange(3, dtype=np.int32), "v": np.ones(3, np.int32)}
    man2 = write_dataset(tiny, str(tmp_path / "t"))
    got = stream.scan_dataset(man2, ctx, batch_rows=128).collect().to_numpy()
    for k in tiny:
        assert np.array_equal(got[k], tiny[k])


def test_token_pipeline_epoch_streams(ctx):
    from repro.data.pipeline import TokenPipeline

    pipe = TokenPipeline(ctx, n_docs=300, vocab=512, seq_len=16, batch=4,
                         seed=3, quality_threshold=0.2)
    batches = list(pipe.epoch())
    assert len(batches) >= 1
    for b in batches:
        assert b["tokens"].shape == (4, 16)
        assert b["tokens"].max() < 512
    # epoch covers the processed docs (minus the < batch leftover)
    n_batched = sum(b["tokens"].shape[0] for b in batches)
    assert pipe.n_docs - 4 < n_batched <= pipe.n_docs


# -- distributed I/O round-trips (satellite) ------------------------------------

def test_write_read_csv_roundtrip_bit_exact(ctx, tmp_path):
    data = _table(500, seed=16)
    ddf = DDF.from_numpy(data, ctx)
    paths = write_csv_dist(ddf, str(tmp_path / "out"))
    assert len(paths) == ctx.nworkers
    schema = {"junk": np.int32, "k": np.int32, "v": np.int32}
    back = read_csv_dist(paths, schema, ctx,
                         mapping={w: [paths[w]] for w in range(ctx.nworkers)})
    got, ref = back.to_numpy(), ddf.to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k


def test_scan_csv_roundtrip_bit_exact(ctx, tmp_path):
    data = _table(600, seed=17)
    ddf = DDF.from_numpy(data, ctx)
    paths = write_csv_dist(ddf, str(tmp_path / "out"))
    schema = {"junk": np.int32, "k": np.int32, "v": np.int32}
    lz = stream.scan_csv(paths, schema, ctx,
                         directory=str(tmp_path / "ds"),
                         chunk_rows=128, batch_rows=200)
    got = lz.collect().to_numpy()
    ref = ddf.to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k
    assert lz.last_info["batches"] == 3


def test_read_csv_dist_empty_workers_and_uneven_mapping(ctx, tmp_path):
    data = _table(120, seed=18)
    ddf = DDF.from_numpy(data, ctx)
    paths = write_csv_dist(ddf, str(tmp_path / "out"))
    schema = {"junk": np.int32, "k": np.int32, "v": np.int32}
    # all files on worker 0; every other worker gets an empty partition
    back = read_csv_dist(paths, schema, ctx, mapping={0: paths})
    counts = np.asarray(back.counts)
    assert counts[0] == 120
    assert (counts[1:] == 0).all()
    got = back.to_numpy()
    ref = ddf.to_numpy()
    for k in ref:
        assert np.array_equal(np.sort(ref[k]), np.sort(got[k]))


def test_read_csv_dist_capacity_overflow_raises(ctx, tmp_path):
    """Regression: rows beyond capacity used to be silently dropped."""
    data = _table(100, seed=19)
    ddf = DDF.from_numpy(data, ctx)
    paths = write_csv_dist(ddf, str(tmp_path / "out"))
    schema = {"junk": np.int32, "k": np.int32, "v": np.int32}
    with pytest.raises(ValueError, match="silently drop"):
        read_csv_dist(paths, schema, ctx, capacity=3, mapping={0: paths})
    # auto-sizing (capacity omitted) still loads everything
    back = read_csv_dist(paths, schema, ctx, mapping={0: paths})
    assert back.num_rows() == 100


def test_read_csv_dist_schema_mismatch(ctx, tmp_path):
    data = _table(50, seed=20)
    ddf = DDF.from_numpy(data, ctx)
    paths = write_csv_dist(ddf, str(tmp_path / "out"))
    with pytest.raises(ValueError, match="schema mismatch"):
        read_csv_dist(paths, {"absent": np.int32}, ctx)
