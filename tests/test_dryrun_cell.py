"""Dry-run path smoke: one real (arch x shape x mesh) cell lowered+compiled
in a subprocess with 256 fake devices — CI coverage for mesh.py, shapes.py,
sharding.py, dryrun.py and the HLO cost walker working together."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_olmo_train_cell_compiles_and_rooflines():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["DRYRUN_XLA_FLAGS"] = ("--xla_force_host_platform_device_count=256 "
                               "--xla_disable_hlo_passes=while-loop-invariant-code-motion")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = (
        "import os\n"
        "from repro.launch.dryrun import run_cell\n"
        "rec = run_cell('olmo-1b', 'train_4k', multi_pod=False, save=False)\n"
        "import json; print('REC=' + json.dumps({k: rec[k] for k in ('status','n_devices','flops')}))\n"
        "assert rec['status'] == 'ok', rec\n"
        "assert rec['memory']['bytes_per_device'] < 16e9\n"
        "ro = rec['roofline']\n"
        "assert ro['model_flops_per_chip'] > 0 and rec['flops'] > 0\n"
        "assert 0.2 < ro['useful_flops_ratio'] <= 1.5\n"
    )
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200, env=env, cwd=ROOT)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("REC=")][0]
    rec = json.loads(line[4:])
    assert rec["status"] == "ok" and rec["n_devices"] == 256
