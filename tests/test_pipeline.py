"""DDF-based LM data pipeline (the paper's technique as the trainer's data
path): dedup/filter/sort/rebalance stages + batch contract."""

import jax
import numpy as np
import pytest

from repro.core import DDFContext
from repro.data.pipeline import TokenPipeline
from repro.data.synthetic import synthetic_token_corpus, uniform_table, zipf_table


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def test_pipeline_stages(ctx):
    n_docs = 500
    pipe = TokenPipeline(ctx, n_docs=n_docs, vocab=1000, seq_len=32, batch=4,
                         quality_threshold=0.2)
    corpus = synthetic_token_corpus(n_docs, 1000, seed=0)
    n_unique = len(np.unique(corpus["content_hash"]))
    # dedup: every surviving doc has a distinct content hash
    assert pipe.n_docs <= n_unique
    # quality filter applied on top of dedup
    assert pipe.n_docs < n_unique  # threshold 0.2 must drop some
    # rebalance: partitions within 1 row
    counts = np.asarray(pipe.docs.counts)
    assert counts.max() - counts.min() <= 1
    # length bucketing: docs globally sorted by length
    lens = pipe.docs.to_numpy()["length"]
    assert np.all(np.diff(lens) >= 0)


def test_pipeline_batches_shape_and_determinism(ctx):
    pipe = TokenPipeline(ctx, n_docs=200, vocab=512, seq_len=16, batch=3, seed=7)
    b1 = next(pipe)
    assert b1["tokens"].shape == (3, 16)
    assert b1["labels"].shape == (3, 16)
    assert b1["loss_mask"].shape == (3, 16)
    assert b1["tokens"].max() < 512
    pipe2 = TokenPipeline(ctx, n_docs=200, vocab=512, seq_len=16, batch=3, seed=7)
    b2 = next(pipe2)
    for k in b1:
        assert np.array_equal(b1[k], b2[k]), f"{k} not reproducible across restart"


def test_generators_cardinality_and_skew():
    t = uniform_table(10_000, cardinality=0.9)
    C = len(np.unique(t["c0"])) / 10_000
    assert 0.5 < C <= 0.92  # ~paper's 90% regime (collisions reduce it)
    z = zipf_table(10_000, a=1.5)
    _, counts = np.unique(z["c0"], return_counts=True)
    assert counts.max() > 10 * np.median(counts)  # heavy skew
