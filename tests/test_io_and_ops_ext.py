"""Partitioned I/O (paper §5.3.8) + extended operators (transpose, window
aggregates) — the Table 2 / §8 surface beyond the core eight."""

import csv
import os

import jax
import numpy as np
import pytest

from repro.core import DDF, DDFContext
from repro.data.io import assign_files, read_csv_dist, write_csv_dist


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def _write_csvs(tmp, n_files, rows_per):
    paths = []
    rng = np.random.default_rng(0)
    all_rows = []
    for i in range(n_files):
        p = os.path.join(tmp, f"in-{i}.csv")
        with open(p, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["k", "v"])
            for _ in range(rows_per):
                row = [int(rng.integers(0, 100)), int(rng.integers(0, 1000))]
                w.writerow(row)
                all_rows.append(tuple(row))
        paths.append(p)
    return paths, all_rows


def test_read_csv_dist_roundtrip(ctx, tmp_path):
    paths, all_rows = _write_csvs(str(tmp_path), n_files=5, rows_per=40)
    schema = {"k": np.int32, "v": np.int32}
    d = read_csv_dist(paths, schema, ctx)
    got = d.to_numpy()
    assert sorted(zip(got["k"].tolist(), got["v"].tolist())) == sorted(all_rows)

    outdir = os.path.join(str(tmp_path), "out")
    written = write_csv_dist(d, outdir)
    assert len(written) == ctx.nworkers
    back = []
    for p in written:
        with open(p) as f:
            for r in csv.DictReader(f):
                back.append((int(r["k"]), int(r["v"])))
    assert sorted(back) == sorted(all_rows)


def test_empty_partition_schema(ctx, tmp_path):
    """Workers with no files construct an empty partition with the shared
    schema (paper §5.3.8)."""
    paths, all_rows = _write_csvs(str(tmp_path), n_files=1, rows_per=7)
    schema = {"k": np.int32, "v": np.int32}
    # explicit mapping: everything to worker 0
    d = read_csv_dist(paths, schema, ctx, mapping={0: paths})
    counts = np.asarray(d.counts)
    assert counts[0] == 7 and counts[1:].sum() == 0
    assert d.column_names == ("k", "v")
    # and operators work over the empty partitions
    assert int(d.agg("v", "count")) == 7


def test_assign_files_round_robin():
    a = assign_files(["a", "b", "c", "d", "e"], 2)
    assert a == [["a", "c", "e"], ["b", "d"]]


def test_rolling_agg_ops(ctx):
    rng = np.random.default_rng(3)
    vals = rng.integers(0, 100, 200).astype(np.int32)
    d = DDF.from_numpy({"v": vals}, ctx)
    w = 6
    for op, ref_fn in (("sum", np.sum), ("mean", np.mean), ("min", np.min), ("max", np.max)):
        R, info = d.rolling("v", w, op=op)
        assert not np.asarray(info["halo_short"]).any()
        rr = R.to_numpy()
        got = rr[f"v_roll{op}"][rr["window_valid"]]
        ref = np.asarray([ref_fn(vals[i - w + 1: i + 1]) for i in range(w - 1, len(vals))],
                         np.float32)
        np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_transpose(ctx):
    data = {"a": np.arange(6, dtype=np.int32),
            "b": (10 * np.arange(6)).astype(np.int32)}
    d = DDF.from_numpy(data, ctx)
    t = d.transpose()
    tt = t.to_numpy()
    # transposed: rows = original columns (sorted), cols r0..r5
    # every worker gets the full transpose; take worker 0's copy
    assert tt["__col"].tolist()[:2] == [0, 1]
    row_a = [tt[f"r{i}"][0] for i in range(6)]
    row_b = [tt[f"r{i}"][1] for i in range(6)]
    assert row_a == data["a"].tolist()
    assert row_b == data["b"].tolist()


def test_rename(ctx):
    d = DDF.from_numpy({"a": np.arange(4, dtype=np.int32)}, ctx)
    r = d.rename({"a": "z"})
    assert r.column_names == ("z",)
    assert np.array_equal(r.to_numpy()["z"], np.arange(4))
