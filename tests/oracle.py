"""Pure-numpy single-process reference semantics ("the oracle").

Differential testing needs an implementation whose correctness is obvious:
every operator here is a direct transcription of its relational definition
over a plain ``{column: np.ndarray}`` table — no partitioning, no hashing,
no capacity, no device. ``tests/test_differential.py`` drives random
pipelines through the eager engine, the lazy optimizer, and the streaming
engine and asserts each one's result equals the oracle's.

Row order is NOT part of the contract for shuffle-based operators (hash
order and tie order are engine details), so results are compared through
:func:`canonical` — the sorted multiset of rows with every value
normalized to plain Python. Sortedness after an explicit sort is asserted
separately by the test via :func:`is_sorted_by`.

Aggregation ops mirror the engine's ``{col}_{op}`` output naming and its
string-column rules (min/max/count are ordered ops and apply to strings;
sum/mean do not).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "canonical",
    "is_sorted_by",
    "o_select",
    "o_project",
    "o_join",
    "o_groupby",
    "o_unique",
    "o_union",
    "o_difference",
    "o_sort",
]


def _norm(v):
    """One cell -> plain Python (so int32 == int64 == python int compares)."""
    if isinstance(v, (np.str_, str)):
        return str(v)
    if isinstance(v, (np.bool_, bool)):
        return bool(v)
    if isinstance(v, (np.floating, float)):
        return float(v)
    return int(v)


def canonical(table) -> tuple:
    """Order-insensitive comparable form: (sorted column names, sorted rows
    of normalized cells, columns in sorted-name order)."""
    names = sorted(table)
    arrays = [np.asarray(table[c]) for c in names]
    n = len(arrays[0]) if arrays else 0
    rows = sorted(tuple(_norm(a[i]) for a in arrays) for i in range(n))
    return tuple(names), tuple(rows)


def is_sorted_by(table, by: str, descending: bool = False) -> bool:
    """True when column ``by`` is monotone in the given direction."""
    a = np.asarray(table[by])
    if len(a) <= 1:
        return True
    return bool(np.all(a[:-1] >= a[1:]) if descending
                else np.all(a[:-1] <= a[1:]))


def o_select(table, mask) -> dict:
    mask = np.asarray(mask, bool)
    return {c: np.asarray(v)[mask] for c, v in table.items()}


def o_project(table, names) -> dict:
    return {c: np.asarray(table[c]) for c in names}


def o_join(left, right, on) -> dict:
    """Inner equi-join, nested-loop definition. Right-side key columns are
    dropped (they equal the left's); non-key name collisions are the
    caller's problem, as in the engine."""
    on = tuple(on)
    lkeys = list(zip(*(np.asarray(left[c]) for c in on)))
    rkeys = list(zip(*(np.asarray(right[c]) for c in on)))
    li, ri = [], []
    for i, lk in enumerate(lkeys):
        for j, rk in enumerate(rkeys):
            if lk == rk:
                li.append(i)
                ri.append(j)
    out = {c: np.asarray(v)[li] for c, v in left.items()}
    for c, v in right.items():
        if c not in on:
            out[c] = np.asarray(v)[ri]
    return out


_ORDERED_ONLY = ("min", "max", "count")


def o_groupby(table, by, aggs) -> dict:
    """GroupBy-aggregate; output columns are the keys plus ``{col}_{op}``.

    Mirrors the engine's typing rule: arithmetic aggregations (sum/mean)
    over string columns raise TypeError; min/max/count are order-only and
    apply to everything."""
    by = tuple(by)
    keys = list(zip(*(np.asarray(table[c]) for c in by)))
    groups: dict[tuple, list] = {}
    for i, k in enumerate(keys):
        groups.setdefault(k, []).append(i)
    uniq = sorted(groups)
    out = {c: np.asarray([k[j] for k in uniq])
           for j, c in enumerate(by)}
    for c, ops in aggs.items():
        vals = np.asarray(table[c])
        if vals.dtype.kind in ("U", "S"):
            bad = [o for o in ops if o not in _ORDERED_ONLY]
            if bad:
                raise TypeError(f"oracle groupby: {bad} over string {c!r}")
        for op in ops:
            col = []
            for k in uniq:
                g = vals[groups[k]]
                if op == "sum":
                    col.append(g.sum())
                elif op == "count":
                    col.append(len(g))
                elif op == "min":
                    # python min/max: numpy's reductions have no unicode loop
                    col.append(min(g.tolist()))
                elif op == "max":
                    col.append(max(g.tolist()))
                elif op == "mean":
                    col.append(g.sum() / len(g))
                else:
                    raise ValueError(f"oracle groupby: unknown op {op!r}")
            out[f"{c}_{op}"] = np.asarray(col)
    return out


def o_unique(table, subset) -> dict:
    """Distinct rows over ``subset`` (the table is expected to be already
    projected to ``subset``, which makes first-occurrence unambiguous)."""
    subset = tuple(subset)
    keys = list(zip(*(np.asarray(table[c]) for c in subset)))
    seen, idx = set(), []
    for i, k in enumerate(keys):
        if k not in seen:
            seen.add(k)
            idx.append(i)
    return {c: np.asarray(v)[idx] for c, v in table.items()}


def o_union(left, right, on) -> dict:
    """Set union by key = concat + distinct (tables projected to keys)."""
    both = {c: np.concatenate([np.asarray(left[c]), np.asarray(right[c])])
            for c in left}
    return o_unique(both, on)


def o_difference(left, right, on) -> dict:
    """Anti-join: every left row whose key has no match in right."""
    on = tuple(on)
    rkeys = set(zip(*(np.asarray(right[c]) for c in on))) if len(
        np.asarray(right[on[0]])) else set()
    lkeys = list(zip(*(np.asarray(left[c]) for c in on)))
    mask = np.asarray([k not in rkeys for k in lkeys], bool) if lkeys \
        else np.zeros(0, bool)
    return {c: np.asarray(v)[mask] for c, v in left.items()}


def o_sort(table, by: str, descending: bool = False) -> dict:
    order = np.argsort(np.asarray(table[by]), kind="stable")
    if descending:
        order = order[::-1]
    return {c: np.asarray(v)[order] for c, v in table.items()}
