"""Dict-encoded string columns: vocab algebra, distributed unification,
worker-count and kernel-backend invariance, and the CSV typed-error
regression.

The tentpole invariants under test:

1. ``DictVocab`` is a *sorted* dictionary, so codes are order-isomorphic
   with their strings — every ordered kernel (sort, min/max, range
   partition) works on codes unchanged.
2. Vocab unification at binary boundaries (join/union/difference) is pure
   metadata + an injective per-row recode: it NEVER changes the row set,
   and results are bit-identical whether the two sides' vocabularies are
   identical, overlapping, or disjoint.
3. Results are invariant across worker counts (P ∈ {1, 4, 8}, forced host
   devices in a subprocess) and across kernel backends
   (``set_backend("pallas")`` vs ``"jnp"``).
4. Non-numeric CSV cells in numeric columns raise the typed
   ``DatasetSchemaError`` naming the column; string columns declared as
   ``"dict"`` ingest into the dict-encoded path.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import DDF, DDFContext
from repro.core.vocab import DictVocab, encode_strings, storage_schema
from repro.data.dataset import DatasetSchemaError, csv_to_dataset, read_rows
from repro.expr import col
from repro.kernels import use_backend

N = 32
CAP = 4 * N


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


# -- vocab algebra -------------------------------------------------------------

def test_vocab_sorted_dedup_and_codes():
    v = DictVocab.from_values(["sfo", "iad", "sfo", "atl"])
    assert v.words == ("atl", "iad", "sfo")
    assert [v.code_of(w) for w in v.words] == [0, 1, 2]
    assert v.code_of("zzz") is None
    codes, v2 = encode_strings(np.array(["iad", "atl", "iad"]))
    assert v2.words == ("atl", "iad")
    assert codes.tolist() == [1, 0, 1]
    assert codes.dtype == np.int32
    assert v2.decode(codes).tolist() == ["iad", "atl", "iad"]


def test_vocab_merge_and_recode_injective():
    a = DictVocab.from_values(["atl", "iad", "sfo"])
    b = DictVocab.from_values(["bos", "iad", "jfk"])
    m = a.merge(b)
    assert m.words == ("atl", "bos", "iad", "jfk", "sfo")
    ra, rb = a.recode_map(m), b.recode_map(m)
    # injective, order-preserving, and exact on every word
    for v, r in ((a, ra), (b, rb)):
        assert sorted(set(r.tolist())) == r.tolist()
        for i, w in enumerate(v.words):
            assert m.words[r[i]] == w
    # identity detection: merging into itself needs no recode
    assert a.is_identity_into(a.merge(a))
    with pytest.raises(ValueError):
        b.recode_map(a)  # not a superset


def test_vocab_encode_names_absent_value():
    v = DictVocab.from_values(["atl", "iad"])
    with pytest.raises(KeyError, match="sfo"):
        v.encode(np.array(["atl", "sfo"]))


def test_storage_schema_maps_dict_to_int32():
    s = (("k", "dict", ()), ("v", "int32", ()))
    assert storage_schema(s) == (("k", "int32", ()), ("v", "int32", ()))


# -- recode never changes the row set -----------------------------------------

def test_recode_preserves_row_set(ctx):
    rng = np.random.default_rng(5)
    words = np.asarray(["atl", "bos", "iad", "sfo"])
    L = {"k": words[rng.integers(0, 4, N)],
         "v": rng.integers(0, 100, N).astype(np.int32)}
    d = DDF.from_numpy(L, ctx, capacity=CAP)
    merged = d.vocabs["k"].merge(DictVocab.from_values(["den", "jfk", "zzz"]))
    r = d._recode({"k": d.vocabs["k"].recode_map(merged)})
    r.vocabs = {"k": merged}
    before = sorted(zip(L["k"].tolist(), L["v"].tolist()))
    after_tbl = r.to_numpy()
    after = sorted(zip(after_tbl["k"].tolist(), after_tbl["v"].tolist()))
    assert before == after


def test_lazy_recode_visible_and_bit_identical(ctx):
    rng = np.random.default_rng(6)
    L = {"k": np.asarray(["atl", "bos", "iad", "sfo"])[rng.integers(0, 4, N)],
         "v": rng.integers(0, 100, N).astype(np.int32)}
    R = {"k": np.asarray(["bos", "den", "iad", "jfk"])[rng.integers(0, 4, N)],
         "w": rng.integers(0, 100, N).astype(np.int32)}
    dl = DDF.from_numpy(L, ctx, capacity=CAP)
    dr = DDF.from_numpy(R, ctx, capacity=CAP)
    lz = dl.lazy().join(dr.lazy(), on=("k",), strategy="shuffle",
                        capacity=CAP * 4)
    # divergent vocabs => the planned DAG carries an explicit RECODE node
    assert "RECODE" in lz.explain(optimized=False)
    assert "RECODE" in lz.explain()
    eager = dl.join(dr, on=("k",), strategy="shuffle", capacity=CAP * 4)[0]
    a, b = eager.to_numpy(), lz.to_numpy()
    assert sorted(a) == sorted(b)
    for c in a:
        assert sorted(a[c].tolist()) == sorted(b[c].tolist()), c


# -- unification across vocab regimes, backends, worker counts -----------------

def _regime_tables(regime: str):
    """(L, R) numpy tables whose key vocabularies are identical /
    overlapping / disjoint by construction."""
    rng = np.random.default_rng(17)
    pools = {
        "identical": (("atl", "bos", "iad", "sfo"),
                      ("atl", "bos", "iad", "sfo")),
        "overlapping": (("atl", "bos", "iad", "sfo"),
                        ("bos", "den", "iad", "jfk")),
        "disjoint": (("atl", "bos", "iad", "sfo"),
                     ("den", "jfk", "lax", "ord")),
    }
    lp, rp = pools[regime]
    L = {"k": np.asarray(lp)[rng.integers(0, 4, N)],
         "v": rng.integers(0, 100, N).astype(np.int32)}
    R = {"k": np.asarray(rp)[rng.integers(0, 4, N)],
         "w": rng.integers(0, 100, N).astype(np.int32)}
    return L, R


def _unification_results(ctx, regime: str):
    """Canonicalized decoded results of every binary set/join op for one
    vocab regime — the worker-count/backend-invariant payload."""
    L, R = _regime_tables(regime)
    dl = DDF.from_numpy(L, ctx, capacity=CAP)
    dr = DDF.from_numpy(R, ctx, capacity=CAP)
    out = {}
    j = dl.join(dr, on=("k",), strategy="shuffle", capacity=CAP * 4)[0]
    t = j.to_numpy()
    out["join"] = sorted(zip(t["k"].tolist(), t["v"].tolist(),
                             t["w"].tolist()))
    u = dl.project(["k"]).union(dr.project(["k"]), on=("k",))[0].to_numpy()
    out["union"] = sorted(u["k"].tolist())
    d = dl.project(["k"]).difference(dr.project(["k"]), on=("k",))[0].to_numpy()
    out["difference"] = sorted(d["k"].tolist())
    g = dl.groupby(("k",), {"v": ("min", "max")})[0].to_numpy()
    out["groupby"] = sorted(zip(g["k"].tolist(), g["v_min"].tolist(),
                                g["v_max"].tolist()))
    return out


def _expected_results(regime: str):
    L, R = _regime_tables(regime)
    lk, rk = L["k"].tolist(), R["k"].tolist()
    out = {}
    out["join"] = sorted((k, int(v), int(w))
                         for k, v in zip(lk, L["v"])
                         for k2, w in zip(rk, R["w"]) if k == k2)
    out["union"] = sorted(set(lk) | set(rk))
    out["difference"] = sorted(set(lk) - set(rk))
    out["groupby"] = sorted(
        (k, int(min(L["v"][L["k"] == k])), int(max(L["v"][L["k"] == k])))
        for k in set(lk))
    return out


@pytest.mark.parametrize("regime", ["identical", "overlapping", "disjoint"])
def test_unification_regimes_match_numpy(ctx, regime):
    assert _unification_results(ctx, regime) == _expected_results(regime)


@pytest.mark.parametrize("regime", ["identical", "overlapping", "disjoint"])
def test_unification_backend_invariant(ctx, regime):
    """pallas (interpret mode off-TPU) vs jnp kernels: same decoded rows."""
    with use_backend("jnp"):
        a = _unification_results(ctx, regime)
    with use_backend("pallas"):
        b = _unification_results(ctx, regime)
    assert a == b == _expected_results(regime)


@pytest.mark.slow
def test_unification_worker_count_invariant():
    """P ∈ {1, 4, 8} (forced host devices, subprocess): identical decoded
    results for every vocab regime."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.dirname(os.environ["TYPES_TEST_FILE"]))
import jax
from repro.core import DDFContext
tt = __import__("test_types")
results = {}
for P in (1, 4, 8):
    mesh = jax.make_mesh((P,), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    results[P] = {r: tt._unification_results(ctx, r)
                  for r in ("identical", "overlapping", "disjoint")}
for P in (4, 8):
    assert results[P] == results[1], f"P={P} diverged from P=1"
for r in ("identical", "overlapping", "disjoint"):
    assert results[1][r] == tt._expected_results(r), r
print("WORKER COUNT INVARIANT OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["TYPES_TEST_FILE"] = os.path.abspath(__file__)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    res = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=600, env=env)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "WORKER COUNT INVARIANT OK" in res.stdout


# -- string predicates bind to code space --------------------------------------

def test_string_predicates(ctx):
    L = {"k": np.asarray(["atl", "bos", "iad", "sfo"] * 8),
         "v": np.arange(N, dtype=np.int32)}
    d = DDF.from_numpy(L, ctx, capacity=CAP)
    eq = d.select(col("k").eq("iad")).to_numpy()
    assert set(eq["k"].tolist()) == {"iad"}
    absent = d.select(col("k").eq("zzz")).to_numpy()
    assert len(absent["k"]) == 0  # absent literal: provably-false filter
    ne_absent = d.select(col("k").ne("zzz")).to_numpy()
    assert len(ne_absent["k"]) == N  # absent ne: provably-true filter
    lt = d.select(col("k") < "bos").to_numpy()
    assert set(lt["k"].tolist()) == {"atl"}
    isin = d.select(col("k").is_in(["atl", "sfo", "zzz"])).to_numpy()
    assert set(isin["k"].tolist()) == {"atl", "sfo"}


def test_string_sum_raises(ctx):
    L = {"k": np.asarray(["atl", "bos"] * 16),
         "v": np.arange(N, dtype=np.int32)}
    d = DDF.from_numpy(L, ctx, capacity=CAP)
    with pytest.raises(TypeError, match="no arithmetic"):
        d.groupby(("v",), {"k": ("sum",)})
    with pytest.raises(TypeError, match="no arithmetic"):
        d.agg("k", "sum")
    assert d.agg("k", "min") == "atl"
    assert d.agg("k", "max") == "bos"


# -- CSV ingestion: typed errors + dict routing (regression) -------------------

def test_csv_bad_cell_names_column(tmp_path):
    f = tmp_path / "bad.csv"
    f.write_text("k,v\n1,banana\n2,3\n")
    with pytest.raises(DatasetSchemaError, match=r"'v'.*banana"):
        csv_to_dataset([str(f)], {"k": "int32", "v": "int32"},
                       str(tmp_path / "ds"))


def test_csv_dict_column_roundtrip(tmp_path):
    f = tmp_path / "ok.csv"
    f.write_text("k,v\nsfo,1\niad,2\nsfo,3\n")
    man = csv_to_dataset([str(f)], {"k": "dict", "v": "int32"},
                         str(tmp_path / "ds"))
    assert dict((n, dt) for n, dt, _ in man.schema)["k"] == "dict"
    vocab = man.vocab_map["k"]
    assert vocab.words == ("iad", "sfo")
    codes = read_rows(man, 0, 3)["k"]
    assert vocab.decode(np.asarray(codes)).tolist() == ["sfo", "iad", "sfo"]
