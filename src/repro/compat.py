"""JAX version compatibility shims.

The codebase targets the current ``jax.shard_map`` / ``jax.lax.axis_size``
surface; older jax releases (e.g. 0.4.x) expose the same functionality under
``jax.experimental.shard_map.shard_map`` (with ``check_rep`` instead of
``check_vma``) and have no ``axis_size`` (but ``jax.lax.psum(1, axis)``
constant-folds to a static int inside ``shard_map``). Everything that enters a
``shard_map`` region goes through these two wrappers so the rest of the code
is version-agnostic.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "axis_size", "optimization_barrier"]


def _native_shard_map():
    try:
        return jax.shard_map  # jax >= 0.6 (also jax.experimental alias gone)
    except AttributeError:
        return None


_NATIVE = _native_shard_map()
if _NATIVE is None:
    from jax.experimental.shard_map import shard_map as _EXPERIMENTAL
else:
    _EXPERIMENTAL = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions.

    ``check_vma`` maps onto the old ``check_rep`` flag: both toggle the
    per-device replication/varying-axis check.
    """
    if _NATIVE is not None:
        return _NATIVE(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=check_vma)
    return _EXPERIMENTAL(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_rep=check_vma)


def axis_size(axis) -> int:
    """Static size of a mapped mesh axis (or tuple of axes) from inside
    ``shard_map``. Returns a Python int usable as a loop bound / shape."""
    try:
        return jax.lax.axis_size(axis)
    except AttributeError:
        return jax.lax.psum(1, axis)


def _native_barrier_differentiates() -> bool:
    try:
        jax.jvp(jax.lax.optimization_barrier, (1.0,), (1.0,))
        return True
    except Exception:
        return False


if _native_barrier_differentiates():
    # Newer jax: the primitive has its own differentiation rule (including
    # forward mode) — use it untouched.
    optimization_barrier = jax.lax.optimization_barrier
else:
    @jax.custom_vjp
    def optimization_barrier(x):
        """``jax.lax.optimization_barrier`` with an explicit identity gradient.

        Old jax releases ship the primitive without a differentiation rule;
        the barrier is semantically the identity, so its VJP passes cotangents
        through unchanged while keeping the scheduling barrier in the forward.
        """
        return jax.lax.optimization_barrier(x)

    def _ob_fwd(x):
        return optimization_barrier(x), None

    def _ob_bwd(_, g):
        return (g,)

    optimization_barrier.defvjp(_ob_fwd, _ob_bwd)
