"""Sharding plans: map model/optimizer state onto the production mesh.

Train: 2-D sharding — FSDP over the data axes (+pod), TP over "model" —
MaxText-style. Serve: TP-only params (each DP serving replica holds a full
TP-sharded copy), batch over data axes, KV-cache *sequence* dimension over
"model" (flash-decoding-style split-K), or over (data+model) for the
batch=1 long-context shape.

Rules are divisibility-aware: each param kind carries an ordered candidate
list of PartitionSpecs and the first one whose sharded dims divide evenly
wins (e.g. granite's 24 heads don't divide a 16-way model axis, so attention
falls back to head_dim sharding). This is what makes one plan serve all 10
assigned architectures.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Sequence

import jax

from repro.compat import optimization_barrier
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingPlan", "make_plan", "param_shardings", "batch_shardings",
           "decode_state_shardings"]


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    dp: tuple[str, ...]          # batch axes (e.g. ("pod","data"))
    tp: str = "model"
    mode: str = "train"          # train | serve | serve_long

    @property
    def fsdp(self) -> tuple[str, ...]:
        return self.dp if self.mode == "train" else ()

    def ns(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            return self.mesh.shape[axes]
        return int(np.prod([self.mesh.shape[a] for a in axes]))


def make_plan(mesh: Mesh, mode: str = "train") -> ShardingPlan:
    names = mesh.axis_names
    dp = tuple(a for a in names if a != "model")
    return ShardingPlan(mesh=mesh, dp=dp, tp="model", mode=mode)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _candidates(name: str, plan: ShardingPlan) -> list[tuple]:
    """Ordered PartitionSpec candidates per (trailing-dims) param kind."""
    F: tuple | None = plan.fsdp or None
    T = plan.tp
    rules: dict[str, list[tuple]] = {
        # embeddings (V, d): vocab over TP, d over FSDP
        "embed": [(T, F), (T, None), (None, F), (None, None)],
        "unembed": [(T, F), (T, None), (None, F), (None, None)],
        "pos_embed": [(None, F), (None, None)],
        "enc_pos": [(None, F), (None, None)],
        "vis_proj": [(F, T), (None, None)],
        # attention
        "wq": [(F, T, None), (F, None, T), (F, None, None)],
        "wk": [(F, T, None), (F, None, T), (F, None, None)],
        "wv": [(F, T, None), (F, None, T), (F, None, None)],
        "wo": [(T, None, F), (None, T, F), (None, None, F)],
        "bq": [(T, None), (None, T), (None, None)],
        "bk": [(T, None), (None, T), (None, None)],
        "bv": [(T, None), (None, T), (None, None)],
        # dense mlp
        "w_gate": [(F, T)],
        "w_up": [(F, T)],
        "w_down": [(T, F)],
        # moe (E, d, ff) / (E, ff, d) — expert dim unsharded (40/32 don't
        # divide 16); TP inside each expert
        "router": [(F, None), (None, None)],
        "moe/w_gate": [(None, F, T)],
        "moe/w_up": [(None, F, T)],
        "moe/w_down": [(None, T, F)],
        # mamba2
        "w_x": [(F, T)],
        "w_z": [(F, T)],
        "w_b": [(F, None)],
        "w_c": [(F, None)],
        "w_dt": [(F, T), (F, None)],
        "w_out": [(T, F)],
        "conv_x": [(None, T), (None, None)],
        "conv_b": [(None, None)],
        "conv_c": [(None, None)],
        "A_log": [(T,), (None,)],
        "D": [(T,), (None,)],
        "dt_bias": [(T,), (None,)],
    }
    return rules.get(name, [(None,)])


def _fits(spec: tuple, shape: tuple[int, ...], plan: ShardingPlan) -> bool:
    for dim, axes in zip(shape, spec):
        if axes is None:
            continue
        if dim % plan.axis_size(axes) != 0:
            return False
    return True


def _spec_for(path: tuple, shape: tuple[int, ...], plan: ShardingPlan) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    if "moe" in keys and name in ("w_gate", "w_up", "w_down"):
        name = f"moe/{name}"
    cands = _candidates(name, plan)
    # stacked layer dims: rules describe trailing dims; pad leading Nones
    for cand in cands:
        lead = len(shape) - len(cand)
        if lead < 0:
            continue
        full = (None,) * lead + cand
        if _fits(full, shape, plan):
            return P(*full)
    return P()  # replicate


def param_shardings(specs, plan: ShardingPlan):
    """pytree of ShapeDtypeStruct -> pytree of NamedSharding."""
    def f(path, leaf):
        return plan.ns(*_spec_for(path, leaf.shape, plan))
    return jax.tree_util.tree_map_with_path(f, specs)


def _gather_spec(path: tuple, shape: tuple[int, ...], plan: ShardingPlan) -> P:
    """Storage spec minus the FSDP axes: the ZeRO-3 'gathered at use' form."""
    spec = _spec_for(path, shape, plan)
    fs = set(plan.fsdp)
    out = []
    for axes in tuple(spec):
        if axes is None:
            out.append(None)
        elif isinstance(axes, str):
            out.append(None if axes in fs else axes)
        else:
            kept = tuple(a for a in axes if a not in fs)
            out.append(kept if kept else None)
    return P(*out)


def act_seq(h, plan: ShardingPlan | None):
    """Sequence-parallel residual stream: (B, S, d) constrained to
    P(dp, tp, None) between blocks, so remat-saved layer inputs shard over
    the FULL mesh (Megatron-SP; the difference between 102GB and 6GB of
    carries for deepseek-67b train)."""
    if plan is None:
        return h
    if h.shape[1] % plan.axis_size(plan.tp) or h.shape[0] % plan.axis_size(plan.dp):
        return h
    return jax.lax.with_sharding_constraint(h, plan.ns(plan.dp, plan.tp, None))


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _resharded(w, use_sh, grad_sh):
    return jax.lax.with_sharding_constraint(w, use_sh)


def _resharded_fwd(w, use_sh, grad_sh):
    return jax.lax.with_sharding_constraint(w, use_sh), None


def _resharded_bwd(use_sh, grad_sh, _res, g):
    # Cotangent immediately reduce-scattered back to the storage layout.
    # Without this, with_sharding_constraint's transpose keeps layer grads in
    # the *gathered* (dp-replicated) layout and the stacked grad accumulator
    # of scan-over-layers balloons (40GB/device for deepseek-67b).
    return (jax.lax.with_sharding_constraint(g, grad_sh),)


_resharded.defvjp(_resharded_fwd, _resharded_bwd)


def gather_params(tree, plan: ShardingPlan | None, cast_dtype="bfloat16"):
    """Constrain a param subtree to its FSDP-gathered layout (weights
    replicated over dp, still TP-sharded). Applied inside each layer body so
    XLA all-gathers weights per layer (streaming FSDP) instead of psumming
    activation-sized partials — the standard ZeRO-3 lowering. Gradients
    re-shard to the storage layout per layer (ZeRO reduce-scatter).

    §Perf iteration 1a: weights are cast to the compute dtype BEFORE the
    gather (fp32 master stays sharded), halving FSDP gather traffic; the
    cast's transpose keeps the fp32 reduce-scatter on the grad side."""
    if plan is None or not plan.fsdp:
        return tree
    import jax.numpy as jnp
    cast = jnp.dtype(cast_dtype) if cast_dtype else None
    def f(path, leaf):
        use = plan.ns(*_gather_spec(path, leaf.shape, plan))
        store = plan.ns(*_spec_for(path, leaf.shape, plan))
        if cast is not None and leaf.dtype == jnp.float32 and leaf.ndim >= 2:
            # pin the bf16 copy in the SHARDED layout (constraint + barrier)
            # so the partitioner cannot reorder to gather-f32-then-convert
            leaf = jax.lax.with_sharding_constraint(leaf.astype(cast), store)
            leaf = optimization_barrier(leaf)
        return _resharded(leaf, use, store)
    return jax.tree_util.tree_map_with_path(f, tree)


def use_param(leaf, plan: ShardingPlan | None, name: str):
    """gather_params for a single named parameter (embed / unembed / ...)."""
    if plan is None or not plan.fsdp:
        return leaf
    key = (jax.tree_util.DictKey(name),)
    use = plan.ns(*_gather_spec(key, leaf.shape, plan))
    store = plan.ns(*_spec_for(key, leaf.shape, plan))
    return _resharded(leaf, use, store)


# ---------------------------------------------------------------------------
# batch / activation / decode-state rules
# ---------------------------------------------------------------------------

def batch_shardings(batch_specs, plan: ShardingPlan):
    """tokens/labels/loss_mask (B, S): batch over dp; frame/patch embeds
    (B, T, d): batch over dp."""
    def f(path, leaf):
        spec = [plan.dp] + [None] * (len(leaf.shape) - 1)
        if leaf.shape[0] % plan.axis_size(plan.dp) != 0:
            spec[0] = None
        return plan.ns(*spec)
    return jax.tree_util.tree_map_with_path(f, batch_specs)


def decode_state_shardings(state_specs, plan: ShardingPlan, long_context: bool = False):
    """KV caches (L, B, T, KV, hd): batch over dp, cache seq over TP
    (split-K decode). long_context (B=1): seq over (dp+tp).
    SSM states (L, B, h, dh, ds): batch over dp, heads over TP."""
    seq_axes = (plan.dp + (plan.tp,)) if long_context else plan.tp
    batch_axes = None if long_context else plan.dp

    def f(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        shape = leaf.shape
        if "kv" in keys and len(shape) == 5:  # (L,B,T,KV,hd) or scale (L,B,T,KV,1)
            spec = [None, batch_axes, seq_axes, None, None]
        elif "ssm" in keys and "state" in keys and len(shape) == 5:
            spec = [None, batch_axes, plan.tp, None, None]
            if shape[2] % plan.axis_size(plan.tp) != 0:
                spec[2] = None
        elif "enc_out" in keys:
            spec = [batch_axes, None, None]
        elif len(shape) >= 2 and "conv" in "".join(keys):
            spec = [None, batch_axes] + [None] * (len(shape) - 2)
        elif len(shape) == 0:
            spec = []
        else:
            spec = [None, batch_axes] + [None] * (len(shape) - 2)
        # divisibility guards
        for i, axes in enumerate(spec):
            if axes is not None and shape[i] % plan.axis_size(axes) != 0:
                spec[i] = None
        return plan.ns(*spec)

    return jax.tree_util.tree_map_with_path(f, state_specs)
