"""jit'd wrappers + dispatch for the Pallas kernels.

Every wrapper resolves an execution mode — native ``pallas`` (TPU),
``interpret`` (the kernel body run by the Pallas interpreter: bit-identical
on any backend, the CPU correctness fallback), or the plain ``jnp``/``xla``
reference — through :mod:`repro.kernels.registry`, which consults
``cost_model.kernel_params`` (row thresholds, dtype support, native-lowering
flag) and the process-wide ``set_backend`` override. ``force`` pins a mode
for tests and benchmarks.

The dataframe wrappers (:func:`hash_partition`, :func:`segment_reduce`)
additionally handle the static-shape plumbing the hot paths need: padding
arbitrary row counts up to a block multiple (with exact histogram
correction) and merging block-local partials into per-segment outputs that
match the jnp path bit-for-bit on every associative case.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import hash_partition as _hp
from . import registry
from . import segment_reduce as _sr
from . import ssd_scan as _ssd
from . import ref

__all__ = ["on_tpu", "flash_attention", "ssd_scan", "hash_partition",
           "partition_histogram", "segment_reduce",
           "segment_reduce_partials", "ref"]


def on_tpu() -> bool:
    """True when the default jax backend is TPU (native Pallas lowering)."""
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, q_block=128, kv_block=128, force: str | None = None):
    """(B,S,H,hd) x (B,S,KV,hd)^2 -> (B,S,H,hd) attention (model layer).

    Mode: native Pallas on TPU, XLA reference elsewhere; ``force`` pins
    "pallas" | "interpret" | "xla" for tests."""
    mode = force or ("pallas" if on_tpu() else "xla")
    if mode == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   q_block=q_block, kv_block=kv_block)
    if mode == "interpret":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   q_block=q_block, kv_block=kv_block,
                                   interpret=True)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale)


def ssd_scan(x, dt, A, B, C, D, *, chunk=128, force: str | None = None):
    """Mamba-2 SSD chunked scan (model layer); mode selection as
    :func:`flash_attention`."""
    mode = force or ("pallas" if on_tpu() else "xla")
    if mode == "pallas":
        return _ssd.ssd_scan(x, dt, A, B, C, D, chunk=chunk)
    if mode == "interpret":
        return _ssd.ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    return ref.ssd_scan_ref(x, dt, A, B, C, D, chunk=chunk)


def hash_partition(keys, num_partitions, *, block: int | None = None,
                   force: str | None = None, with_hist: bool = True):
    """Destination partition ids + histogram for the shuffle build side.

    Args:
      keys: (N,) or (N, n_cols) integer/uint arrays (bitcast to uint32;
        the dataframe path pre-normalizes other dtypes via
        ``partition.u32_normalize`` so the kernel hash equals
        ``partition.hash_columns`` bit-for-bit).
      num_partitions: P.
      block: pallas grid block rows (default from
        ``cost_model.kernel_params``). N is padded up to a block multiple
        internally; the histogram is corrected for the pad rows, so any N
        is accepted.
      force: pin "pallas" | "interpret" | "jnp"/"xla" (default: registry
        dispatch).
      with_hist: False skips the (block x P) one-hot histogram work in
        the kernel and returns ``hist=None`` — what
        ``partition.hash_partition_ids`` uses, since destinations are all
        the shuffle build needs.

    Returns:
      (dest (N,) int32, hist (P,) int32 | None) — bit-identical across
      modes.
    """
    if keys.ndim == 1:
        keys = keys[:, None]
    N = keys.shape[0]
    mode = force or registry.resolve("hash_partition", N)
    if mode in ("jnp", "xla") or N == 0:
        dest, hist = ref.hash_partition_ref(keys, num_partitions)
        return dest, (hist if with_hist else None)
    if block is None:
        block = registry.current_params().block["hash_partition"]
    blk = min(block, N)
    pad = (-N) % blk
    ku = keys.astype(jnp.uint32)
    if pad:
        ku = jnp.concatenate([ku, jnp.zeros((pad, ku.shape[1]), ku.dtype)])
    dest, hist = _hp.hash_partition(ku, num_partitions, block=blk,
                                    interpret=(mode == "interpret"),
                                    with_hist=with_hist)
    if with_hist:
        hist = jnp.sum(hist, axis=0)
    if pad:
        if with_hist:
            # pad rows are all-zero keys: one deterministic destination
            hist = hist.at[dest[N]].add(-pad)
        dest = dest[:N]
    return dest, hist


def partition_histogram(keys, num_partitions, *, block: int | None = None,
                        force: str | None = None):
    """Per-partition destination counts for the shuffle keys — the
    statistics layer's observation primitive (ISSUE 9).

    The same dispatched :func:`hash_partition` pass that computes
    destination ids also accumulates the (P,) histogram in its one-hot
    kernel leg; this wrapper returns just that histogram, so the adaptive
    re-planner and ``patterns.quota_from_histogram`` consume the exact
    per-partition row counts the shuffle is about to see (bit-identical
    across pallas/interpret/jnp modes, and to the streaming runner's host
    ``bincount`` mirror).
    """
    _, hist = hash_partition(keys, num_partitions, block=block, force=force,
                             with_hist=True)
    return hist


def segment_reduce_partials(values, seg_ids, *, max_segments=128, block=1024,
                            op="sum", interpret=False):
    """Re-export of :func:`segment_reduce.segment_reduce_partials` (the raw
    combine kernel) so hot paths and tests import one module."""
    return _sr.segment_reduce_partials(values, seg_ids,
                                       max_segments=max_segments, block=block,
                                       op=op, interpret=interpret)


def segment_reduce(values, seg_ids, num_segments, *, op="sum",
                   max_segments: int | None = None, block: int | None = None,
                   force: str | None = None):
    """Segment reduction over sorted seg_ids: combine kernel + jnp merge.

    The groupby hot path (``local_ops.local_groupby``) calls this with
    *dense contiguous* segment ids, for which the default sizing
    ``max_segments = block`` makes the kernel path exact for any input
    (a block of ``block`` sorted dense ids spans at most ``block``
    segments). Values are padded to a block multiple with op-identity
    fill; partials merge via ``jax.ops.segment_{sum,min,max}`` in the
    value dtype, so integer results are bit-identical to the direct
    scatter-add path (float sums reassociate — docs/KERNELS.md).

    Args:
      values: (N, width) value rows, sorted by ``seg_ids``.
      seg_ids: (N,) int32 non-decreasing segment ids.
      num_segments: segments in the output; ids >= num_segments land in a
        drop bucket (trimmed), matching the callers' overflow-bucket use.
      op: "sum" | "max" | "min".
      max_segments / block: kernel sizing (defaults:
        ``cost_model.kernel_params`` block; max_segments = block).
      force: pin a mode; default dispatches via the registry.

    Returns:
      (num_segments, width) array in the value dtype.
    """
    N, width = values.shape
    mode = force or registry.resolve("segment_reduce", N, values.dtype)
    if mode in ("jnp", "xla") or N == 0:
        return ref.segment_reduce_ref(values, seg_ids, num_segments, op=op)
    if block is None:
        block = registry.current_params().block["segment_reduce"]
    blk = min(block, N)
    if max_segments is None:
        max_segments = blk
    pad = (-N) % blk
    if pad:
        if op == "sum":
            fill = jnp.zeros((), values.dtype)
        elif op == "min":
            fill = _sr._hi_sentinel(values.dtype)
        else:
            fill = _sr._lo_sentinel(values.dtype)
        values = jnp.concatenate(
            [values, jnp.full((pad, width), fill, values.dtype)])
        # pad ids with num_segments: keeps the sort order and lands in the
        # drop bucket below
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((pad,), num_segments, jnp.int32)])
    psum, pseg = _sr.segment_reduce_partials(
        values, seg_ids, max_segments=max_segments, block=blk, op=op,
        interpret=(mode == "interpret"))
    pseg = jnp.clip(pseg, 0, num_segments)  # ids past the end -> drop bucket
    if op == "sum":
        out = jax.ops.segment_sum(psum, pseg, num_segments=num_segments + 1)
    elif op == "max":
        out = jax.ops.segment_max(psum, pseg, num_segments=num_segments + 1)
    else:
        out = jax.ops.segment_min(psum, pseg, num_segments=num_segments + 1)
    return out[:num_segments]
