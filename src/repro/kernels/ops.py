"""jit'd wrappers + platform dispatch for the Pallas kernels.

On TPU the Pallas path runs natively; everywhere else (this CPU container)
``interpret=True`` executes the kernel body in Python for correctness, and
the model layers default to their XLA implementations. ``force``
overrides are for tests/benches.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import hash_partition as _hp
from . import segment_reduce as _sr
from . import ssd_scan as _ssd
from . import ref

__all__ = ["on_tpu", "flash_attention", "ssd_scan", "hash_partition",
           "segment_reduce", "ref"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, q_block=128, kv_block=128, force: str | None = None):
    """(B,S,H,hd) x (B,S,KV,hd)^2 -> (B,S,H,hd)."""
    mode = force or ("pallas" if on_tpu() else "xla")
    if mode == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   q_block=q_block, kv_block=kv_block)
    if mode == "interpret":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   q_block=q_block, kv_block=kv_block,
                                   interpret=True)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale)


def ssd_scan(x, dt, A, B, C, D, *, chunk=128, force: str | None = None):
    mode = force or ("pallas" if on_tpu() else "xla")
    if mode == "pallas":
        return _ssd.ssd_scan(x, dt, A, B, C, D, chunk=chunk)
    if mode == "interpret":
        return _ssd.ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    return ref.ssd_scan_ref(x, dt, A, B, C, D, chunk=chunk)


def hash_partition(keys, num_partitions, *, block=1024, force: str | None = None):
    """Returns (dest (N,), hist (P,)) — per-block partials summed."""
    mode = force or ("pallas" if on_tpu() else "xla")
    if mode in ("pallas", "interpret"):
        dest, hist = _hp.hash_partition(keys, num_partitions, block=block,
                                        interpret=(mode == "interpret"))
        return dest, jnp.sum(hist, axis=0)
    return ref.hash_partition_ref(keys, num_partitions)


def segment_reduce(values, seg_ids, num_segments, *, op="sum",
                   max_segments=128, block=1024, force: str | None = None):
    """Segment reduction over sorted seg_ids."""
    mode = force or ("pallas" if on_tpu() else "xla")
    if mode in ("pallas", "interpret"):
        psum, pseg = _sr.segment_reduce_partials(
            values, seg_ids, max_segments=max_segments, block=block, op=op,
            interpret=(mode == "interpret"))
        pseg = jnp.clip(pseg, 0, num_segments)  # ids past the end -> bucket
        if op == "sum":
            out = jax.ops.segment_sum(psum, pseg, num_segments=num_segments + 1)
        elif op == "max":
            out = jax.ops.segment_max(psum, pseg, num_segments=num_segments + 1)
        else:
            out = jax.ops.segment_min(psum, pseg, num_segments=num_segments + 1)
        return out[:num_segments]
    return ref.segment_reduce_ref(values, seg_ids, num_segments, op=op)
