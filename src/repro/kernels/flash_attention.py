"""Flash attention (causal GQA, sliding window, logit softcap) as a Pallas
TPU kernel.

TPU-native design (DESIGN.md §2): grid = (B*H, n_q_blocks, n_kv_blocks) with
the kv dimension iterated sequentially (minor-most), so the online-softmax
accumulators (m, l, acc) live in VMEM scratch across kv steps. Q/K/V blocks
are MXU-shaped (q_block x head_dim and kv_block x head_dim, multiples of
128); GQA is expressed through the K/V index_map (query-head -> kv-head
division) so grouped heads never materialize broadcast K/V in HBM.

Causal + sliding-window blocks outside the window are skipped with pl.when
(compute-free on real TPU; the XLA fallback path masks instead).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, q_block, kv_block, n_kv, seq_len):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_block
    k_start = ki * kv_block
    # block-level skip: causal (kv entirely after q) / window (entirely before)
    live = jnp.asarray(True)
    if causal:
        live = live & (k_start <= q_start + q_block - 1)
    if window is not None:
        live = live & (k_start + kv_block - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (q_block, hd)
        k = k_ref[0].astype(jnp.float32)            # (kv_block, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        mask = k_pos < seq_len
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (B, S, H, hd)
    k: jax.Array,            # (B, S, KV, hd)
    v: jax.Array,            # (B, S, KV, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, S, H, hd). S must divide the block sizes."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    n_q = -(-S // q_block)
    n_kv = -(-S // kv_block)
    assert S % q_block == 0 and S % kv_block == 0, (S, q_block, kv_block)

    # flatten heads into the grid's major dim: (B*H, S, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        q_block=q_block, kv_block=kv_block, n_kv=n_kv, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda bh, qi, ki: (bh, qi, 0)),
            # GQA: query head bh -> kv head bh//G, no HBM broadcast
            pl.BlockSpec((1, kv_block, hd), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
