"""Segment-reduce kernel: the combine leg of Combine-Shuffle-Reduce (§5.3.4).

Input: values sorted by segment id (the groupby sort order) + the segment
ids. Per row block, the kernel reduces rows into at most ``max_segments``
block-local partials using a one-hot (block x max_segments) matmul — the
MXU-native replacement for scatter-add (TPU has no atomics; DESIGN.md §2).
Cross-block merging of partials (cheap: nb x max_segments rows) stays in
jnp (ops.segment_sum), mirroring the paper's combine -> shuffle -> reduce
split where the combine output is small (O(n*C)).

Partials are computed **in the value dtype**: integer sums use an integer
one-hot matmul (exact, wraps like ``segment_sum``), floats accumulate in
their own dtype. That makes the kernel path bit-identical to the jnp
scatter-add path for every associative case (all integer ops, float
min/max); float sums are subject to the usual reassociation caveat
(docs/KERNELS.md).

Precondition: every block spans <= max_segments distinct segments. The
dataframe hot path (``local_ops.local_groupby``) passes *dense contiguous*
group ids, which span <= block per block by construction, so it sizes
``max_segments = block``; other callers size max_segments from the sampled
cardinality (paper §5.4.1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_reduce_partials"]

# the same identity sentinels the jnp operator paths mask with — one
# definition (core.dataframe) so kernel/jnp bit-parity cannot drift
from ..core.dataframe import max_sentinel as _hi_sentinel  # noqa: E402
from ..core.dataframe import min_sentinel as _lo_sentinel  # noqa: E402


def _kernel(vals_ref, segs_ref, psum_ref, pseg_ref, *, block, width, max_segments, op):
    vals = vals_ref[...]                       # (block, width), value dtype
    segs = segs_ref[...][:, 0]                 # (block,) int32, sorted
    base = segs[0]
    local = segs - base                        # block-local dense ids
    local = jnp.clip(local, 0, max_segments - 1)
    sid = jax.lax.broadcasted_iota(jnp.int32, (block, max_segments), 1)
    onehot = local[:, None] == sid             # (block, maxseg) bool
    if op == "sum":
        out = jax.lax.dot_general(onehot.astype(vals.dtype), vals,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=vals.dtype)
    elif op == "max":
        big = jnp.where(onehot[..., None], vals[:, None, :],
                        _lo_sentinel(vals.dtype))
        out = jnp.max(big, axis=0)
    elif op == "min":
        big = jnp.where(onehot[..., None], vals[:, None, :],
                        _hi_sentinel(vals.dtype))
        out = jnp.min(big, axis=0)
    else:
        raise ValueError(op)
    psum_ref[...] = out                         # (max_segments, width)
    pseg_ref[...] = (base + jax.lax.iota(jnp.int32, max_segments))[:, None]


def segment_reduce_partials(
    values: jax.Array,     # (N, width) sorted by segment
    seg_ids: jax.Array,    # (N,) int32 non-decreasing
    *,
    max_segments: int = 128,
    block: int = 1024,
    op: str = "sum",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Block-local segment partials: the Pallas combine kernel.

    Returns (partials (nb*max_segments, width) in the value dtype,
    partial_seg_ids (nb*max_segments,) int32). Partials for segment ids the
    block does not contain are identity-valued (0 for sum, +/-sentinel for
    min/max) and their ids may collide with real ids only on identity
    values — safe for sum/max/min merging."""
    N, width = values.shape
    assert N % block == 0, (N, block)
    nb = N // block

    kernel = functools.partial(_kernel, block=block, width=width,
                               max_segments=max_segments, op=op)
    psum, pseg = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block, width), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((max_segments, width), lambda i: (i, 0)),
            pl.BlockSpec((max_segments, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * max_segments, width), values.dtype),
            jax.ShapeDtypeStruct((nb * max_segments, 1), jnp.int32),
        ],
        interpret=interpret,
    )(values, seg_ids[:, None].astype(jnp.int32))
    return psum, pseg[:, 0]
