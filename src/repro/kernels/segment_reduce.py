"""Segment-reduce kernel: the combine leg of Combine-Shuffle-Reduce (§5.3.4).

Input: values sorted by segment id (the groupby sort order) + the segment
ids. Per row block, the kernel reduces rows into at most ``max_segments``
block-local partials using a one-hot (block x max_segments) matmul — the
MXU-native replacement for scatter-add (TPU has no atomics; DESIGN.md §2).
Cross-block merging of partials (cheap: nb x max_segments rows) stays in
jnp (ops.segment_sum), mirroring the paper's combine -> shuffle -> reduce
split where the combine output is small (O(n*C)).

Precondition: every block spans <= max_segments distinct segments (callers
size max_segments from the sampled cardinality, paper §5.4.1; ops.py
verifies and falls back to the jnp path otherwise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_reduce_partials"]


def _kernel(vals_ref, segs_ref, psum_ref, pseg_ref, *, block, width, max_segments, op):
    vals = vals_ref[...].astype(jnp.float32)   # (block, width)
    segs = segs_ref[...][:, 0]                 # (block,) int32, sorted
    base = segs[0]
    local = segs - base                        # block-local dense ids
    local = jnp.clip(local, 0, max_segments - 1)
    sid = jax.lax.broadcasted_iota(jnp.int32, (block, max_segments), 1)
    onehot = (local[:, None] == sid).astype(jnp.float32)  # (block, maxseg)
    if op == "sum":
        out = jax.lax.dot_general(onehot, vals, (((0,), (0,)), ((), ())))
    elif op == "max":
        big = jnp.where(onehot[..., None] > 0, vals[:, None, :], -jnp.inf)
        out = jnp.max(big, axis=0)
    elif op == "min":
        big = jnp.where(onehot[..., None] > 0, vals[:, None, :], jnp.inf)
        out = jnp.min(big, axis=0)
    else:
        raise ValueError(op)
    psum_ref[...] = out                         # (max_segments, width)
    pseg_ref[...] = (base + jax.lax.iota(jnp.int32, max_segments))[:, None]


def segment_reduce_partials(
    values: jax.Array,     # (N, width) sorted by segment
    seg_ids: jax.Array,    # (N,) int32 non-decreasing
    *,
    max_segments: int = 128,
    block: int = 1024,
    op: str = "sum",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (partials (nb*max_segments, width) f32,
    partial_seg_ids (nb*max_segments,) int32). Partials for segment ids the
    block does not contain are identity-valued and their ids may collide
    with real ids only on identity values — safe for sum/max/min merging."""
    N, width = values.shape
    assert N % block == 0, (N, block)
    nb = N // block

    kernel = functools.partial(_kernel, block=block, width=width,
                               max_segments=max_segments, op=op)
    psum, pseg = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block, width), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((max_segments, width), lambda i: (i, 0)),
            pl.BlockSpec((max_segments, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * max_segments, width), jnp.float32),
            jax.ShapeDtypeStruct((nb * max_segments, 1), jnp.int32),
        ],
        interpret=interpret,
    )(values, seg_ids[:, None].astype(jnp.int32))
    return psum, pseg[:, 0]
