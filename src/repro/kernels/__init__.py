"""Pallas kernel layer: hand-written TPU kernels behind cost-model dispatch.

Two kernel families live here:

- **dataframe kernels** — ``hash_partition`` (the shuffle build side) and
  ``segment_reduce`` / ``segment_reduce_partials`` (the groupby combine
  leg). The engine hot paths (``core.partition.hash_partition_ids``,
  ``core.local_ops.local_groupby``) route through them via the dispatch
  :mod:`~repro.kernels.registry`: native Pallas on TPU when
  ``cost_model.kernel_params`` says it is profitable, ``interpret=True``
  as the bit-identical CPU correctness mode, plain jnp otherwise. Override
  process-wide with :func:`set_backend` (``"pallas" | "jnp" | "auto"``) or
  the ``REPRO_KERNEL_BACKEND`` environment variable. See docs/KERNELS.md.
- **model kernels** — ``flash_attention`` and ``ssd_scan`` for the LM
  workloads sharing the mesh (dispatching on TPU presence only).

``ops`` holds the dispatching wrappers, ``ref`` the pure-jnp fallbacks /
oracles, ``registry`` the backend override + decision logic.
"""

from . import ops, ref, registry  # noqa: F401
from .ops import (  # noqa: F401
    hash_partition,
    partition_histogram,
    segment_reduce,
    segment_reduce_partials,
)
from .registry import (  # noqa: F401
    dispatch_signature,
    explain,
    get_backend,
    resolve,
    set_backend,
    use_backend,
)

__all__ = [
    "ops",
    "ref",
    "registry",
    "hash_partition",
    "partition_histogram",
    "segment_reduce",
    "segment_reduce_partials",
    "set_backend",
    "get_backend",
    "use_backend",
    "resolve",
    "explain",
    "dispatch_signature",
]
