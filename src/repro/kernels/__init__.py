from . import ops, ref  # noqa: F401
