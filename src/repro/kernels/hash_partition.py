"""Hash-partition kernel: the paper's hot auxiliary operator (§4.2).

Computes, per row block: the lowbias32 key hash, the destination partition
id (hash % P), and a per-block destination histogram. The histogram is the
quota-planning input (paper §5.4.2 — sampled data distribution drives the
shuffle quota) and the scatter offsets come from its exclusive scan.

TPU-native shape: rows are processed in (block x 1) lanes; the histogram
uses a one-hot (block x P) matmul against ones — an MXU-friendly reduction
instead of the GPU-style atomic-increment histogram (which has no TPU
analogue; DESIGN.md §2).

This is the raw kernel (N must be a block multiple, keys already uint32);
the engine calls it through ``ops.hash_partition`` (padding + histogram
correction + registry dispatch) from ``partition.hash_partition_ids``,
with ``partition.u32_normalize`` pre-normalizing key dtypes so the hash
equals the jnp chain bit-for-bit (docs/KERNELS.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hash_partition"]

_M1 = 0x7FEB352D
_M2 = 0x846CA68B
_GOLDEN = 0x9E3779B9


def _mix(keys, *, num_partitions, block, n_cols):
    h = jnp.zeros((block,), jnp.uint32)
    for c in range(n_cols):
        x = keys[:, c]
        x = x ^ (x >> 16)
        x = x * jnp.uint32(_M1)
        x = x ^ (x >> 15)
        x = x * jnp.uint32(_M2)
        x = x ^ (x >> 16)
        h = h ^ (x + jnp.uint32(_GOLDEN) + (h << 6) + (h >> 2))
    return (h % jnp.uint32(num_partitions)).astype(jnp.int32)


def _kernel(keys_ref, dest_ref, hist_ref, *, num_partitions, block, n_cols):
    keys = keys_ref[...]                      # (block, n_cols) uint32
    dest = _mix(keys, num_partitions=num_partitions, block=block, n_cols=n_cols)
    dest_ref[...] = dest[:, None]
    # one-hot histogram via compare + sum (VPU/MXU friendly)
    pid = jax.lax.broadcasted_iota(jnp.int32, (block, num_partitions), 1)
    onehot = (dest[:, None] == pid).astype(jnp.float32)
    hist_ref[...] = jnp.sum(onehot, axis=0, keepdims=True).astype(jnp.int32)


def _kernel_dest_only(keys_ref, dest_ref, *, num_partitions, block, n_cols):
    keys = keys_ref[...]
    dest = _mix(keys, num_partitions=num_partitions, block=block, n_cols=n_cols)
    dest_ref[...] = dest[:, None]


def hash_partition(
    keys: jax.Array,       # (N, n_cols) any int dtype (bitcast to u32)
    num_partitions: int,
    *,
    block: int = 1024,
    interpret: bool = False,
    with_hist: bool = True,
) -> tuple[jax.Array, jax.Array | None]:
    """Returns (dest (N,) int32, hist (num_blocks, P) int32).

    ``with_hist=False`` skips the (block x P) one-hot histogram reduction
    entirely (hist comes back ``None``) — the shape the shuffle build side
    wants, since ``hash_partition_ids`` only consumes the destinations."""
    if keys.ndim == 1:
        keys = keys[:, None]
    N, n_cols = keys.shape
    assert N % block == 0, (N, block)
    nb = N // block
    ku = keys.astype(jnp.uint32)
    opts = dict(num_partitions=num_partitions, block=block, n_cols=n_cols)

    if not with_hist:
        dest = pl.pallas_call(
            functools.partial(_kernel_dest_only, **opts),
            grid=(nb,),
            in_specs=[pl.BlockSpec((block, n_cols), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
            interpret=interpret,
        )(ku)
        return dest[:, 0], None

    dest, hist = pl.pallas_call(
        functools.partial(_kernel, **opts),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, n_cols), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, num_partitions), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
            jax.ShapeDtypeStruct((nb, num_partitions), jnp.int32),
        ],
        interpret=interpret,
    )(ku)
    return dest[:, 0], hist
