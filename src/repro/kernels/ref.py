"""Pure-jnp oracles for every Pallas kernel.

The model-layer kernels (flash attention, SSD scan) are compared against
these with ``allclose``; the dataframe kernels (``hash_partition_ref``,
``segment_reduce_ref``) are also the *dispatch fallbacks* — the registry's
"jnp" mode — so they must be (and are property-tested to be) bit-identical
to the Pallas path wherever the operation is associative (integer hashing
and sums, min/max in every dtype)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "ssd_scan_ref", "hash_partition_ref",
           "segment_reduce_ref"]


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """q: (B,S,H,hd); k/v: (B,S,KV,hd) -> (B,S,H,hd). Dense masked softmax."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgc,bthc->bhgqt", qg, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bthc->bqhgc", p, vf)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, D, *, chunk=128):
    """Identical semantics to kernels.ssd_scan (sequential recurrence)."""
    from ..models.ssm import ssd_scan_ref as _model_ref
    y, _ = _model_ref(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                      B.astype(jnp.float32), C.astype(jnp.float32), chunk)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype)


def hash_partition_ref(keys, num_partitions):
    """Destination ids + histogram from the lowbias32 hash chain.

    Must match ``partition.hash32``/``hash_columns`` bit-for-bit: callers
    pass pre-normalized uint32 key columns (``partition.u32_normalize``
    handles 64-bit folding / bool / float bitcasting)."""
    if keys.ndim == 1:
        keys = keys[:, None]
    h = jnp.zeros((keys.shape[0],), jnp.uint32)
    for c in range(keys.shape[1]):
        x = keys[:, c].astype(jnp.uint32)
        x = x ^ (x >> 16)
        x = x * jnp.uint32(0x7FEB352D)
        x = x ^ (x >> 15)
        x = x * jnp.uint32(0x846CA68B)
        x = x ^ (x >> 16)
        h = h ^ (x + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2))
    dest = (h % jnp.uint32(num_partitions)).astype(jnp.int32)
    hist = jnp.zeros((num_partitions,), jnp.int32).at[dest].add(1)
    return dest, hist


def segment_reduce_ref(values, seg_ids, num_segments, op="sum"):
    """Dtype-preserving direct segment reduction (scatter-add/min/max).

    This is the "jnp" dispatch path of ``ops.segment_reduce`` and the
    semantics the kernel path must reproduce: integer ops are exact (wrap
    like the kernel's integer matmul), min/max exact in every dtype."""
    if op == "sum":
        return jax.ops.segment_sum(values, seg_ids, num_segments=num_segments)
    if op == "max":
        return jax.ops.segment_max(values, seg_ids, num_segments=num_segments)
    if op == "min":
        return jax.ops.segment_min(values, seg_ids, num_segments=num_segments)
    raise ValueError(op)
