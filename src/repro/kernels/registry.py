"""Kernel dispatch registry: route hot paths to Pallas or jnp (ISSUE 5).

The dataframe hot paths (hash partitioning on the shuffle build side,
segment aggregation in the groupby combine leg) call :func:`resolve` at
trace time to pick an execution mode per kernel:

- ``"pallas"``    — the native Pallas lowering (TPU);
- ``"interpret"`` — the same kernel body executed via
  ``pallas_call(interpret=True)``: bit-identical semantics on any backend,
  used as the CPU correctness fallback so parity tests and the CI smoke
  leg run without a TPU;
- ``"jnp"``       — the plain jax.numpy implementation (the pre-ISSUE-5
  behavior, and the fallback whenever Pallas is not profitable).

Dispatch is driven by two inputs:

1. the process-wide **backend override** — ``set_backend("pallas")`` forces
   the Pallas path everywhere (interpret mode off-TPU), ``"jnp"`` pins the
   plain path, ``"auto"`` (default) defers to the cost model. The initial
   value comes from the ``REPRO_KERNEL_BACKEND`` environment variable (the
   CI kernel smoke leg sets it);
2. the **cost model** — ``repro.core.cost_model.kernel_params`` supplies
   per-kernel row thresholds, supported dtypes and the native-lowering flag
   for the current jax backend. ``auto`` picks Pallas only when
   ``KernelParams.profitable`` says the launch overhead amortizes.

Because the decision is taken at trace time, every compiled-operator cache
key must include :func:`dispatch_signature` — ``repro.core.api.cached_op``
and the plan cache in ``repro.plan.executor`` do — so flipping the backend
never aliases a compiled program built for the other one.
"""

from __future__ import annotations

import contextlib
import functools
import os

import jax

from ..core import cost_model

__all__ = [
    "KERNEL_OPS",
    "set_backend",
    "get_backend",
    "use_backend",
    "current_params",
    "resolve",
    "explain",
    "dispatch_signature",
]

# kernels the registry dispatches (names match cost_model.kernel_params)
KERNEL_OPS = ("hash_partition", "segment_reduce")

_VALID = ("auto", "pallas", "jnp")

_backend = os.environ.get("REPRO_KERNEL_BACKEND", "auto")
if _backend not in _VALID:
    raise ValueError(
        f"REPRO_KERNEL_BACKEND={_backend!r} invalid; expected one of {_VALID}")


def set_backend(mode: str) -> str:
    """Set the process-wide kernel backend override; returns the previous
    value.

    ``"pallas"`` forces the Pallas path for every dispatched kernel
    (native on TPU, ``interpret=True`` elsewhere — bit-identical, slow);
    ``"jnp"`` pins the plain jax.numpy path; ``"auto"`` (the default)
    lets ``cost_model.kernel_params`` decide per kernel and row count.
    Compiled-op caches key on the override, so flipping it retraces
    rather than reusing programs built for the other backend."""
    global _backend
    if mode not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {mode!r}")
    prev = _backend
    _backend = mode
    return prev


def get_backend() -> str:
    """Current backend override: "auto" | "pallas" | "jnp"."""
    return _backend


@contextlib.contextmanager
def use_backend(mode: str):
    """Context manager form of :func:`set_backend` (restores on exit)."""
    prev = set_backend(mode)
    try:
        yield
    finally:
        set_backend(prev)


@functools.lru_cache(maxsize=8)
def _params(jax_backend: str) -> cost_model.KernelParams:
    return cost_model.kernel_params(jax_backend)


def current_params() -> cost_model.KernelParams:
    """The :class:`~repro.core.cost_model.KernelParams` for the current jax
    backend (cached per backend name)."""
    return _params(jax.default_backend())


def resolve(kernel: str, n_rows: int, dtype=None) -> str:
    """Pick the execution mode for one kernel call at trace time.

    Args:
      kernel: a :data:`KERNEL_OPS` name.
      n_rows: static row count of the call (the partition capacity).
      dtype: value dtype, for the kernel's supported-dtype gate (``None``
        skips the gate — hash_partition normalizes all dtypes itself).

    Returns:
      "pallas" | "interpret" | "jnp". A forced ``"pallas"`` backend still
      returns "jnp" for dtypes the kernel cannot lower — the jnp path *is*
      the kernel's semantics, so forced-parity runs stay exact.
    """
    if kernel not in KERNEL_OPS:
        raise ValueError(f"unknown kernel {kernel!r}; expected {KERNEL_OPS}")
    decision = _decide(kernel, n_rows, dtype)
    _note_dispatch(kernel, n_rows, decision)
    return decision


def _decide(kernel: str, n_rows: int, dtype) -> str:
    p = current_params()
    if _backend == "jnp":
        return "jnp"
    if dtype is not None and not p.dtype_supported(kernel, dtype):
        return "jnp"
    if _backend == "pallas":
        return "pallas" if p.native else "interpret"
    return "pallas" if p.profitable(kernel, n_rows, dtype) else "jnp"


def _note_dispatch(kernel: str, n_rows: int, decision: str) -> None:
    """Record one dispatch decision: always counted in the global metrics
    registry; while tracing, also attached to the enclosing span's
    ``kernel_dispatch`` attr (or an instant event when no span is open).
    Dispatch happens at trace time, so the cost is per compile, not per
    batch. Imports are deferred — ``repro.obs`` pulls in no engine modules,
    but keeping the registry import-light avoids any cycle risk."""
    from ..obs import metrics as _metrics
    from ..obs import trace as _trace

    _metrics.registry().counter(f"kernels.dispatch.{kernel}.{decision}").add(1)
    if _trace.enabled():
        sp = _trace.current_span()
        entry = {"kernel": kernel, "n_rows": int(n_rows),
                 "decision": decision}
        if sp is not None:
            sp.attrs.setdefault("kernel_dispatch", []).append(entry)
        else:
            _trace.instant("kernels.dispatch", **entry)


def explain(kernel: str, n_rows: int, dtype=None) -> dict:
    """The :func:`resolve` decision plus the model inputs that produced it
    (for benchmarks and debugging dispatch behavior). Unlike
    :func:`resolve`, no dispatch decision is recorded — explaining is not
    dispatching."""
    p = current_params()
    return {
        "kernel": kernel,
        "n_rows": int(n_rows),
        "dtype": None if dtype is None else str(dtype),
        "backend_override": _backend,
        "jax_backend": p.backend,
        "native": p.native,
        "min_rows": int(p.min_rows.get(kernel, 0)),
        "dtype_supported": (dtype is None or p.dtype_supported(kernel, dtype)),
        "decision": _decide(kernel, n_rows, dtype),
    }


def dispatch_signature() -> tuple:
    """Stable key component capturing every global input to :func:`resolve`
    — include it in any cache keyed on traced kernel behavior."""
    return (_backend, jax.default_backend())
