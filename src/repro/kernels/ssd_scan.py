"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

Grid = (B*H, n_chunks) with chunks iterated sequentially (minor-most), so
the recurrent (d_state x d_head) SSM state lives in VMEM scratch across
chunk steps — the inter-chunk recurrence happens *inside* the kernel, not
as a host-level scan. Per chunk the intra-chunk work is three MXU matmuls
(C@B^T masked by the decay kernel, the score@x product, and the state
update B^T@x), exactly the SSD block decomposition (arXiv:2405.21060).

This is the hardware adaptation of the paper's "local core operator +
carry" structure: quadratic-in-chunk compute is MXU-shaped; the carried
state is the halo (DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_scr, *,
            chunk, dh, ds):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)      # (chunk, dh)
    dt = dt_ref[0].astype(jnp.float32)    # (chunk, 1)
    A = a_ref[0, 0]                       # scalar (negative decay rate)
    Bp = b_ref[0].astype(jnp.float32)     # (chunk, ds)
    Cp = c_ref[0].astype(jnp.float32)     # (chunk, ds)
    D = d_ref[0, 0]

    a = A * dt[:, 0]                      # (chunk,) log-decay per step
    acum = jnp.cumsum(a)                  # inclusive
    # decay kernel L[i,j] = exp(acum[i] - acum[j] + a[j])? — careful:
    # L[i,j] = exp(sum_{j<k<=i} a[k]) = exp(acum[i] - acum[j]) for j <= i
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(acum[:, None] - acum[None, :]), 0.0)

    xb = x * dt                           # discretized input (chunk, dh)
    scores = jax.lax.dot_general(Cp, Bp, (((1,), (1,)), ((), ()))) * L
    y = jax.lax.dot(scores, xb)           # intra-chunk (chunk, dh)

    # inter-chunk: contribution of the incoming state
    state = state_scr[...]                # (ds, dh)
    y += jax.lax.dot(Cp * jnp.exp(acum)[:, None], state)

    # state update: state' = exp(sum a) * state + B^T diag(exp(acum[-1]-acum)) xb
    decay_tail = jnp.exp(acum[chunk - 1] - acum)          # (chunk,)
    state_scr[...] = (jnp.exp(acum[chunk - 1]) * state
                      + jax.lax.dot_general(Bp * decay_tail[:, None], xb,
                                            (((0,), (0,)), ((), ()))))
    y_ref[0] = (y + D * x).astype(y_ref.dtype)


def ssd_scan(
    x: jax.Array,    # (B, L, H, dh)
    dt: jax.Array,   # (B, L, H) positive
    A: jax.Array,    # (H,) negative
    B: jax.Array,    # (B, L, G, ds); G must divide H
    C: jax.Array,    # (B, L, G, ds)
    D: jax.Array,    # (H,)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns y (B, L, H, dh) = SSD(x) + D*x, state carried inside kernel."""
    b, L, H, dh = x.shape
    G, ds = B.shape[2], B.shape[3]
    rep = H // G
    nc = L // chunk
    assert L % chunk == 0, (L, chunk)

    xf = x.transpose(0, 2, 1, 3).reshape(b * H, L, dh)
    dtf = dt.transpose(0, 2, 1).reshape(b * H, L, 1)
    af = jnp.tile(A, b).reshape(b * H, 1)
    df = jnp.tile(D, b).reshape(b * H, 1)
    # B/C indexed per (batch, group): bh -> (bh//H)*G + (bh%H)//rep
    Bf = B.transpose(0, 2, 1, 3).reshape(b * G, L, ds)
    Cf = C.transpose(0, 2, 1, 3).reshape(b * G, L, ds)

    def bc_map(bh, ci, H=H, G=G, rep=rep):
        return ((bh // H) * G + (bh % H) // rep, ci, 0)

    kernel = functools.partial(_kernel, chunk=chunk, dh=dh, ds=ds)
    out = pl.pallas_call(
        kernel,
        grid=(b * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, chunk, ds), bc_map),
            pl.BlockSpec((1, chunk, ds), bc_map),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dh), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * H, L, dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((ds, dh), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, Bf, Cf, df)
    return out.reshape(b, H, L, dh).transpose(0, 2, 1, 3)
