"""Mid-stream adaptive re-planning for the morsel-driven runner.

:class:`AdaptiveController` is the feedback half of the statistics
subsystem: while the streaming runner drives a carry-fold (groupby /
unique), the controller ingests each batch's *observed* facts — rows
admitted, the host-side hash-partition histogram over the shuffle keys,
and the per-worker partial-group counts — and, when the plan's static
quota/capacity drift far enough from what the data actually does,
re-derives those knobs for all later morsels (generalizing the spill
join's double-on-overflow capacity growth into proactive, histogram-led
correction).

Corrections are **result-invariant**: quota/capacity/num_chunks only size
static buffers, so any values large enough for the data produce
bit-identical output (undersized ones raise loudly under
``strict_overflow``). That, plus fully deterministic decision rules and
JSON-able state snapshotted into ``StreamCheckpoint`` (``state_dict`` /
``restore``), keeps resumed adaptive queries bit-identical to
uninterrupted ones — and to non-adaptive and eager execution.

Knobs live in ``cost_model``: ``ADAPTIVE_REPLAN_EVERY`` (decision
cadence, in batches), ``ADAPTIVE_DRIFT`` (relative quota drift that
triggers a re-plan), ``ADAPTIVE_QUOTA_SAFETY`` / ``ADAPTIVE_CAPACITY_SAFETY``
(headroom over the observed maxima).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import cost_model
from ..core import patterns

__all__ = ["AdaptiveController"]

#: hard cap on re-plans per query: each re-plan recompiles the pipeline
#: for the new static shapes, so corrections must stay rare
_MAX_REPLANS = 4


class AdaptiveController:
    """Deterministic quota/capacity feedback controller for one stream.

    The runner calls :meth:`observe` once per batch with what actually
    happened, :meth:`should_replan` at the re-plan cadence, and
    :meth:`apply` to rewrite the batch-root node when a correction is
    due. ``state_dict``/``restore`` round-trip the whole decision state
    through JSON so a checkpoint taken mid-correction resumes with the
    same future decisions (bit-identical results either way).
    """

    def __init__(self, num_partitions: int, plan_quota: int,
                 plan_capacity: int,
                 replan_every: int | None = None):
        self.P = int(num_partitions)
        self.plan_quota = int(plan_quota)
        self.plan_capacity = int(plan_capacity)
        self.replan_every = int(replan_every
                                or cost_model.ADAPTIVE_REPLAN_EVERY)
        self.batches = 0
        self.replans = 0
        self.max_hist = 0        # max rows any one partition received
        self.max_groups = 0      # max partial groups on any one worker
        self.rows_ewma = 0.0
        self.card_ewma = 0.0     # observed groups_out / rows_in
        self.quota_override: int | None = None
        self.capacity_override: int | None = None

    # -- observation ----------------------------------------------------

    def observe(self, rows_in: int, hist=None, groups_out=None,
                max_worker_groups=None) -> None:
        """Fold one batch's observed facts into the controller state.

        ``hist`` is the host hash-partition histogram over the shuffle
        keys (len P); ``groups_out`` the batch's total surviving groups;
        ``max_worker_groups`` the largest per-worker partial count."""
        self.batches += 1
        w = 0.5
        self.rows_ewma = (rows_in if self.batches == 1
                          else w * rows_in + (1 - w) * self.rows_ewma)
        if hist is not None and len(hist):
            self.max_hist = max(self.max_hist, int(np.max(hist)))
        if groups_out is not None and rows_in > 0:
            card = min(float(groups_out) / float(rows_in), 1.0)
            self.card_ewma = (card if self.card_ewma == 0.0
                              else w * card + (1 - w) * self.card_ewma)
        if max_worker_groups is not None:
            self.max_groups = max(self.max_groups, int(max_worker_groups))

    # -- decisions ------------------------------------------------------

    def _target_quota(self) -> int | None:
        if self.max_hist <= 0:
            return None
        return patterns.quota_from_histogram(
            np.asarray([self.max_hist]), self.plan_capacity, self.P,
            safety=cost_model.ADAPTIVE_QUOTA_SAFETY)

    def should_replan(self) -> bool:
        """True when it's a decision point and observed quota need has
        drifted more than ``ADAPTIVE_DRIFT`` from the current plan."""
        if self.replans >= _MAX_REPLANS or self.batches == 0:
            return False
        if self.batches % self.replan_every != 0:
            return False
        target = self._target_quota()
        if target is None:
            return False
        current = self.quota_override or self.plan_quota
        drift = abs(target - current) / max(float(current), 1.0)
        return drift > cost_model.ADAPTIVE_DRIFT

    def apply(self, node):
        """Recompute the quota/capacity corrections from everything
        observed so far, then return ``node`` with them pinned for all
        later morsels (one re-plan consumed)."""
        self.replans += 1
        target = self._target_quota()
        if target is not None:
            self.quota_override = int(target)
        if self.max_groups > 0:
            cap = int(min(
                self.plan_capacity,
                max(self.max_groups * cost_model.ADAPTIVE_CAPACITY_SAFETY,
                    16)))
            self.capacity_override = cap
        return self.pin(node)

    def pin(self, node):
        """Return ``node`` with the *current* overrides applied (no new
        decision — what a checkpoint-resumed stream uses to re-enter the
        exact corrected plan). The optimizer keeps explicit values;
        ``num_chunks`` resets to None so it re-derives for the new
        shapes."""
        fields = {f.name for f in dataclasses.fields(node)}
        repl = {}
        if self.quota_override is not None and "quota" in fields:
            repl["quota"] = self.quota_override
        if self.capacity_override is not None and "capacity" in fields:
            repl["capacity"] = self.capacity_override
        if repl and "num_chunks" in fields:
            repl["num_chunks"] = None
        if (repl and self.card_ewma > 0.0 and "cardinality_hint" in fields):
            repl["cardinality_hint"] = round(
                min(max(self.card_ewma, 1e-3), 1.0), 3)
        return dataclasses.replace(node, **repl) if repl else node

    @property
    def current_quota(self) -> int:
        """The quota later morsels will run with (override or plan)."""
        return self.quota_override or self.plan_quota

    # -- checkpoint plumbing --------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able snapshot of the full decision state."""
        return {
            "P": self.P,
            "plan_quota": self.plan_quota,
            "plan_capacity": self.plan_capacity,
            "replan_every": self.replan_every,
            "batches": self.batches,
            "replans": self.replans,
            "max_hist": self.max_hist,
            "max_groups": self.max_groups,
            "rows_ewma": self.rows_ewma,
            "card_ewma": self.card_ewma,
            "quota_override": self.quota_override,
            "capacity_override": self.capacity_override,
        }

    @classmethod
    def restore(cls, state: dict) -> "AdaptiveController":
        """Rebuild a controller from :meth:`state_dict` output; resumed
        streams make exactly the decisions the interrupted one would."""
        c = cls(state["P"], state["plan_quota"], state["plan_capacity"],
                state.get("replan_every"))
        c.batches = int(state["batches"])
        c.replans = int(state["replans"])
        c.max_hist = int(state["max_hist"])
        c.max_groups = int(state["max_groups"])
        c.rows_ewma = float(state["rows_ewma"])
        c.card_ewma = float(state["card_ewma"])
        qo = state.get("quota_override")
        co = state.get("capacity_override")
        c.quota_override = None if qo is None else int(qo)
        c.capacity_override = None if co is None else int(co)
        return c
