"""Statistics subsystem: chunk sketches, estimation, adaptive re-planning.

Three cooperating parts (ISSUE 9):

- :mod:`repro.stats.sketch` — per-chunk :class:`ChunkStats` (row count,
  per-column min/max, KMV distinct sketch) computed at dataset write time
  and serialized into the JSON manifest; mergeable to dataset level;
  :func:`backfill_stats` migrates pre-stats datasets in place.
- :mod:`repro.stats.estimate` — interval evaluation of absorbed scan
  predicates over chunk bounds (:func:`chunk_skip_mask`: skip whole
  chunks before decode, never a chunk that could match), real selectivity
  and key-cardinality estimates, and :class:`PlanStats`, the bundle the
  plan optimizer / cost model / admission controller consume in place of
  fixed ratios.
- :mod:`repro.stats.adaptive` — :class:`AdaptiveController`, the
  mid-stream feedback loop correcting quota/capacity/num_chunks for later
  morsels from observed batch cardinalities, checkpoint-snapshotted so
  resumed queries stay bit-identical.

See docs/STATISTICS.md for formats, formulas, and knobs.
"""

from .sketch import (
    ChunkStats,
    ColumnStats,
    DEFAULT_KMV_K,
    STATS_VERSION,
    backfill_stats,
    hash32,
    merge_chunk_stats,
)
from .estimate import (
    Interval,
    PlanStats,
    chunk_skip_mask,
    expr_interval,
    key_cardinality,
    plan_stats,
    predicate_selectivity,
    scan_row_estimate,
)
from .adaptive import AdaptiveController

__all__ = [
    "ColumnStats",
    "ChunkStats",
    "merge_chunk_stats",
    "hash32",
    "DEFAULT_KMV_K",
    "STATS_VERSION",
    "backfill_stats",
    "Interval",
    "expr_interval",
    "chunk_skip_mask",
    "predicate_selectivity",
    "key_cardinality",
    "scan_row_estimate",
    "PlanStats",
    "plan_stats",
    "AdaptiveController",
]
