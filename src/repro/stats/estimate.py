"""Predicate interval evaluation, chunk skipping, and plan estimates.

The read-time half of the statistics subsystem: given the per-chunk
sketches ``repro.stats.sketch`` serialized into a dataset manifest, this
module answers three planner questions —

1. **Which chunks can be skipped?** :func:`chunk_skip_mask` evaluates each
   absorbed scan predicate over per-chunk min/max bounds with interval
   arithmetic. A chunk is skipped only when some conjunct is *provably*
   false for every row the bounds admit — the mask is always a subset of
   the truly-empty chunks, so skipping is bit-identical (a skipped chunk's
   rows would all have been filtered before device admission anyway).
2. **How selective is a scan?** :func:`predicate_selectivity` /
   ``PlanStats.scan_selectivity`` replace the optimizer's fixed
   ``SELECT_SELECTIVITY = 0.5`` per predicate with a per-chunk,
   count-weighted estimate: provably true/false chunks contribute 1/0,
   ``col <op> literal`` chunks contribute the uniform-range fraction
   (equality via the KMV distinct estimate), everything else falls back
   to the fixed ratio.
3. **How many groups will a groupby/unique produce?**
   ``PlanStats.groupby_cardinality`` combines per-key-column KMV distinct
   estimates (capped by the row count) into the cardinality fraction
   ``patterns.plan_groupby`` and ``cost_model`` consume in place of the
   ``UNKNOWN_CARDINALITY`` sentinel.

Everything here is conservative by construction: a missing sketch, an
unknown bound, an unsupported expression shape, or a legacy callable
predicate yields "no estimate", and callers fall back to the fixed
ratios — stats can tighten plans, never corrupt them.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Mapping

import numpy as np

from ..core.vocab import DICT_DTYPE
from ..expr.tree import (
    Alias,
    BinOp,
    Cast,
    Col,
    Cond,
    Expr,
    Lit,
    UnaryOp,
)
from ..plan.logical import (
    GroupBy,
    Project,
    Rebalance,
    Recode,
    Scan,
    Select,
    Unique,
    walk,
)
from .sketch import ChunkStats, merge_chunk_stats

__all__ = [
    "Interval",
    "expr_interval",
    "chunk_skip_mask",
    "predicate_selectivity",
    "key_cardinality",
    "scan_row_estimate",
    "PlanStats",
    "plan_stats",
]

_FIXED_SELECTIVITY = 0.5  # mirror of plan.logical.SELECT_SELECTIVITY

#: node types that pass key columns through from a scan unchanged — the
#: transparency condition for trusting scan-level key sketches at a
#: downstream groupby/unique (Rename/WithColumn/MapColumns/Join all may
#: rewrite or multiply keys, so they opt out of estimation). Recode is a
#: per-column injective code remap: it changes code *values* but never the
#: number of distinct keys, which is all the cardinality path consumes.
_KEY_TRANSPARENT = (Scan, Select, Project, Rebalance, Recode)


@dataclasses.dataclass(frozen=True)
class Interval:
    """Closed value interval with a boolean tag.

    ``boolish`` marks intervals whose values are boolean 0/1 (comparison
    results, boolean literals/columns): only boolish operands combine
    under ``&``/``|``/``^``/``~``-as-not, keeping logical and bitwise
    integer semantics apart. ``None`` anywhere upstream means "unknown"
    and poisons conservatively.
    """

    lo: float
    hi: float
    boolish: bool = False


_TRUE = Interval(1, 1, True)
_FALSE = Interval(0, 0, True)
_MAYBE = Interval(0, 1, True)


def _widen_f32(lo, hi):
    """Widen bounds past float32 rounding (Cast-to-float can round a bound
    toward the interval's interior; nextafter pushes both ends back out)."""
    lo32, hi32 = np.float32(lo), np.float32(hi)
    return (float(np.nextafter(lo32, -np.inf)),
            float(np.nextafter(hi32, np.inf)))


def _bool_pair(a: Interval, b: Interval, fn) -> Interval:
    vals = {fn(x, y) for x in (int(a.lo), int(a.hi))
            for y in (int(b.lo), int(b.hi))}
    return Interval(min(vals), max(vals), True)


def _cmp(op: str, l: Interval, r: Interval) -> Interval:
    """Comparison over intervals: certainly true / certainly false / maybe."""
    if op == "gt":
        if l.lo > r.hi:
            return _TRUE
        if l.hi <= r.lo:
            return _FALSE
        return _MAYBE
    if op == "ge":
        if l.lo >= r.hi:
            return _TRUE
        if l.hi < r.lo:
            return _FALSE
        return _MAYBE
    if op == "lt":
        return _cmp("gt", r, l)
    if op == "le":
        return _cmp("ge", r, l)
    if op == "eq":
        if l.lo == l.hi == r.lo == r.hi:
            return _TRUE
        if l.hi < r.lo or l.lo > r.hi:
            return _FALSE
        return _MAYBE
    if op == "ne":
        inner = _cmp("eq", l, r)
        return Interval(1 - inner.hi, 1 - inner.lo, True)
    raise KeyError(op)


def _arith(op: str, l: Interval, r: Interval) -> Interval | None:
    if op in ("add", "sub", "mul"):
        if op == "add":
            cands = [l.lo + r.lo, l.hi + r.hi]
        elif op == "sub":
            cands = [l.lo - r.hi, l.hi - r.lo]
        else:
            cands = [x * y for x in (l.lo, l.hi) for y in (r.lo, r.hi)]
        cands = [c for c in cands if not math.isnan(c)]
        if not cands:
            return None
        return Interval(min(cands), max(cands))
    if op in ("truediv", "floordiv"):
        if r.lo <= 0 <= r.hi:
            return None  # divisor range spans 0
        cands = [x / y for x in (l.lo, l.hi) for y in (r.lo, r.hi)]
        lo, hi = min(cands), max(cands)
        if op == "floordiv":
            lo, hi = math.floor(lo), math.floor(hi)
        return Interval(lo, hi)
    if op == "mod":
        if r.lo == r.hi and r.lo > 0:
            return Interval(0, r.lo)  # closed over float fmod too
        return None
    return None  # pow and anything exotic: unknown


def expr_interval(e, ranges: Mapping[str, Interval]) -> Interval | None:
    """Evaluate an expression tree to a value interval over column bounds.

    ``ranges`` maps column name -> :class:`Interval` of that column's
    values in the row set under consideration (a chunk); columns with
    unusable bounds are simply absent. Returns None for anything that
    cannot be bounded soundly — every consumer treats None as "cannot
    prune / no estimate". Legacy callable predicates are not ``Expr``
    instances and return None here by construction."""
    if not isinstance(e, Expr):
        return None
    if isinstance(e, Alias):
        return expr_interval(e.child, ranges)
    if isinstance(e, Col):
        return ranges.get(e.name)
    if isinstance(e, Lit):
        if e.kind == "bool":
            return _TRUE if e.value else _FALSE
        if e.kind == "str":
            # an *unbound* string literal (bound ones are int code
            # literals); no numeric interval exists for it
            return None
        v = float(e.value)
        if math.isnan(v):
            return None
        return Interval(v, v)
    if isinstance(e, Cast):
        iv = expr_interval(e.child, ranges)
        if iv is None or iv.boolish:
            return iv  # bool cast keeps 0/1 values
        kind = np.dtype(e.dtype).kind
        if kind in ("i", "u"):
            # astype truncates toward zero; floor/ceil bounds cover it
            return Interval(math.floor(iv.lo), math.ceil(iv.hi))
        if kind == "f":
            lo, hi = _widen_f32(iv.lo, iv.hi)
            return Interval(lo, hi)
        if kind == "b":
            return None  # truthiness cast: not worth modelling
        return None
    if isinstance(e, UnaryOp):
        iv = expr_interval(e.child, ranges)
        if iv is None:
            return None
        if e.op == "neg":
            return Interval(-iv.hi, -iv.lo)
        if e.op == "abs":
            lo, hi = abs(iv.lo), abs(iv.hi)
            if iv.lo <= 0 <= iv.hi:
                return Interval(0, max(lo, hi))
            return Interval(min(lo, hi), max(lo, hi))
        if e.op == "invert":
            if iv.boolish:
                return Interval(1 - iv.hi, 1 - iv.lo, True)
            return Interval(-iv.hi - 1, -iv.lo - 1)  # int ~x == -x-1
        return None
    if isinstance(e, BinOp):
        l = expr_interval(e.left, ranges)
        r = expr_interval(e.right, ranges)
        if e.op in ("and", "or", "xor"):
            # short-circuit soundly: certainly-false & anything is false,
            # certainly-true | anything is true — even if the other side
            # is unbounded
            if e.op == "and" and ((l is not None and l.boolish and l.hi == 0)
                                  or (r is not None and r.boolish
                                      and r.hi == 0)):
                return _FALSE
            if e.op == "or" and ((l is not None and l.boolish and l.lo == 1)
                                 or (r is not None and r.boolish
                                     and r.lo == 1)):
                return _TRUE
            if l is None or r is None or not (l.boolish and r.boolish):
                return None
            return _bool_pair(l, r, {"and": lambda a, b: a & b,
                                     "or": lambda a, b: a | b,
                                     "xor": lambda a, b: a ^ b}[e.op])
        if l is None or r is None:
            return None
        if e.op in ("gt", "ge", "lt", "le", "eq", "ne"):
            return _cmp(e.op, l, r)
        return _arith(e.op, l, r)
    if isinstance(e, Cond):
        p = expr_interval(e.pred, ranges)
        t = expr_interval(e.if_true, ranges)
        f = expr_interval(e.if_false, ranges)
        if p is not None and p.boolish:
            if p.lo == 1:
                return t
            if p.hi == 0:
                return f
        if t is None or f is None:
            return None
        return Interval(min(t.lo, f.lo), max(t.hi, f.hi),
                        t.boolish and f.boolish)
    return None  # Agg and future node types: unknown


def _chunk_ranges(cs: ChunkStats, schema: tuple, vocabs=None) -> dict:
    """Column bound intervals for one chunk (unusable bounds omitted).

    Dict-encoded string columns sketch their *string* min/max; because the
    manifest vocab is sorted, mapping both bounds to their codes yields a
    valid interval over the int32 code column the device (and every bound
    predicate literal) actually sees. Chunk bounds are values present in
    the dataset, so the lookup always hits; a miss (stale stats) just
    omits the column — conservative, never wrong."""
    kinds = {}
    for n, dt, tail in schema:
        if not tail:
            kinds[n] = ("dict" if str(dt) == DICT_DTYPE
                        else np.dtype(dt).kind)
    out = {}
    for name, col in cs.columns:
        if col.min is None or col.max is None:
            continue
        if kinds.get(name) == "dict":
            v = (vocabs or {}).get(name)
            if v is None:
                continue
            lo, hi = v.code_of(str(col.min)), v.code_of(str(col.max))
            if lo is None or hi is None:
                continue
            out[name] = Interval(float(lo), float(hi))
            continue
        boolish = kinds.get(name) == "b"
        out[name] = Interval(float(col.min), float(col.max), boolish)
    return out


def _provably_empty(iv: Interval | None) -> bool:
    return iv is not None and iv.lo == 0 and iv.hi == 0


def chunk_skip_mask(manifest, pred_sigs) -> np.ndarray:
    """Per-chunk skip decisions for a scan's absorbed predicates.

    Returns a bool array aligned with ``manifest.chunks``: True means the
    chunk provably yields zero rows under the conjunction of
    ``pred_sigs`` (or is empty outright) and its decode can be skipped
    without changing results. Without stats, or with only legacy callable
    predicates, nothing is skipped."""
    n = len(manifest.chunks)
    skip = np.zeros(n, dtype=bool)
    stats = getattr(manifest, "stats", None)
    if stats is None or len(stats) != n:
        return skip
    exprs = [s for s in pred_sigs if isinstance(s, Expr)]
    vocabs = getattr(manifest, "vocab_map", None) or {}
    for i, cs in enumerate(stats):
        if cs.count == 0:
            skip[i] = True
            continue
        if not exprs:
            continue
        ranges = _chunk_ranges(cs, manifest.schema, vocabs)
        if any(_provably_empty(expr_interval(e, ranges)) for e in exprs):
            skip[i] = True
    return skip


def _col_cmp_lit(e):
    """Match (possibly aliased/flipped) ``col <op> literal``; returns
    ``(op, column name, value)`` with op normalized to the column-on-the-
    left form, or None."""
    while isinstance(e, Alias):
        e = e.child
    if not isinstance(e, BinOp) or e.op not in ("gt", "ge", "lt", "le",
                                                "eq", "ne"):
        return None
    l, r = e.left, e.right
    while isinstance(l, Alias):
        l = l.child
    while isinstance(r, Alias):
        r = r.child
    flip = {"gt": "lt", "ge": "le", "lt": "gt", "le": "ge",
            "eq": "eq", "ne": "ne"}
    if isinstance(l, Col) and isinstance(r, Lit):
        return e.op, l.name, r.value
    if isinstance(l, Lit) and isinstance(r, Col):
        return flip[e.op], r.name, l.value
    return None


def _range_fraction(op: str, lo: float, hi: float, v: float,
                    distinct: float) -> float:
    """Uniform-distribution selectivity of ``col <op> v`` over [lo, hi]."""
    span = hi - lo
    if op in ("eq", "ne"):
        f = 1.0 / max(distinct, 1.0)
        return f if op == "eq" else 1.0 - f
    if span <= 0:
        # single-valued column and the interval test was inconclusive
        # (shouldn't happen); split the difference
        return 0.5
    if op == "gt" or op == "ge":
        f = (hi - v) / span
    else:
        f = (v - lo) / span
    return min(max(f, 0.0), 1.0)


def predicate_selectivity(e, cs: ChunkStats, schema: tuple,
                          vocabs=None) -> float:
    """Estimated fraction of one chunk's rows passing predicate ``e``.

    Interval-provable outcomes give exact 0/1; ``col <op> literal`` uses
    the uniform-range fraction (equality via the KMV distinct estimate);
    anything else falls back to the fixed 0.5 ratio. Dict columns compare
    in code space: bound predicates carry code literals and the chunk's
    string bounds map through ``vocabs``."""
    ranges = _chunk_ranges(cs, schema, vocabs)
    iv = expr_interval(e, ranges)
    if iv is not None and iv.boolish:
        if iv.lo == 1:
            return 1.0
        if iv.hi == 0:
            return 0.0
    m = _col_cmp_lit(e)
    if m is not None:
        op, name, v = m
        col = cs.column(name)
        if col is not None and col.min is not None and col.max is not None:
            lo, hi = col.min, col.max
            voc = (vocabs or {}).get(name)
            if voc is not None:
                lo, hi = voc.code_of(str(lo)), voc.code_of(str(hi))
                if lo is None or hi is None:
                    return _FIXED_SELECTIVITY
            try:
                return _range_fraction(op, float(lo), float(hi),
                                       float(v), col.distinct())
            except (TypeError, ValueError):
                return _FIXED_SELECTIVITY
    return _FIXED_SELECTIVITY


def _scan_chunk_rows(manifest, scan) -> tuple | None:
    """Per-chunk estimated surviving rows for a scan, or None w/o stats.

    Each chunk contributes ``count x prod(per-pred selectivity)``; chunks
    the skip mask prunes contribute zero (their decode never happens)."""
    stats = getattr(manifest, "stats", None)
    if stats is None or len(stats) != len(manifest.chunks):
        return None
    skip = chunk_skip_mask(manifest, scan.pred_sigs)
    vocabs = getattr(manifest, "vocab_map", None) or {}
    out = []
    for i, cs in enumerate(stats):
        if skip[i]:
            out.append(0.0)
            continue
        est = float(cs.count)
        for sig in scan.pred_sigs:
            if isinstance(sig, Expr):
                est *= predicate_selectivity(sig, cs, manifest.schema,
                                             vocabs)
            else:
                est *= _FIXED_SELECTIVITY  # legacy callable: fixed ratio
        out.append(est)
    return tuple(out)


def scan_row_estimate(manifest, scan) -> float | None:
    """Estimated total rows a scan admits over the whole dataset (after
    chunk skipping and predicate filtering); None without stats. Feeds the
    admission controller's working-set estimate for scan-bearing queries."""
    per_chunk = _scan_chunk_rows(manifest, scan)
    if per_chunk is None:
        return None
    return float(sum(per_chunk))


def key_cardinality(manifest, cols) -> float | None:
    """Estimated distinct-key fraction of the dataset over ``cols``.

    Per-column dataset-level KMV distinct estimates multiply (independence
    assumption) and cap at the row count; returned as the fraction in
    (0, 1] that ``patterns.plan_groupby`` consumes. None when stats or any
    requested column sketch is missing."""
    stats = getattr(manifest, "stats", None)
    if not stats or not cols:
        return None
    merged = merge_chunk_stats(stats)
    total = merged.count
    if total <= 0:
        return None
    combined = 1.0
    for c in cols:
        cs = merged.column(c)
        if cs is None:
            return None
        combined *= max(cs.distinct(), 1.0)
    combined = min(combined, float(total))
    return min(max(combined / total, 1.0 / total), 1.0)


def _sole_transparent_scan(node) -> Scan | None:
    """The unique Scan under ``node`` when every intervening node passes
    key columns through untouched; else None."""
    scans = []
    for n in walk(node):
        if not isinstance(n, _KEY_TRANSPARENT):
            return None
        if isinstance(n, Scan):
            scans.append(n)
    return scans[0] if len(scans) == 1 else None


class PlanStats:
    """Bundle of per-scan dataset statistics threaded through the planner.

    Built by :func:`plan_stats` from a ``{sid: DatasetManifest}`` mapping;
    every accessor returns None when it has nothing trustworthy to say, so
    callers always keep their fixed-ratio fallback. ``cache_key`` is a
    content hash of the underlying sketches — plan-cache keys include it
    so plans never alias across datasets (or re-sketched versions of the
    same dataset).
    """

    def __init__(self, manifests: Mapping[int, object]):
        self._m = {sid: man for sid, man in manifests.items()
                   if getattr(man, "stats", None)}
        h = hashlib.sha256()
        for sid in sorted(self._m):
            man = self._m[sid]
            h.update(repr((sid, man.schema, man.stats)).encode())
        self.cache_key = h.hexdigest()

    def __hash__(self):
        return hash(self.cache_key)

    def __eq__(self, other):
        return (isinstance(other, PlanStats)
                and self.cache_key == other.cache_key)

    def has(self, sid: int) -> bool:
        """True when scan ``sid`` has usable sketches."""
        return sid in self._m

    def scan_selectivity(self, scan) -> float | None:
        """Overall surviving-row fraction for a scan's absorbed predicates
        (chunk skipping folded in); None without stats or predicates."""
        man = self._m.get(scan.sid)
        if man is None or not scan.pred_sigs:
            return None
        per_chunk = _scan_chunk_rows(man, scan)
        if per_chunk is None:
            return None
        total = man.num_rows
        if total <= 0:
            return None
        return float(sum(per_chunk)) / float(total)

    def scan_rows(self, scan) -> float | None:
        """Estimated admitted rows for the scan (dataset-wide)."""
        man = self._m.get(scan.sid)
        return None if man is None else scan_row_estimate(man, scan)

    def _node_cardinality(self, node, keys) -> float | None:
        scan = _sole_transparent_scan(node.child)
        if scan is None or not self.has(scan.sid):
            return None
        man = self._m[scan.sid]
        card = key_cardinality(man, keys)
        if card is None:
            return None
        # predicates shrink rows but distinct keys shrink at most as much:
        # re-express the (capped) distinct estimate over the filtered rows
        if scan.pred_sigs:
            sel = self.scan_selectivity(scan)
            if sel:
                card = min(card / max(sel, card), 1.0)
        return card

    def groupby_cardinality(self, node) -> float | None:
        """Estimated group fraction for a GroupBy over a (transparent)
        scan subtree; None whenever keys may have been transformed."""
        if not isinstance(node, GroupBy):
            return None
        return self._node_cardinality(node, node.by)

    def unique_cardinality(self, node) -> float | None:
        """Estimated distinct fraction for a Unique, same contract as
        :meth:`groupby_cardinality`."""
        if not isinstance(node, Unique):
            return None
        return self._node_cardinality(node, node.subset)


def plan_stats(manifests: Mapping[int, object]) -> PlanStats | None:
    """Build :class:`PlanStats` from ``{sid: manifest}``; None when no
    manifest carries sketches (so "no stats" stays one cheap None check
    everywhere downstream)."""
    ps = PlanStats(manifests or {})
    return ps if ps._m else None
