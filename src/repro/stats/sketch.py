"""Per-chunk dataset sketches: row counts, min/max bounds, KMV distinct.

The write-time half of the statistics subsystem (ISSUE 9). Every chunk a
``DatasetWriter`` flushes gets one :class:`ChunkStats` — the exact row
count, per-column min/max bounds, and a k-minimum-values (KMV) sketch of
each column's distinct hashes — serialized into the dataset's JSON
manifest under an optional, versioned ``stats`` key (old manifests load
unchanged; unknown future stats versions are ignored, never fatal).

Sketches are **mergeable**: chunk sketches roll up to dataset sketches
with :func:`merge_chunk_stats` (min/max combine conservatively, KMV sets
union and re-truncate to the k smallest), so every downstream consumer —
chunk skipping, selectivity estimation, key-cardinality estimation
(``repro.stats.estimate``) — works at either granularity.

Conservatism contract: a column whose min/max cannot be trusted for
pruning (non-scalar tail, or non-finite values — NaN compares unordered,
so ``~(col > 0)`` keeps NaN rows) stores ``None`` bounds, which every
consumer treats as "unknown, do not prune". The KMV hash is the same
lowbias32 / boost-combine family the engine's device shuffle
(``partition.hash32``) and host spill bucketing use, so distinct
estimates describe exactly the key space the shuffle partitions on.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

__all__ = [
    "ColumnStats",
    "ChunkStats",
    "merge_chunk_stats",
    "hash32",
    "DEFAULT_KMV_K",
    "STATS_VERSION",
    "backfill_stats",
]

#: KMV sketch size: distinct-count error ~ 1/sqrt(k-2) (~9% at 128) for a
#: few hundred bytes per column per chunk in the JSON manifest.
DEFAULT_KMV_K = 128

#: version of the ``stats`` manifest payload this module writes/parses
STATS_VERSION = 1

_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_HASH_SPACE = float(2**32)


def hash32(col: np.ndarray) -> np.ndarray:
    """lowbias32 over a column, mirroring ``partition.hash32`` bit-for-bit.

    int64/uint64 fold-xor their high word, bools widen to uint32, floats go
    through a float32 bitcast — the same normalization the device shuffle
    and the runner's host spill bucketing apply, so KMV distinct estimates
    are statements about the very hash space keys are partitioned in."""
    x = np.asarray(col)
    if x.dtype.kind in ("U", "S"):
        # decoded dict-column values: a stable per-string hash (crc32)
        # seeds the same lowbias finalizer. Distinct strings == distinct
        # codes, so KMV over decoded values estimates exactly the key
        # cardinality the code-space shuffle partitions on.
        import zlib
        x = np.fromiter((zlib.crc32(str(s).encode("utf-8")) for s in x.ravel()),
                        dtype=np.uint32, count=x.size)
    elif x.dtype in (np.int64, np.uint64):
        u = x.astype(np.uint64)
        x = (u ^ (u >> np.uint64(32))).astype(np.uint32)
    elif x.dtype == np.bool_:
        x = x.astype(np.uint32)
    elif np.issubdtype(x.dtype, np.floating):
        x = np.ascontiguousarray(x.astype(np.float32)).view(np.uint32)
    else:
        x = x.astype(np.uint32)
    with np.errstate(over="ignore"):
        x = x ^ (x >> np.uint32(16))
        x = x * _M1
        x = x ^ (x >> np.uint32(15))
        x = x * _M2
        x = x ^ (x >> np.uint32(16))
    return x


def _scalar(v):
    """Native Python scalar (JSON-exact for int64) or None for non-finite."""
    v = v.item() if hasattr(v, "item") else v
    if isinstance(v, float) and not np.isfinite(v):
        return None
    return v


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Sketch of one scalar column over some row set.

    ``min``/``max`` are native Python scalars, or ``None`` when bounds are
    unusable for pruning (empty column, or non-finite values present —
    NaN rows pass negated predicates, so pruning on a NaN-polluted bound
    would drop matching rows). ``kmv`` holds the k smallest distinct
    lowbias32 hashes (sorted tuple); :meth:`distinct` turns it into a
    distinct-count estimate, exact while fewer than ``k`` hashes exist.
    """

    min: object
    max: object
    kmv: tuple
    k: int = DEFAULT_KMV_K

    @classmethod
    def from_array(cls, arr: np.ndarray, k: int = DEFAULT_KMV_K
                   ) -> "ColumnStats":
        """Sketch one 1-D column array."""
        arr = np.asarray(arr)
        if arr.size == 0:
            return cls(None, None, (), k)
        if arr.dtype.kind in ("U", "S"):
            # decoded dict-column strings: bounds in value space (JSON
            # strings), so chunk skipping can compare string predicates
            u = np.unique(arr.astype(np.str_))
            lo, hi = str(u[0]), str(u[-1])
        else:
            lo, hi = _scalar(arr.min()), _scalar(arr.max())
            if lo is None or hi is None:
                lo = hi = None  # non-finite somewhere: bounds unusable
        hashes = np.unique(hash32(arr))
        kmv = tuple(int(h) for h in hashes[:k])
        return cls(lo, hi, kmv, k)

    def distinct(self) -> float:
        """Distinct-value estimate: exact below ``k``, else the KMV
        estimator ``(k-1) / (kth smallest hash / 2^32)``."""
        if len(self.kmv) < self.k:
            return float(len(self.kmv))
        kth = self.kmv[self.k - 1]
        return (self.k - 1) / ((kth + 1) / _HASH_SPACE)

    def merge(self, other: "ColumnStats") -> "ColumnStats":
        """Combine two sketches of disjoint row sets (conservative: an
        unknown bound on either side stays unknown)."""
        k = min(self.k, other.k)
        lo = None if self.min is None or other.min is None \
            else min(self.min, other.min)
        hi = None if self.max is None or other.max is None \
            else max(self.max, other.max)
        kmv = tuple(sorted(set(self.kmv) | set(other.kmv))[:k])
        return ColumnStats(lo, hi, kmv, k)

    def to_json(self) -> dict:
        """JSON payload for the manifest ``stats`` key."""
        return {"min": self.min, "max": self.max, "kmv": list(self.kmv)}

    @classmethod
    def from_json(cls, d: Mapping, k: int = DEFAULT_KMV_K) -> "ColumnStats":
        """Inverse of :meth:`to_json` (``k`` rides at the stats top level)."""
        return cls(d.get("min"), d.get("max"),
                   tuple(int(h) for h in d.get("kmv", ())), k)


@dataclasses.dataclass(frozen=True)
class ChunkStats:
    """Sketch of one dataset chunk: row count + per-column sketches.

    ``columns`` is a name-sorted tuple of ``(name, ColumnStats)`` covering
    scalar (no trailing shape) columns only — vector columns have no
    order/pruning semantics. Frozen and hashable, so a tuple of these can
    ride on the (hashable) ``DatasetManifest``.
    """

    count: int
    columns: tuple

    @classmethod
    def from_columns(cls, cols: Mapping[str, np.ndarray],
                     k: int = DEFAULT_KMV_K) -> "ChunkStats":
        """Sketch one chunk's column dict (scalar columns only)."""
        count = len(next(iter(cols.values()))) if cols else 0
        out = []
        for name in sorted(cols):
            arr = np.asarray(cols[name])
            if arr.ndim != 1:
                continue
            out.append((name, ColumnStats.from_array(arr, k)))
        return cls(int(count), tuple(out))

    def column(self, name: str) -> ColumnStats | None:
        """The named column's sketch, or None when not sketched."""
        for n, cs in self.columns:
            if n == name:
                return cs
        return None

    def merge(self, other: "ChunkStats") -> "ChunkStats":
        """Roll two chunk sketches up into one (shared columns only)."""
        mine = dict(self.columns)
        theirs = dict(other.columns)
        cols = tuple((n, mine[n].merge(theirs[n]))
                     for n in sorted(set(mine) & set(theirs)))
        return ChunkStats(self.count + other.count, cols)

    def to_json(self) -> dict:
        """JSON payload for one entry of the manifest's stats chunk list."""
        return {"count": self.count,
                "columns": {n: cs.to_json() for n, cs in self.columns}}

    @classmethod
    def from_json(cls, d: Mapping, k: int = DEFAULT_KMV_K) -> "ChunkStats":
        """Inverse of :meth:`to_json`."""
        cols = tuple(sorted(
            (n, ColumnStats.from_json(c, k))
            for n, c in d.get("columns", {}).items()))
        return cls(int(d.get("count", 0)), cols)


def merge_chunk_stats(stats: Sequence[ChunkStats]) -> ChunkStats:
    """Roll per-chunk sketches up to one dataset-level sketch."""
    stats = list(stats)
    if not stats:
        return ChunkStats(0, ())
    out = stats[0]
    for s in stats[1:]:
        out = out.merge(s)
    return out


def backfill_stats(directory: str, k: int = DEFAULT_KMV_K,
                   force: bool = False):
    """Compute sketches for an existing dataset and rewrite its manifest
    in place (atomically — tmp file + rename, crash leaves the old
    manifest intact). Datasets that already carry stats are left untouched
    unless ``force=True``. Returns the (re-)loaded ``DatasetManifest``.

    This is the migration path for datasets written before the statistics
    subsystem (or with ``stats=False``): one pass decoding each chunk,
    identical results to write-time sketching."""
    from ..data.dataset import DatasetManifest, read_chunk  # no import cycle

    man = DatasetManifest.load(directory)
    if man.stats is not None and not force:
        return man
    vocabs = man.vocab_map

    def decoded(i: int) -> dict:
        # dict columns come back as codes; sketch the decoded strings so
        # backfilled stats match write-time stats exactly
        cols = read_chunk(man, i)
        return {n: (vocabs[n].decode(v) if n in vocabs else v)
                for n, v in cols.items()}

    stats = tuple(
        ChunkStats.from_columns(decoded(i), k)
        for i in range(len(man.chunks)))
    dataclasses.replace(man, stats=stats, stats_k=k).save()
    return DatasetManifest.load(directory)
