"""Admission control: bound how much work shares the mesh at once.

The service cannot let every submitted query start immediately — device
memory is static (every DDF/scan batch is a fixed-capacity padded table)
and compiled-program working sets add up. Admission control enforces three
bounds, in order:

1. **concurrency** — at most ``max_running`` queries hold admission slots;
2. **memory budget** — the sum of admitted queries' cost-model-estimated
   working sets (:func:`estimate_query_bytes`) stays under
   ``memory_budget_bytes``. A single query whose own estimate exceeds the
   whole budget is still admitted *alone* (otherwise it could never run);
   the budget throttles co-residency, it is not a hard per-query cap;
3. **backlog** — queries that don't fit wait in a FIFO backlog of at most
   ``max_backlog``; past that the service **sheds**: submission fails with
   :class:`AdmissionError` instead of queueing unboundedly (the overload
   behavior a front door needs — reject fast, don't collapse).

The memory estimate reuses the streaming cost model's framing: a scan-
bearing query's resident set is its cost-model-sized morsel (scan
``capacity * P`` rows at the manifest's ``row_bytes``) inflated by
``working_set_factor`` for shuffle buffers and operator intermediates
(matching ``cost_model.choose_batch_rows``), plus its in-memory source
tables; a scan-free query is its source tables inflated the same way.
Everything is computed from host-side metadata (capacities, schemas) — no
device sync on the submission path.

The static estimate is also *corrected by observation*: streaming runs
report their measured peak working set (the runner's
``peak_working_set_bytes`` gauge, via ``repro.obs``), and
:meth:`AdmissionController.observe` folds the observed-vs-estimated ratio
into an EWMA keyed by the query's plan shape (:func:`query_learn_key`).
Repeat submissions of the same shape are admitted against the corrected
estimate — the feedback loop that keeps the cost model honest at the
front door.
"""

from __future__ import annotations

import collections
import hashlib
import threading

import numpy as np

from ..plan.logical import Scan, plan_signature, walk
from .session import QuerySession, QueryState

__all__ = [
    "AdmissionError",
    "AdmissionController",
    "estimate_query_bytes",
    "query_learn_key",
]

#: default per-mesh memory budget for co-resident queries (bytes)
DEFAULT_MEMORY_BUDGET = 256e6


class AdmissionError(RuntimeError):
    """Submission rejected: the admission backlog is full (shed-on-overflow)
    or the service is shutting down."""


def _ddf_row_bytes(columns) -> float:
    """Bytes per row of an in-memory DDF's schema."""
    total = 0.0
    for v in columns.values():
        total += np.dtype(v.dtype).itemsize * int(np.prod(v.shape[1:], dtype=np.int64))
    return max(total, 1.0)


def estimate_query_bytes(query, working_set_factor: float = 4.0) -> float:
    """Cost-model working-set estimate for one query, in bytes.

    ``query`` is a ``LazyDDF`` (scan-bearing or not) or a callable (an
    opaque eager thunk — charged 0, it brings its own already-resident
    tables). Scan leaves contribute one morsel's padded device table
    (``capacity * P * row_bytes``) times ``working_set_factor``; when the
    dataset manifest carries per-chunk sketches (``repro.stats``), the
    morsel guess is tightened by the selectivity-adjusted row estimate —
    a tiny highly-selective scan no longer reserves a full morsel's
    worth of budget. ``Source`` leaves contribute their full padded
    capacity times the same factor (shuffle outputs/intermediates scale
    with input size). Duplicate sids are counted once.
    """
    if not hasattr(query, "_root"):
        return 0.0  # eager thunks (and anything else the scheduler vets)
    P = query._ctx.nworkers
    total = 0.0
    seen: set = set()
    for n in walk(query._root):
        if isinstance(n, Scan) and n.sid not in seen:
            seen.add(n.sid)
            man = query._scans[n.sid]
            rows = float(n.capacity * P)
            from ..stats import scan_row_estimate  # avoid import cycle
            est = scan_row_estimate(man, n)
            if est is not None:
                rows = min(rows, max(float(est), 1.0))
            total += rows * man.row_bytes()
    for sid, ddf in query._sources.items():
        if sid in seen:
            continue
        seen.add(sid)
        total += ddf.capacity * P * _ddf_row_bytes(ddf.columns)
    return total * max(working_set_factor, 1.0)


def query_learn_key(query) -> str | None:
    """Identity under which observed working-set peaks are learned: the
    plan's process-stable shape (``plan_signature``) plus the worker
    count. Queries with the same shape and mesh have the same static
    buffer sizing, so one query's measured peak predicts the next's.
    Opaque eager thunks have no plan to key on — None, no learning."""
    if not hasattr(query, "_root"):
        return None
    h = hashlib.sha256()
    h.update(plan_signature(query._root).encode())
    h.update(f"P={query._ctx.nworkers}".encode())
    return h.hexdigest()


#: clamp on the learned estimate-correction ratio — one wild measurement
#: (or a tiny probe run of a shape) cannot swing admissions unboundedly
_RATIO_BOUNDS = (0.125, 8.0)

#: EWMA weight of the newest observation when updating a learned ratio
_EWMA_WEIGHT = 0.5


class AdmissionController:
    """Slot + budget accounting and the FIFO backlog.

    Thread-safe; the service calls :meth:`offer` at submission time and
    :meth:`release` when a query reaches a terminal state (the scheduler's
    finish callback). ``release`` returns the backlogged sessions that now
    fit, in FIFO order — the service hands those to the scheduler.
    """

    def __init__(self, max_running: int = 4, max_backlog: int = 32,
                 memory_budget_bytes: float = DEFAULT_MEMORY_BUDGET,
                 working_set_factor: float = 4.0):
        self.max_running = max(int(max_running), 1)
        self.max_backlog = max(int(max_backlog), 0)
        self.memory_budget_bytes = float(memory_budget_bytes)
        self.working_set_factor = float(working_set_factor)
        self._lock = threading.Lock()
        self._running: dict[str, float] = {}  # qid -> cost bytes
        self._backlog: collections.deque[QuerySession] = collections.deque()
        # learned correction ratios: query_learn_key -> EWMA of
        # observed peak working set / static cost-model estimate
        self._learned: dict[str, float] = {}
        self.admitted_total = 0
        self.rejected_total = 0
        self.queued_total = 0
        self.observed_total = 0

    # -- internals -------------------------------------------------------------
    def _fits(self, cost: float) -> bool:
        if len(self._running) >= self.max_running:
            return False
        if not self._running:
            return True  # a lone over-budget query must still run
        return sum(self._running.values()) + cost <= self.memory_budget_bytes

    def _admit(self, session: QuerySession) -> None:
        self._running[session.qid] = session.cost_bytes
        self.admitted_total += 1
        session._transition(QueryState.ADMITTED)

    # -- service surface -------------------------------------------------------
    def offer(self, session: QuerySession) -> str:
        """Place a PENDING session: returns ``"admitted"`` or ``"queued"``.

        Estimates the session's cost (stored on ``session.cost_bytes``),
        admits it when it fits, otherwise backlogs it FIFO. A full backlog
        sheds: the session is failed with :class:`AdmissionError` and the
        same error is raised to the submitter.
        """
        if not session.cost_bytes:
            session.cost_base = estimate_query_bytes(
                session.query, self.working_set_factor)
            session.admission_key = query_learn_key(session.query)
            session.cost_bytes = session.cost_base
        with self._lock:
            ratio = (self._learned.get(session.admission_key)
                     if session.admission_key else None)
            if ratio is not None and session.cost_base:
                session.cost_bytes = session.cost_base * ratio
            if self._fits(session.cost_bytes) and not self._backlog:
                self._admit(session)
                return "admitted"
            if len(self._backlog) >= self.max_backlog:
                self.rejected_total += 1
                err = AdmissionError(
                    f"query {session.qid} rejected: admission backlog full "
                    f"({len(self._backlog)}/{self.max_backlog} queued, "
                    f"{len(self._running)}/{self.max_running} running, "
                    f"{sum(self._running.values()):.0f}/"
                    f"{self.memory_budget_bytes:.0f} budget bytes in use)")
                session._finish(QueryState.FAILED, error=err)
                raise err
            self._backlog.append(session)
            self.queued_total += 1
            return "queued"

    def release(self, session: QuerySession) -> list:
        """Free a finished query's slot; admit now-fitting backlog heads.

        Cancelled-while-pending sessions are dropped from the backlog here
        (lazily — ``QuerySession.cancel`` resolves their future without
        touching the deque). Returns newly admitted sessions, FIFO order.
        """
        with self._lock:
            self._running.pop(session.qid, None)
            admitted = []
            while self._backlog:
                head = self._backlog[0]
                if head.state in QueryState.TERMINAL:
                    self._backlog.popleft()  # cancelled while queued
                    continue
                if not self._fits(head.cost_bytes):
                    break
                self._backlog.popleft()
                self._admit(head)
                admitted.append(head)
            return admitted

    def observe(self, session: QuerySession) -> None:
        """Close the estimate-vs-reality loop for one finished query.

        Streaming runs measure their actual peak working set (the
        ``peak_working_set_bytes`` gauge in the runner's info); the ratio
        of that observed peak (re-inflated by ``working_set_factor``, the
        same headroom the static estimate carries for unmeasured shuffle
        intermediates) to the query's *base* estimate becomes an EWMA-
        learned correction for the query's plan shape. The next submission
        of the same shape is admitted against the corrected estimate —
        systematically over-estimated shapes stop hogging budget,
        under-estimated ones stop over-committing the mesh. Ratios are
        clamped to ``_RATIO_BOUNDS``; queries without a learn key or a
        measured peak (eager thunks, failed runs) teach nothing."""
        key = getattr(session, "admission_key", None)
        base = getattr(session, "cost_base", 0.0)
        peak = (session.info or {}).get("peak_working_set_bytes")
        if not key or not base or not peak:
            return
        lo, hi = _RATIO_BOUNDS
        obs = min(max(float(peak) * self.working_set_factor / base, lo), hi)
        with self._lock:
            prev = self._learned.get(key)
            self._learned[key] = (obs if prev is None else
                                  (1.0 - _EWMA_WEIGHT) * prev
                                  + _EWMA_WEIGHT * obs)
            self.observed_total += 1

    def learned_ratio(self, query) -> float | None:
        """The current correction ratio for ``query``'s plan shape (None
        when nothing has been learned yet)."""
        key = query_learn_key(query)
        with self._lock:
            return self._learned.get(key) if key else None

    def backlog_depth(self) -> int:
        """Current number of queued (not yet admitted) sessions."""
        with self._lock:
            return sum(1 for s in self._backlog
                       if s.state not in QueryState.TERMINAL)

    def stats(self) -> dict:
        """Telemetry snapshot for ``service.stats()``."""
        with self._lock:
            return {
                "max_running": self.max_running,
                "max_backlog": self.max_backlog,
                "memory_budget_bytes": self.memory_budget_bytes,
                "running": len(self._running),
                "in_use_bytes": float(sum(self._running.values())),
                "backlog": sum(1 for s in self._backlog
                               if s.state not in QueryState.TERMINAL),
                "admitted_total": self.admitted_total,
                "queued_total": self.queued_total,
                "rejected_total": self.rejected_total,
                "learned_keys": len(self._learned),
                "observed_total": self.observed_total,
            }
