"""Async morsel scheduler: interleave many queries' morsels on one mesh.

The streaming runner sizes every morsel from the cost model precisely so a
morsel can act as a *scheduling quantum* — one scan batch through the one
compiled shard_map program. ``repro.stream.StreamExecution`` exposes that
loop as an externally drivable step generator, and this scheduler drives
many of them concurrently: a single worker thread round-robins ``next()``
across the active queries' generators, so device programs from different
queries interleave at morsel granularity while each query's own morsel
order — and therefore its result, bit for bit — is exactly what a solo run
produces. (One driver thread, many queries: determinism per query comes
free, host-side decode still overlaps device work through each runner's
own prefetch thread, and the mesh never sees two competing dispatches.)

Scheduling policies:

- ``"round_robin"`` — one morsel per active query per turn. Simple, and
  perfectly fair in *morsel count*; queries with expensive morsels get a
  proportionally larger share of device time.
- ``"fair"`` — deficit-weighted fair queuing (deficit round robin over
  measured morsel wall seconds). Each turn a query's deficit grows by
  ``quantum_s * weight``; it runs morsels while its deficit covers the
  next morsel's estimated cost (the last measured one) and pays each
  morsel's measured cost from the deficit. Queries with cheap morsels
  batch several per turn; expensive-morsel queries yield the mesh after
  one — device *time* is shared in proportion to weight, not morsel count.

Scan-free lazy queries (and opaque eager thunks) are one-quantum queries:
their single compiled dispatch is one "morsel".

Lifecycle integration: the scheduler transitions sessions ADMITTED ->
RUNNING at their first morsel and resolves them to DONE/FAILED/CANCELLED;
a cancel request (``QuerySession.cancel``) is honored at the next morsel
boundary by closing the query's step generator (``GeneratorExit`` unwinds
the runner's ``finally`` blocks, releasing spill/prefetch state). The
``on_finish`` callback hands every terminal session back to the service,
which releases its admission slot and enqueues newly admitted work.
"""

from __future__ import annotations

import collections
import threading
import time

from ..core.api import DDF
from ..obs import trace as _trace
from ..plan.frame import LazyDDF
from ..stream.runner import StreamExecution
from .session import QueryCancelled, QuerySession, QueryState

__all__ = ["MorselScheduler", "POLICIES"]

#: supported scheduling policies
POLICIES = ("round_robin", "fair")

#: cap on accumulated deficit, in turns' worth of quantum — an idle-ish
#: query cannot bank unbounded credit and then monopolize the mesh
_DEFICIT_CAP_TURNS = 4.0


def _steps_for(session: QuerySession):
    """Build the step generator for a submitted query.

    Streaming (scan-bearing ``LazyDDF``) queries run through
    ``StreamExecution`` with the session's stream options; scan-free lazy
    queries and eager thunks become one-quantum generators. Every
    generator returns ``(result, info dict)``.
    """
    q = session.query
    if isinstance(q, LazyDDF):
        if q._scans:
            ex = StreamExecution(q, **session.opts)

            def stream_steps():
                yield from ex.steps()
                return ex.result, ex.info

            return stream_steps()
        if session.opts:
            raise ValueError(
                f"query {session.qid}: stream options "
                f"{sorted(session.opts)} only apply to scan-bearing "
                "(streaming) queries")

        def lazy_steps():
            out = q.collect()
            yield "device"
            return out, dict(q.last_info or {})

        return lazy_steps()
    if isinstance(q, DDF):
        raise TypeError(
            "submit() takes a LazyDDF (use .lazy() on an eager DDF) or a "
            "zero-argument callable, not a materialized DDF")
    if callable(q):
        def eager_steps():
            out = q()
            yield "eager"
            return out, {}

        return eager_steps()
    raise TypeError(f"unsupported query type {type(q).__name__}")


class _Active:
    """Scheduler-internal per-query run state."""

    __slots__ = ("session", "gen", "deficit", "cost_est", "t_start")

    def __init__(self, session: QuerySession, gen):
        self.session = session
        self.gen = gen
        self.deficit = 0.0
        self.cost_est = 0.0
        self.t_start: float | None = None  # trace clock, first morsel


class MorselScheduler:
    """The service's single worker loop driving all admitted queries.

    ``enqueue`` hands over ADMITTED sessions; the loop builds their step
    generators lazily (so a cancel-before-start never touches the mesh)
    and interleaves morsels per the policy. ``shutdown(cancel=False)``
    drains the active set; ``cancel=True`` closes every generator and
    cancels pending sessions instead.
    """

    def __init__(self, policy: str = "fair", quantum_s: float = 0.02,
                 on_finish=None):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        self.policy = policy
        self.quantum_s = float(quantum_s)
        self._on_finish = on_finish
        # RLock: the finish callback (service release -> enqueue of newly
        # admitted work) can re-enter the scheduler from the worker thread
        # while an activation already holds the condition
        self._cond = threading.Condition(threading.RLock())
        self._incoming: collections.deque[QuerySession] = collections.deque()
        self._active: collections.deque[_Active] = collections.deque()
        self._stop = False
        self._abort = False
        self._thread: threading.Thread | None = None
        self.morsels_total = 0
        self.turns_total = 0

    # -- service surface -------------------------------------------------------
    def start(self) -> None:
        """Start the worker thread (idempotent)."""
        with self._cond:
            if self._thread is not None:
                return
            self._thread = threading.Thread(
                target=self._loop, name="repro-service-scheduler", daemon=True)
            self._thread.start()

    def enqueue(self, session: QuerySession) -> None:
        """Hand an ADMITTED session to the worker loop.

        Accepted during a draining shutdown (backlogged sessions admitted
        as slots free up are part of the drain), rejected once a
        cancelling shutdown is underway."""
        with self._cond:
            if self._stop and self._abort:
                raise RuntimeError("scheduler is shut down")
            self._incoming.append(session)
            self._cond.notify()

    def shutdown(self, cancel: bool = False, timeout: float | None = None) -> None:
        """Stop the loop: drain active queries, or cancel them.

        ``cancel=False`` (drain) finishes everything already enqueued, then
        exits; ``cancel=True`` closes active generators and cancels
        still-queued sessions at the next loop iteration.
        """
        with self._cond:
            self._stop = True
            self._abort = bool(cancel)
            self._cond.notify()
            t = self._thread
        if t is not None:
            t.join(timeout)

    def active_count(self) -> int:
        """Number of queries currently interleaving (excludes incoming)."""
        with self._cond:
            return len(self._active)

    def stats(self) -> dict:
        """Telemetry snapshot for ``service.stats()``."""
        with self._cond:
            return {
                "policy": self.policy,
                "quantum_s": self.quantum_s,
                "active": len(self._active),
                "incoming": len(self._incoming),
                "morsels_total": self.morsels_total,
                "turns_total": self.turns_total,
            }

    # -- worker loop -----------------------------------------------------------
    def _finish(self, entry: _Active, state: str, result=None, error=None,
                info=None) -> None:
        entry.session._finish(state, result=result, error=error, info=info)
        if _trace.enabled() and entry.t_start is not None:
            # retroactive query-lifetime span: stack spans would misnest
            # across interleaved queries on the one driver thread
            s = entry.session
            _trace.complete("service.query", entry.t_start, qid=s.qid,
                            label=s.label, state=state, morsels=s.morsels,
                            device_s=s.device_s)
        if self._on_finish is not None:
            self._on_finish(entry.session)

    def _activate(self, session: QuerySession) -> _Active | None:
        if session.cancel_requested():
            # cancelled between admission and first morsel: never build the
            # generator, never touch the mesh
            session._finish(QueryState.CANCELLED)
            if self._on_finish is not None:
                self._on_finish(session)
            return None
        try:
            gen = _steps_for(session)
        except BaseException as e:
            session._finish(QueryState.FAILED, error=e)
            if self._on_finish is not None:
                self._on_finish(session)
            return None
        return _Active(session, gen)

    def _step_once(self, entry: _Active) -> bool:
        """Run one morsel of ``entry``; False when the query left the
        active set (finished, failed, or cancelled)."""
        s = entry.session
        if s.cancel_requested():
            entry.gen.close()
            self._finish(entry, QueryState.CANCELLED,
                         error=QueryCancelled(s.qid))
            return False
        if s.state == QueryState.ADMITTED:
            s._transition(QueryState.RUNNING)
            s.started_at = time.monotonic()
            entry.t_start = _trace.now()
        t0 = time.perf_counter()
        try:
            with _trace.span("service.morsel", qid=s.qid):
                next(entry.gen)
        except StopIteration as e:
            out, info = e.value if e.value is not None else (None, {})
            self._finish(entry, QueryState.DONE, result=out, info=info)
            return False
        except BaseException as e:
            self._finish(entry, QueryState.FAILED, error=e)
            return False
        dt = time.perf_counter() - t0
        s.morsels += 1
        s.device_s += dt
        entry.cost_est = dt
        with self._cond:
            self.morsels_total += 1
        return True

    def _run_turn(self, entry: _Active) -> bool:
        """One scheduling turn for ``entry`` per the policy; False when the
        query finished during the turn."""
        with self._cond:
            self.turns_total += 1
        if self.policy == "round_robin":
            return self._step_once(entry)
        # deficit round robin over measured morsel seconds; the cap can
        # never fall below one morsel's estimated cost, else a query whose
        # morsels outweigh the banked maximum would starve forever
        w = max(entry.session.weight, 1e-6)
        cap = max(_DEFICIT_CAP_TURNS * self.quantum_s * w, entry.cost_est)
        entry.deficit = min(entry.deficit + self.quantum_s * w, cap)
        while entry.deficit >= entry.cost_est:
            if not self._step_once(entry):
                return False
            entry.deficit = max(entry.deficit - entry.cost_est, 0.0)
            if entry.cost_est <= 0.0:
                break  # unmeasurably cheap morsel: one per turn is enough
        return True

    def _loop(self) -> None:
        while True:
            with self._cond:
                while (not self._stop and not self._incoming
                       and not self._active):
                    self._cond.wait()
                while self._incoming:
                    entry = self._activate(self._incoming.popleft())
                    if entry is not None:
                        self._active.append(entry)
                if self._stop and (self._abort or not self._active):
                    abort = self._abort
                    break
                if not self._active:
                    continue
                entry = self._active.popleft()
            alive = self._run_turn(entry)
            if alive:
                with self._cond:
                    self._active.append(entry)
        if abort:
            # cancelling shutdown: close every generator, cancel sessions
            for entry in list(self._active):
                entry.session._cancel.set()
                entry.gen.close()
                if entry.session.state not in QueryState.TERMINAL:
                    self._finish(entry, QueryState.CANCELLED,
                                 error=QueryCancelled(entry.session.qid))
            self._active.clear()
            for session in list(self._incoming):
                session.cancel()
            self._incoming.clear()
