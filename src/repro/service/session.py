"""Per-query lifecycle state for the concurrent query service.

Every query submitted to :class:`~repro.service.QueryService` gets a
:class:`QuerySession`: a unique query id, a lifecycle state machine

    PENDING -> ADMITTED -> RUNNING -> DONE | FAILED | CANCELLED

(PENDING and ADMITTED may also jump straight to FAILED/CANCELLED — an
admission shed or a cancel before the first morsel), a result future the
submitting thread blocks on (:meth:`QuerySession.result`), and a
cooperative cancellation flag the morsel scheduler checks between quanta.

The :class:`SessionManager` is the service's registry: it mints ids,
tracks every session, and snapshots per-state counts for
``QueryService.stats()``. All state transitions run under the session's
lock and are validated against the state machine — an illegal transition
is a bug in the service, not a user error, and raises ``RuntimeError``.
"""

from __future__ import annotations

import threading
import time
import uuid

__all__ = [
    "QueryState",
    "QueryCancelled",
    "QuerySession",
    "SessionManager",
]


class QueryState:
    """Lifecycle states of a query session (string constants).

    ``PENDING`` — submitted, waiting in the admission backlog;
    ``ADMITTED`` — holds an admission slot, queued for the scheduler;
    ``RUNNING`` — at least one morsel executed;
    ``DONE`` / ``FAILED`` / ``CANCELLED`` — terminal.
    """

    PENDING = "PENDING"
    ADMITTED = "ADMITTED"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    CANCELLED = "CANCELLED"

    ALL = (PENDING, ADMITTED, RUNNING, DONE, FAILED, CANCELLED)
    TERMINAL = (DONE, FAILED, CANCELLED)


_TRANSITIONS = {
    QueryState.PENDING: {QueryState.ADMITTED, QueryState.FAILED,
                         QueryState.CANCELLED},
    QueryState.ADMITTED: {QueryState.RUNNING, QueryState.FAILED,
                          QueryState.CANCELLED},
    QueryState.RUNNING: {QueryState.DONE, QueryState.FAILED,
                         QueryState.CANCELLED},
    QueryState.DONE: set(),
    QueryState.FAILED: set(),
    QueryState.CANCELLED: set(),
}


class QueryCancelled(Exception):
    """Raised by :meth:`QuerySession.result` when the query was cancelled
    (by :meth:`QuerySession.cancel` or a cancelling service shutdown)
    before producing a result."""


class QuerySession:
    """Handle + lifecycle state for one submitted query.

    The submitting thread keeps this handle: :meth:`result` blocks until
    the scheduler finishes the query (returning the result DDF, or raising
    the query's error / :class:`QueryCancelled`); :meth:`cancel` requests
    cooperative cancellation — the scheduler stops the query at the next
    morsel boundary, so one in-flight morsel may still complete.

    Attributes populated by the service/scheduler: ``morsels`` (quanta
    executed), ``device_s`` (measured wall seconds inside this query's
    morsels), ``cost_bytes`` (admission estimate), ``info`` (the runner's
    folded counters, for streaming queries).
    """

    def __init__(self, qid: str, query, opts: dict, weight: float = 1.0,
                 label: str | None = None):
        self.qid = qid
        self.query = query
        self.opts = dict(opts)
        self.weight = float(weight)
        self.label = label or qid
        self.state = QueryState.PENDING
        self.cost_bytes = 0.0
        self.cost_base = 0.0  # pre-correction admission estimate
        self.admission_key: str | None = None  # plan-shape learning key
        self.morsels = 0
        self.device_s = 0.0
        self.info: dict = {}
        self.submitted_at = time.monotonic()
        self.started_at: float | None = None
        self.finished_at: float | None = None
        self._lock = threading.Lock()
        self._done = threading.Event()
        self._cancel = threading.Event()
        self._result = None
        self._error: BaseException | None = None

    # -- state machine ---------------------------------------------------------
    def _transition(self, new: str) -> None:
        """Validated state transition (service-internal)."""
        with self._lock:
            if new not in _TRANSITIONS[self.state]:
                raise RuntimeError(
                    f"query {self.qid}: illegal transition "
                    f"{self.state} -> {new}")
            self.state = new

    def _finish(self, state: str, result=None, error=None,
                info: dict | None = None) -> None:
        """Terminal transition + future resolution (service-internal)."""
        self._transition(state)
        self._result = result
        self._error = error
        if info:
            self.info = dict(info)
        self.finished_at = time.monotonic()
        self._done.set()

    # -- public handle surface -------------------------------------------------
    def cancel(self) -> bool:
        """Request cooperative cancellation.

        A PENDING (backlogged) query is cancelled immediately; an admitted
        or running query stops at its next morsel boundary (the scheduler
        closes its step generator, unwinding spill/prefetch state).
        Returns False when the query already reached a terminal state.
        """
        with self._lock:
            if self.state in QueryState.TERMINAL:
                return False
            self._cancel.set()
            if self.state == QueryState.PENDING:
                # not yet handed to the scheduler: resolve here; the
                # admission backlog drops finished sessions lazily
                self.state = QueryState.CANCELLED
                self.finished_at = time.monotonic()
                self._done.set()
            return True

    def cancel_requested(self) -> bool:
        """True once :meth:`cancel` has been called (scheduler checkpoint)."""
        return self._cancel.is_set()

    def done(self) -> bool:
        """True once the session reached a terminal state."""
        return self._done.is_set()

    def result(self, timeout: float | None = None):
        """Block until the query finishes; return its result DDF.

        Raises the query's error for FAILED sessions,
        :class:`QueryCancelled` for cancelled ones, and ``TimeoutError``
        when ``timeout`` (seconds) elapses first.
        """
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"query {self.qid} still {self.state} after {timeout}s")
        if self.state == QueryState.CANCELLED:
            raise QueryCancelled(f"query {self.qid} was cancelled")
        if self._error is not None:
            raise self._error
        return self._result

    def describe(self) -> dict:
        """JSON-able snapshot of this session for ``service.stats()``."""
        wall = ((self.finished_at or time.monotonic())
                - self.submitted_at)
        return {
            "qid": self.qid,
            "label": self.label,
            "state": self.state,
            "weight": self.weight,
            "morsels": self.morsels,
            "device_s": round(self.device_s, 6),
            "cost_bytes": float(self.cost_bytes),
            "wall_s": round(wall, 6),
        }

    def __repr__(self) -> str:
        return f"QuerySession({self.qid!r}, {self.state}, morsels={self.morsels})"


class SessionManager:
    """Registry of every session a service has seen.

    Mints unique query ids (monotonic sequence + uuid suffix, so ids are
    both orderable in logs and globally unique), keeps sessions for the
    service's lifetime (terminal sessions stay inspectable through
    ``stats()``), and serves per-state counts.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._sessions: dict[str, QuerySession] = {}
        self._seq = 0

    def create(self, query, opts: dict, weight: float = 1.0,
               label: str | None = None) -> QuerySession:
        """Mint a new PENDING session for ``query``."""
        with self._lock:
            self._seq += 1
            qid = f"q{self._seq:04d}-{uuid.uuid4().hex[:8]}"
            s = QuerySession(qid, query, opts, weight=weight, label=label)
            self._sessions[qid] = s
            return s

    def get(self, qid: str) -> QuerySession:
        """Look up a session by id (KeyError on unknown ids)."""
        with self._lock:
            return self._sessions[qid]

    def sessions(self) -> list:
        """All sessions, in submission order."""
        with self._lock:
            return list(self._sessions.values())

    def counts(self) -> dict:
        """``{state: count}`` over every session ever submitted."""
        out = {s: 0 for s in QueryState.ALL}
        for sess in self.sessions():
            out[sess.state] += 1
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._sessions)
