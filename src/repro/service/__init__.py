"""Concurrent query service: many lazy/streaming queries, one shared mesh.

Everything below this package serves exactly one synchronous caller at a
time; ``QueryService`` is the long-lived layer that turns the library into
a system (ISSUE 7 tentpole, the ROADMAP's "millions of users" direction).
It multiplexes many simultaneous queries over one device mesh by driving
their cost-model-sized morsels through a single scheduler thread:

- ``session``   — per-query lifecycle (PENDING -> ADMITTED -> RUNNING ->
  DONE/FAILED/CANCELLED), unique query ids, result futures, cooperative
  cancellation (:class:`QuerySession`, :class:`SessionManager`);
- ``scheduler`` — the async morsel scheduler interleaving step generators
  (``repro.stream.StreamExecution``) from independent queries, with
  round-robin and deficit-weighted fair-queuing policies
  (:class:`MorselScheduler`);
- ``admission`` — cost-model-estimated memory budgets, bounded concurrent
  admissions, FIFO backlog with shed-on-overflow
  (:class:`AdmissionController`, :class:`AdmissionError`);
- ``cache``     — the shared plan/compiled-op cache manager with
  hit/miss/eviction telemetry (:class:`CacheManager`) — queries sharing a
  pipeline shape share one optimizer pass and one compiled program.

Typical use::

    from repro.service import QueryService

    with QueryService(policy="fair", max_running=4) as svc:
        handles = [svc.submit(q) for q in queries]      # LazyDDFs
        results = [h.result() for h in handles]         # eager DDFs
        print(svc.stats())

Results are bit-identical to running each query's ``collect`` /
``collect_stream`` serially: one driver thread serializes device
dispatches, every query owns its runner state, and the shared caches are
keyed structurally. See docs/SERVICE.md.
"""

from __future__ import annotations

import threading

from ..obs import trace as _trace
from .admission import AdmissionController, AdmissionError, estimate_query_bytes
from .cache import CacheManager
from .scheduler import POLICIES, MorselScheduler
from .session import QueryCancelled, QuerySession, QueryState, SessionManager

__all__ = [
    "QueryService",
    "QuerySession",
    "QueryState",
    "QueryCancelled",
    "SessionManager",
    "MorselScheduler",
    "POLICIES",
    "AdmissionController",
    "AdmissionError",
    "estimate_query_bytes",
    "CacheManager",
]


class QueryService:
    """Long-lived front door multiplexing queries over one shared mesh.

    Args:
      policy: scheduling policy — ``"fair"`` (deficit-weighted fair
        queuing over measured morsel seconds, the default) or
        ``"round_robin"`` (one morsel per query per turn).
      max_running: concurrent admission slots (queries interleaving on the
        mesh at once).
      max_backlog: FIFO backlog depth past the admission slots; a full
        backlog sheds new submissions with :class:`AdmissionError`.
      memory_budget_bytes: cost-model working-set budget shared by the
        admitted queries (see :func:`estimate_query_bytes`).
      quantum_s: fair-queuing quantum — device seconds granted per
        scheduling turn per unit weight.

    ``submit`` accepts a ``LazyDDF`` (scan-bearing plans run through the
    streaming engine morsel by morsel; scan-free plans are one-quantum
    compiled dispatches) or a zero-argument callable (an opaque eager
    escape hatch). Streaming keyword options (``batch_rows``,
    ``checkpoint_dir``, ...) pass through to the runner.
    """

    def __init__(self, policy: str = "fair", max_running: int = 4,
                 max_backlog: int = 32,
                 memory_budget_bytes: float = 256e6,
                 quantum_s: float = 0.02):
        self.sessions = SessionManager()
        self.admission = AdmissionController(
            max_running=max_running, max_backlog=max_backlog,
            memory_budget_bytes=memory_budget_bytes)
        self.caches = CacheManager()
        self.scheduler = MorselScheduler(policy=policy, quantum_s=quantum_s,
                                         on_finish=self._on_query_finished)
        self._lock = threading.Lock()
        self._closed = False
        self.scheduler.start()

    # -- submission ------------------------------------------------------------
    def submit(self, query, weight: float = 1.0, label: str | None = None,
               **stream_opts) -> QuerySession:
        """Submit a query; returns its :class:`QuerySession` handle.

        The session is PENDING until admission control grants it a slot
        (immediately, or FIFO from the backlog as earlier queries finish).
        Raises :class:`AdmissionError` when the backlog is full
        (shed-on-overflow) or the service is shut down. ``weight`` scales
        the query's share under the ``"fair"`` policy; ``label`` names it
        in ``stats()``.
        """
        with self._lock:
            if self._closed:
                raise AdmissionError("service is shut down")
            session = self.sessions.create(query, stream_opts, weight=weight,
                                           label=label)
            verdict = self.admission.offer(session)
        if verdict == "admitted":
            self.scheduler.enqueue(session)
        return session

    def cancel(self, qid: str) -> bool:
        """Cancel a query by id (cooperative; see
        :meth:`QuerySession.cancel`). False if already terminal."""
        return self.sessions.get(qid).cancel()

    # -- scheduler callback ----------------------------------------------------
    def _on_query_finished(self, session: QuerySession) -> None:
        # learn from the finished query's measured peak working set before
        # releasing its slot (so a same-shape backlog head is re-costed
        # against the corrected estimate)
        self.admission.observe(session)
        for newly_admitted in self.admission.release(session):
            self.scheduler.enqueue(newly_admitted)

    # -- introspection ---------------------------------------------------------
    def stats(self) -> dict:
        """One consistent snapshot of the whole service.

        ``{"sessions": {state: count}, "queries": [per-session dicts],
        "scheduler": {...}, "admission": {...}, "caches": {"plan"/"op":
        cumulative + windowed hit/miss/eviction counts}, "trace":
        {"enabled", "spans", "dropped", "by_name"}}`` — the schema is
        documented in docs/SERVICE.md (tracing in docs/OBSERVABILITY.md).
        """
        return {
            "sessions": self.sessions.counts(),
            "queries": [s.describe() for s in self.sessions.sessions()],
            "scheduler": self.scheduler.stats(),
            "admission": self.admission.stats(),
            "caches": self.caches.stats(),
            "trace": _trace.summary(),
        }

    # -- lifecycle -------------------------------------------------------------
    def shutdown(self, cancel: bool = False, timeout: float | None = None) -> None:
        """Stop the service: drain every submitted query (default) or
        cancel active + pending work (``cancel=True``). Idempotent; new
        submissions are shed from the moment shutdown begins."""
        with self._lock:
            self._closed = True
        self.scheduler.shutdown(cancel=cancel, timeout=timeout)

    def __enter__(self) -> "QueryService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # cancel on error exits, drain on clean ones
        self.shutdown(cancel=exc_type is not None)
