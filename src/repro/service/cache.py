"""Shared plan/compiled-op cache manager: cross-query reuse + telemetry.

The two host-side LRUs in front of compilation — the optimized-plan cache
(``repro.plan.executor._PLAN_CACHE``) and the compiled-op cache
(``repro.core.api._OP_CACHE``) — are process-wide by design: their keys
are structural (plan shape, schemas, mesh signature, kernel-dispatch
signature), so two *different* queries running the *same* pipeline shape
share one optimizer pass and one compiled shard_map program. That reuse is
exactly what a multi-query service wants (the aggregation-patterns work,
arXiv 2010.14596, motivates sharing compiled operator state across queries
hitting the same patterns), and with the underlying ``_LRUCache`` made
thread-safe + counter-instrumented, it is also safe and observable under
concurrency.

``CacheManager`` is the service's window onto those caches: cumulative
stats, a marked baseline at service construction, and per-window deltas so
``service.stats()`` can report hit/miss/eviction counts attributable to
*this* service's queries rather than the whole process history.
"""

from __future__ import annotations

from ..plan import executor as _executor

__all__ = ["CacheManager"]


def _diff(now: dict, base: dict) -> dict:
    out = {}
    for name in ("hits", "misses", "evictions"):
        out[name] = now[name] - base.get(name, 0)
    out["size"] = now["size"]
    out["maxsize"] = now["maxsize"]
    return out


class CacheManager:
    """Snapshot/delta view over the shared plan + compiled-op caches.

    ``mark()`` re-baselines the window (called at service construction);
    ``stats()`` returns both cumulative process-wide counters and the
    since-mark delta. ``hit_rate(kind)`` is the windowed hit fraction
    (``None`` before any lookup), the headline number for
    ``BENCH_SERVICE.json``'s cross-query-reuse evidence.
    """

    def __init__(self):
        self._base = _executor.cache_stats()

    def mark(self) -> None:
        """Re-baseline the telemetry window to 'now'."""
        self._base = _executor.cache_stats()

    def stats(self) -> dict:
        """``{"plan": {...}, "op": {...}}``, each with cumulative counters
        plus a ``"window"`` sub-dict of since-mark deltas."""
        now = _executor.cache_stats()
        out = {}
        for kind in ("plan", "op"):
            entry = dict(now[kind])
            entry["window"] = _diff(now[kind], self._base.get(kind, {}))
            out[kind] = entry
        return out

    def hit_rate(self, kind: str = "op") -> float | None:
        """Windowed hit fraction for ``kind`` ("plan" or "op"); ``None``
        when the window saw no lookups."""
        w = self.stats()[kind]["window"]
        total = w["hits"] + w["misses"]
        return (w["hits"] / total) if total else None
