"""repro: high-performance distributed dataframes + LM training on TPU/JAX.

Reproduction and extension of "In-depth Analysis On Parallel Processing
Patterns for High-Performance Dataframes" (Perera et al., 2023) as a
production-grade JAX framework. See DESIGN.md.
"""

__version__ = "0.1.0"
