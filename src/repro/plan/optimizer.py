"""Rewrite passes over logical plans (the cost-model-driven query optimizer).

``optimize`` runs, in order:

0. :func:`normalize_predicates` — constant-fold expression predicates and
   AND-split boolean conjunctions into separate ``SELECT`` nodes, so each
   conjunct can sink independently (different join sides, into a SCAN).
1. :func:`pushdown_predicates` — sink ``SELECT`` below projections, sorts and
   (side-resolvable) joins so filters run before shuffles shrink payloads.
2. :func:`pushdown_projections` — thread the set of columns each ancestor
   actually needs down the DAG and insert minimal ``PROJECT*`` nodes below
   shuffle boundaries (shrinks shuffled bytes; paper §5: comm terms scale
   with bold-n in bytes).
3. :func:`plan_shuffles` — the single host-side planning pass: concretize
   every shuffle op's strategy, quota, capacity and pipeline depth
   ``num_chunks`` from DAG-propagated size estimates via the Hockney cost
   model (replaces eager mode's scattered per-method planning).
4. :func:`elide_shuffles` — co-partition reuse (paper Table 2): drop a keyed
   op's shuffle when its input is already hash-partitioned on a subset of
   its keys (e.g. join→groupby on the same key runs the groupby locally).
5. :func:`fuse_elementwise` — collapse adjacent embarrassingly-parallel ops
   into one ``EP[...]`` stage compiled as a single shard_map body.

All passes are pure: nodes are immutable, so each pass rebuilds the DAG
bottom-up and returns a new root. Every pass is also exposed individually so
tests can assert on single rewrites via ``format_plan``.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from .. import expr as _expr
from ..core import cost_model, patterns
from ..core.partition import default_quota
from .logical import (
    JOIN_SUFFIX,
    Difference,
    Fused,
    GroupBy,
    Join,
    MapColumns,
    Node,
    Project,
    Rebalance,
    Recode,
    Rename,
    Scan,
    Select,
    Sort,
    Union,
    Unique,
    WithColumn,
    capacity_of,
    estimate_rows,
    partitioning_of,
    row_bytes_of,
    schema_names,
    schema_of,
)

__all__ = [
    "optimize",
    "normalize_predicates",
    "pushdown_predicates",
    "pushdown_projections",
    "pushdown_scans",
    "plan_shuffles",
    "elide_shuffles",
    "fuse_elementwise",
]

_EP = (Select, Project, Rename, MapColumns, WithColumn)


def _rewrite_up(root: Node, fn) -> Node:
    """Bottom-up structural rewrite: children first, then ``fn`` per node."""
    memo: dict = {}

    def rec(orig: Node) -> Node:
        if id(orig) in memo:
            return memo[id(orig)]
        n = orig
        kids = tuple(rec(c) for c in n.children)
        if kids != n.children:
            n = n.with_children(kids)
        out = fn(n)
        memo[id(orig)] = out
        return out

    return rec(root)


# -- pass 0: expression-predicate normalization --------------------------------

def _expr_select(child: Node, e, name: str) -> Select:
    """Build a SELECT from an expression tree (compiled body, exact used
    set, identity = the tree itself)."""
    return Select(child, _expr.to_jax_fn(e), name,
                  tuple(sorted(_expr.referenced_columns(e))), expr=e)


def normalize_predicates(root: Node) -> Node:
    """Constant-fold expression predicates and split boolean conjunctions.

    ``SELECT[(a > 3) & (b < 7)]`` becomes two stacked SELECTs so each
    conjunct pushes down independently (one can sink to a join's left
    input, the other to its right, or into a SCAN). The split preserves
    bit-exact semantics: filtering twice keeps the same surviving rows in
    the same order, and ``&`` is only split when both sides are boolean
    over the child schema (it is also integer bitwise-AND). Legacy callable
    predicates pass through untouched — their structure is opaque.
    """

    def norm(node: Node) -> Node:
        if not (isinstance(node, Select) and node.expr is not None):
            return node
        e = _expr.fold_constants(node.expr)
        parts = _expr.split_conjuncts(e, schema_of(node.child))
        if len(parts) == 1 and parts[0] == node.expr:
            return node
        out = node.child
        for i, p in enumerate(parts):
            nm = node.name if len(parts) == 1 else f"{node.name}.{i}"
            out = _expr_select(out, p, nm)
        return out

    return _rewrite_up(root, norm)


# -- pass 1: predicate pushdown ----------------------------------------------

def _sink_select_once(sel: Select) -> Node:
    """Push one SELECT one level down when legal; returns ``sel`` unchanged
    otherwise. Legality needs the predicate's accessed columns (``used``)."""
    child = sel.child
    if sel.used is None:
        return sel
    used = set(sel.used)
    if isinstance(child, Project) and used <= set(child.names):
        return dataclasses.replace(
            child, child=dataclasses.replace(sel, child=child.child))
    if isinstance(child, WithColumn) and child.name not in used:
        # the filter does not read the computed column: filter first, so
        # fewer rows pay the expression (and the SELECT keeps sinking)
        return dataclasses.replace(
            child, child=dataclasses.replace(sel, child=child.child))
    if isinstance(child, Sort):
        # filter-then-sort: same rows in the same global order (sample-sort
        # pivots move, but equal keys stay co-located and ties stay stable).
        return dataclasses.replace(
            child, child=dataclasses.replace(sel, child=child.child))
    if isinstance(child, Join):
        lnames = set(schema_names(schema_of(child.left)))
        rnames = set(schema_names(schema_of(child.right)))
        on = set(child.on)
        if used <= lnames:
            return dataclasses.replace(
                child, left=dataclasses.replace(sel, child=child.left))
        # names clashing with the left side are suffixed in the join output,
        # so an un-suffixed name in `used` can only target the right side if
        # it does not collide with a left non-key column.
        if used <= (rnames | on) and not (used & (lnames - on)):
            return dataclasses.replace(
                child, right=dataclasses.replace(sel, child=child.right))
    return sel


def pushdown_predicates(root: Node) -> Node:
    """Sink SELECT nodes below projections, sorts and joins (to fixpoint)."""
    prev = None
    while prev != root:
        prev = root
        root = _rewrite_up(
            root, lambda n: _sink_select_once(n) if isinstance(n, Select) else n)
    return root


# -- pass 2: projection pushdown ----------------------------------------------

def _maybe_project(node: Node, needed: frozenset) -> Node:
    names = schema_names(schema_of(node))
    keep = tuple(sorted(n for n in names if n in needed))
    if keep and set(keep) < set(names):
        return Project(node, keep, synthetic=True)
    return node


def pushdown_projections(root: Node) -> Node:
    """Insert minimal PROJECT* nodes below shuffle boundaries.

    The required-column set flows top-down from the root schema; at every
    shuffle input (join/groupby/... child) and source, columns nobody above
    needs are dropped before they are shuffled.
    """

    def prune(node: Node, needed: frozenset) -> Node:
        if isinstance(node, Select):
            used = set(node.used) if node.used is not None else set(
                schema_names(schema_of(node.child)))
            return dataclasses.replace(
                node, child=prune(node.child, frozenset(needed | used)))
        if isinstance(node, Project):
            keep = tuple(n for n in node.names if n in needed) or node.names
            return dataclasses.replace(
                node, names=keep, child=prune(node.child, frozenset(keep)))
        if isinstance(node, Rename):
            inv = {new: old for old, new in node.mapping}
            child_needed = frozenset(inv.get(n, n) for n in needed)
            return dataclasses.replace(node, child=prune(node.child, child_needed))
        if isinstance(node, MapColumns):
            child_names = set(schema_names(schema_of(node.child)))
            used = set(node.used) if node.used is not None else child_names
            child = prune(node.child, frozenset(used))
            return dataclasses.replace(node, child=_maybe_project(child, frozenset(used)))
        if isinstance(node, WithColumn):
            if node.name not in needed:
                # dead computed column: nobody above reads it, drop the node
                return prune(node.child, needed)
            refs = _expr.referenced_columns(node.expr)
            child_needed = frozenset((needed - {node.name}) | refs)
            child = prune(node.child, child_needed)
            return dataclasses.replace(
                node, child=_maybe_project(child, child_needed))
        if isinstance(node, Join):
            lnames = set(schema_names(schema_of(node.left)))
            on = set(node.on)
            needed_l = set((needed & lnames) | on)
            needed_r = set(on)
            for rn, _, _ in schema_of(node.right):
                if rn in on:
                    continue
                out_name = rn if rn not in lnames else rn + JOIN_SUFFIX
                if out_name in needed:
                    needed_r.add(rn)
                    if out_name != rn:
                        # an ancestor references the suffixed name; keep the
                        # colliding left column so the suffix (and thus the
                        # output schema) survives pruning
                        needed_l.add(rn)
            needed_l = frozenset(needed_l)
            left = _maybe_project(prune(node.left, needed_l), needed_l)
            right = _maybe_project(prune(node.right, frozenset(needed_r)),
                                   frozenset(needed_r))
            return dataclasses.replace(node, left=left, right=right)
        if isinstance(node, GroupBy):
            child_needed = frozenset(set(node.by) | {c for c, _ in node.aggs})
            child = _maybe_project(prune(node.child, child_needed), child_needed)
            return dataclasses.replace(node, child=child)
        if isinstance(node, Unique):
            child_needed = frozenset(needed | set(node.subset))
            child = _maybe_project(prune(node.child, child_needed), child_needed)
            return dataclasses.replace(node, child=child)
        if isinstance(node, Union):
            child_needed = frozenset(needed | set(node.on))
            left = _maybe_project(prune(node.left, child_needed), child_needed)
            right = _maybe_project(prune(node.right, child_needed), child_needed)
            return dataclasses.replace(node, left=left, right=right)
        if isinstance(node, Difference):
            needed_l = frozenset(needed | set(node.on))
            needed_r = frozenset(node.on)  # anti-join reads only the keys
            left = _maybe_project(prune(node.left, needed_l), needed_l)
            right = _maybe_project(prune(node.right, needed_r), needed_r)
            return dataclasses.replace(node, left=left, right=right)
        if isinstance(node, Sort):
            child_needed = frozenset(needed | {node.by})
            child = _maybe_project(prune(node.child, child_needed), child_needed)
            return dataclasses.replace(node, child=child)
        if isinstance(node, Rebalance):
            child = _maybe_project(prune(node.child, needed), frozenset(needed))
            return dataclasses.replace(node, child=child)
        if isinstance(node, Recode):
            # keep only the gather maps for columns an ancestor reads; a
            # fully-pruned recode disappears (the merged-vocab metadata
            # lives on the LazyDDF, not the node)
            maps = tuple((n, m) for n, m in node.mappings if n in needed)
            child = prune(node.child, needed)
            if not maps:
                return child
            return dataclasses.replace(node, mappings=maps, child=child)
        # Source (and any leaf): narrowing happens at the consumer boundary.
        return node

    out_names = frozenset(schema_names(schema_of(root)))
    return prune(root, out_names)


# -- pass 2b: scan pushdown ----------------------------------------------------

def _host_pred_ok(fn, schema) -> bool:
    """Probe whether a select predicate can run host-side on numpy columns
    (the scan's pre-admission filter). Mirrors ``probe_columns`` but with a
    plain numpy table; any exception or a non-boolean/miss-shaped result
    means the predicate stays on the device."""
    cols = {n: np.ones((2,) + tuple(tail), dtype=np.dtype(dt))
            for n, dt, tail in schema}
    try:
        out = np.asarray(fn(dict(cols)))
    except Exception:
        return False
    return out.shape[:1] == (2,) and out.dtype in (np.dtype(bool),)


def pushdown_scans(root: Node) -> Node:
    """Absorb projections and predicates sitting on a ``SCAN`` into the scan.

    Three rewrites run to fixpoint:

    - ``PROJECT(SCAN)`` -> ``SCAN[columns]`` — only the referenced ``.npz``
      members are decompressed per batch;
    - ``SELECT(SCAN)`` -> ``SCAN[+pred]`` — the predicate runs host-side on
      the decoded chunk *before* rows are admitted to the device.
      Expression predicates absorb when host-portable
      (``repro.expr.host_portable``: numpy and jax provably agree — float
      *arithmetic* promotes differently and keeps the SELECT on device),
      compiling straight to numpy (``repro.expr.to_numpy_fn``) with no
      trial probe; the tree becomes the scan's structural signature.
      Legacy callables are probed on a tiny numpy table first; ones that
      cannot run on numpy stay as device SELECTs;
    - ``PROJECT(SELECT(x))`` -> ``SELECT(PROJECT(x))`` when the predicate's
      accessed columns survive the projection, so projections keep sinking
      toward the scan.
    """

    def preds_survive_narrow(sc: Scan, restricted) -> bool:
        # expression preds always survive: the runner decodes their exact
        # referenced columns on top of the projected set; callables must
        # re-probe against the restricted schema
        return all(isinstance(sig, _expr.Expr) or _host_pred_ok(fn, restricted)
                   for sig, fn in zip(sc.pred_sigs, sc.pred_fns))

    def absorb(node: Node) -> Node:
        if isinstance(node, Project) and isinstance(node.child, Scan):
            sc = node.child
            narrowed = dataclasses.replace(sc, columns=tuple(sorted(node.names)))
            if sc.pred_fns and not preds_survive_narrow(sc, schema_of(narrowed)):
                return node
            return narrowed
        if isinstance(node, Select) and isinstance(node.child, Scan):
            sc = node.child
            if node.expr is not None:
                if _expr.host_portable(node.expr, schema_of(sc)):
                    return dataclasses.replace(
                        sc,
                        pred_names=sc.pred_names + (node.name,),
                        pred_sigs=sc.pred_sigs + (node.expr,),
                        pred_fns=sc.pred_fns + (_expr.to_numpy_fn(node.expr),))
                return node  # float-arith predicate: stays a device SELECT
            if node.fn_sig and _host_pred_ok(node.fn, schema_of(sc)):
                return dataclasses.replace(
                    sc,
                    pred_names=sc.pred_names + (node.name,),
                    pred_sigs=sc.pred_sigs + (node.fn_sig,),
                    pred_fns=sc.pred_fns + (node.fn,))
        if (isinstance(node, Project) and isinstance(node.child, Select)
                and node.child.used is not None
                and set(node.child.used) <= set(node.names)):
            sel = node.child
            return dataclasses.replace(
                sel, child=dataclasses.replace(node, child=sel.child))
        if isinstance(node, Project) and isinstance(node.child, Recode):
            # PROJECT(RECODE(x)) -> RECODE(PROJECT(x)): projections keep
            # sinking toward the scan; maps for projected-away columns drop
            rc = node.child
            keep = set(node.names)
            maps = tuple((n, m) for n, m in rc.mappings if n in keep)
            proj = dataclasses.replace(node, child=rc.child)
            if not maps:
                return proj
            return dataclasses.replace(rc, mappings=maps, child=proj)
        return node

    prev = None
    while prev != root:
        prev = root
        root = _rewrite_up(root, absorb)
    return root


# -- pass 3: cost-model shuffle planning ---------------------------------------

def plan_shuffles(root: Node, nworkers: int, src_rows: Mapping,
                  params: cost_model.CostParams | None = None,
                  stats=None) -> Node:
    """Concretize strategy / quota / capacity / ``num_chunks`` per shuffle op.

    One host-side pass over the whole DAG: row estimates propagate from the
    (single-sync) source counts, row widths come from the post-pushdown
    schemas, and the PR-1 pipelined-shuffle cost model picks the chunk depth
    per shuffle (``cost_model.choose_chunk_count``). Explicit user overrides
    (non-None quota/capacity/num_chunks/strategy) are respected. With
    ``stats`` (``repro.stats.PlanStats``), scan selectivities and
    groupby/unique key cardinalities come from the dataset sketches: a
    hint-free GroupBy gets its ``cardinality_hint`` pinned to the sketch
    estimate so ``patterns.plan_groupby`` and the cost model plan from a
    real cardinality instead of the unknown sentinel.
    """
    P = nworkers
    p = params or cost_model.CostParams()
    memo: dict = {}

    def rows(n: Node) -> float:
        return estimate_rows(n, src_rows, memo, stats)

    def chunks(node, n_rows_w: float, rb: float, core_op: str, card: float = 1.0):
        if node.num_chunks is not None:
            return node.num_chunks
        core_s = cost_model.t_local(core_op, max(n_rows_w, 1.0), card, p)
        return cost_model.choose_chunk_count(P, n_rows_w * rb, p, core_s=core_s)

    def plan(node: Node) -> Node:
        if isinstance(node, Join):
            cap_l = capacity_of(node.left, P)
            # the join shuffles BOTH relations with one quota, so size it
            # (and the output) from the larger side — with streamed scans
            # the probe batch can be far smaller than the build relation
            cap_m = max(cap_l, capacity_of(node.right, P))
            quota = node.quota or default_quota(cap_m, P)
            capacity = node.capacity or 2 * cap_m
            nl, nr = rows(node.left), rows(node.right)
            rb = (row_bytes_of(schema_of(node.left))
                  + row_bytes_of(schema_of(node.right))) / 2.0
            strategy = node.strategy
            if strategy == "auto":
                strategy = cost_model.choose_join_strategy(nl, nr, P, rb, p)
            if strategy == "broadcast":
                strategy = "broadcast_left" if nl <= nr else "broadcast_right"
            num_chunks = node.num_chunks or 1
            if strategy == "shuffle":
                num_chunks = chunks(node, (nl + nr) / max(P, 1), rb, "hash_join")
            return dataclasses.replace(node, strategy=strategy, quota=quota,
                                       capacity=capacity, num_chunks=num_chunks)
        if isinstance(node, GroupBy):
            cap = capacity_of(node.child, P)
            hint = node.cardinality_hint
            if hint is None and stats is not None:
                est = stats.groupby_cardinality(node)
                if est is not None:
                    hint = round(est, 3)
                    node = dataclasses.replace(node, cardinality_hint=hint)
            card = hint if hint is not None else 0.0
            plan_ = patterns.plan_groupby(
                card, P, node.capacity or cap, n_rows=rows(node.child),
                row_bytes=row_bytes_of(schema_of(node.child)), params=p,
                pre_combine=node.pre_combine)
            return dataclasses.replace(
                node,
                pre_combine=plan_.strategy == "combine_shuffle_reduce",
                quota=node.quota or default_quota(cap, P),
                capacity=node.capacity or cap,
                num_chunks=node.num_chunks or plan_.num_chunks)
        if isinstance(node, Unique):
            cap = capacity_of(node.child, P)
            rb = row_bytes_of(schema_of(node.child))
            return dataclasses.replace(
                node, quota=node.quota or default_quota(cap, P),
                capacity=node.capacity or cap,
                num_chunks=chunks(node, rows(node.child) / max(P, 1), rb, "unique"))
        if isinstance(node, Union):
            cap = capacity_of(node.left, P) + capacity_of(node.right, P)
            rb = row_bytes_of(schema_of(node.left))
            n_w = (rows(node.left) + rows(node.right)) / max(P, 1)
            return dataclasses.replace(
                node, quota=node.quota or default_quota(cap, P),
                capacity=node.capacity or cap,
                num_chunks=chunks(node, n_w, rb, "unique"))
        if isinstance(node, Difference):
            cap = capacity_of(node.left, P)
            # both relations shuffle with one quota (see Join above)
            cap_q = max(cap, capacity_of(node.right, P))
            rb = row_bytes_of(schema_of(node.left))
            return dataclasses.replace(
                node, quota=node.quota or default_quota(cap_q, P),
                capacity=node.capacity or cap,
                num_chunks=chunks(node, rows(node.left) / max(P, 1), rb,
                                  "set_difference"))
        if isinstance(node, Sort):
            cap = capacity_of(node.child, P)
            rb = row_bytes_of(schema_of(node.child))
            return dataclasses.replace(
                node, quota=node.quota or default_quota(cap, P, safety=3.0),
                capacity=node.capacity or 2 * cap,
                num_chunks=chunks(node, rows(node.child) / max(P, 1), rb, "sort"))
        if isinstance(node, Rebalance):
            cap = capacity_of(node.child, P)
            rb = row_bytes_of(schema_of(node.child))
            return dataclasses.replace(
                node, quota=node.quota or cap,
                num_chunks=chunks(node, rows(node.child) / max(P, 1), rb, "map"))
        return node

    return _rewrite_up(root, plan)


# -- pass 4: shuffle elision (co-partition reuse) ------------------------------

def elide_shuffles(root: Node) -> Node:
    """Drop shuffles whose input is already co-partitioned on the op's key.

    A keyed op needs rows with equal keys co-located. If the input is
    hash-partitioned on tuple T and T's columns are a subset of the op's
    keys, equal op-keys imply equal T — already co-located, so the op runs
    locally (paper Table 2's co-partition column). Binary set ops and joins
    additionally need both inputs partitioned by the *same* tuple (same hash
    placement). Runs after :func:`plan_shuffles` so join strategies are
    concrete.
    """

    def elide(node: Node) -> Node:
        if isinstance(node, GroupBy) and not node.elide_shuffle:
            p = partitioning_of(node.child)
            if p and set(p) <= set(node.by):
                return dataclasses.replace(node, elide_shuffle=True)
        if isinstance(node, Unique) and not node.elide_shuffle:
            p = partitioning_of(node.child)
            if p and set(p) <= set(node.subset):
                return dataclasses.replace(node, elide_shuffle=True)
        if isinstance(node, Join) and node.strategy == "shuffle":
            pl, pr = partitioning_of(node.left), partitioning_of(node.right)
            if pl and pl == pr and set(pl) <= set(node.on):
                return dataclasses.replace(node, strategy="local")
        if isinstance(node, (Union, Difference)) and not node.elide_shuffle:
            pl, pr = partitioning_of(node.left), partitioning_of(node.right)
            if pl and pl == pr and set(pl) <= set(node.on):
                return dataclasses.replace(node, elide_shuffle=True)
        return node

    return _rewrite_up(root, elide)


# -- pass 5: embarrassingly-parallel fusion ------------------------------------

def fuse_elementwise(root: Node) -> Node:
    """Fuse chains of adjacent EP ops into single ``Fused`` stages."""

    def fuse(node: Node) -> Node:
        if isinstance(node, _EP):
            c = node.child
            if isinstance(c, Fused):
                return Fused(c.child, c.steps + (node,))
            if isinstance(c, _EP):
                return Fused(c.child, (c, node))
        return node

    return _rewrite_up(root, fuse)


# -- the full pipeline ---------------------------------------------------------

def optimize(root: Node, nworkers: int, src_rows: Mapping,
             params: cost_model.CostParams | None = None,
             stats=None) -> Node:
    """Run all rewrite passes and return the optimized, fully-planned root.

    ``stats`` (an optional ``repro.stats.PlanStats``) feeds sketch-derived
    selectivities/cardinalities into the shuffle-planning pass; omitted,
    the planner keeps its fixed conservative ratios."""
    root = normalize_predicates(root)
    root = pushdown_predicates(root)
    root = pushdown_projections(root)
    root = pushdown_scans(root)
    root = plan_shuffles(root, nworkers, src_rows, params, stats=stats)
    root = elide_shuffles(root)
    root = fuse_elementwise(root)
    return root
