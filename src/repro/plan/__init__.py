"""Lazy logical-plan layer: build → optimize → compile whole pipelines.

This package turns the eager per-operator ``DDF`` API into a deferred one:

- ``logical``  — immutable plan node types + property propagation
  (schema, capacity, partitioning, row estimates);
- ``optimizer`` — rewrite passes: predicate/projection pushdown, cost-model
  shuffle planning, shuffle elision (co-partition reuse), EP fusion;
- ``executor`` — whole-pipeline compilation through the shared shard_map
  builder with plan + compiled-op caches;
- ``frame``    — the user-facing ``LazyDDF`` handle.

Entry points: ``DDF.lazy()``, ``DDF.from_numpy(..., mode="lazy")``, or flip
the module default with :func:`set_default_mode` ("eager" ships as the
compatibility default; "lazy" makes ``DDF.from_numpy`` return ``LazyDDF``).
"""

from . import executor, logical, optimizer  # noqa: F401
from .frame import LazyDDF  # noqa: F401
from .logical import format_plan  # noqa: F401
from .optimizer import optimize  # noqa: F401

__all__ = ["LazyDDF", "optimize", "format_plan", "set_default_mode",
           "get_default_mode"]

_DEFAULT_MODE = "eager"


def set_default_mode(mode: str) -> None:
    """Set the module-wide API default: "lazy" makes ``DDF.from_numpy``
    return a ``LazyDDF`` (plan-building) handle; "eager" preserves the
    original immediate-execution semantics."""
    global _DEFAULT_MODE
    if mode not in ("eager", "lazy"):
        raise ValueError(f"mode must be 'eager' or 'lazy', got {mode!r}")
    _DEFAULT_MODE = mode


def get_default_mode() -> str:
    """Current module-wide API default ("eager" or "lazy")."""
    return _DEFAULT_MODE
