"""``LazyDDF``: the lazy distributed-dataframe handle.

Operator methods mirror the eager ``DDF`` surface but only *build* logical
nodes (``repro.plan.logical``); nothing touches the devices until a terminal
call:

- ``.collect()`` / ``.eager()`` — optimize + compile + execute, returning an
  eager ``DDF`` (``.collect_with_info()`` also returns the aux counters);
- ``.to_numpy()`` — collect and gather to host;
- ``.explain()`` — render the (optimized) plan without executing.

Schema validation happens at graph-build time: unknown columns raise
``KeyError`` carrying the available schema immediately, not deep inside jit.
Select predicates and map functions are probed on a tiny host table to learn
which columns they read (enabling predicate/projection pushdown) and the map
output schema.
"""

from __future__ import annotations

import itertools
from typing import Callable, Mapping, Sequence

from .. import expr as _expr
from ..core.api import DDF, DDFContext, callable_signature
from . import executor
from .logical import (
    Difference,
    GroupBy,
    Join,
    MapColumns,
    Node,
    Project,
    Rebalance,
    Recode,
    Rename,
    Select,
    Sort,
    Source,
    Union,
    Unique,
    WithColumn,
    format_plan,
    probe_columns,
    schema_names,
    schema_of,
)

__all__ = ["LazyDDF"]

_SIDS = itertools.count()


class LazyDDF:
    """Lazy distributed dataframe: a logical-plan root + its source tables.

    Build pipelines by chaining operator methods (each returns a new
    ``LazyDDF``; plans are immutable and shareable), then call a terminal
    (``collect`` / ``to_numpy`` / ``explain``). Obtain one via
    ``DDF.lazy()`` or ``DDF.from_numpy(..., mode="lazy")``.
    """

    def __init__(self, root: Node, ctx: DDFContext, sources: Mapping,
                 scans: Mapping | None = None, vocabs: Mapping | None = None):
        self._root = root
        self._ctx = ctx
        self._sources = dict(sources)
        # scan sid -> DatasetManifest (out-of-core leaves, repro.stream)
        self._scans = dict(scans or {})
        # host-side vocabularies of dict-encoded string columns of the
        # plan's OUTPUT (name -> repro.core.vocab.DictVocab); the device
        # plan only ever sees their int32 code columns
        self._vocabs = dict(vocabs or {})
        self.last_info: dict | None = None
        self.last_profile = None  # repro.obs.Profile after collect(profile=True)

    @classmethod
    def from_ddf(cls, ddf: DDF) -> "LazyDDF":
        """Wrap a materialized eager DDF as a plan source."""
        sid = next(_SIDS)
        schema = tuple(sorted(
            (n, str(v.dtype), tuple(v.shape[1:])) for n, v in ddf.columns.items()))
        return cls(Source(sid, schema, ddf.capacity), ddf.ctx, {sid: ddf},
                   vocabs=dict(ddf.vocabs))

    # -- introspection ----------------------------------------------------------
    @property
    def schema(self) -> tuple:
        """Propagated output schema: ((name, dtype, trailing shape), ...)."""
        return schema_of(self._root)

    @property
    def column_names(self) -> tuple:
        return schema_names(self.schema)

    @property
    def plan(self) -> Node:
        """The (unoptimized) logical-plan root."""
        return self._root

    def _check(self, names: Sequence[str], op: str) -> None:
        have = set(self.column_names)
        missing = [n for n in names if n not in have]
        if missing:
            raise KeyError(f"{op}: unknown column(s) {missing}; "
                           f"available schema: {sorted(have)}")

    def _derive(self, node: Node, other: "LazyDDF | None" = None,
                vocabs: Mapping | None = None) -> "LazyDDF":
        srcs = dict(self._sources)
        scans = dict(self._scans)
        if other is not None:
            if other._ctx is not self._ctx and other._ctx != self._ctx:
                raise ValueError("cannot combine LazyDDFs from different contexts")
            srcs.update(other._sources)
            scans.update(other._scans)
        return LazyDDF(node, self._ctx, srcs, scans,
                       vocabs=self._vocabs if vocabs is None else vocabs)

    def _unify(self, other: "LazyDDF", op: str):
        """Vocab unification at a binary plan boundary: merge each shared
        dict column's vocabs host-side and wrap either input in an explicit
        ``RECODE`` node when its codes must move into the merged space —
        visible in ``explain()`` and charged by the cost model. Returns
        ``(left_root, right_root, merged_vocabs)``."""
        lv = {n: v for n, v in self._vocabs.items() if n in self.column_names}
        rv = {n: v for n, v in other._vocabs.items()
              if n in other.column_names}
        mixed = sorted((set(lv) ^ set(rv))
                       & set(self.column_names) & set(other.column_names))
        if mixed:
            raise TypeError(
                f"{op}: column(s) {mixed} are dict-encoded strings on one "
                f"side but plain numerics on the other — codes and raw "
                f"values are not comparable; encode both sides or neither")
        merged = {**rv, **lv}
        lmaps, rmaps = [], []
        for n in sorted(set(lv) & set(rv)):
            if lv[n].words == rv[n].words:
                continue
            mv = lv[n].merge(rv[n])
            merged[n] = mv
            if not lv[n].is_identity_into(mv):
                lmaps.append((n, tuple(int(c) for c in lv[n].recode_map(mv))))
            if not rv[n].is_identity_into(mv):
                rmaps.append((n, tuple(int(c) for c in rv[n].recode_map(mv))))
        lroot = Recode(self._root, tuple(lmaps)) if lmaps else self._root
        rroot = Recode(other._root, tuple(rmaps)) if rmaps else other._root
        return lroot, rroot, merged

    @staticmethod
    def _coerce(other) -> "LazyDDF":
        return other.lazy() if isinstance(other, DDF) else other

    def _probe(self, fn: Callable, op: str):
        """Probe a user callable, converting a missing-column KeyError into
        the build-time schema error the frame contract promises."""
        try:
            return probe_columns(fn, self.schema)
        except KeyError as e:
            raise KeyError(f"{op}: callable references unknown column(s) "
                           f"[{e.args[0] if e.args else e}]; available "
                           f"schema: {sorted(self.column_names)}") from e

    # -- embarrassingly parallel -------------------------------------------------
    def select(self, pred, name: str = "pred") -> "LazyDDF":
        """Filter rows by a boolean expression: ``select(col("a") > 3)``.

        The expression's exact referenced-column set drives predicate and
        projection pushdown (and absorption into SCAN leaves, where it is
        evaluated host-side before rows are admitted); unknown column
        references raise ``KeyError`` at build time; the constant-folded
        tree itself is the node's structural identity, so equal pipelines
        hit the plan and compile caches.

        Passing a Python callable over the column dict is deprecated
        (one-shot ``DeprecationWarning``) but bit-identical: the callable
        is probed host-side to learn which columns it reads, under the
        legacy contract that its column-access pattern is data-independent
        (dict iteration / ``in``-membership disable pushdown)."""
        if isinstance(pred, (_expr.Expr, bool)) or _expr.is_when_builder(pred):
            pred = _expr.prepare_row_expr(pred, self.column_names, "select",
                                          vocabs=self._vocabs or None)
            return self._derive(Select(
                self._root, _expr.to_jax_fn(pred), name,
                tuple(sorted(_expr.referenced_columns(pred))), expr=pred))
        _expr.warn_callable_deprecated("select")
        used, _ = self._probe(pred, f"select '{name}'")
        return self._derive(Select(self._root, pred, name, used,
                                   fn_sig=callable_signature(pred)))

    def with_column(self, name: str, value) -> "LazyDDF":
        """Add (or overwrite) column ``name`` from an expression:
        ``with_column("c", col("a") + col("b"))``. Scalars coerce to
        literals. The output dtype/shape is inferred from the tree (jax
        promotion rules) for schema propagation; unknown column references
        raise ``KeyError`` at build time."""
        e = _expr.prepare_row_expr(value, self.column_names, "with_column",
                                   vocabs=self._vocabs or None)
        return self._derive(
            WithColumn(self._root, str(name), e, fn=_expr.to_jax_fn(e)),
            vocabs={n: v for n, v in self._vocabs.items() if n != name})

    def project(self, names: Sequence[str]) -> "LazyDDF":
        """Keep only ``names`` (validated against the propagated schema)."""
        names = tuple(names)
        self._check(names, "project")
        return self._derive(
            Project(self._root, names),
            vocabs={n: v for n, v in self._vocabs.items() if n in set(names)})

    def drop(self, names: Sequence[str]) -> "LazyDDF":
        """Drop columns — inverse of :meth:`project`."""
        names = tuple(names)
        self._check(names, "drop")
        keep = tuple(n for n in self.column_names if n not in set(names))
        return self._derive(
            Project(self._root, keep),
            vocabs={n: v for n, v in self._vocabs.items() if n in set(keep)})

    def rename(self, mapping: Mapping[str, str]) -> "LazyDDF":
        """Rename columns (old -> new). Colliding targets raise ValueError
        (matching eager ``DDF.rename``; a silent overwrite drops a column)."""
        self._check(tuple(mapping), "rename")
        targets = [mapping.get(n, n) for n in self.column_names]
        dup = {t for t in targets if targets.count(t) > 1}
        if dup:
            raise ValueError(f"rename: duplicate target column(s) {sorted(dup)}")
        return self._derive(
            Rename(self._root, tuple(sorted(mapping.items()))),
            vocabs={mapping.get(n, n): v for n, v in self._vocabs.items()})

    def map_columns(self, fn: Callable, name: str = "map") -> "LazyDDF":
        """Legacy column-wise map over the raw column dict (deprecated —
        use expression-based :meth:`with_column` / :meth:`project`); output
        schema is probed host-side at build time."""
        _expr.warn_callable_deprecated("map_columns")
        used, out_schema = self._probe(fn, f"map_columns '{name}'")
        if out_schema is None:
            raise TypeError(
                f"map_columns '{name}': fn must return a column mapping when "
                "probed on a tiny table (needed for schema propagation)")
        return self._derive(MapColumns(self._root, fn, name, used, out_schema,
                                       fn_sig=callable_signature(fn)),
                            vocabs={})  # opaque map: code semantics unknown

    # -- keyed / shuffle ops ------------------------------------------------------
    def join(self, other, on: Sequence[str], strategy: str = "auto",
             quota: int | None = None, capacity: int | None = None,
             num_chunks: int | None = None) -> "LazyDDF":
        """Equi-join; the optimizer picks hash-shuffle vs broadcast and the
        pipeline depth for the whole pipeline unless pinned here."""
        other = self._coerce(other)
        on = tuple(on)
        self._check(on, "join")
        other._check(on, "join(right)")
        lroot, rroot, merged = self._unify(other, "join")
        return self._derive(Join(lroot, rroot, on, strategy,
                                 quota, capacity, num_chunks), other,
                            vocabs=merged)

    def groupby(self, by: Sequence[str], aggs,
                pre_combine: bool | None = None,
                cardinality_hint: float | None = None,
                quota: int | None = None, capacity: int | None = None,
                num_chunks: int | None = None) -> "LazyDDF":
        """GroupBy-aggregate; strategy/pipelining planned from DAG estimates
        (and elided entirely when the input is already co-partitioned).
        ``aggs`` is either the canonical ``{value_col: (op, ...)}`` mapping
        or a sequence of aggregation expressions (``[col("v").sum(),
        col("v").mean().alias("avg")]``); aliases become a RENAME node on
        top of the GROUPBY."""
        by = tuple(by)
        renames: tuple = ()
        if not isinstance(aggs, Mapping):
            aggs, renames = _expr.parse_agg_specs(aggs)
        self._check(by, "groupby")
        self._check(tuple(aggs), "groupby(aggs)")
        aggs_t = tuple(sorted((k, tuple(v)) for k, v in aggs.items()))
        bad = sorted(f"{c}.{o}" for c, ops_ in aggs_t for o in ops_
                     if c in self._vocabs and o in ("sum", "mean"))
        if bad:
            raise TypeError(
                f"groupby: aggregation(s) {bad} are arithmetic over a "
                f"dict-encoded string column — codes have order but no "
                f"arithmetic; only min/max/count apply to strings")
        out_vocabs = {n: v for n, v in self._vocabs.items() if n in set(by)}
        for c, ops_ in aggs_t:
            if c in self._vocabs:  # ordered aggs of a dict column stay dict
                for o in ops_:
                    if o in ("min", "max"):
                        out_vocabs[f"{c}_{o}"] = self._vocabs[c]
        out = self._derive(GroupBy(self._root, by, aggs_t, pre_combine,
                                   cardinality_hint, quota, capacity,
                                   num_chunks),
                           vocabs=out_vocabs)
        return out.rename(dict(renames)) if renames else out

    def unique(self, subset: Sequence[str], quota: int | None = None,
               capacity: int | None = None,
               num_chunks: int | None = None) -> "LazyDDF":
        """Distinct rows by ``subset`` key columns."""
        subset = tuple(subset)
        self._check(subset, "unique")
        return self._derive(Unique(self._root, subset, quota, capacity, num_chunks))

    def union(self, other, on: Sequence[str], quota: int | None = None,
              capacity: int | None = None,
              num_chunks: int | None = None) -> "LazyDDF":
        """Set union by key (both inputs must share a schema)."""
        other = self._coerce(other)
        on = tuple(on)
        self._check(on, "union")
        if set(self.column_names) != set(other.column_names):
            raise ValueError(
                f"union: schema mismatch {sorted(self.column_names)} vs "
                f"{sorted(other.column_names)}")
        lroot, rroot, merged = self._unify(other, "union")
        return self._derive(Union(lroot, rroot, on, quota,
                                  capacity, num_chunks), other, vocabs=merged)

    def difference(self, other, on: Sequence[str], quota: int | None = None,
                   capacity: int | None = None,
                   num_chunks: int | None = None) -> "LazyDDF":
        """Set difference by key (rows of self whose key is absent in other)."""
        other = self._coerce(other)
        on = tuple(on)
        self._check(on, "difference")
        other._check(on, "difference(right)")
        lroot, rroot, merged = self._unify(other, "difference")
        return self._derive(Difference(lroot, rroot, on, quota,
                                       capacity, num_chunks), other,
                            vocabs=merged)

    def sort_values(self, by: str, descending: bool = False,
                    quota: int | None = None, capacity: int | None = None,
                    num_chunks: int | None = None) -> "LazyDDF":
        """Global sample sort by ``by``."""
        self._check((by,), "sort_values")
        return self._derive(Sort(self._root, by, descending, quota,
                                 capacity, num_chunks))

    def rebalance(self, quota: int | None = None,
                  num_chunks: int | None = None) -> "LazyDDF":
        """Evenly redistribute rows across workers, preserving global order."""
        return self._derive(Rebalance(self._root, quota, num_chunks))

    # -- terminals ---------------------------------------------------------------
    def _rows(self) -> dict:
        rows = executor.source_row_counts(self._sources)
        rows.update({sid: m.num_rows for sid, m in self._scans.items()})
        return rows

    def collect(self, level: str = "all", profile: bool = False) -> DDF:
        """Optimize + compile + execute the pipeline; returns an eager DDF.

        Aux outputs (overflow counters etc.) land in ``self.last_info``.
        ``level="plan-only"`` skips the rewrite passes (A/B baseline).
        Plans with ``SCAN`` leaves (built via ``repro.stream.scan_csv`` /
        ``scan_dataset``) route through :meth:`collect_stream` — the
        out-of-core engine is the only way to run them (and it always runs
        the full optimizer, so ``level`` overrides are rejected there).

        ``profile=True`` runs the query with tracing enabled for its
        duration and stores a ``repro.obs.Profile`` (spans plus the cost
        model's predicted-vs-observed samples) in ``self.last_profile``.
        Profiling never changes results — it only adds a device sync per
        dispatched program for honest wall times."""
        if profile:
            from .. import obs as _obs
            with _obs.profiled() as prof:
                out = self.collect(level=level)
            self.last_profile = prof
            return out
        if self._scans:
            if level != "all":
                raise ValueError(
                    f"collect(level={level!r}) is not supported for "
                    "scan-bearing plans; the streaming engine always runs "
                    "the full optimizer")
            return self.collect_stream()
        out, info = executor.execute(self._root, self._ctx, self._sources,
                                     src_rows=self._rows(), level=level)
        self.last_info = info
        out.vocabs = {n: v for n, v in self._vocabs.items()
                      if n in out.columns}
        return out

    def collect_stream(self, batch_rows: int | None = None,
                       prefetch: bool = True, **opts) -> DDF:
        """Run the pipeline through the out-of-core streaming engine
        (``repro.stream``): SCAN leaves are sliced into cost-model-sized
        batches, each batch runs through the compiled plan, and non-EP
        tails finalize via carry-state merges (groupby/unique) or host-side
        spill + merge (sort, scan×scan joins). Returns the final eager DDF;
        per-batch aux counters land in ``self.last_info``."""
        from ..stream import runner as _runner
        out, info = _runner.collect(self, batch_rows=batch_rows,
                                    prefetch=prefetch, **opts)
        self.last_info = info
        out.vocabs = {n: v for n, v in self._vocabs.items()
                      if n in out.columns}
        return out

    def to_batches(self, batch_rows: int | None = None,
                   prefetch: bool = True, **opts):
        """Stream the pipeline's result as host column-dict batches.

        For fully streamable plans this is true out-of-core iteration —
        each yielded batch is one morsel through the compiled plan and the
        full result never materializes. Plans whose tail needs carry/spill
        finalization finalize first, then yield the result in
        ``batch_rows``-sized slices. Dict-encoded string columns are
        decoded per batch — consumers see strings, never codes."""
        from ..stream import runner as _runner
        batches = _runner.to_batches(self, batch_rows=batch_rows,
                                     prefetch=prefetch, **opts)
        if not self._vocabs:
            return batches
        vocabs = dict(self._vocabs)

        def decoded():
            for b in batches:
                yield {n: (vocabs[n].decode(v) if n in vocabs else v)
                       for n, v in b.items()}

        return decoded()

    def collect_with_info(self, level: str = "all"):
        """Like :meth:`collect` but returns ``(DDF, info dict)``."""
        out = self.collect(level=level)
        return out, self.last_info

    def eager(self) -> DDF:
        """Materialize to an eager DDF (today's semantics escape hatch)."""
        return self.collect()

    def to_numpy(self) -> dict:
        """Collect and gather live rows to host, in partition order."""
        return self.collect().to_numpy()

    def explain(self, optimized: bool = True, analyze: bool = False) -> str:
        """Render the logical plan (post-optimizer by default) with row
        estimates and a shuffle count — no device execution.

        Scan-bearing queries whose dataset manifests carry chunk sketches
        show sketch-estimated predicate selectivity next to the fixed
        ratio on each SCAN line (``sel~0.08 (fixed 0.25)``), and their row
        estimates/shuffle plans use the sketch numbers — the same stats
        the streaming runner plans with.

        ``analyze=True`` additionally *executes* the query under profiling
        (the EXPLAIN ANALYZE idiom) and appends the measured per-operator
        profile — predicted vs observed milliseconds per op and the
        per-pattern cost-model error — to the rendered plan. The analyzed
        result is bit-identical to a plain :meth:`collect` and lands in
        ``self.last_info`` as usual."""
        from ..stats import plan_stats as _plan_stats

        rows = self._rows()
        stats = _plan_stats(self._scans)
        if not optimized:
            text = format_plan(self._root, rows, stats=stats)
        else:
            plan = executor.optimized_plan(self._root, self._ctx, rows,
                                           stats=stats)
            text = format_plan(plan, rows, stats=stats)
        if not analyze:
            return text
        self.collect(profile=True)
        return text + "\n\n" + self.last_profile.render()

    def __repr__(self) -> str:
        return (f"LazyDDF(cols={list(self.column_names)}, "
                f"plan={type(self._root).__name__})")
