"""Logical-plan node types for the lazy DDF API (paper §2, Fig. 2b).

The lazy layer represents a whole dataframe pipeline as an immutable DAG of
logical nodes *before* anything executes, so the cost-model-driven optimizer
(``repro.plan.optimizer``) can see the entire query — the design argued for
by Modin's dataframe algebra and Cylon's execution plans. Each node mirrors
one ``DDF`` operator; node classes are frozen dataclasses, hashable and
structurally comparable, which is what lets optimized plans key the compiled
-plan cache.

Alongside the node types this module implements the *property propagation*
the optimizer relies on:

- :func:`schema_of` — output schema (name, dtype, trailing shape) per node.
- :func:`capacity_of` — static output capacity, mirroring the eager
  operator defaults exactly (bit-exactness contract).
- :func:`partitioning_of` — the hash-partition key tuple the node's output
  is co-partitioned on, or None; drives shuffle elision (paper Table 2
  co-partition reuse).
- :func:`estimate_rows` — global row-count estimates propagated from source
  counts, feeding the cost model's strategy/chunk-depth selection.

Operator bodies arrive in two forms. The first-class form is a
``repro.expr`` expression tree stored *on the node* (``Select.expr``,
``WithColumn.expr``, ``Scan.pred_sigs`` entries): immutable, structurally
hashable, with exact referenced-column sets — plan equality and the compile
caches key on the tree itself. The legacy form is an opaque callable
(``Select``/``MapColumns`` with ``expr=None``) compared by its
user-supplied ``name`` plus a callable fingerprint
(``repro.core.api.callable_signature``: code location, bytecode, hashable
closure/default values) rather than the function object itself, so
structurally-identical plans hit the compile caches while different
predicates never alias.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, ClassVar, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from .. import expr as _expr

__all__ = [
    "Node",
    "Source",
    "Scan",
    "Select",
    "Project",
    "Rename",
    "MapColumns",
    "WithColumn",
    "Join",
    "GroupBy",
    "Unique",
    "Union",
    "Difference",
    "Sort",
    "Rebalance",
    "Recode",
    "Fused",
    "Schema",
    "schema_of",
    "schema_names",
    "capacity_of",
    "partitioning_of",
    "estimate_rows",
    "row_bytes_of",
    "probe_columns",
    "count_shuffles",
    "format_plan",
    "plan_signature",
    "walk",
]

# ((column name, dtype string, trailing shape), ...) sorted by name.
Schema = tuple

SELECT_SELECTIVITY = 0.5   # default filter selectivity when nothing is known
UNKNOWN_CARDINALITY = 0.5  # default key-cardinality fraction for dedup ops
JOIN_SUFFIX = "_r"


@dataclasses.dataclass(frozen=True)
class Node:
    """Base class for logical-plan nodes (immutable, hashable, comparable)."""

    _CHILD_FIELDS: ClassVar[tuple] = ()

    @property
    def children(self) -> tuple:
        """Input nodes, in argument order."""
        return tuple(getattr(self, f) for f in self._CHILD_FIELDS)

    def with_children(self, new: Sequence["Node"]) -> "Node":
        """Copy of this node with its input nodes replaced."""
        return dataclasses.replace(self, **dict(zip(self._CHILD_FIELDS, new)))


@dataclasses.dataclass(frozen=True)
class Source(Node):
    """Leaf: one materialized eager DDF, identified by a stable source id."""

    sid: int
    schema: Schema
    capacity: int


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    """Leaf: a chunked on-disk dataset streamed in cost-model-sized batches.

    ``sid`` keys the ``DatasetManifest`` held by the owning ``LazyDDF`` /
    streaming runner (manifests stay out of the node so plans remain
    hashable). ``schema`` is the full on-disk schema; ``columns`` is the
    projection pushed into the scan (None = all — only these ``.npz``
    members are decompressed per batch). ``pred_names``/``pred_sigs``
    identify predicates pushed into the scan for plan equality and compile
    caching: a ``pred_sigs`` entry is the predicate's *expression tree*
    when it came from the expression API (structural identity, and the
    runner may decode extra referenced columns beyond ``columns`` for it)
    or a callable fingerprint for the legacy probed form. The host
    evaluators themselves, ``pred_fns``, are compare-excluded, mirroring
    :class:`Select`; the runner applies them host-side per batch *before*
    rows are admitted to the device. ``capacity`` is the per-worker batch
    capacity the runner slices the manifest into."""

    sid: int
    schema: Schema
    capacity: int
    columns: tuple | None = None
    pred_names: tuple = ()
    pred_sigs: tuple = ()
    pred_fns: tuple = dataclasses.field(compare=False, default=())


@dataclasses.dataclass(frozen=True)
class Select(Node):
    """Row filter (embarrassingly parallel). ``used`` lists the columns the
    predicate reads — exact when ``expr`` carries the predicate's
    expression tree (the first-class form; ``fn`` is then its compiled jax
    body and node identity comes from the tree itself), probed at build
    time for legacy callables (None means unknown/all, and ``fn_sig`` — the
    ``api.callable_signature`` fingerprint — keeps structurally-equal nodes
    with different predicates distinct)."""

    child: Node
    fn: Callable = dataclasses.field(compare=False)
    name: str = "pred"
    used: tuple | None = None
    fn_sig: tuple = ()
    expr: object | None = None

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class Project(Node):
    """Column projection. ``synthetic`` marks optimizer-inserted pushdowns."""

    child: Node
    names: tuple
    synthetic: bool = False

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class Rename(Node):
    """Column rename; ``mapping`` is ((old, new), ...) sorted."""

    child: Node
    mapping: tuple

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class MapColumns(Node):
    """Column-wise map (embarrassingly parallel). Output schema is probed at
    build time (``out_schema``); ``used`` and ``fn_sig`` as in
    :class:`Select`."""

    child: Node
    fn: Callable = dataclasses.field(compare=False)
    name: str = "map"
    used: tuple | None = None
    out_schema: Schema | None = None
    fn_sig: tuple = ()

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class WithColumn(Node):
    """Add (or overwrite) one column from an expression (embarrassingly
    parallel): all child columns pass through, plus ``name`` computed by
    ``expr``. ``expr`` is compare-included — node identity and cache keys
    are the expression's structural hash; ``fn`` is its compiled jax body
    (compare-excluded). The output dtype/shape is derived from the tree via
    ``repro.expr.infer_schema_entry``, never probed."""

    child: Node
    name: str
    expr: object = None
    fn: Callable = dataclasses.field(compare=False, default=None)

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class Join(Node):
    """Equi-join. ``strategy``: "auto" (planner decides) | "shuffle" |
    "broadcast" (planner picks the gathered side) | "broadcast_left" /
    "broadcast_right" (that side is replicated) | "local" (co-partition
    reuse: shuffle elided)."""

    left: Node
    right: Node
    on: tuple
    strategy: str = "auto"
    quota: int | None = None
    capacity: int | None = None
    num_chunks: int | None = None

    _CHILD_FIELDS: ClassVar[tuple] = ("left", "right")


@dataclasses.dataclass(frozen=True)
class GroupBy(Node):
    """GroupBy-aggregate; ``aggs`` is ((value_col, (op, ...)), ...) sorted.

    ``emit_partials=True`` makes the node emit mergeable partial aggregates
    (``<col>_sum``/``<col>_count``/... — mean stays decomposed, no
    finalization) — the per-batch form the streaming runner's carry state
    merges across batches before one final ``finalize_groupby``."""

    child: Node
    by: tuple
    aggs: tuple
    pre_combine: bool | None = None
    cardinality_hint: float | None = None
    quota: int | None = None
    capacity: int | None = None
    num_chunks: int | None = None
    elide_shuffle: bool = False
    emit_partials: bool = False

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class Unique(Node):
    """Distinct rows by ``subset`` key columns."""

    child: Node
    subset: tuple
    quota: int | None = None
    capacity: int | None = None
    num_chunks: int | None = None
    elide_shuffle: bool = False

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class Union(Node):
    """Set union by key (concat + distinct); both inputs share a schema."""

    left: Node
    right: Node
    on: tuple
    quota: int | None = None
    capacity: int | None = None
    num_chunks: int | None = None
    elide_shuffle: bool = False

    _CHILD_FIELDS: ClassVar[tuple] = ("left", "right")


@dataclasses.dataclass(frozen=True)
class Difference(Node):
    """Set difference by key (co-partition + local anti-join)."""

    left: Node
    right: Node
    on: tuple
    quota: int | None = None
    capacity: int | None = None
    num_chunks: int | None = None
    elide_shuffle: bool = False

    _CHILD_FIELDS: ClassVar[tuple] = ("left", "right")


@dataclasses.dataclass(frozen=True)
class Sort(Node):
    """Global sample sort by one key column (range shuffle)."""

    child: Node
    by: str
    descending: bool = False
    quota: int | None = None
    capacity: int | None = None
    num_chunks: int | None = None

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class Rebalance(Node):
    """Even redistribution of rows across workers, preserving global order."""

    child: Node
    quota: int | None = None
    num_chunks: int | None = None

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class Recode(Node):
    """Vocab-unification recode of dict-encoded code columns
    (embarrassingly parallel). Inserted at Join/Union/Difference boundaries
    where the two inputs carry *different* vocabularies for a shared string
    column: the merged vocab is computed host-side at plan-build time and
    ``mappings`` holds the per-column monotone gather maps into the merged
    code space — ``((name, (new_code_for_old_code_i, ...)), ...)`` sorted
    by name. Execution is one ``int32`` gather per column
    (``new = map[old]``).

    Deliberately *not* fused into EP chains: it stays a standalone node so
    ``explain()`` shows the RECODE step and the cost model charges it
    individually (``repro.obs.model_check``)."""

    child: Node
    mappings: tuple

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


@dataclasses.dataclass(frozen=True)
class Fused(Node):
    """A chain of embarrassingly-parallel steps compiled as one shard_map
    body (the optimizer's fusion pass). ``steps`` apply in order to the
    child's output; each step is an EP node whose own child link is only
    used for schema propagation."""

    child: Node
    steps: tuple

    _CHILD_FIELDS: ClassVar[tuple] = ("child",)


# -- build-time probing -------------------------------------------------------

class _RecordingColumns(dict):
    """Column dict that records which keys a probed callable reads."""

    def __init__(self, cols):
        super().__init__(cols)
        self.accessed: set = set()
        self.touched_all = False

    def __getitem__(self, k):
        self.accessed.add(k)
        return super().__getitem__(k)

    def get(self, k, default=None):
        self.accessed.add(k)
        return super().get(k, default)

    def _all(self):
        self.touched_all = True

    def keys(self):
        self._all()
        return super().keys()

    def values(self):
        self._all()
        return super().values()

    def items(self):
        self._all()
        return super().items()

    def __iter__(self):
        self._all()
        return super().__iter__()

    def __contains__(self, k):
        # membership tests make the callable's behavior depend on the full
        # column set, so pushdown must not narrow it (treat as touch-all)
        self._all()
        return super().__contains__(k)


def probe_columns(fn: Callable, schema: Schema):
    """Run ``fn`` on a tiny concrete table to learn (used columns, output
    schema). The probe sees a ones-valued table, so callables whose column
    accesses depend on data *values* (not just the schema) can under-report
    ``used``; the API contract requires data-independent access patterns
    (iteration and ``in``-membership are detected and reported as
    touch-all). Returns ``(used, out_schema)`` where ``used`` is a sorted name
    tuple or None (unknown — the callable iterated the dict or raised) and
    ``out_schema`` is the probed output schema or None (non-dict result,
    e.g. a select predicate mask). A ``KeyError`` (the callable referenced
    a column absent from ``schema``) propagates so callers can surface it
    at build time instead of deep inside jit."""
    cols = {n: jnp.ones((2,) + tuple(tail), jnp.dtype(dt)) for n, dt, tail in schema}
    rec = _RecordingColumns(cols)
    try:
        out = fn(rec)
    except KeyError:
        raise
    except Exception:
        return None, None
    used = None if rec.touched_all else tuple(sorted(rec.accessed))
    out_schema = None
    if isinstance(out, Mapping):
        try:
            out_schema = tuple(sorted(
                (n, str(jnp.asarray(v).dtype), tuple(jnp.asarray(v).shape[1:]))
                for n, v in dict(out).items()))
        except Exception:
            out_schema = None
    return used, out_schema


# -- property propagation -----------------------------------------------------

def schema_names(schema: Schema) -> tuple:
    """Column names of a schema, in schema order."""
    return tuple(n for n, _, _ in schema)


def _join_schema(ls: Schema, rs: Schema, on: tuple) -> Schema:
    lnames = set(schema_names(ls))
    out = list(ls)
    for n, dt, tail in rs:
        if n in on:
            continue
        out.append((n if n not in lnames else n + JOIN_SUFFIX, dt, tail))
    return tuple(sorted(out))


def _groupby_schema(child: Schema, by: tuple, aggs: tuple) -> Schema:
    d = {n: (dt, tail) for n, dt, tail in child}
    out = [(n, *d[n]) for n in by]
    for col, ops in aggs:
        for op in ops:
            if op == "count":
                out.append((f"{col}_count", "int32", ()))
            elif op == "mean":
                out.append((f"{col}_mean", "float32", d[col][1]))
            else:
                out.append((f"{col}_{op}", d[col][0], d[col][1]))
    return tuple(sorted(set(out)))


def _groupby_partial_schema(child: Schema, by: tuple, aggs: tuple) -> Schema:
    """Schema of the mergeable partial-aggregate form (``emit_partials``):
    mean decomposes into sum+count, nothing is finalized or dropped."""
    d = {n: (dt, tail) for n, dt, tail in child}
    out = [(n, *d[n]) for n in by]
    for col, ops in aggs:
        for op in ops:
            if op == "mean":
                out.append((f"{col}_sum", d[col][0], d[col][1]))
                out.append((f"{col}_count", "int32", ()))
            elif op == "count":
                out.append((f"{col}_count", "int32", ()))
            else:
                out.append((f"{col}_{op}", d[col][0], d[col][1]))
    return tuple(sorted(set(out)))


def schema_of(node: Node, memo: dict | None = None) -> Schema:
    """Output schema of a node: ((name, dtype, trailing shape), ...) sorted."""
    memo = {} if memo is None else memo
    if id(node) in memo:
        return memo[id(node)]
    if isinstance(node, Source):
        s = node.schema
    elif isinstance(node, Scan):
        if node.columns is None:
            s = node.schema
        else:
            keep = set(node.columns)
            s = tuple(x for x in node.schema if x[0] in keep)
    elif isinstance(node, (Select, Sort, Rebalance, Unique, Recode)):
        s = schema_of(node.child, memo)
    elif isinstance(node, Project):
        d = {n: (dt, tail) for n, dt, tail in schema_of(node.child, memo)}
        s = tuple(sorted((n, *d[n]) for n in node.names))
    elif isinstance(node, Rename):
        m = dict(node.mapping)
        s = tuple(sorted((m.get(n, n), dt, tail)
                         for n, dt, tail in schema_of(node.child, memo)))
    elif isinstance(node, MapColumns):
        if node.out_schema is None:
            raise ValueError(f"map '{node.name}': output schema unknown "
                             "(probe failed); cannot plan")
        s = node.out_schema
    elif isinstance(node, WithColumn):
        child_s = schema_of(node.child, memo)
        dt, tail = _expr.infer_schema_entry(node.expr, child_s)
        s = tuple(sorted([x for x in child_s if x[0] != node.name]
                         + [(node.name, dt, tail)]))
    elif isinstance(node, Join):
        s = _join_schema(schema_of(node.left, memo), schema_of(node.right, memo), node.on)
    elif isinstance(node, GroupBy):
        fn = _groupby_partial_schema if node.emit_partials else _groupby_schema
        s = fn(schema_of(node.child, memo), node.by, node.aggs)
    elif isinstance(node, (Union, Difference)):
        s = schema_of(node.left, memo)
    elif isinstance(node, Fused):
        s = schema_of(node.steps[-1], memo)
    else:
        raise TypeError(node)
    memo[id(node)] = s
    return s


def row_bytes_of(schema: Schema) -> float:
    """Bytes per row implied by a schema (drives the Hockney comm terms)."""
    total = 0.0
    for _, dt, tail in schema:
        total += np.dtype(dt).itemsize * float(np.prod(tail)) if tail else np.dtype(dt).itemsize
    return max(total, 1.0)


def capacity_of(node: Node, nworkers: int) -> int:
    """Static per-partition output capacity, mirroring the eager defaults."""
    if isinstance(node, (Source, Scan)):
        return node.capacity
    if isinstance(node, (Select, Project, Rename, MapColumns, WithColumn,
                         Recode, Fused)):
        return capacity_of(node.child, nworkers)
    if isinstance(node, Join):
        return node.capacity if node.capacity else 2 * capacity_of(node.left, nworkers)
    if isinstance(node, (GroupBy, Unique)):
        return node.capacity if node.capacity else capacity_of(node.child, nworkers)
    if isinstance(node, Union):
        return node.capacity if node.capacity else (
            capacity_of(node.left, nworkers) + capacity_of(node.right, nworkers))
    if isinstance(node, Difference):
        return node.capacity if node.capacity else capacity_of(node.left, nworkers)
    if isinstance(node, Sort):
        return node.capacity if node.capacity else 2 * capacity_of(node.child, nworkers)
    if isinstance(node, Rebalance):
        q = node.quota if node.quota else capacity_of(node.child, nworkers)
        return nworkers * q
    raise TypeError(node)


def partitioning_of(node: Node) -> tuple | None:
    """Hash-partition key tuple the node's output is co-partitioned on, or
    None. "Co-partitioned on K" means: rows with equal K-values live on the
    same worker, placed by ``hash_partition_ids`` over K in order — the
    property the shuffle-elision pass exploits (paper Table 2)."""
    if isinstance(node, (Source, Scan)):
        return None
    if isinstance(node, Select):
        return partitioning_of(node.child)
    if isinstance(node, Project):
        p = partitioning_of(node.child)
        return p if p and set(p) <= set(node.names) else None
    if isinstance(node, Rename):
        p = partitioning_of(node.child)
        m = dict(node.mapping)
        return tuple(m.get(c, c) for c in p) if p else None
    if isinstance(node, MapColumns):
        return None  # conservatively: the map may rewrite key columns
    if isinstance(node, WithColumn):
        p = partitioning_of(node.child)
        # overwriting a partition-key column breaks co-partitioning; a new
        # column leaves the child's hash placement intact
        return None if p and node.name in p else p
    if isinstance(node, Join):
        if node.strategy in ("shuffle",):
            return node.on
        if node.strategy == "local":
            return partitioning_of(node.left)
        if node.strategy == "broadcast_left":   # left replicated, right in place
            return partitioning_of(node.right)
        if node.strategy == "broadcast_right":
            return partitioning_of(node.left)
        return None  # "auto"/"broadcast": unknown until planned
    if isinstance(node, GroupBy):
        return partitioning_of(node.child) if node.elide_shuffle else node.by
    if isinstance(node, Unique):
        return partitioning_of(node.child) if node.elide_shuffle else node.subset
    if isinstance(node, (Union, Difference)):
        return partitioning_of(node.left) if node.elide_shuffle else node.on
    if isinstance(node, (Sort, Rebalance)):
        return None  # range/round-robin placement, not hash
    if isinstance(node, Recode):
        p = partitioning_of(node.child)
        # rows don't move, but a recoded key column's hash placement no
        # longer matches hash_partition_ids over its (new) values
        recoded = {n for n, _ in node.mappings}
        return None if p and (set(p) & recoded) else p
    if isinstance(node, Fused):
        p = partitioning_of(node.child)
        for step in node.steps:
            if p is None:
                return None
            if isinstance(step, Select):
                continue
            if isinstance(step, Project):
                p = p if set(p) <= set(step.names) else None
            elif isinstance(step, Rename):
                m = dict(step.mapping)
                p = tuple(m.get(c, c) for c in p)
            elif isinstance(step, WithColumn):
                p = None if step.name in p else p
            else:  # MapColumns
                p = None
        return p
    raise TypeError(node)


def estimate_rows(node: Node, src_rows: Mapping, memo: dict | None = None,
                  stats=None) -> float:
    """Estimated global row count, propagated from measured source counts.

    ``src_rows`` maps source id -> exact global rows (one host sync per
    pipeline, done by the executor). Estimates use the paper's planning
    inputs: filter selectivity, key cardinality, and join multiplicity
    default to conservative constants when no hint is available. With
    ``stats`` (a ``repro.stats.PlanStats``), scan predicate selectivity
    and groupby/unique key cardinality come from the dataset's chunk
    sketches instead of the fixed ratios — any estimate the sketches
    cannot support falls back to the constants.
    """
    memo = {} if memo is None else memo
    if id(node) in memo:
        return memo[id(node)]
    if isinstance(node, Source):
        r = float(src_rows.get(node.sid, node.capacity))
    elif isinstance(node, Scan):
        # predicates pushed into the scan filter before admission
        sel = stats.scan_selectivity(node) if stats is not None else None
        if sel is None:
            sel = SELECT_SELECTIVITY ** len(node.pred_sigs)
        r = float(src_rows.get(node.sid, node.capacity)) * sel
    elif isinstance(node, Select):
        r = SELECT_SELECTIVITY * estimate_rows(node.child, src_rows, memo,
                                               stats)
    elif isinstance(node, (Project, Rename, MapColumns, WithColumn, Sort,
                           Rebalance, Recode)):
        r = estimate_rows(node.child, src_rows, memo, stats)
    elif isinstance(node, Join):
        r = max(estimate_rows(node.left, src_rows, memo, stats),
                estimate_rows(node.right, src_rows, memo, stats))
    elif isinstance(node, GroupBy):
        card = node.cardinality_hint
        if card is None and stats is not None:
            card = stats.groupby_cardinality(node)
        card = card if card is not None and 0.0 < card <= 1.0 else UNKNOWN_CARDINALITY
        r = card * estimate_rows(node.child, src_rows, memo, stats)
    elif isinstance(node, Unique):
        card = stats.unique_cardinality(node) if stats is not None else None
        card = card if card is not None and 0.0 < card <= 1.0 else UNKNOWN_CARDINALITY
        r = card * estimate_rows(node.child, src_rows, memo, stats)
    elif isinstance(node, Union):
        r = (estimate_rows(node.left, src_rows, memo, stats)
             + estimate_rows(node.right, src_rows, memo, stats))
    elif isinstance(node, Difference):
        r = estimate_rows(node.left, src_rows, memo, stats)
    elif isinstance(node, Fused):
        r = estimate_rows(node.child, src_rows, memo, stats)
        for step in node.steps:
            if isinstance(step, Select):
                r *= SELECT_SELECTIVITY
    else:
        raise TypeError(node)
    memo[id(node)] = r
    return r


# -- traversal / display ------------------------------------------------------

def walk(root: Node):
    """Post-order traversal of the DAG, visiting shared nodes once."""
    seen: set = set()
    out: list = []

    def rec(n: Node):
        if id(n) in seen:
            return
        seen.add(id(n))
        for c in n.children:
            rec(c)
        out.append(n)

    rec(root)
    return out


def count_shuffles(root: Node) -> int:
    """Number of all-to-all shuffle communication ops the plan will execute
    (a join's co-partitioning pair counts as one shuffle op, matching the
    pattern taxonomy; elided/broadcast ops count zero)."""
    n = 0
    for node in walk(root):
        if isinstance(node, Join) and node.strategy in ("auto", "shuffle"):
            n += 1
        elif isinstance(node, (GroupBy, Unique, Union, Difference)) and not node.elide_shuffle:
            n += 1
        elif isinstance(node, (Sort, Rebalance)):
            n += 1
    return n


def _describe(node: Node) -> str:
    def planned(n):
        parts = []
        if n.quota is not None:
            parts.append(f"quota={n.quota}")
        if getattr(n, "capacity", None) is not None:
            parts.append(f"capacity={n.capacity}")
        if n.num_chunks is not None:
            parts.append(f"num_chunks={n.num_chunks}")
        return (" " + " ".join(parts)) if parts else ""

    if isinstance(node, Source):
        return (f"SOURCE#{node.sid} cols={schema_names(node.schema)} "
                f"capacity={node.capacity}")
    if isinstance(node, Scan):
        cols = node.columns if node.columns is not None else schema_names(node.schema)
        preds = ""
        if node.pred_names:
            shown = tuple(
                str(sig) if isinstance(sig, _expr.Expr) else name
                for name, sig in zip(node.pred_names, node.pred_sigs))
            preds = f" absorbed preds=[{', '.join(shown)}]"
        return (f"SCAN#{node.sid} cols={tuple(cols)} "
                f"batch_capacity={node.capacity}{preds}")
    if isinstance(node, Select):
        if node.expr is not None:
            return f"SELECT[{node.expr}]"
        return f"SELECT {node.name} used={node.used}"
    if isinstance(node, Project):
        star = "*" if node.synthetic else ""
        return f"PROJECT{star} cols={node.names}"
    if isinstance(node, Rename):
        return f"RENAME {dict(node.mapping)}"
    if isinstance(node, MapColumns):
        return f"MAP {node.name}"
    if isinstance(node, WithColumn):
        return f"WITH_COLUMN {node.name} = {node.expr}"
    if isinstance(node, Join):
        return f"JOIN on={node.on} strategy={node.strategy}{planned(node)}"
    if isinstance(node, GroupBy):
        s = f"GROUPBY by={node.by} aggs={node.aggs} pre_combine={node.pre_combine}"
        s += planned(node)
        s += " partials" if node.emit_partials else ""
        return s + (" elide_shuffle" if node.elide_shuffle else "")
    if isinstance(node, Unique):
        return (f"UNIQUE subset={node.subset}{planned(node)}"
                + (" elide_shuffle" if node.elide_shuffle else ""))
    if isinstance(node, Union):
        return (f"UNION on={node.on}{planned(node)}"
                + (" elide_shuffle" if node.elide_shuffle else ""))
    if isinstance(node, Difference):
        return (f"DIFFERENCE on={node.on}{planned(node)}"
                + (" elide_shuffle" if node.elide_shuffle else ""))
    if isinstance(node, Sort):
        return (f"SORT by={node.by}"
                + (" desc" if node.descending else "") + planned(node))
    if isinstance(node, Rebalance):
        parts = []
        if node.quota is not None:
            parts.append(f"quota={node.quota}")
        if node.num_chunks is not None:
            parts.append(f"num_chunks={node.num_chunks}")
        return "REBALANCE" + ((" " + " ".join(parts)) if parts else "")
    if isinstance(node, Recode):
        shown = " ".join(f"{n}->|{len(m)}|" for n, m in node.mappings)
        return f"RECODE {shown}"
    if isinstance(node, Fused):
        inner = []
        for s in node.steps:
            if isinstance(s, Select):
                inner.append(f"select[{s.expr}]" if s.expr is not None
                             else f"select:{s.name}")
            elif isinstance(s, Project):
                inner.append(f"project{'*' if s.synthetic else ''}{s.names}")
            elif isinstance(s, Rename):
                inner.append(f"rename{dict(s.mapping)}")
            elif isinstance(s, WithColumn):
                inner.append(f"with_column:{s.name}={s.expr}")
            else:
                inner.append(f"map:{s.name}")
        return "EP[" + " -> ".join(inner) + "]"
    return repr(node)


def format_plan(root: Node, src_rows: Mapping | None = None,
                stats=None) -> str:
    """Indented textual rendering of a plan tree (the ``.explain()`` body).

    Children print below their parent at one extra indent level; with
    ``src_rows`` each line carries the propagated row estimate. With
    ``stats`` (a ``repro.stats.PlanStats``) scan lines additionally show
    the sketch-estimated predicate selectivity next to the fixed ratio
    the planner would otherwise assume (``sel~0.08 (fixed 0.25)``).
    ``stats`` is never passed by :func:`plan_signature`, so identity keys
    are unaffected. A summary line reports the shuffle-op count.
    """
    memo: dict = {}
    lines: list = []

    def rec(n: Node, depth: int):
        extra = ""
        if src_rows is not None:
            extra = f"  rows~{estimate_rows(n, src_rows, memo, stats):.0f}"
        if stats is not None and isinstance(n, Scan) and n.pred_sigs:
            est = stats.scan_selectivity(n)
            if est is not None:
                fixed = SELECT_SELECTIVITY ** len(n.pred_sigs)
                extra += f"  sel~{est:.3g} (fixed {fixed:.3g})"
        lines.append("  " * depth + _describe(n) + extra)
        for c in n.children:
            rec(c, depth + 1)

    rec(root, 0)
    lines.append(f"shuffles: {count_shuffles(root)}")
    return "\n".join(lines)


def plan_signature(root: Node) -> str:
    """Process-stable text identity of a plan's *shape*.

    :func:`format_plan` output normalized so that re-building the same
    pipeline — in this process or after a restart — yields the same
    string: object addresses are stripped (legacy predicate closures print
    as ``<function ... at 0x...>``) and the process-global source/scan id
    counters (``#N`` / ``sid=N``) are renumbered by first appearance.

    Shared identity key for anything that must recognize "the same query
    again" across processes or rebuilds: the streaming checkpoint
    ``query_key`` and the admission controller's learned working-set
    corrections.
    """
    text = re.sub(r"0x[0-9a-f]+", "0x", format_plan(root))
    seen: dict[str, int] = {}

    def renum(m):
        s = m.group(1)
        if s not in seen:
            seen[s] = len(seen)
        return f"#{seen[s]}"

    text = re.sub(r"#(\d+)", renum, text)
    return re.sub(r"sid=(\d+)", lambda m: "sid=" + renum(m)[1:], text)
