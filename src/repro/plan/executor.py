"""Whole-pipeline compiler/executor for optimized logical plans.

The executor lowers an optimized DAG into **one** ``fn(comm, *tables)``
composed from the in-shard_map operators of ``repro.core.operators`` /
``local_ops``, compiles it through the same ``_build_op`` machinery (and
compiled-op cache) the eager API uses, and runs it as a single jitted
shard_map program — so an N-op pipeline pays one dispatch instead of N, and
XLA can schedule collectives across operator boundaries.

Two host-side caches sit in front of compilation:

- the optimized-plan cache (:data:`_PLAN_CACHE`), keyed by (workers, fabric,
  structural plan signature, source row counts) — skips re-running the
  optimizer for repeated collects;
- the compiled-op cache (``repro.core.api._OP_CACHE``), keyed by the fully
  planned DAG + argument schemas — skips re-tracing/compiling.

Source row counts are fetched with a single device->host sync per pipeline
(:func:`source_row_counts`) and memoized on the source DDFs, replacing the
per-method blocking syncs of eager mode.
"""

from __future__ import annotations

import time
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from ..core import cost_model, operators
from ..obs import model_check as _model
from ..obs import trace as _trace
from ..core.api import DDF, DDFContext, _LRUCache, _schema_sig, cached_op
from ..core.dataframe import Table, concat
from ..core.local_ops import (
    finalize_groupby,
    local_anti_join,
    local_groupby,
    local_join,
    local_unique,
)
from ..core.local_ops import select as local_select
from ..core.local_ops import with_column as local_with_column
from . import optimizer
from .logical import (
    Difference,
    Fused,
    GroupBy,
    Join,
    MapColumns,
    Node,
    Project,
    Rebalance,
    Recode,
    Rename,
    Scan,
    Select,
    Sort,
    Source,
    Union,
    Unique,
    WithColumn,
    walk,
)

__all__ = ["execute", "run_planned", "optimized_plan", "source_row_counts",
           "cache_stats"]

_PLAN_CACHE = _LRUCache(maxsize=128)


def cache_stats() -> dict:
    """Telemetry snapshot of the two host-side caches.

    ``{"plan": {hits, misses, evictions, size, maxsize},
       "op": {...}}`` — the optimized-plan cache above and the compiled-op
    cache shared with the eager API. Counters are cumulative for the
    process; ``repro.service.CacheManager`` diffs snapshots to attribute
    hits to a window (e.g. one batch of concurrent queries).
    """
    from ..core.api import _OP_CACHE

    return {"plan": _PLAN_CACHE.stats(), "op": _OP_CACHE.stats()}


def source_row_counts(sources: Mapping) -> dict:
    """Global row count per source id, with ONE device->host sync.

    All not-yet-known source count vectors are concatenated device-side and
    transferred in a single ``np.asarray`` call; results are memoized on the
    source DDF instances (``DDF.num_rows`` cache), so repeated collects of
    pipelines over the same tables sync zero times.
    """
    out: dict = {}
    pending = []
    for s in sorted(sources):
        d = sources[s]
        if d._nrows is not None:
            out[s] = d._nrows
        else:
            pending.append(s)
    if pending:
        allc = np.asarray(jnp.concatenate(
            [jnp.ravel(sources[s].counts) for s in pending]))
        off = 0
        for s in pending:
            n = int(sources[s].counts.shape[0])
            val = int(allc[off:off + n].sum())
            off += n
            out[s] = val
            sources[s]._nrows = val
    return out


def optimized_plan(root: Node, ctx: DDFContext, src_rows: Mapping,
                   level: str = "all", stats=None) -> Node:
    """Optimize (and fully plan) a logical DAG, with caching.

    ``level``: "all" runs every rewrite pass; "plan-only" runs just the
    cost-model shuffle planning (for A/B-ing the optimizer; execution always
    needs concrete quotas/capacities). The cache key includes the kernel
    dispatch signature (like ``cached_op``'s compiled-op keys) so plans —
    and anything keyed off them downstream — never alias across
    ``repro.kernels.set_backend`` flips; when ``stats``
    (``repro.stats.PlanStats``) inform the plan, its content hash keys the
    cache too, so re-sketched datasets never reuse stale plans.
    """
    from ..kernels import registry as _kernel_registry

    key = (ctx.nworkers, ctx.axes, ctx.fabric, level, root,
           tuple(sorted(src_rows.items())),
           _kernel_registry.dispatch_signature(),
           stats.cache_key if stats is not None else None)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        params = cost_model.params_for_fabric(ctx.fabric)
        if level == "all":
            plan = optimizer.optimize(root, ctx.nworkers, src_rows, params,
                                      stats=stats)
        else:
            plan = optimizer.plan_shuffles(root, ctx.nworkers, src_rows,
                                           params, stats=stats)
        _PLAN_CACHE.put(key, plan)
    return plan


def _apply_ep(step: Node, t: Table) -> Table:
    """Apply one embarrassingly-parallel step to a local partition."""
    if isinstance(step, Select):
        return local_select(t, step.fn)
    if isinstance(step, Project):
        return t.select_columns(step.names)
    if isinstance(step, Rename):
        m = dict(step.mapping)
        return Table({m.get(k, k): v for k, v in t.columns.items()}, t.nvalid)
    if isinstance(step, MapColumns):
        return Table(dict(step.fn(t.columns)), t.nvalid)
    if isinstance(step, WithColumn):
        return local_with_column(t, step.name, step.fn)
    if isinstance(step, Recode):
        # vocab unification: one int32 gather per recoded column into the
        # merged code space (maps are tiny host constants baked into the
        # compiled program — plan identity includes their values)
        cols = dict(t.columns)
        for name, m in step.mappings:
            cols[name] = jnp.asarray(np.asarray(m, np.int32))[cols[name]]
        return Table(cols, t.nvalid)
    raise TypeError(step)


def _make_plan_fn(root: Node, ordered_sids: tuple):
    """Build the single shard_map body that evaluates the whole plan."""
    order = {n: i for i, n in enumerate(walk(root))}

    def fn(comm, *tables):
        env = dict(zip(ordered_sids, tables))
        memo: dict = {}
        aux: dict = {}

        def put_aux(node, info: dict):
            i = order[node]
            for k, v in info.items():
                aux[f"n{i}:{k}"] = v

        def lower(node: Node) -> Table:
            if node in memo:
                return memo[node]
            if isinstance(node, (Source, Scan)):
                # a Scan's per-batch table is bound by the streaming runner
                # under the scan's sid, exactly like a Source binding
                out = env[node.sid]
            elif isinstance(node, Fused):
                out = lower(node.child)
                for step in node.steps:
                    out = _apply_ep(step, out)
            elif isinstance(node, (Select, Project, Rename, MapColumns,
                                   WithColumn, Recode)):
                out = _apply_ep(node, lower(node.child))
            elif isinstance(node, Join):
                l, r = lower(node.left), lower(node.right)
                if node.strategy == "shuffle":
                    out, info = operators.dist_join_shuffle(
                        comm, l, r, node.on, node.quota, node.capacity,
                        num_chunks=node.num_chunks or 1)
                    put_aux(node, info)
                elif node.strategy == "local":
                    out, ov = local_join(l, r, node.on, node.capacity)
                    put_aux(node, {"overflow_join": ov})
                elif node.strategy == "broadcast_right":
                    out, info = operators.dist_join_broadcast(
                        comm, l, r, node.on, node.capacity)
                    put_aux(node, info)
                elif node.strategy == "broadcast_left":
                    out, info = operators.dist_join_broadcast(
                        comm, l, r, node.on, node.capacity, gather="left")
                    put_aux(node, info)
                else:
                    raise ValueError(f"unplanned join strategy {node.strategy!r}")
            elif isinstance(node, GroupBy):
                t = lower(node.child)
                aggs = {k: v for k, v in node.aggs}
                if node.elide_shuffle:
                    red, ov_agg = local_groupby(t, node.by, aggs,
                                                capacity=node.capacity,
                                                merge=False, with_overflow=True)
                    put_aux(node, {"overflow_agg": ov_agg})
                    out = red if node.emit_partials else finalize_groupby(red, aggs)
                else:
                    out, info = operators.dist_groupby(
                        comm, t, node.by, aggs, node.quota, node.capacity,
                        bool(node.pre_combine), num_chunks=node.num_chunks or 1,
                        finalize=not node.emit_partials)
                    put_aux(node, info)
            elif isinstance(node, Unique):
                t = lower(node.child)
                if node.elide_shuffle:
                    out, ov_agg = local_unique(t, node.subset,
                                               capacity=node.capacity,
                                               with_overflow=True)
                    put_aux(node, {"overflow_agg": ov_agg})
                else:
                    out, info = operators.dist_unique(
                        comm, t, node.subset, node.quota, node.capacity,
                        num_chunks=node.num_chunks or 1)
                    put_aux(node, info)
            elif isinstance(node, Union):
                l, r = lower(node.left), lower(node.right)
                if node.elide_shuffle:
                    out, ov_agg = local_unique(concat(l, r), node.on,
                                               capacity=node.capacity,
                                               with_overflow=True)
                    put_aux(node, {"overflow_agg": ov_agg})
                else:
                    out, info = operators.dist_union(
                        comm, l, r, node.on, node.quota, node.capacity,
                        num_chunks=node.num_chunks or 1)
                    put_aux(node, info)
            elif isinstance(node, Difference):
                l, r = lower(node.left), lower(node.right)
                if node.elide_shuffle:
                    out = local_anti_join(l, r, node.on, capacity=node.capacity)
                else:
                    out, info = operators.dist_difference(
                        comm, l, r, node.on, node.quota, node.capacity,
                        num_chunks=node.num_chunks or 1)
                    put_aux(node, info)
            elif isinstance(node, Sort):
                out, info = operators.dist_sort(
                    comm, lower(node.child), node.by, node.quota, node.capacity,
                    descending=node.descending, num_chunks=node.num_chunks or 1)
                put_aux(node, {"overflow_shuffle": info["overflow_shuffle"]})
            elif isinstance(node, Rebalance):
                out, info = operators.rebalance(
                    comm, lower(node.child), node.quota,
                    num_chunks=node.num_chunks or 1)
                put_aux(node, info)
            else:
                raise TypeError(node)
            memo[node] = out
            return out

        result = lower(root)
        return result, aux

    return fn


def execute(root: Node, ctx: DDFContext, sources: Mapping,
            src_rows: Mapping | None = None, level: str = "all"):
    """Optimize, compile and run a logical plan.

    Args:
      root: the logical DAG to evaluate.
      ctx: execution environment (mesh + row-partition axes).
      sources: source id -> eager DDF backing each ``Source`` leaf.
      src_rows: optional pre-fetched source row counts (else one sync).
      level: optimizer level, see :func:`optimized_plan`.

    Returns:
      (result DDF, info dict) where info maps ``"n<i>:<counter>"`` aux keys
      (overflow counters etc., one leading per-worker axis) per plan node.
    """
    src_rows = dict(src_rows) if src_rows is not None else source_row_counts(sources)
    plan = optimized_plan(root, ctx, src_rows, level=level)
    if _trace.enabled():
        return _run_profiled(plan, ctx, sources, src_rows)
    return run_planned(plan, ctx, sources)


def _run_profiled(plan: Node, ctx: DDFContext, sources: Mapping,
                  src_rows: Mapping):
    """:func:`run_planned` under tracing: span the program dispatch, block
    for a true wall measurement, and record predicted-vs-observed samples
    for the plan's modeled operators (``repro.obs.model_check``). The sync
    only adds a barrier — results are bit-identical to the untraced path."""
    params = cost_model.params_for_fabric(ctx.fabric)
    preds = _model.predict_plan(plan, ctx.nworkers, src_rows, params)
    with _trace.span("plan.execute", ops=len(preds),
                     workers=ctx.nworkers) as sp:
        t0 = time.perf_counter()
        out, aux = run_planned(plan, ctx, sources)
        jax.block_until_ready(out.counts)
        dt = time.perf_counter() - t0
        rows = int(np.asarray(out.counts).sum())
        sp.set(wall_s=dt, out_rows=rows)
    _model.record_program(preds, dt, observed_rows=rows)
    return out, aux


def run_planned(plan: Node, ctx: DDFContext, sources: Mapping):
    """Execute an already-optimized/planned DAG — no optimizer pass.

    The streaming runner calls this once per batch: the compiled-op cache
    key is the planned DAG + argument schemas, so every batch after the
    first is a cache hit (one trace/compile per streamed pipeline).
    ``sources`` must bind every ``Source``/``Scan`` sid in ``plan``.
    Returns ``(result DDF, aux info dict)`` like :func:`execute`.
    """
    ordered_sids = tuple(sorted(sources))
    ddfs = [sources[s] for s in ordered_sids]
    arg_schemas = tuple(_schema_sig(d) for d in ddfs)
    fn = _make_plan_fn(plan, ordered_sids)
    op = cached_op(ctx, ("plan", plan), fn, arg_schemas)
    flat = []
    for d in ddfs:
        flat.append(d.columns)
        flat.append(d.counts)
    (cols, counts), aux = op(*flat)
    return DDF(dict(cols), counts, ctx), dict(aux)
