"""Typed columnar expression tree — the analyzable operator-input surface.

An :class:`Expr` describes a per-row computation over a table's columns as an
immutable tree of frozen dataclass nodes: ``col("a") + lit(3)``,
``(col("a") > 3) & (col("b") < 7)``, ``when(cond).then(x).otherwise(y)``,
``col("x").sum()``. Unlike the opaque Python callables the API used to take
(bytecode-fingerprinted and numpy-probed to *guess* which columns they
touch), an expression is a value the engine can inspect exactly:

- :func:`referenced_columns` — the exact column set, for projection pushdown
  and build-time schema validation;
- structural equality/hashing — frozen dataclasses compare and hash by
  shape, so two independently-built identical expressions key the same
  compiled-plan cache entry while different literals never alias;
- dual compilation — :func:`to_jax_fn` lowers to a pure jax function for
  in-shard_map device execution, :func:`to_numpy_fn` to a numpy function for
  host-side SCAN pre-admission filtering (no probe needed: an expression is
  known to evaluate on either backend);
- rewrites — :func:`fold_constants` and :func:`split_conjuncts` normalize
  predicates before pushdown.

Equality note: ``==``/``!=`` on :class:`Expr` are *structural* (dataclass
semantics) so plan nodes and caches stay sound; build elementwise comparison
predicates with :meth:`Expr.eq` / :meth:`Expr.ne`. Using an expression in a
boolean context (``if expr:``) raises ``TypeError`` — combine predicates
with ``&``, ``|``, ``~``.
"""

from __future__ import annotations

import dataclasses
import operator
from typing import Mapping

import jax.numpy as jnp
import numpy as np

__all__ = [
    "Expr",
    "Col",
    "Lit",
    "BinOp",
    "UnaryOp",
    "Cond",
    "Cast",
    "Agg",
    "Alias",
    "col",
    "lit",
    "when",
    "referenced_columns",
    "fold_constants",
    "split_conjuncts",
    "to_jax_fn",
    "to_numpy_fn",
    "infer_schema_entry",
    "ensure_columns",
    "ensure_row_expr",
    "is_when_builder",
    "prepare_row_expr",
    "host_portable",
    "bind_vocabs",
]

# op key -> (render symbol, python/array implementation)
_BIN_OPS = {
    "add": ("+", operator.add),
    "sub": ("-", operator.sub),
    "mul": ("*", operator.mul),
    "truediv": ("/", operator.truediv),
    "floordiv": ("//", operator.floordiv),
    "mod": ("%", operator.mod),
    "pow": ("**", operator.pow),
    "gt": (">", operator.gt),
    "ge": (">=", operator.ge),
    "lt": ("<", operator.lt),
    "le": ("<=", operator.le),
    "eq": ("==", operator.eq),
    "ne": ("!=", operator.ne),
    "and": ("&", operator.and_),
    "or": ("|", operator.or_),
    "xor": ("^", operator.xor),
}

_UNARY_OPS = {
    "neg": operator.neg,
    "invert": operator.invert,
    "abs": operator.abs,
}

_AGG_OPS = ("sum", "count", "min", "max", "mean")


def _to_expr(v) -> "Expr":
    if isinstance(v, Expr):
        return v
    if isinstance(v, (_When, _WhenThen)):
        raise TypeError(
            "incomplete when(...) expression: finish the builder with "
            ".then(value).otherwise(value)")
    return lit(v)


def _reject_bare_bool(value, op: str) -> None:
    """Catch the ``col("a") == 3`` mistake: ``==``/``!=`` on expressions
    compare *structure* and return a Python bool, which would otherwise
    coerce to a constant literal and silently produce all-True/all-False
    results. Predicate positions reject raw bools with guidance."""
    if isinstance(value, bool):
        raise TypeError(
            f"{op}: got a plain Python bool — `==`/`!=` on expressions "
            "compare structure, not values; use .eq()/.ne() for "
            f"elementwise equality (or lit({value}) for an explicit "
            "constant)")


@dataclasses.dataclass(frozen=True)
class Expr:
    """Base class for expression nodes (immutable, structurally hashable).

    Subclass instances are built via :func:`col` / :func:`lit` /
    :func:`when` and the overloaded operators; users never instantiate node
    classes directly. Arithmetic (``+ - * / // % **``), comparisons
    (``> >= < <=`` plus :meth:`eq`/:meth:`ne`), boolean combinators
    (``& | ^ ~``), ``-``/``abs``, :meth:`cast`, aggregation methods
    (:meth:`sum` ...) and :meth:`alias` all return new trees.
    """

    # -- arithmetic -----------------------------------------------------------
    def __add__(self, o):
        return BinOp("add", self, _to_expr(o))

    def __radd__(self, o):
        return BinOp("add", _to_expr(o), self)

    def __sub__(self, o):
        return BinOp("sub", self, _to_expr(o))

    def __rsub__(self, o):
        return BinOp("sub", _to_expr(o), self)

    def __mul__(self, o):
        return BinOp("mul", self, _to_expr(o))

    def __rmul__(self, o):
        return BinOp("mul", _to_expr(o), self)

    def __truediv__(self, o):
        return BinOp("truediv", self, _to_expr(o))

    def __rtruediv__(self, o):
        return BinOp("truediv", _to_expr(o), self)

    def __floordiv__(self, o):
        return BinOp("floordiv", self, _to_expr(o))

    def __rfloordiv__(self, o):
        return BinOp("floordiv", _to_expr(o), self)

    def __mod__(self, o):
        return BinOp("mod", self, _to_expr(o))

    def __rmod__(self, o):
        return BinOp("mod", _to_expr(o), self)

    def __pow__(self, o):
        return BinOp("pow", self, _to_expr(o))

    def __rpow__(self, o):
        return BinOp("pow", _to_expr(o), self)

    # -- comparisons ----------------------------------------------------------
    # NOTE: == / != keep dataclass *structural* semantics (plan equality and
    # cache keys depend on them); elementwise equality is .eq() / .ne().
    def __gt__(self, o):
        return BinOp("gt", self, _to_expr(o))

    def __ge__(self, o):
        return BinOp("ge", self, _to_expr(o))

    def __lt__(self, o):
        return BinOp("lt", self, _to_expr(o))

    def __le__(self, o):
        return BinOp("le", self, _to_expr(o))

    def eq(self, o) -> "Expr":
        """Elementwise equality predicate (``==`` is structural equality)."""
        return BinOp("eq", self, _to_expr(o))

    def ne(self, o) -> "Expr":
        """Elementwise inequality predicate (``!=`` is structural)."""
        return BinOp("ne", self, _to_expr(o))

    def is_in(self, values) -> "Expr":
        """Membership predicate: ``col("c").is_in(["iad", "sfo"])``.

        Desugars to an OR chain of :meth:`eq` comparisons, so each literal
        binds independently against a dict-encoded column's vocab (absent
        values fold to elementwise false); an empty value list is the
        constant-false predicate."""
        vals = list(values)
        if not vals:
            return lit(False)
        out = self.eq(vals[0])
        for v in vals[1:]:
            out = BinOp("or", out, self.eq(v))
        return out

    # -- boolean / bitwise ----------------------------------------------------
    # A bare Python bool operand here is almost always the `col(x) == v`
    # mistake (structural equality returns a bool); reject it instead of
    # silently folding the predicate to a constant — lit(True) stays
    # available for an intentional constant.
    def __and__(self, o):
        _reject_bare_bool(o, "&")
        return BinOp("and", self, _to_expr(o))

    def __rand__(self, o):
        _reject_bare_bool(o, "&")
        return BinOp("and", _to_expr(o), self)

    def __or__(self, o):
        _reject_bare_bool(o, "|")
        return BinOp("or", self, _to_expr(o))

    def __ror__(self, o):
        _reject_bare_bool(o, "|")
        return BinOp("or", _to_expr(o), self)

    def __xor__(self, o):
        _reject_bare_bool(o, "^")
        return BinOp("xor", self, _to_expr(o))

    def __invert__(self):
        return UnaryOp("invert", self)

    def __neg__(self):
        return UnaryOp("neg", self)

    def __abs__(self):
        return UnaryOp("abs", self)

    def __bool__(self):
        raise TypeError(
            "an expression has no truth value; combine predicates with "
            "& | ~ (not `and`/`or`/`not`) and compare with .eq()/.ne()")

    # -- conversions / naming -------------------------------------------------
    def cast(self, dtype) -> "Expr":
        """Elementwise dtype cast (``astype`` on both backends)."""
        return Cast(self, str(np.dtype(dtype)))

    def alias(self, name: str) -> "Expr":
        """Name this expression's output (groupby aggregation specs)."""
        return Alias(self, str(name))

    # -- aggregations (groupby specs) ----------------------------------------
    def sum(self) -> "Expr":
        """Aggregation spec: per-group sum of this column."""
        return Agg("sum", self)

    def count(self) -> "Expr":
        """Aggregation spec: per-group row count."""
        return Agg("count", self)

    def min(self) -> "Expr":
        """Aggregation spec: per-group minimum."""
        return Agg("min", self)

    def max(self) -> "Expr":
        """Aggregation spec: per-group maximum."""
        return Agg("max", self)

    def mean(self) -> "Expr":
        """Aggregation spec: per-group mean (float32)."""
        return Agg("mean", self)


@dataclasses.dataclass(frozen=True)
class Col(Expr):
    """Reference to a column by name (``col("a")``)."""

    name: str

    def __str__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class Lit(Expr):
    """Scalar literal. ``kind`` (bool/int/float/str) is derived from the value so
    ``lit(3)`` and ``lit(3.0)`` never alias structurally (Python's
    ``3 == 3.0`` would otherwise make them cache-equal); ``dtype`` pins a
    concrete dtype (else the literal stays weakly typed, letting the column
    dtype drive promotion exactly like a Python scalar in jax)."""

    value: object
    dtype: str | None = None
    kind: str = dataclasses.field(default="", init=False)

    def __post_init__(self):
        v = self.value
        if isinstance(v, (np.generic,)):
            v = v.item()
            object.__setattr__(self, "value", v)
        if isinstance(v, bool):
            k = "bool"
        elif isinstance(v, int):
            k = "int"
        elif isinstance(v, float):
            k = "float"
        elif isinstance(v, str):
            # string literals only ever compare against dict-encoded
            # columns; prepare_row_expr rewrites them into int32 code
            # space (bind_vocabs) before compilation — an unbound string
            # literal is a typed build-time error, never a device value.
            k = "str"
        else:
            raise TypeError(
                f"lit() takes a Python/numpy scalar (bool/int/float/str), "
                f"got {type(v).__name__}")
        object.__setattr__(self, "kind", k)

    def __str__(self):
        return repr(self.value) if self.dtype is None else \
            f"lit({self.value!r}, {self.dtype})"


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    """Binary operation node; ``op`` is a key of the operator table
    (arithmetic / comparison / boolean)."""

    op: str
    left: Expr
    right: Expr

    def __str__(self):
        sym = _BIN_OPS[self.op][0]
        return f"({self.left} {sym} {self.right})"


@dataclasses.dataclass(frozen=True)
class UnaryOp(Expr):
    """Unary operation node: ``neg`` (-x), ``invert`` (~x), ``abs``."""

    op: str
    child: Expr

    def __str__(self):
        if self.op == "neg":
            return f"(-{self.child})"
        if self.op == "invert":
            return f"(~{self.child})"
        return f"{self.op}({self.child})"


@dataclasses.dataclass(frozen=True)
class Cond(Expr):
    """Conditional select: ``when(pred).then(t).otherwise(f)`` — elementwise
    ``where(pred, t, f)`` on both backends."""

    pred: Expr
    if_true: Expr
    if_false: Expr

    def __str__(self):
        return f"when({self.pred}, {self.if_true}, {self.if_false})"


@dataclasses.dataclass(frozen=True)
class Cast(Expr):
    """Elementwise dtype cast node."""

    child: Expr
    dtype: str

    def __str__(self):
        return f"{self.child}.cast({self.dtype})"


@dataclasses.dataclass(frozen=True)
class Agg(Expr):
    """Aggregation spec node (``col("x").sum()``) — only meaningful as a
    groupby aggregation spec, never inside a row-level expression."""

    op: str
    child: Expr

    def __post_init__(self):
        if self.op not in _AGG_OPS:
            raise ValueError(f"unknown aggregation op {self.op!r}; "
                             f"supported: {_AGG_OPS}")

    def __str__(self):
        return f"{self.child}.{self.op}()"


@dataclasses.dataclass(frozen=True)
class Alias(Expr):
    """Output-name wrapper (``.alias("total")``) for aggregation specs."""

    child: Expr
    name: str

    def __str__(self):
        return f"{self.child} as {self.name!r}"


# -- builders -----------------------------------------------------------------

def col(name: str) -> Col:
    """Reference a column by name: ``col("a") > 3`` builds a predicate."""
    return Col(str(name))


def lit(value, dtype=None) -> Lit:
    """Scalar literal. Weakly typed unless ``dtype`` pins one, mirroring how
    a bare Python scalar promotes against column dtypes in jax. String
    literals are build-time-only: they bind against a dict-encoded column's
    vocab (``prepare_row_expr``) and never reach the device."""
    return Lit(value, None if dtype is None else str(np.dtype(dtype)))


class _When:
    """Builder state after ``when(pred)``; call ``.then(value)`` next."""

    def __init__(self, pred):
        self._pred = _to_expr(pred)

    def then(self, value) -> "_WhenThen":
        """Value when the predicate holds; finish with ``.otherwise()``."""
        return _WhenThen(self._pred, _to_expr(value))

    def __repr__(self):
        return f"when({self._pred}).then(...)"


class _WhenThen:
    """Builder state after ``.then(v)``; call ``.otherwise(value)`` to get
    the :class:`Cond` expression."""

    def __init__(self, pred, if_true):
        self._pred = pred
        self._if_true = if_true

    def otherwise(self, value) -> Cond:
        """Value when the predicate does not hold; returns the expression."""
        return Cond(self._pred, self._if_true, _to_expr(value))

    def __repr__(self):
        return f"when({self._pred}).then({self._if_true}).otherwise(...)"


def when(pred) -> _When:
    """Start a conditional: ``when(col("a") > 0).then(1).otherwise(-1)``."""
    _reject_bare_bool(pred, "when")
    return _When(pred)


# -- analysis -----------------------------------------------------------------

def _children(e: Expr) -> tuple:
    if isinstance(e, BinOp):
        return (e.left, e.right)
    if isinstance(e, (UnaryOp, Cast, Agg, Alias)):
        return (e.child,)
    if isinstance(e, Cond):
        return (e.pred, e.if_true, e.if_false)
    return ()


def referenced_columns(e: Expr) -> frozenset:
    """Exact set of column names the expression reads — the introspection
    callables never gave us (``probe_columns`` guesses from a trial run;
    this is definitional)."""
    out: set = set()

    def rec(x: Expr):
        if isinstance(x, Col):
            out.add(x.name)
        for c in _children(x):
            rec(c)

    rec(e)
    return frozenset(out)


def _contains_agg(e: Expr) -> bool:
    if isinstance(e, (Agg, Alias)):
        return True
    return any(_contains_agg(c) for c in _children(e))


def ensure_row_expr(e: Expr, op: str) -> None:
    """Reject aggregation/alias nodes inside row-level expressions
    (select predicates, with_column values) with a actionable error."""
    if _contains_agg(e):
        raise TypeError(
            f"{op}: aggregation expressions (.sum()/.alias()/...) are only "
            "valid as groupby aggregation specs, not in row-level "
            "expressions; compute derived inputs with with_column and "
            "aggregate the result")


def ensure_columns(e: Expr, available, op: str) -> None:
    """Validate referenced columns against a schema, raising ``KeyError``
    with the same wording as the eager path's column checks."""
    have = set(available)
    missing = sorted(n for n in referenced_columns(e) if n not in have)
    if missing:
        raise KeyError(
            f"{op}: unknown column(s) {missing}; "
            f"available schema: {sorted(have)}")


def is_when_builder(value) -> bool:
    """True for an unfinished ``when(...)``/``when(...).then(...)`` builder
    — callers route these to the guidance error instead of the legacy
    callable or literal fallbacks."""
    return isinstance(value, (_When, _WhenThen))


def prepare_row_expr(value, available, op: str, vocabs=None) -> "Expr":
    """The shared normalize-and-validate entry for row-level expression
    inputs (``select`` predicates, ``with_column`` values, scan
    predicates): coerce scalars to literals, reject unfinished ``when``
    builders and aggregation nodes with guidance, constant-fold, rewrite
    string literals into dict-code space against ``vocabs``
    (:func:`bind_vocabs`), and validate referenced columns against
    ``available`` (``KeyError`` with the eager wording). Every layer calls
    this one helper so eager, lazy and scan behavior cannot drift apart.

    Args:
      vocabs: optional mapping ``column name -> DictVocab`` for the
        dict-encoded columns in scope. A string literal that still
        compares against a non-dict column after binding raises a typed
        ``TypeError`` naming the operation.
    """
    if is_when_builder(value):
        raise TypeError(
            f"{op}: incomplete when(...) expression: finish the builder "
            "with .then(value).otherwise(value)")
    _reject_bare_bool(value, op)
    e = value if isinstance(value, Expr) else lit(value)
    e = fold_constants(e)
    if vocabs:
        e = fold_constants(bind_vocabs(e, vocabs))
    _ensure_strings_bound(e, op)
    ensure_row_expr(e, op)
    ensure_columns(e, available, op)
    return e


#: comparison flip table for Lit-op-Col orderings (``"x" < col("c")`` is
#: ``col("c") > "x"``)
_CMP_FLIP = {"gt": "lt", "ge": "le", "lt": "gt", "le": "ge",
             "eq": "eq", "ne": "ne"}


def _ensure_strings_bound(e: Expr, op: str) -> None:
    """Reject string literals that survived vocab binding: they compare
    against a column with no dict vocab in scope (or appear outside a
    comparison), which has no device meaning."""

    def rec(x: Expr) -> None:
        if isinstance(x, Lit) and x.kind == "str":
            raise TypeError(
                f"{op}: string literal {x.value!r} does not compare against "
                "a dict-encoded string column here — string comparisons "
                "require a dict-encoded column (see docs/TYPES.md)")
        for c in _children(x):
            rec(c)

    rec(e)


def bind_vocabs(e: Expr, vocabs: Mapping) -> Expr:
    """Rewrite string-literal comparisons into dict-code space.

    For every comparison between ``col(name)`` (with ``name`` in
    ``vocabs``) and a string literal, emit the equivalent ``int32``
    code-space predicate against the column's sorted vocab:

    - ``eq``/``ne`` with a *present* literal become code equality; with an
      *absent* literal they fold to elementwise false / true
      (``codes < 0`` / ``codes >= 0``) — never an error, matching SQL
      semantics for a value the data cannot contain;
    - ordered comparisons use the ``np.searchsorted`` boundary of the
      literal, which is exact whether or not the literal is present
      (sorted vocab => codes are order-isomorphic with strings);
    - a comparison between two dict *columns* requires identical vocabs
      (join/union unification recodes them first) and raises ``TypeError``
      otherwise.

    ``vocabs`` maps column name -> :class:`repro.core.vocab.DictVocab`
    (anything providing ``code_of``/``bound`` works). Non-string parts of
    the tree pass through untouched.
    """

    def cmp_code(op: str, name: str, s: str) -> Expr:
        v = vocabs[name]
        c = Col(name)
        if op in ("eq", "ne"):
            code = v.code_of(s)
            if code is None:
                # absent from the vocab: no row can match (eq) / every row
                # matches (ne) — fold to a constant-valued elementwise
                # predicate over the codes so shapes stay row-wise
                return BinOp("lt" if op == "eq" else "ge", c, Lit(0))
            return BinOp(op, c, Lit(int(code)))
        side = "left" if op in ("lt", "ge") else "right"
        bound = int(v.bound(s, side))
        return BinOp("lt" if op in ("lt", "le") else "ge", c, Lit(bound))

    def rec(x: Expr) -> Expr:
        if isinstance(x, BinOp):
            if x.op in _CMP_FLIP:
                le, ri = x.left, x.right
                if isinstance(le, Col) and isinstance(ri, Lit) \
                        and ri.kind == "str" and le.name in vocabs:
                    return cmp_code(x.op, le.name, ri.value)
                if isinstance(ri, Col) and isinstance(le, Lit) \
                        and le.kind == "str" and ri.name in vocabs:
                    return cmp_code(_CMP_FLIP[x.op], ri.name, le.value)
                if isinstance(le, Col) and isinstance(ri, Col) \
                        and le.name in vocabs and ri.name in vocabs \
                        and vocabs[le.name] != vocabs[ri.name]:
                    raise TypeError(
                        f"comparison between dict columns {le.name!r} and "
                        f"{ri.name!r} with different vocabularies; join or "
                        "union them first so vocab unification recodes "
                        "both sides")
            left, right = rec(x.left), rec(x.right)
            if left is x.left and right is x.right:
                return x
            return BinOp(x.op, left, right)
        if isinstance(x, UnaryOp):
            child = rec(x.child)
            return x if child is x.child else UnaryOp(x.op, child)
        if isinstance(x, Cond):
            p, t, f = rec(x.pred), rec(x.if_true), rec(x.if_false)
            if p is x.pred and t is x.if_true and f is x.if_false:
                return x
            return Cond(p, t, f)
        if isinstance(x, (Cast, Agg, Alias)):
            child = rec(x.child)
            return x if child is x.child else \
                dataclasses.replace(x, child=child)
        return x

    return rec(e) if vocabs else e


def host_portable(e: Expr, schema) -> bool:
    """True when host (numpy) and device (jax) evaluation of a predicate
    provably agree, so the optimizer may absorb it into a SCAN's host-side
    filter without changing which rows pass.

    Portable: all-integer comparisons (operands are signed-integer/bool
    columns, integer literals, or integer-only computations — unsigned
    columns are excluded, see ``intlike``), float comparisons
    anchored on device-exact float columns/literals, and boolean
    combinations of such; boolean columns/literals. Rejected: float
    *arithmetic* and mixed int-column vs float comparisons (numpy promotes
    through float64 where jax stays float32 — results can flip above
    2^24), ``truediv``/``pow``, float casts, and 64-bit columns/dtype pins
    (jax with x64 disabled truncates them to 32 bits on device, so the
    host sees different values than the device SELECT being replaced
    would). A rejected predicate simply stays a device SELECT."""
    dts = {n: np.dtype(d) for n, d, _ in schema}

    def exact(d) -> bool:
        # the dtype survives device admission unchanged (jax x64 disabled
        # truncates 64-bit ints/floats to 32 bits)
        d = np.dtype(d)
        return d.itemsize < 8 or d.kind not in ("i", "u", "f")

    def intlike(x: Expr) -> bool:
        # the subtree computes exclusively in signed-integer/bool space.
        # Unsigned columns are excluded outright: numpy compares them
        # against out-of-range (e.g. negative) weak literals exactly,
        # while jax wraps the literal into the unsigned dtype — provable
        # agreement would need per-literal range analysis.
        if isinstance(x, Col):
            d = dts.get(x.name)
            return d is not None and d.kind in ("i", "b") and exact(d)
        if isinstance(x, Lit):
            return x.kind in ("bool", "int") and (
                x.dtype is None or (np.dtype(x.dtype).kind in ("i", "b")
                                    and exact(x.dtype)))
        if isinstance(x, BinOp):
            return x.op in ("add", "sub", "mul", "floordiv", "mod",
                            "and", "or", "xor") \
                and intlike(x.left) and intlike(x.right)
        if isinstance(x, UnaryOp):
            return intlike(x.child)
        if isinstance(x, Cast):
            return np.dtype(x.dtype).kind in ("i", "b") \
                and exact(x.dtype) and intlike(x.child)
        if isinstance(x, Cond):
            return pred_ok(x.pred) and intlike(x.if_true) \
                and intlike(x.if_false)
        return False

    def float_atom(x: Expr) -> bool:
        # one side of a float-space comparison: a device-exact float
        # column, a weak literal (promotes to the column dtype on BOTH
        # backends under NEP 50 / jax weak typing), or a device-exact
        # float-pinned literal
        if isinstance(x, Col):
            d = dts.get(x.name)
            return d is not None and d.kind == "f" and exact(d)
        if isinstance(x, Lit):
            return x.dtype is None or (np.dtype(x.dtype).kind == "f"
                                       and exact(x.dtype))
        return False

    def compare_ok(left: Expr, right: Expr) -> bool:
        # both sides must promote identically on numpy and jax: either an
        # all-integer comparison, or a float comparison anchored on float
        # columns/literals. A mixed int-column vs float comparison is
        # float64 on numpy but float32 on jax (flips above 2^24), so it
        # is rejected.
        if intlike(left) and intlike(right):
            return True
        return float_atom(left) and float_atom(right)

    def pred_ok(x: Expr) -> bool:
        if isinstance(x, BinOp):
            if x.op in ("gt", "ge", "lt", "le", "eq", "ne"):
                return compare_ok(x.left, x.right)
            if x.op in ("and", "or", "xor"):
                return pred_ok(x.left) and pred_ok(x.right)
            return False
        if isinstance(x, UnaryOp) and x.op == "invert":
            return pred_ok(x.child)
        if isinstance(x, Col):
            d = dts.get(x.name)
            return d is not None and d.kind == "b"
        if isinstance(x, Lit):
            return x.kind == "bool"
        return False

    return pred_ok(e)


# -- rewrites -----------------------------------------------------------------

def _surely_bool(e: Expr) -> bool:
    """True when the expression produces booleans for *any* input schema
    (comparisons, boolean combinations of such) — the schema-free soundness
    test the fold identities need (``&``/``|`` double as integer bitwise
    ops, where ``x & True`` is ``x & 1``, not ``x``)."""
    if isinstance(e, BinOp):
        if e.op in ("gt", "ge", "lt", "le", "eq", "ne"):
            return True
        if e.op in ("and", "or", "xor"):
            return _surely_bool(e.left) and _surely_bool(e.right)
        return False
    if isinstance(e, UnaryOp) and e.op == "invert":
        return _surely_bool(e.child)
    if isinstance(e, Cond):
        return _surely_bool(e.if_true) and _surely_bool(e.if_false)
    if isinstance(e, Lit):
        return e.kind == "bool"
    return False


def fold_constants(e: Expr) -> Expr:
    """Evaluate literal-only subtrees down to literals and apply boolean
    identities (``x & True -> x``, ``x | False -> x``, literal-predicate
    ``when`` branch selection). Runs at build time so equivalent spellings
    (``col("a") > lit(1) + lit(2)`` vs ``col("a") > 3``) produce the same
    structural hash, and again in the optimizer's predicate normalization.

    Folding is semantics-preserving by construction: dtype-pinned literals
    are never collapsed (the pin drives promotion of the unfolded tree),
    and the boolean identities only apply when the kept side provably
    produces booleans on any schema (``x & True`` over an integer ``x`` is
    bitwise ``x & 1``, not ``x``)."""
    if isinstance(e, BinOp):
        left, right = fold_constants(e.left), fold_constants(e.right)
        if isinstance(left, Lit) and isinstance(right, Lit) \
                and left.dtype is None and right.dtype is None:
            try:
                return lit(_BIN_OPS[e.op][1](left.value, right.value))
            except Exception:
                pass
        if e.op == "and":
            if isinstance(left, Lit) and left.value is True \
                    and _surely_bool(right):
                return right
            if isinstance(right, Lit) and right.value is True \
                    and _surely_bool(left):
                return left
        if e.op == "or":
            if isinstance(left, Lit) and left.value is False \
                    and _surely_bool(right):
                return right
            if isinstance(right, Lit) and right.value is False \
                    and _surely_bool(left):
                return left
        if left is e.left and right is e.right:
            return e
        return BinOp(e.op, left, right)
    if isinstance(e, UnaryOp):
        child = fold_constants(e.child)
        if isinstance(child, Lit) and child.dtype is None:
            try:
                return lit(_UNARY_OPS[e.op](child.value))
            except Exception:
                pass
        return e if child is e.child else UnaryOp(e.op, child)
    if isinstance(e, Cond):
        pred = fold_constants(e.pred)
        t, f = fold_constants(e.if_true), fold_constants(e.if_false)
        if isinstance(pred, Lit) and pred.kind == "bool":
            return t if pred.value else f
        if pred is e.pred and t is e.if_true and f is e.if_false:
            return e
        return Cond(pred, t, f)
    if isinstance(e, Cast):
        child = fold_constants(e.child)
        return e if child is e.child else Cast(child, e.dtype)
    if isinstance(e, (Agg, Alias)):
        child = fold_constants(e.child)
        if child is e.child:
            return e
        return dataclasses.replace(e, child=child)
    return e


def infer_schema_entry(e: Expr, schema) -> tuple:
    """Output ``(dtype string, trailing shape)`` of a row-level expression
    over ``schema`` (((name, dtype, tail), ...)), by evaluating it with jax
    on a tiny ones-valued table — jax's own promotion rules, so the
    propagated schema matches what device execution will produce."""
    cols = {n: jnp.ones((2,) + tuple(tail), jnp.dtype(dt))
            for n, dt, tail in schema}
    out = jnp.asarray(_eval(e, cols, jnp))
    return str(out.dtype), tuple(out.shape[1:]) if out.ndim else ()


def _is_bool_expr(e: Expr, schema) -> bool:
    if _surely_bool(e):  # static fast path: no jax dispatch for the
        return True      # common comparison-built predicates
    refs = referenced_columns(e)
    sub = tuple(x for x in schema if x[0] in refs)
    try:
        dt, _ = infer_schema_entry(e, sub)
    except Exception:
        return False
    return dt == "bool"


def split_conjuncts(e: Expr, schema) -> tuple:
    """Split a predicate into its top-level AND conjuncts, so each can push
    down independently (e.g. to different join sides, or into a SCAN).
    ``&`` is also integer bitwise-AND, so a conjunct split only happens when
    both sides infer to boolean dtype over ``schema``; otherwise the
    expression is returned whole."""
    if isinstance(e, BinOp) and e.op == "and" \
            and _is_bool_expr(e.left, schema) and _is_bool_expr(e.right, schema):
        return split_conjuncts(e.left, schema) + split_conjuncts(e.right, schema)
    return (e,)


# -- compilation --------------------------------------------------------------

def _eval(e: Expr, cols: Mapping, xp):
    if isinstance(e, Col):
        return cols[e.name]
    if isinstance(e, Lit):
        if e.dtype is not None:
            return xp.asarray(e.value, dtype=xp.dtype(e.dtype))
        return e.value  # weakly typed scalar: column dtype drives promotion
    if isinstance(e, BinOp):
        return _BIN_OPS[e.op][1](_eval(e.left, cols, xp),
                                 _eval(e.right, cols, xp))
    if isinstance(e, UnaryOp):
        return _UNARY_OPS[e.op](_eval(e.child, cols, xp))
    if isinstance(e, Cond):
        return xp.where(_eval(e.pred, cols, xp),
                        _eval(e.if_true, cols, xp),
                        _eval(e.if_false, cols, xp))
    if isinstance(e, Cast):
        return xp.asarray(_eval(e.child, cols, xp)).astype(xp.dtype(e.dtype))
    if isinstance(e, (Agg, Alias)):
        raise TypeError(f"aggregation expression {e} cannot be evaluated "
                        "row-wise; it is a groupby aggregation spec")
    raise TypeError(e)


def to_jax_fn(e: Expr):
    """Compile to a pure jax function ``cols dict -> jax.Array`` for
    in-shard_map device execution (select masks, with_column values)."""

    def fn(cols):
        return _eval(e, cols, jnp)

    return fn


def to_numpy_fn(e: Expr):
    """Compile to a numpy function ``cols dict -> np.ndarray`` for
    host-side SCAN pre-admission filtering. Expressions always lower to
    numpy — unlike user callables, no trial probe is needed."""

    def fn(cols):
        return np.asarray(_eval(e, cols, np))

    return fn
