"""First-class columnar expression API (ISSUE 4 tentpole).

The public operator-input surface for the dataframe engine: build typed
expression trees with :func:`col` / :func:`lit` / :func:`when` and Python
operators, pass them to ``DDF.select`` / ``DDF.with_column`` / groupby
aggregation specs (eager, lazy and streaming layers all accept them).
Expressions replace the old opaque-callable forms — which remain as a
deprecated shim — giving the optimizer exact referenced-column sets,
structural plan-cache keys, host-compilable SCAN predicates and
device-compilable bodies. See ``docs/EXPRESSIONS.md``.
"""

import warnings

from .aggs import parse_agg_specs  # noqa: F401
from .tree import (  # noqa: F401
    Agg,
    Alias,
    BinOp,
    Cast,
    Col,
    Cond,
    Expr,
    Lit,
    UnaryOp,
    bind_vocabs,
    col,
    ensure_columns,
    ensure_row_expr,
    fold_constants,
    host_portable,
    infer_schema_entry,
    is_when_builder,
    lit,
    prepare_row_expr,
    referenced_columns,
    split_conjuncts,
    to_jax_fn,
    to_numpy_fn,
    when,
)

__all__ = [
    "Expr",
    "Col",
    "Lit",
    "BinOp",
    "UnaryOp",
    "Cond",
    "Cast",
    "Agg",
    "Alias",
    "col",
    "lit",
    "when",
    "referenced_columns",
    "fold_constants",
    "split_conjuncts",
    "to_jax_fn",
    "to_numpy_fn",
    "infer_schema_entry",
    "ensure_columns",
    "ensure_row_expr",
    "is_when_builder",
    "prepare_row_expr",
    "host_portable",
    "bind_vocabs",
    "parse_agg_specs",
    "warn_callable_deprecated",
]

# one warning per op name per process: enough signal to migrate without
# drowning a loop that calls the legacy form per batch
_WARNED: set = set()


def warn_callable_deprecated(op: str) -> None:
    """Emit the one-shot ``DeprecationWarning`` for a legacy callable-taking
    operator form (``select``/``map_columns`` with a Python function).
    Behavior of the legacy path is unchanged — bit-identical results through
    the probe-based pipeline — but expressions are the supported surface."""
    if op in _WARNED:
        return
    _WARNED.add(op)
    warnings.warn(
        f"{op} with a Python callable is deprecated; pass a repro.expr "
        "expression instead (e.g. select(col('a') > 3)). The callable form "
        "keeps bit-identical behavior but hides column references from the "
        "optimizer. See docs/EXPRESSIONS.md for the migration guide.",
        DeprecationWarning, stacklevel=3)
