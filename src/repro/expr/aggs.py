"""Groupby aggregation specs as expressions.

``groupby(keys, [col("v").sum(), col("v").mean().alias("avg")])`` is parsed
into the engine's canonical ``{value_col: (op, ...)}`` mapping plus the
renames implied by aliases (the distributed groupby kernel emits fixed
``<col>_<op>`` names; aliases are applied as a zero-copy rename on top).
"""

from __future__ import annotations

from .tree import Agg, Alias, Col

__all__ = ["parse_agg_specs"]


def parse_agg_specs(specs) -> tuple:
    """Parse a sequence of aggregation expressions into ``(aggs, renames)``.

    Each spec must be ``col(name).<op>()`` optionally wrapped in
    ``.alias(out_name)``; ``aggs`` is the canonical ``{col: (op, ...)}``
    mapping and ``renames`` is a sorted ``((default_name, alias), ...)``
    tuple for aliases that differ from the default ``<col>_<op>`` output
    name. Duplicate (col, op) pairs with conflicting aliases raise
    ``ValueError``; non-column aggregation inputs raise ``TypeError`` with
    migration guidance (compute derived inputs with ``with_column`` first).
    """
    aggs: dict = {}
    renames: dict = {}
    seen: dict = {}
    for spec in specs:
        alias = None
        e = spec
        if isinstance(e, Alias):
            alias, e = e.name, e.child
        if not isinstance(e, Agg):
            raise TypeError(
                f"groupby aggregation spec must be an aggregation "
                f"expression like col('x').sum() (got {spec!r})")
        if not isinstance(e.child, Col):
            raise TypeError(
                f"groupby aggregates a plain column, got {spec}; compute "
                "derived inputs with with_column first "
                "(e.g. with_column('t', col('a') + col('b')) then "
                "col('t').sum())")
        name, op = e.child.name, e.op
        key = (name, op)
        if key in seen:
            if seen[key] != alias:
                raise ValueError(
                    f"groupby: duplicate aggregation {name}_{op} with "
                    "conflicting aliases")
            continue
        seen[key] = alias
        aggs.setdefault(name, []).append(op)
        default = f"{name}_{op}"
        if alias is not None and alias != default:
            renames[default] = alias
    if not aggs:
        raise ValueError("groupby: empty aggregation spec")
    outs: set = set()
    for (name, op), alias in seen.items():
        out_name = alias if alias is not None else f"{name}_{op}"
        if out_name in outs:
            raise ValueError(
                f"groupby: aggregation specs produce duplicate output "
                f"column {out_name!r}; give conflicting aggregations "
                "distinct .alias() names")
        outs.add(out_name)
    return ({k: tuple(v) for k, v in aggs.items()},
            tuple(sorted(renames.items())))
