from .synthetic import uniform_table, zipf_table, synthetic_token_corpus  # noqa: F401
from .pipeline import TokenPipeline  # noqa: F401
from .io import read_csv_dist, write_csv_dist  # noqa: F401
from .dataset import (  # noqa: F401
    DatasetManifest,
    DatasetWriter,
    csv_to_dataset,
    open_dataset,
    read_rows,
    write_dataset,
)
