"""Synthetic data generators (paper §6 methodology: uniformly random
two-int64-column tables at a controlled cardinality; plus zipf-skewed
variants for the load-balance experiments, and a token corpus for LM
training)."""

from __future__ import annotations

import numpy as np

__all__ = ["uniform_table", "zipf_table", "synthetic_token_corpus"]


def uniform_table(n_rows: int, cardinality: float = 0.9, n_cols: int = 2,
                  seed: int = 0, dtype=np.int32) -> dict[str, np.ndarray]:
    """Paper §6: uniform random, cardinality C => keys drawn from C*n values."""
    rng = np.random.default_rng(seed)
    n_keys = max(int(n_rows * cardinality), 1)
    cols = {"c0": rng.integers(0, n_keys, size=n_rows).astype(dtype)}
    for i in range(1, n_cols):
        cols[f"c{i}"] = rng.integers(0, np.iinfo(np.int32).max, size=n_rows).astype(dtype)
    return cols


def zipf_table(n_rows: int, a: float = 1.5, n_cols: int = 2, seed: int = 0,
               dtype=np.int32) -> dict[str, np.ndarray]:
    """Skewed keys (paper §5.4.2 data-distribution discussion)."""
    rng = np.random.default_rng(seed)
    keys = rng.zipf(a, size=n_rows).astype(dtype)
    cols = {"c0": keys}
    for i in range(1, n_cols):
        cols[f"c{i}"] = rng.integers(0, np.iinfo(np.int32).max, size=n_rows).astype(dtype)
    return cols


def synthetic_token_corpus(n_docs: int, vocab: int, mean_len: int = 512,
                           dup_fraction: float = 0.2, seed: int = 0):
    """Documents with controlled duplication (for the dedup stage) and
    variable lengths (for the sort/bucketing stage)."""
    rng = np.random.default_rng(seed)
    lens = np.maximum(8, rng.poisson(mean_len, n_docs)).astype(np.int32)
    doc_id = np.arange(n_docs, dtype=np.int32)
    # duplicated docs share a content hash
    n_unique = max(int(n_docs * (1 - dup_fraction)), 1)
    content = rng.integers(0, n_unique, size=n_docs).astype(np.int32)
    lens = lens[content % len(lens)]  # duplicates share length
    return {"doc_id": doc_id, "content_hash": content, "length": lens,
            "quality": rng.random(n_docs).astype(np.float32)}
