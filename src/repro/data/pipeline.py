"""LM data pipeline built on the DDF engine — the paper's technique as the
trainer's first-class data path (DESIGN.md §3).

Stages (each one of the paper's patterns):
  1. partitioned input  — synthetic corpus metadata split across workers
  2. dedup              — Combine-Shuffle-Reduce ``unique`` on content hash
  3. quality filter     — Embarrassingly-Parallel ``select``
  4. length bucketing   — Sample-Shuffle-Compute ``sort_values`` by length
  5. rebalance          — Partitioned-I/O repartition (straggler guard)
  6. stats              — Globally-Reduce aggregations (token budget)

The pipeline yields fixed-shape token batches; document token content is
generated deterministically from (doc_id, position) so the corpus never
needs to exist on disk — honest for a synthetic benchmark while keeping the
DDF stages real.
"""

from __future__ import annotations

import numpy as np

from ..core import DDF, DDFContext
from .synthetic import synthetic_token_corpus

__all__ = ["TokenPipeline"]


class TokenPipeline:
    def __init__(self, ctx: DDFContext, n_docs: int, vocab: int, seq_len: int,
                 batch: int, seed: int = 0, quality_threshold: float = 0.05):
        self.ctx = ctx
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed

        corpus = synthetic_token_corpus(n_docs, vocab, seed=seed)
        # mode pinned: this internal pipeline drives the eager tuple-returning
        # API and must not be affected by repro.plan.set_default_mode("lazy")
        ddf = DDF.from_numpy(corpus, ctx, mode="eager",
                             capacity=2 * (n_docs // ctx.nworkers + 1))

        # 2. dedup on content hash (combine-shuffle-reduce)
        ddf, self.dedup_info = ddf.unique(("content_hash",))
        # 3. quality filter (embarrassingly parallel)
        ddf = ddf.select(lambda c: c["quality"] > quality_threshold, name="quality")
        # 4. length bucketing (sample-shuffle-compute)
        ddf, self.sort_info = ddf.sort_values("length")
        # 5. rebalance (partitioned I/O)
        ddf, self.rebalance_info = ddf.rebalance()
        self.docs = ddf
        # 6. global stats (globally reduce)
        self.total_tokens = int(ddf.agg("length", "sum"))
        self.n_docs = ddf.length()

        host = ddf.to_numpy()
        self._doc_ids = host["doc_id"]
        self._lengths = host["length"]
        self._rng = np.random.default_rng(seed + 1)

    def __iter__(self):
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        """Pack documents into a (batch, seq_len) token block. Tokens are a
        deterministic hash of (doc_id, pos) — reproducible across restarts."""
        B, S = self.batch, self.seq_len
        idx = self._rng.integers(0, len(self._doc_ids), size=B)
        doc = self._doc_ids[idx][:, None].astype(np.uint32)
        pos = np.arange(S, dtype=np.uint32)[None, :]
        h = (doc * np.uint32(2654435761) + pos * np.uint32(40503)) & np.uint32(0xFFFFFFFF)
        h ^= h >> np.uint32(16)
        tokens = (h % np.uint32(self.vocab)).astype(np.int32)
        length = np.minimum(self._lengths[idx], S)[:, None]
        mask = (np.arange(S)[None, :] < length).astype(np.float32)
        labels = np.roll(tokens, -1, axis=1)
        return {"tokens": tokens, "labels": labels, "loss_mask": mask}
