"""LM data pipeline built on the DDF engine — the paper's technique as the
trainer's first-class data path (DESIGN.md §3).

Stages (each one of the paper's patterns):
  1. partitioned input  — synthetic corpus written as a chunked on-disk
                          dataset, opened through ``repro.stream.scan_dataset``
  2. dedup              — Combine-Shuffle-Reduce ``unique`` on content hash
                          (streamed with cross-batch carry state)
  3. quality filter     — Embarrassingly-Parallel ``select`` with a
                          ``repro.expr`` predicate (pushed into the scan
                          where the planner can — evaluated host-side,
                          no callable probe)
  4. length bucketing   — Sample-Shuffle-Compute ``sort_values`` by length
                          (host-side spill + merge when streamed)
  5. rebalance          — Partitioned-I/O repartition (straggler guard)
  6. stats              — Globally-Reduce aggregations (token budget)

The whole document pipeline runs through the out-of-core streaming engine:
construction materializes the processed docs via ``collect_stream`` and
:meth:`TokenPipeline.epoch` re-streams one epoch through ``.to_batches()``
so the trainer's data path exercises the streaming engine end to end.

The pipeline yields fixed-shape token batches; document token content is
generated deterministically from (doc_id, position) so the corpus never
needs to exist on disk at token granularity — honest for a synthetic
benchmark while keeping the DDF stages real.
"""

from __future__ import annotations

import tempfile

import numpy as np

from ..core import DDFContext
from ..expr import col
from .dataset import write_dataset
from .synthetic import synthetic_token_corpus

__all__ = ["TokenPipeline"]


class TokenPipeline:
    def __init__(self, ctx: DDFContext, n_docs: int, vocab: int, seq_len: int,
                 batch: int, seed: int = 0, quality_threshold: float = 0.05):
        self.ctx = ctx
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self._quality_threshold = quality_threshold

        corpus = synthetic_token_corpus(n_docs, vocab, seed=seed)
        # 1. partitioned input: the corpus lives as a chunked on-disk
        # dataset; the pipeline streams it in morsels rather than
        # materializing the full table on device first
        self._tmpdir = tempfile.TemporaryDirectory(prefix="repro-corpus-")
        chunk = max(n_docs // 8, 64)
        self._manifest = write_dataset(corpus, self._tmpdir.name,
                                       chunk_rows=chunk)
        self._batch_rows = max(n_docs // 4, 64)

        lz = self._doc_plan()
        ddf = lz.collect_stream(prefetch=True)
        self.stream_info = dict(lz.last_info or {})
        self.docs = ddf
        # legacy per-stage info slots now carry the streamed run's counters
        self.dedup_info = self.sort_info = self.rebalance_info = self.stream_info
        # 6. global stats (globally reduce)
        self.total_tokens = int(ddf.agg("length", "sum"))
        self.n_docs = ddf.length()

        host = ddf.to_numpy()
        self._doc_ids = host["doc_id"]
        self._lengths = host["length"]
        self._rng = np.random.default_rng(seed + 1)

    def _doc_plan(self):
        """Build the lazy document pipeline over the on-disk corpus:
        scan -> dedup (carry) -> quality select -> length sort (spill) ->
        rebalance."""
        from ..stream import scan_dataset  # local import: stream dep is lazy

        return (scan_dataset(self._manifest, self.ctx,
                             batch_rows=self._batch_rows)
                .unique(("content_hash",))
                .select(col("quality") > self._quality_threshold,
                        name="quality")
                .sort_values("length")
                .rebalance())

    def epoch(self, prefetch: bool = True):
        """Stream one epoch of the processed document pipeline through the
        out-of-core engine (``LazyDDF.to_batches``), yielding packed
        ``(batch, seq_len)`` token blocks per document morsel. Leftover
        docs that do not fill a batch are dropped (epoch semantics)."""
        for host in self._doc_plan().to_batches(prefetch=prefetch):
            ids, lens = host["doc_id"], host["length"]
            for s in range(0, len(ids) - self.batch + 1, self.batch):
                yield self._pack(ids[s:s + self.batch],
                                 lens[s:s + self.batch])

    def _pack(self, doc_ids: np.ndarray, lengths: np.ndarray) -> dict:
        """Pack documents into a (batch, seq_len) token block. Tokens are a
        deterministic hash of (doc_id, pos) — reproducible across restarts."""
        doc = doc_ids[:, None].astype(np.uint32)
        pos = np.arange(self.seq_len, dtype=np.uint32)[None, :]
        h = (doc * np.uint32(2654435761) + pos * np.uint32(40503)) & np.uint32(0xFFFFFFFF)
        h ^= h >> np.uint32(16)
        tokens = (h % np.uint32(self.vocab)).astype(np.int32)
        length = np.minimum(lengths, self.seq_len)[:, None]
        mask = (np.arange(self.seq_len)[None, :] < length).astype(np.float32)
        labels = np.roll(tokens, -1, axis=1)
        return {"tokens": tokens, "labels": labels, "loss_mask": mask}

    def __iter__(self):
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        """Random fixed-shape token batch sampled from the processed docs
        (the steady-state trainer feed; use :meth:`epoch` for sequential
        streamed epochs)."""
        idx = self._rng.integers(0, len(self._doc_ids), size=self.batch)
        return self._pack(self._doc_ids[idx], self._lengths[idx])
