"""Partitioned I/O (paper §5.3.8): distribute input files across workers,
read each worker's assignment, write one output file per partition.

File distribution is host-side (round-robin or explicit one-to-many
mapping); workers with no assigned data construct an empty dataframe with
the shared schema, exactly as the paper specifies. CSV here covers the
paper's formats list conceptually (CSV/JSON/Parquet) — the assignment and
empty-partition semantics are format-independent.
"""

from __future__ import annotations

import csv
import os
from typing import Mapping, Sequence

import numpy as np

from ..core import DDF, DDFContext

__all__ = ["read_csv_dist", "write_csv_dist", "assign_files"]


def assign_files(files: Sequence[str], nworkers: int,
                 mapping: Mapping[int, Sequence[str]] | None = None) -> list[list[str]]:
    """Round-robin by default; or a custom worker -> files mapping."""
    if mapping is not None:
        return [list(mapping.get(w, ())) for w in range(nworkers)]
    out: list[list[str]] = [[] for _ in range(nworkers)]
    for i, f in enumerate(files):
        out[i % nworkers].append(f)
    return out


def _read_csv(path: str, schema: Mapping[str, np.dtype]) -> dict[str, np.ndarray]:
    with open(path) as f:
        reader = csv.DictReader(f)
        rows = list(reader)
    return {k: np.asarray([r[k] for r in rows], dtype=d) for k, d in schema.items()}


def read_csv_dist(files: Sequence[str], schema: Mapping[str, np.dtype],
                  ctx: DDFContext, capacity: int | None = None,
                  mapping: Mapping[int, Sequence[str]] | None = None) -> DDF:
    """Partitioned input: each worker reads its file assignment; empty
    workers get an empty partition with the shared schema (paper §5.3.8)."""
    nw = ctx.nworkers
    assignment = assign_files(files, nw, mapping)
    per_worker: list[dict[str, np.ndarray]] = []
    for flist in assignment:
        parts = [_read_csv(f, schema) for f in flist]
        if parts:
            per_worker.append({k: np.concatenate([p[k] for p in parts]) for k in schema})
        else:
            per_worker.append({k: np.zeros((0,), dtype=d) for k, d in schema.items()})

    cap = capacity or max(max((len(next(iter(p.values()))) for p in per_worker)), 1)
    import jax
    cols = {}
    counts = np.zeros((nw,), np.int32)
    for k, d in schema.items():
        buf = np.zeros((nw, cap), dtype=d)
        for w, p in enumerate(per_worker):
            v = p[k][:cap]
            buf[w, : len(v)] = v
            counts[w] = len(v)
        cols[k] = jax.device_put(buf.reshape(nw * cap), ctx.sharding())
    return DDF(cols, jax.device_put(counts, ctx.sharding()), ctx)


def write_csv_dist(ddf: DDF, directory: str, prefix: str = "part") -> list[str]:
    """Partitioned output: one file per partition (paper §5.3.8)."""
    os.makedirs(directory, exist_ok=True)
    counts = np.asarray(ddf.counts)
    cap = ddf.capacity
    names = sorted(ddf.columns)
    paths = []
    host = {k: np.asarray(v).reshape(ddf.ctx.nworkers, cap) for k, v in ddf.columns.items()}
    for w in range(ddf.ctx.nworkers):
        path = os.path.join(directory, f"{prefix}-{w:05d}.csv")
        with open(path, "w", newline="") as f:
            wr = csv.writer(f)
            wr.writerow(names)
            for i in range(counts[w]):
                wr.writerow([host[k][w, i] for k in names])
        paths.append(path)
    return paths
