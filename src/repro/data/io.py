"""Partitioned I/O (paper §5.3.8): distribute input files across workers,
read each worker's assignment, write one output file per partition.

File distribution is host-side (round-robin or explicit one-to-many
mapping); workers with no assigned data construct an empty dataframe with
the shared schema, exactly as the paper specifies. CSV here covers the
paper's formats list conceptually (CSV/JSON/Parquet) — the assignment and
empty-partition semantics are format-independent.
"""

from __future__ import annotations

import csv
import os
from typing import Mapping, Sequence

import numpy as np

from ..core import DDF, DDFContext
from ..core.vocab import DICT_DTYPE, DictVocab
from .dataset import iter_csv_chunks

__all__ = ["read_csv_dist", "write_csv_dist", "assign_files"]


def _np_dtype(d) -> np.dtype:
    """Host numpy dtype for one schema entry (``"dict"`` reads as strings)."""
    return np.dtype(np.str_) if str(d) == DICT_DTYPE else np.dtype(d)


def assign_files(files: Sequence[str], nworkers: int,
                 mapping: Mapping[int, Sequence[str]] | None = None) -> list[list[str]]:
    """Round-robin by default; or a custom worker -> files mapping."""
    if mapping is not None:
        return [list(mapping.get(w, ())) for w in range(nworkers)]
    out: list[list[str]] = [[] for _ in range(nworkers)]
    for i, f in enumerate(files):
        out[i % nworkers].append(f)
    return out


def _read_csv(path: str, schema: Mapping[str, np.dtype]) -> dict[str, np.ndarray]:
    """Read one CSV file into typed columns via the chunked columnar reader
    (``dataset.iter_csv_chunks`` — no row-at-a-time dict materialization)."""
    chunks = list(iter_csv_chunks(path, schema))
    if not chunks:
        return {k: np.zeros((0,), dtype=_np_dtype(d)) for k, d in schema.items()}
    return {k: np.concatenate([c[k] for c in chunks]) for k in schema}


def read_csv_dist(files: Sequence[str], schema: Mapping[str, np.dtype],
                  ctx: DDFContext, capacity: int | None = None,
                  mapping: Mapping[int, Sequence[str]] | None = None) -> DDF:
    """Partitioned input: each worker reads its file assignment; empty
    workers get an empty partition with the shared schema (paper §5.3.8).

    An explicit ``capacity`` smaller than some worker's assigned rows raises
    ``ValueError`` — rows are never silently dropped. Omit ``capacity`` to
    size partitions from the largest assignment. For datasets that should
    not be fully materialized, use ``repro.stream.scan_csv`` instead.
    """
    nw = ctx.nworkers
    assignment = assign_files(files, nw, mapping)
    per_worker: list[dict[str, np.ndarray]] = []
    for flist in assignment:
        parts = [_read_csv(f, schema) for f in flist]
        if parts:
            per_worker.append({k: np.concatenate([p[k] for p in parts]) for k in schema})
        else:
            per_worker.append({k: np.zeros((0,), dtype=_np_dtype(d))
                               for k, d in schema.items()})

    # dict-encode string columns against ONE vocab shared by all partitions:
    # the distributed invariant every shuffle relies on (codes comparable
    # across workers) holds by construction for a single ingest.
    vocabs: dict[str, DictVocab] = {}
    for k, d in schema.items():
        if str(d) != DICT_DTYPE:
            continue
        vocabs[k] = DictVocab.from_values(
            np.concatenate([np.asarray(p[k], dtype=np.str_) for p in per_worker])
            if any(len(p[k]) for p in per_worker) else np.zeros(0, np.str_))
        for p in per_worker:
            p[k] = vocabs[k].encode(p[k])

    lens = [len(next(iter(p.values()))) for p in per_worker]
    cap = capacity or max(max(lens), 1)
    if max(lens) > cap:
        offenders = {w: n for w, n in enumerate(lens) if n > cap}
        raise ValueError(
            f"read_csv_dist: capacity={cap} would silently drop rows on "
            f"worker(s) {offenders} (rows assigned > capacity). Pass "
            f"capacity >= {max(lens)}, omit capacity to auto-size, or "
            f"stream the files with repro.stream.scan_csv.")
    import jax
    cols = {}
    counts = np.zeros((nw,), np.int32)
    for k, d in schema.items():
        buf = np.zeros((nw, cap),
                       dtype=np.int32 if str(d) == DICT_DTYPE else d)
        for w, p in enumerate(per_worker):
            v = p[k]
            buf[w, : len(v)] = v
            counts[w] = len(v)
        cols[k] = jax.device_put(buf.reshape(nw * cap), ctx.sharding())
    out = DDF(cols, jax.device_put(counts, ctx.sharding()), ctx)
    out.vocabs = vocabs
    return out


def write_csv_dist(ddf: DDF, directory: str, prefix: str = "part") -> list[str]:
    """Partitioned output: one file per partition (paper §5.3.8)."""
    os.makedirs(directory, exist_ok=True)
    counts = np.asarray(ddf.counts)
    cap = ddf.capacity
    names = sorted(ddf.columns)
    paths = []
    host = {k: np.asarray(v).reshape(ddf.ctx.nworkers, cap) for k, v in ddf.columns.items()}
    for k, vocab in getattr(ddf, "vocabs", {}).items():
        if k in host:  # write decoded strings, not int32 codes
            host[k] = vocab.decode(host[k])
    for w in range(ddf.ctx.nworkers):
        path = os.path.join(directory, f"{prefix}-{w:05d}.csv")
        with open(path, "w", newline="") as f:
            wr = csv.writer(f)
            wr.writerow(names)
            for i in range(counts[w]):
                wr.writerow([host[k][w, i] for k in names])
        paths.append(path)
    return paths
