"""Chunked columnar on-disk dataset format (the streaming engine's storage).

A *dataset* is a directory of fixed-row-count column chunks plus a JSON
manifest recording the schema and per-chunk row counts:

    dir/
      manifest.json        {"version": 1, "schema": [...], "chunks": [...],
                            "stats": {...}}   # stats optional (ISSUE 9)
      chunk-00000.npz      one compressed array per column
      chunk-00001.npz
      ...

The manifest gives the streaming runner (``repro.stream``) everything it
needs to slice the dataset into cost-model-sized batches without touching
the data: exact global row count, per-chunk offsets, and the schema (so
row width — and therefore batch sizing — is known up front). Chunks are
``.npz`` archives, so reading a *projection* of the columns only
decompresses the requested members — the on-disk half of the planner's
projection pushdown into ``SCAN``.

CSV ingestion (:func:`csv_to_dataset`, :func:`iter_csv_chunks`) parses
``chunk_rows`` rows at a time into typed columns — replacing the old
row-at-a-time ``DictReader`` path that materialized whole files as Python
dicts before the first numpy array existed.
"""

from __future__ import annotations

import csv
import dataclasses
import json
import os
from typing import Iterable, Iterator, Mapping, Sequence

import numpy as np

from ..core.vocab import DICT_DTYPE, DictVocab, encode_strings, storage_dtype

__all__ = [
    "DatasetManifest",
    "DatasetWriter",
    "DatasetSchemaError",
    "write_dataset",
    "open_dataset",
    "read_chunk",
    "read_rows",
    "csv_to_dataset",
    "iter_csv_chunks",
    "normalize_schema",
    "DEFAULT_CHUNK_ROWS",
]

DEFAULT_CHUNK_ROWS = 65536
_MANIFEST_NAME = "manifest.json"
_VERSION = 1
#: reserved npz member prefix carrying a dict column's per-chunk vocab
_VOCAB_MEMBER = "__vocab__"


class DatasetSchemaError(ValueError):
    """A CSV cell (or appended array) cannot be parsed as its schema dtype.

    Raised with the offending column *named* — the actionable replacement
    for the raw ``ValueError`` numpy's float conversion used to surface on
    non-numeric cells. String-valued columns belong in the dict-encoded
    path: declare them with dtype ``"dict"``."""


def _dtype_name(d) -> str:
    """Canonical dtype string for a schema entry.

    ``"dict"`` passes through (it is not a numpy dtype — codes are stored
    as int32, the vocab rides in the manifest); numpy string dtypes
    (kind U/S) normalize *to* ``"dict"`` so schema inference from string
    arrays lands in the dict-encoded path automatically."""
    if isinstance(d, str) and d == DICT_DTYPE:
        return DICT_DTYPE
    dt = np.dtype(d)
    if dt.kind in ("U", "S"):
        return DICT_DTYPE
    return dt.name


def normalize_schema(schema) -> tuple:
    """Canonical schema tuple ``((name, dtype_str, trailing_shape), ...)``
    sorted by name — the same convention ``repro.plan.logical`` uses.

    Accepts a ``{name: dtype}`` mapping (scalar columns), an iterable of
    ``(name, dtype, tail)`` triples, or an already-normalized tuple. The
    dtype ``"dict"`` (or any numpy string dtype, which normalizes to it)
    marks a dict-encoded string column — int32 codes on disk/device plus a
    manifest-level vocabulary (see docs/TYPES.md).
    """
    if isinstance(schema, Mapping):
        items = [(str(n), _dtype_name(d), ()) for n, d in schema.items()]
    else:
        items = []
        for entry in schema:
            name, dt = entry[0], entry[1]
            tail = tuple(int(x) for x in (entry[2] if len(entry) > 2 else ()))
            items.append((str(name), _dtype_name(dt), tail))
    return tuple(sorted(items))


@dataclasses.dataclass(frozen=True)
class DatasetManifest:
    """Host-side handle on a chunked dataset: directory + schema + chunks.

    ``schema`` is a normalized ``((name, dtype, tail), ...)`` tuple;
    ``chunks`` is ``((filename, rows), ...)`` in on-disk row order. The
    manifest is immutable and hashable so plan nodes / cache keys can
    reference it indirectly via its source id.
    """

    directory: str
    schema: tuple
    chunks: tuple
    #: optional per-chunk ``repro.stats.sketch.ChunkStats`` tuple aligned
    #: with ``chunks`` (None when the dataset carries no sketches); rides
    #: outside cache/checkpoint identity, which hashes schema+chunks only
    stats: tuple | None = None
    #: KMV sketch size the stats were computed with
    stats_k: int = 128
    #: merged vocabularies of the dict-encoded columns:
    #: ``((name, (word, ...)), ...)`` sorted by name. Chunk files carry
    #: their own (smaller) per-chunk vocabs; ``read_chunk`` remaps codes
    #: into this manifest-level space so every decoded batch shares one
    #: code space per column.
    vocabs: tuple = ()

    @property
    def num_rows(self) -> int:
        """Exact global row count (sum of per-chunk counts)."""
        return int(sum(r for _, r in self.chunks))

    @property
    def column_names(self) -> tuple:
        return tuple(n for n, _, _ in self.schema)

    @property
    def vocab_map(self) -> dict:
        """Dict-column vocabularies as ``{name: DictVocab}``."""
        return {n: DictVocab(tuple(words)) for n, words in self.vocabs}

    def row_bytes(self) -> float:
        """Bytes per row implied by the schema (drives batch sizing);
        dict columns count their int32 storage width."""
        total = 0.0
        for _, dt, tail in self.schema:
            size = np.dtype(storage_dtype(dt)).itemsize
            total += size * float(np.prod(tail)) if tail else size
        return max(total, 1.0)

    def save(self) -> str:
        """Write ``manifest.json`` into the dataset directory (atomically:
        tmp file + rename, so a crash mid-save leaves the old manifest —
        the contract :func:`repro.stats.sketch.backfill_stats` relies on).
        Per-chunk sketches, when present, serialize under an optional
        versioned ``stats`` key that pre-stats readers never see."""
        path = os.path.join(self.directory, _MANIFEST_NAME)
        payload = {
            "version": _VERSION,
            "schema": [[n, dt, list(tail)] for n, dt, tail in self.schema],
            "chunks": [[f, int(r)] for f, r in self.chunks],
        }
        if self.stats is not None:
            from ..stats.sketch import STATS_VERSION  # local: avoid cycle
            payload["stats"] = {
                "stats_version": STATS_VERSION,
                "k": int(self.stats_k),
                "chunks": [cs.to_json() for cs in self.stats],
            }
        if self.vocabs:
            payload["vocabs"] = {n: list(words) for n, words in self.vocabs}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1)
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, directory: str) -> "DatasetManifest":
        """Read ``manifest.json`` from ``directory``. The optional
        ``stats`` key is parsed when present with a known version and
        silently ignored otherwise — old manifests (and future stats
        formats) load as stats-free datasets, never errors."""
        path = os.path.join(directory, _MANIFEST_NAME)
        with open(path) as f:
            payload = json.load(f)
        if payload.get("version") != _VERSION:
            raise ValueError(
                f"{path}: unsupported dataset version {payload.get('version')!r}")
        schema = tuple((n, dt, tuple(tail)) for n, dt, tail in payload["schema"])
        chunks = tuple((f, int(r)) for f, r in payload["chunks"])
        stats = None
        stats_k = 128
        raw = payload.get("stats")
        if isinstance(raw, dict):
            from ..stats.sketch import (  # local: avoid import cycle
                STATS_VERSION, ChunkStats, DEFAULT_KMV_K)
            if (raw.get("stats_version") == STATS_VERSION
                    and len(raw.get("chunks", ())) == len(chunks)):
                stats_k = int(raw.get("k", DEFAULT_KMV_K))
                stats = tuple(ChunkStats.from_json(c, stats_k)
                              for c in raw["chunks"])
        vocabs = tuple(sorted(
            (str(n), tuple(str(w) for w in words))
            for n, words in (payload.get("vocabs") or {}).items()))
        return cls(directory, schema, chunks, stats=stats, stats_k=stats_k,
                   vocabs=vocabs)


class DatasetWriter:
    """Incremental chunk writer: append column batches, get a manifest back.

    Buffers appended rows and flushes a ``chunk-NNNNN.npz`` every
    ``chunk_rows`` rows; :meth:`close` flushes the remainder and writes the
    manifest. Used by :func:`write_dataset`, CSV ingestion, and the
    streaming runner's host-side spill (spilled runs *are* datasets).

    With ``stats=True`` (the default) every flushed chunk is sketched
    in-memory (``repro.stats.sketch.ChunkStats``: count, per-column
    min/max, KMV distinct) and the sketches ride into the manifest —
    write-time stats cost one pass over data already in cache. Spill
    writers pass ``stats=False``: spill runs are consumed once, in full.
    """

    def __init__(self, directory: str, schema=None,
                 chunk_rows: int = DEFAULT_CHUNK_ROWS, compress: bool = True,
                 stats: bool = True, stats_k: int = 128):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.chunk_rows = max(int(chunk_rows), 1)
        self.compress = compress
        self._schema = normalize_schema(schema) if schema is not None else None
        self._buffers: list[dict] = []
        self._buffered = 0
        self._chunks: list[tuple] = []
        self._closed = False
        self.stats_enabled = bool(stats)
        self.stats_k = int(stats_k)
        self._stats: list = []

    @property
    def rows_written(self) -> int:
        return int(sum(r for _, r in self._chunks)) + self._buffered

    def state(self) -> tuple[tuple, dict]:
        """Crash-consistent snapshot: ``(flushed chunks, buffered rows)``.

        The flushed chunks are already durable on disk; the buffered
        remainder (always < ``chunk_rows`` — append flushes eagerly) is
        returned as a column dict for the caller to persist. Together with
        the directory/schema this is everything :meth:`resume` needs."""
        if self._buffers:
            buffered = {n: np.concatenate([b[n] for b in self._buffers])
                        for n, _, _ in self._schema}
        else:
            buffered = {}
        return tuple(self._chunks), buffered

    @classmethod
    def resume(cls, directory: str, schema, chunks,
               buffered: Mapping[str, np.ndarray] | None = None,
               chunk_rows: int = DEFAULT_CHUNK_ROWS,
               compress: bool = True) -> "DatasetWriter":
        """Rebuild a writer from a :meth:`state` snapshot.

        ``chunks`` are trusted as-is (their files are on disk); chunk files
        written *after* the snapshot are simply overwritten by index as the
        resumed stream re-appends, and never referenced by the final
        manifest — torn post-snapshot writes cannot corrupt the dataset.
        Resumed writers close without stats (sketches for the pre-snapshot
        chunks were lost with the crashed process; :func:`backfill_stats`
        recomputes them on demand)."""
        w = cls(directory, schema=schema, chunk_rows=chunk_rows,
                compress=compress, stats=False)
        w._chunks = [(f, int(r)) for f, r in chunks]
        if buffered and len(next(iter(buffered.values()))):
            w.append(buffered)
        return w

    def append(self, columns: Mapping[str, np.ndarray]) -> None:
        """Append a batch of rows (same-length arrays keyed by name)."""
        if self._closed:
            raise ValueError("DatasetWriter is closed")
        cols = {k: np.asarray(v) for k, v in columns.items()}
        if self._schema is None:
            self._schema = normalize_schema(
                [(k, v.dtype, v.shape[1:]) for k, v in cols.items()])
        names = set(n for n, _, _ in self._schema)
        if set(cols) != names:
            raise ValueError(f"append: columns {sorted(cols)} do not match "
                             f"schema {sorted(names)}")
        lengths = {len(v) for v in cols.values()}
        if len(lengths) != 1:
            raise ValueError(f"append: column lengths disagree: {lengths}")
        for cn, dt, _ in self._schema:
            if dt == DICT_DTYPE and cols[cn].dtype.kind not in ("U", "S", "O"):
                raise DatasetSchemaError(
                    f"append: column {cn!r} is dict-encoded (string) but got "
                    f"a {cols[cn].dtype} array — dict columns take decoded "
                    "string values; codes are assigned at flush time")
        n = lengths.pop()
        if n == 0:
            return
        self._buffers.append(cols)
        self._buffered += n
        while self._buffered >= self.chunk_rows:
            self._flush(self.chunk_rows)

    def _flush(self, rows: int) -> None:
        if rows <= 0 or self._buffered == 0:
            return
        merged = {n: np.concatenate([b[n] for b in self._buffers])
                  for n, _, _ in self._schema}
        head = {k: v[:rows] for k, v in merged.items()}
        tail = {k: v[rows:] for k, v in merged.items()}
        fname = f"chunk-{len(self._chunks):05d}.npz"
        # dict columns flush as int32 codes + a per-chunk sorted vocab under
        # the reserved __vocab__<name> member; read_chunk remaps the codes
        # into the manifest-level merged vocab space. Sketches see the
        # *decoded* strings so min/max bounds and KMV distinct stay in value
        # space (chunk skipping on string predicates).
        payload = dict(head)
        for n, dt, _ in self._schema:
            if dt == DICT_DTYPE:
                codes, cv = encode_strings(head[n])
                payload[n] = codes
                payload[_VOCAB_MEMBER + n] = cv.values
        save = np.savez_compressed if self.compress else np.savez
        save(os.path.join(self.directory, fname), **payload)
        if self.stats_enabled:
            from ..stats.sketch import ChunkStats  # local: avoid cycle
            self._stats.append(ChunkStats.from_columns(head, self.stats_k))
        self._chunks.append((fname, rows))
        self._buffered -= rows
        self._buffers = [tail] if self._buffered else []

    def close(self) -> DatasetManifest:
        """Flush the buffered remainder and write the manifest."""
        if self._closed:
            return self._manifest
        if self._buffered:
            self._flush(self._buffered)
        if self._schema is None:
            raise ValueError("cannot close an empty DatasetWriter without a "
                             "schema (pass schema= at construction)")
        self._closed = True
        # resumed writers lack sketches for pre-snapshot chunks: only a
        # complete per-chunk set is trustworthy, else drop stats entirely
        # (consumers treat "no stats" as "no estimates"; backfill_stats
        # can recompute later)
        stats = (tuple(self._stats)
                 if self.stats_enabled and len(self._stats) == len(self._chunks)
                 else None)
        self._manifest = DatasetManifest(self.directory, self._schema,
                                         tuple(self._chunks), stats=stats,
                                         stats_k=self.stats_k,
                                         vocabs=self._merged_vocabs())
        self._manifest.save()
        return self._manifest

    def _merged_vocabs(self) -> tuple:
        """Manifest-level vocabs: the sorted union of every flushed chunk's
        per-chunk vocab, read back from disk (robust to :meth:`resume` —
        pre-snapshot chunk vocabs live in their files, not this process)."""
        dict_cols = [n for n, dt, _ in self._schema if dt == DICT_DTYPE]
        if not dict_cols:
            return ()
        acc = {n: DictVocab(()) for n in dict_cols}
        for fname, _ in self._chunks:
            with np.load(os.path.join(self.directory, fname)) as z:
                for n in dict_cols:
                    acc[n] = acc[n].merge(
                        DictVocab(tuple(z[_VOCAB_MEMBER + n])))
        return tuple(sorted((n, acc[n].words) for n in dict_cols))


def write_dataset(data: Mapping[str, np.ndarray], directory: str,
                  chunk_rows: int = DEFAULT_CHUNK_ROWS,
                  compress: bool = True) -> DatasetManifest:
    """Write an in-memory column dict as a chunked dataset; returns its
    manifest. The inverse of reading every row with :func:`read_rows`."""
    w = DatasetWriter(directory, chunk_rows=chunk_rows, compress=compress)
    w.append(data)
    if w._schema is None:  # zero-row input still needs a schema
        w._schema = normalize_schema(
            [(k, np.asarray(v).dtype, np.asarray(v).shape[1:])
             for k, v in data.items()])
    return w.close()


def open_dataset(directory: str) -> DatasetManifest:
    """Load the manifest of a chunked dataset directory."""
    return DatasetManifest.load(directory)


def read_chunk(manifest: DatasetManifest, index: int,
               columns: Sequence[str] | None = None) -> dict:
    """Decode one chunk (optionally a column projection — only the requested
    ``.npz`` members are decompressed). Dict-encoded columns come back as
    int32 codes remapped from the chunk's own vocab into the manifest-level
    merged vocab (a monotone ``np.searchsorted`` gather), so all chunks of
    one dataset share one code space per column."""
    fname, rows = manifest.chunks[index]
    names = tuple(columns) if columns is not None else manifest.column_names
    unknown = [n for n in names if n not in manifest.column_names]
    if unknown:
        raise KeyError(f"read_chunk: unknown column(s) {unknown}; "
                       f"schema: {list(manifest.column_names)}")
    dict_cols = {n for n, dt, _ in manifest.schema if dt == DICT_DTYPE}
    vocabs = manifest.vocab_map if dict_cols & set(names) else {}
    with np.load(os.path.join(manifest.directory, fname)) as z:
        out = {}
        for n in names:
            v = z[n]
            if n in dict_cols and n in vocabs:
                chunk_vocab = DictVocab(tuple(z[_VOCAB_MEMBER + n]))
                remap = chunk_vocab.recode_map(vocabs[n])
                v = (remap[v] if len(remap)
                     else np.zeros_like(v)).astype(np.int32)
            out[n] = v
    for n, v in out.items():
        if len(v) != rows:
            raise ValueError(f"{fname}: column {n!r} has {len(v)} rows, "
                             f"manifest says {rows} (corrupt dataset)")
    return out


def read_rows(manifest: DatasetManifest, start: int, stop: int,
              columns: Sequence[str] | None = None,
              skip_chunks: Sequence[bool] | None = None) -> dict:
    """Global row range ``[start, stop)`` as a column dict, decoding only
    the chunks that overlap the range (the runner's batch reader).

    ``skip_chunks`` (aligned with ``manifest.chunks``) marks chunks whose
    decode may be elided — the statistics layer's chunk-skip mask, where
    True means the chunk provably contributes no rows to the caller's
    predicate. Skipped chunks contribute zero rows (the result simply
    gets shorter); global row offsets are unaffected."""
    names = tuple(columns) if columns is not None else manifest.column_names
    dtypes = {n: (dt, tail) for n, dt, tail in manifest.schema}
    start, stop = max(int(start), 0), max(int(stop), 0)
    parts: dict[str, list] = {n: [] for n in names}
    off = 0
    for i, (_, rows) in enumerate(manifest.chunks):
        lo, hi = max(start, off), min(stop, off + rows)
        if lo < hi and not (skip_chunks is not None and skip_chunks[i]):
            chunk = read_chunk(manifest, i, names)
            for n in names:
                parts[n].append(chunk[n][lo - off:hi - off])
        off += rows
        if off >= stop:
            break
    out = {}
    for n in names:
        dt, tail = dtypes[n]
        out[n] = (np.concatenate(parts[n]) if parts[n]
                  else np.zeros((0,) + tuple(tail),
                                dtype=np.dtype(storage_dtype(dt))))
    return out


# -- CSV ingestion -------------------------------------------------------------

def iter_csv_chunks(path: str, schema, chunk_rows: int = DEFAULT_CHUNK_ROWS
                    ) -> Iterator[dict]:
    """Stream a CSV file as typed column chunks of ``chunk_rows`` rows.

    Parses with ``csv.reader`` and converts column-wise per chunk — never
    materializing the whole file (the old ``DictReader`` path built one
    Python dict per row for the entire file before any array existed).
    Raises ``ValueError`` when the header is missing a schema column; a
    zero-byte file yields no chunks (an empty shard, not an error —
    matching the partitioned-I/O empty-partition semantics).
    """
    schema_t = normalize_schema(schema)
    with open(path, newline="") as f:
        reader = csv.reader(f)
        try:
            header = next(reader)
        except StopIteration:
            return  # zero-byte shard: no header, no rows, no chunks
        missing = [n for n, _, _ in schema_t if n not in header]
        if missing:
            raise ValueError(
                f"{path}: CSV header {header} is missing schema column(s) "
                f"{missing} — schema mismatch")
        idx = {n: header.index(n) for n, _, _ in schema_t}
        rows: list = []
        for row in reader:
            rows.append(row)
            if len(rows) >= chunk_rows:
                yield _typed_chunk(rows, schema_t, idx)
                rows = []
        if rows:
            yield _typed_chunk(rows, schema_t, idx)


def _typed_chunk(rows: list, schema_t: tuple, idx: dict) -> dict:
    out = {}
    for n, dt, _tail in schema_t:
        col = [r[idx[n]] for r in rows]
        if dt == DICT_DTYPE:
            # string columns route into the dict-encoded path: kept as
            # decoded strings here, code-assigned by the DatasetWriter
            out[n] = np.asarray(col, dtype=np.str_)
            continue
        try:
            out[n] = np.asarray(col, dtype=np.dtype(dt))
        except ValueError as exc:
            bad = next((c for c in col if not _parses_as(c, dt)), col[0])
            raise DatasetSchemaError(
                f"column {n!r}: CSV value {bad!r} cannot be parsed as "
                f"{dt} — declare the column as 'dict' to ingest strings "
                f"(dict-encoded), or fix the schema dtype") from exc
    return out


def _parses_as(cell: str, dt: str) -> bool:
    try:
        np.asarray([cell], dtype=np.dtype(dt))
        return True
    except ValueError:
        return False


def csv_to_dataset(files: Iterable[str], schema, directory: str,
                   chunk_rows: int = DEFAULT_CHUNK_ROWS,
                   compress: bool = True) -> DatasetManifest:
    """Chunked CSV ingestion: convert CSV files into a chunked dataset.

    Files are read in order, ``chunk_rows`` rows at a time; the resulting
    dataset concatenates them in file order. Header/schema mismatches raise
    ``ValueError`` naming the offending file and columns.
    """
    w = DatasetWriter(directory, schema=schema, chunk_rows=chunk_rows,
                      compress=compress)
    for path in files:
        for chunk in iter_csv_chunks(path, schema, chunk_rows):
            w.append(chunk)
    return w.close()
