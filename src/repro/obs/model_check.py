"""Cost-model validation: per-operator predicted-vs-observed accounting.

The engine *plans* from the paper's Hockney-style cost model
(``repro.core.cost_model.pattern_cost``) but historically never recorded
what actually happened. This module closes that loop: every planned
shuffle/groupby/scan executed while tracing is enabled appends a
:class:`ModelRecord` pairing the model's predicted seconds/rows/bytes with
the measured wall time and actual volumes, and :func:`model_report`
summarizes prediction error per paper pattern — the reproduction's
validation payoff.

Predictions are computed as a *side table* over the planned DAG
(:func:`predict_plan`, keyed by post-order node index). Plan nodes are
never mutated or annotated in place: node structural identity keys the
compiled-op/plan caches and the streaming checkpoint ``query_key``, so
attaching data to nodes would silently split caches.

A compiled whole-pipeline program has a single wall measurement; the
executor apportions it across the program's planned operators in
proportion to predicted share (:func:`record_program`). Each record keeps
the raw ``program_s`` and its ``share`` in ``meta`` so the apportioning is
never hidden.

Recording is gated on ``repro.obs.trace.enabled()`` and thread-safe
(stream prefetch + service driver threads).
"""

from __future__ import annotations

import dataclasses
import threading

from . import trace as _trace

__all__ = [
    "ModelRecord",
    "mark",
    "model_report",
    "predict_plan",
    "record",
    "record_program",
    "records",
    "reset",
    "scan_prediction",
]

_lock = threading.Lock()
_records: list = []
_MAX_RECORDS = 500_000


@dataclasses.dataclass
class ModelRecord:
    """One predicted-vs-observed sample for a planned operator.

    ``pattern`` is the paper pattern the operator maps to (e.g.
    ``shuffle_compute``); ``op`` labels the concrete operator instance.
    Seconds are per-dispatch wall time; rows/bytes fields are None when a
    side was not measured/predicted for this sample."""

    pattern: str
    op: str
    predicted_s: float
    observed_s: float
    predicted_rows: float | None = None
    observed_rows: int | None = None
    predicted_bytes: float | None = None
    observed_bytes: int | None = None
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def rel_err(self) -> float:
        """``|observed - predicted| / predicted`` for the time terms."""
        return abs(self.observed_s - self.predicted_s) / max(
            self.predicted_s, 1e-9)


def record(pattern: str, op: str, predicted_s: float, observed_s: float,
           **fields) -> None:
    """Append one sample (no-op while tracing is disabled)."""
    if not _trace.enabled():
        return
    rec = ModelRecord(pattern, op, float(predicted_s), float(observed_s),
                      **fields)
    with _lock:
        if len(_records) < _MAX_RECORDS:
            _records.append(rec)


def records(since: int = 0) -> list:
    """Snapshot of collected samples (from index ``since``; :func:`mark`)."""
    with _lock:
        return list(_records[since:])


def mark() -> int:
    """Current sample count — pass to ``records(since=...)`` to scope a
    later read to samples collected after this point."""
    with _lock:
        return len(_records)


def reset() -> None:
    """Drop every collected sample."""
    with _lock:
        _records.clear()


# -- plan -> pattern predictions ----------------------------------------------

def _pattern_for(node):
    """(pattern, core_op) for a *planned* node, or None when the node maps
    to no modeled communication pattern (EP ops, elided shuffles)."""
    from ..plan import logical as L

    if isinstance(node, L.Scan):
        return "partitioned_io", "map"
    if isinstance(node, L.Join):
        if node.strategy == "local":
            return None
        if (node.strategy or "").startswith("broadcast"):
            return "broadcast_compute", "hash_join"
        return "shuffle_compute", "hash_join"
    if isinstance(node, L.GroupBy):
        if node.elide_shuffle:
            return None
        if node.pre_combine:
            return "combine_shuffle_reduce", "groupby"
        return "shuffle_compute", "groupby"
    if isinstance(node, L.Unique):
        if node.elide_shuffle:
            return None
        return "combine_shuffle_reduce", "unique"
    if isinstance(node, (L.Union, L.Difference)):
        if node.elide_shuffle:
            return None
        return "shuffle_compute", "unique"
    if isinstance(node, L.Sort):
        return "sample_shuffle_compute", "sort"
    if isinstance(node, L.Rebalance):
        return "shuffle_compute", "map"
    if isinstance(node, L.Recode):
        # vocab unification: a pure per-row gather, no communication — the
        # one EP node charged individually (it is deliberately kept out of
        # fusion so its cost stays visible)
        return "embarrassingly_parallel", "map"
    return None


def _cardinality(node) -> float:
    from ..plan import logical as L

    if isinstance(node, L.GroupBy):
        c = node.cardinality_hint
        if c is not None and 0.0 < c <= 1.0:
            return c
        return L.UNKNOWN_CARDINALITY
    if isinstance(node, (L.Unique, L.Union, L.Difference)):
        return L.UNKNOWN_CARDINALITY
    return 1.0


def predict_plan(plan, P: int, src_rows, params) -> list:
    """Cost-model predictions for every modeled operator of a planned DAG.

    Returns a side table — one dict per shuffle/groupby/scan-style node,
    in post-order::

        {"node_index": i, "op": "n3:GroupBy", "pattern": ...,
         "predicted_s": ..., "predicted_rows": ..., "predicted_bytes": ...}

    ``node_index`` is the node's position in ``logical.walk(plan)`` (the
    same numbering the executor's aux keys use). ``src_rows`` maps source
    id -> global rows, as passed to the optimizer; ``params`` is the
    fabric's :class:`repro.core.cost_model.CostParams`.
    """
    from ..core import cost_model
    from ..plan import logical as L

    out = []
    memo: dict = {}
    for i, node in enumerate(L.walk(plan)):
        pat = _pattern_for(node)
        if pat is None:
            continue
        pattern, core_op = pat
        if isinstance(node, L.Scan):
            n_in = float(src_rows.get(node.sid, node.capacity))
            in_bytes = n_in * L.row_bytes_of(node.schema)
        else:
            kids = node.children
            n_in = sum(L.estimate_rows(c, src_rows, memo) for c in kids)
            in_bytes = sum(L.estimate_rows(c, src_rows, memo)
                           * L.row_bytes_of(L.schema_of(c)) for c in kids)
        n_in = max(n_in, 1.0)
        rb = in_bytes / n_in
        cost = cost_model.pattern_cost(
            pattern,
            P=P,
            n_rows=n_in / max(P, 1),
            row_bytes=rb,
            cardinality=_cardinality(node),
            core_op=core_op,
            params=params,
            num_chunks=int(getattr(node, "num_chunks", None) or 1),
        )
        out.append({
            "node_index": i,
            "op": f"n{i}:{type(node).__name__}",
            "pattern": pattern,
            "predicted_s": float(cost["total"]),
            "predicted_rows": float(L.estimate_rows(node, src_rows, memo)),
            "predicted_bytes": float(in_bytes),
        })
    return out


def scan_prediction(n_rows: int, row_bytes: float, P: int, params) -> dict:
    """Predicted seconds/bytes for decoding one scan batch — the paper's
    ``partitioned_io`` pattern (read + partition the admitted rows)."""
    from ..core import cost_model

    cost = cost_model.pattern_cost(
        "partitioned_io", P=P, n_rows=max(float(n_rows) / max(P, 1), 1.0),
        row_bytes=float(row_bytes), params=params)
    return {"predicted_s": float(cost["total"]),
            "predicted_rows": float(n_rows),
            "predicted_bytes": float(n_rows) * float(row_bytes)}


def record_program(preds: list, wall_s: float,
                   observed_rows: int | None = None,
                   observed_bytes: int | None = None,
                   op_prefix: str = "") -> None:
    """Record one compiled program's measured wall time against its
    operators' predictions.

    A whole-pipeline shard_map program yields a single wall measurement;
    it is apportioned across the program's modeled operators proportional
    to predicted share, with the raw ``program_s`` and each operator's
    ``share`` kept in ``meta``. ``observed_rows``/``observed_bytes`` (the
    program's output) attach to the root-most operator only."""
    if not _trace.enabled() or not preds:
        return
    total = sum(p["predicted_s"] for p in preds)
    total = total if total > 0 else 1.0
    last = len(preds) - 1
    for j, p in enumerate(preds):
        share = p["predicted_s"] / total
        record(p["pattern"], op_prefix + p["op"],
               p["predicted_s"], wall_s * share,
               predicted_rows=p.get("predicted_rows"),
               predicted_bytes=p.get("predicted_bytes"),
               observed_rows=observed_rows if j == last else None,
               observed_bytes=observed_bytes if j == last else None,
               meta={"program_s": wall_s, "share": share,
                     "node_index": p["node_index"]})


def model_report(samples: list | None = None) -> dict:
    """Per-pattern prediction-error summary over collected samples.

    Returns ``{pattern: {"count", "predicted_s", "observed_s",
    "mean_abs_rel_err", "bias"}}`` where ``bias`` is total observed /
    total predicted seconds (> 1: the model underestimates; < 1: it
    overestimates) and ``mean_abs_rel_err`` averages per-sample
    ``|obs - pred| / pred``. Pass ``samples`` to scope (e.g. one
    profiled run); defaults to every collected sample."""
    samples = records() if samples is None else samples
    out: dict[str, dict] = {}
    for r in samples:
        d = out.setdefault(r.pattern, {"count": 0, "predicted_s": 0.0,
                                       "observed_s": 0.0, "_err": 0.0})
        d["count"] += 1
        d["predicted_s"] += r.predicted_s
        d["observed_s"] += r.observed_s
        d["_err"] += r.rel_err
    for d in out.values():
        d["mean_abs_rel_err"] = d.pop("_err") / d["count"]
        d["bias"] = d["observed_s"] / max(d["predicted_s"], 1e-12)
    return out
