"""Structured tracing: nestable spans with a process-wide recorder.

A span times one unit of engine work — a morsel decode, a compiled-program
dispatch, a service scheduling quantum — and nests per thread (the
streaming prefetch thread and the service driver thread each keep their
own span stack; the recorder they append to is shared and lock-guarded).

Near-zero cost when disabled (the default): :func:`span` returns one
shared no-op handle, so the hot paths pay a single boolean check and no
per-call object allocation. Enable with :func:`enable` / :func:`tracing`,
or process-wide via the ``REPRO_TRACE=1`` environment variable.

Recorded spans export as Chrome/Perfetto ``trace_event`` JSON via
:meth:`Trace.to_chrome_trace` — load the saved file in
https://ui.perfetto.dev or ``chrome://tracing``.

Intervals that do not nest on a call stack (a streaming stage suspended
and resumed across service quanta, a query's whole lifetime closed from
the scheduler) are recorded retroactively with :func:`complete` from
explicit :func:`now` timestamps, so interleaved queries never corrupt a
thread's span stack.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time

__all__ = [
    "Span",
    "Trace",
    "complete",
    "current_span",
    "disable",
    "enable",
    "enabled",
    "get_trace",
    "instant",
    "mark",
    "now",
    "reset",
    "span",
    "summary",
    "tracing",
]

_EPOCH = time.perf_counter()
_PID = os.getpid()
# backstop against unbounded growth in long-lived traced processes; the
# drop count is surfaced on the Trace so truncation is never silent
_MAX_EVENTS = 1_000_000

_enabled = os.environ.get("REPRO_TRACE", "") not in ("", "0")
_lock = threading.Lock()
_events: list = []
_dropped = 0
_ids = itertools.count(1)
_tls = threading.local()


def now() -> float:
    """Seconds since the trace epoch (module import) — the spans' clock.

    Use with :func:`complete` to record intervals retroactively."""
    return time.perf_counter() - _EPOCH


def enabled() -> bool:
    """True when spans are currently being recorded."""
    return _enabled


def enable() -> None:
    """Start recording spans (process-global, all threads)."""
    global _enabled
    _enabled = True


def disable() -> None:
    """Stop recording spans; spans already recorded are kept."""
    global _enabled
    _enabled = False


def reset() -> None:
    """Drop every recorded span (the enabled flag is unchanged)."""
    global _dropped
    with _lock:
        _events.clear()
        _dropped = 0


def mark() -> int:
    """Current recorded-span count; pass as ``since`` to :func:`get_trace`
    to scope a later snapshot to spans recorded after this point."""
    with _lock:
        return len(_events)


def _record(sp: "Span") -> None:
    global _dropped
    with _lock:
        if len(_events) < _MAX_EVENTS:
            _events.append(sp)
        else:
            _dropped += 1


class Span:
    """One recorded (or in-flight) span: a name, a wall interval, attrs.

    Use via :func:`span` as a context manager; inside the ``with`` block,
    :meth:`set` (or mutating ``attrs`` directly) attaches data — e.g. the
    kernel registry appends its dispatch decisions to the enclosing span's
    ``attrs["kernel_dispatch"]`` list."""

    __slots__ = ("sid", "parent", "name", "cat", "t0", "t1", "tid",
                 "thread", "attrs")

    def __init__(self, name: str, cat: str | None = None,
                 attrs: dict | None = None):
        self.sid = next(_ids)
        self.parent: int | None = None
        self.name = name
        self.cat = cat
        self.t0 = 0.0
        self.t1 = 0.0
        self.tid = 0
        self.thread = ""
        self.attrs = {} if attrs is None else attrs

    def set(self, **attrs):
        """Attach attributes to this span; returns the span."""
        self.attrs.update(attrs)
        return self

    @property
    def duration_s(self) -> float:
        """Recorded wall seconds (0.0 while still open)."""
        return max(self.t1 - self.t0, 0.0)

    def __enter__(self):
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        self.parent = stack[-1].sid if stack else None
        t = threading.current_thread()
        self.tid = t.ident or 0
        self.thread = t.name
        stack.append(self)
        self.t0 = now()
        return self

    def __exit__(self, *exc):
        self.t1 = now()
        stack = getattr(_tls, "stack", [])
        if stack and stack[-1] is self:
            stack.pop()
        else:
            # out-of-order exit (a generator holding an open span was
            # closed while a later span was live): drop self wherever it
            # sits so the rest of the stack stays consistent
            try:
                stack.remove(self)
            except ValueError:
                pass
        _record(self)
        return False

    def __repr__(self):
        return (f"Span({self.name!r}, {self.duration_s * 1e3:.3f}ms, "
                f"attrs={self.attrs!r})")


class _NullSpan:
    """Shared do-nothing span handle returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    @property
    def attrs(self):
        # a throwaway dict: mutations are discarded, callers need no guard
        return {}

    @property
    def duration_s(self):
        return 0.0


_NULL = _NullSpan()


def span(name: str, cat: str | None = None, **attrs):
    """Open a nestable span: ``with span("shuffle", bytes=nb): ...``.

    Returns the shared no-op handle while tracing is disabled, so callers
    on hot paths need no enabled-check of their own (when attribute
    *computation* is expensive, gate it on :func:`enabled`)."""
    if not _enabled:
        return _NULL
    return Span(name, cat, attrs)


def instant(name: str, **attrs) -> None:
    """Record a zero-duration marker event (no stack participation)."""
    if not _enabled:
        return
    sp = Span(name, "instant", attrs)
    t = threading.current_thread()
    sp.tid = t.ident or 0
    sp.thread = t.name
    sp.t0 = sp.t1 = now()
    _record(sp)


def complete(name: str, t0: float, t1: float | None = None, **attrs) -> None:
    """Record a span retroactively from explicit :func:`now` timestamps.

    For intervals that do not nest on a thread's call stack — a streaming
    stage whose generator is suspended/resumed between other queries'
    quanta, or a query's submit-to-finish lifetime closed by the service
    scheduler."""
    if not _enabled:
        return
    sp = Span(name, None, attrs)
    t = threading.current_thread()
    sp.tid = t.ident or 0
    sp.thread = t.name
    sp.t0 = float(t0)
    sp.t1 = now() if t1 is None else float(t1)
    _record(sp)


def current_span() -> Span | None:
    """The innermost open span on this thread (None when disabled or no
    span is open) — the hook for attaching attributes from deep callees."""
    if not _enabled:
        return None
    stack = getattr(_tls, "stack", None)
    return stack[-1] if stack else None


class _Tracing:
    """Context manager for :func:`tracing` (re-entrant, state-restoring)."""

    __slots__ = ("_prev",)

    def __enter__(self):
        self._prev = _enabled
        enable()
        return self

    def __exit__(self, *exc):
        if not self._prev:
            disable()
        return False


def tracing() -> _Tracing:
    """Enable tracing for a ``with`` block, restoring the prior state on
    exit (nesting inside an already-enabled region is a no-op)."""
    return _Tracing()


def _jsonable(v):
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    item = getattr(v, "item", None)  # numpy scalars
    if callable(item):
        try:
            return _jsonable(item())
        except (TypeError, ValueError):
            pass
    return repr(v)


class Trace:
    """An immutable snapshot of recorded spans (see :func:`get_trace`).

    ``spans`` is the tuple of :class:`Span` records; ``dropped`` counts
    spans lost to the recorder's size backstop (0 in normal runs)."""

    def __init__(self, spans, dropped: int = 0):
        self.spans = tuple(spans)
        self.dropped = int(dropped)

    def __len__(self):
        return len(self.spans)

    def to_chrome_trace(self) -> dict:
        """The trace as a Chrome/Perfetto ``trace_event`` JSON object.

        Returns the dict form (``{"traceEvents": [...]}`` with complete
        ``"X"`` events, microsecond timestamps, and thread-name metadata);
        ``json.dump`` it or use :meth:`save` to write a file Perfetto and
        ``chrome://tracing`` load directly."""
        events = []
        threads: dict[int, str] = {}
        for sp in self.spans:
            if sp.thread and sp.tid not in threads:
                threads[sp.tid] = sp.thread
        for tid, tname in threads.items():
            events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                           "tid": tid, "args": {"name": tname}})
        for sp in self.spans:
            events.append({"name": sp.name,
                           "cat": sp.cat or "repro",
                           "ph": "X",
                           "ts": sp.t0 * 1e6,
                           "dur": sp.duration_s * 1e6,
                           "pid": _PID,
                           "tid": sp.tid,
                           "args": _jsonable(sp.attrs)})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        """Write :meth:`to_chrome_trace` JSON to ``path``; returns it."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path

    def summary(self) -> dict:
        """Aggregate by span name: ``{name: {"count", "total_s"}}``."""
        out: dict[str, dict] = {}
        for sp in self.spans:
            d = out.setdefault(sp.name, {"count": 0, "total_s": 0.0})
            d["count"] += 1
            d["total_s"] += sp.duration_s
        return out


def get_trace(since: int = 0) -> Trace:
    """Snapshot the recorder (spans from index ``since``; see :func:`mark`)."""
    with _lock:
        return Trace(_events[since:], _dropped)


def summary() -> dict:
    """Compact process-trace summary for telemetry surfaces (e.g.
    ``QueryService.stats()["trace"]``): enabled flag, span/drop counts,
    and per-name aggregates."""
    tr = get_trace()
    return {"enabled": _enabled, "spans": len(tr), "dropped": tr.dropped,
            "by_name": tr.summary()}
