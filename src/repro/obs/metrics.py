"""Typed process metrics: counters, gauges, and timing summaries.

One process-global registry (:func:`registry`) unifies what used to be
ad-hoc counters scattered across the engine: the streaming runner's
``info`` dict scalars (``batches``, ``retries:<site>``, ``checkpoints``),
kernel-dispatch decision counts, and — via :func:`engine_snapshot` — the
shared plan/compiled-op ``_LRUCache`` stats.

Sub-registries chain to a parent under a prefix: a streaming run creates
``MetricsRegistry(parent=registry(), prefix="stream.")`` so its local
counters are the single source of truth for that run *and* every
increment also lands in the process totals. :meth:`Counter.restore`
(reloading counters from a checkpoint snapshot on resume) deliberately
sets only the local value — the restored counts were earned by the
crashed process, so propagating them would double-count the work in this
process's totals.

All metric mutation is thread-safe (prefetch thread, service driver
thread); metrics are always on — unlike spans they are a handful of
locked integer bumps, not worth a disable path.
"""

from __future__ import annotations

import threading

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "Timing",
    "engine_snapshot",
    "registry",
]


class Counter:
    """Monotonic counter. ``add`` propagates to the parent counter;
    ``restore`` does not (see the module docstring for why)."""

    __slots__ = ("name", "_value", "_lock", "_parent")

    def __init__(self, name: str, parent: "Counter | None" = None):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()
        self._parent = parent

    def add(self, n: int = 1) -> None:
        """Increment by ``n`` (thread-safe), propagating to the parent."""
        with self._lock:
            self._value += n
        if self._parent is not None:
            self._parent.add(n)

    def restore(self, value) -> None:
        """Set the local value *without* parent propagation — for reloading
        a checkpointed count on resume, where the restored work was done
        (and already counted) by the previous process."""
        with self._lock:
            self._value = value

    @property
    def value(self):
        """The current count."""
        with self._lock:
            return self._value


class Gauge:
    """Last-value gauge with a high-water mark (:meth:`max` for peaks)."""

    __slots__ = ("name", "_value", "_hwm", "_lock", "_parent")

    def __init__(self, name: str, parent: "Gauge | None" = None):
        self.name = name
        self._value = None
        self._hwm = None
        self._lock = threading.Lock()
        self._parent = parent

    def set(self, v) -> None:
        """Set the current value (the high-water mark keeps the max)."""
        with self._lock:
            self._value = v
            self._hwm = v if self._hwm is None else max(self._hwm, v)
        if self._parent is not None:
            self._parent.set(v)

    def max(self, v) -> None:
        """Raise the gauge to ``v`` only if higher — peak tracking."""
        with self._lock:
            if self._value is None or v > self._value:
                self._value = v
                self._hwm = v if self._hwm is None else max(self._hwm, v)
        if self._parent is not None:
            self._parent.max(v)

    def restore(self, v) -> None:
        """Set the local value *without* parent propagation — the gauge
        analogue of :meth:`Counter.restore` for checkpoint resume."""
        with self._lock:
            self._value = v
            self._hwm = v if self._hwm is None else max(self._hwm, v)

    @property
    def value(self):
        """The current value (None if never set)."""
        with self._lock:
            return self._value

    @property
    def hwm(self):
        """The high-water mark (None if never set)."""
        with self._lock:
            return self._hwm


class Timing:
    """Streaming timing summary: count / total / min / max seconds."""

    __slots__ = ("name", "_count", "_total", "_min", "_max", "_lock",
                 "_parent")

    def __init__(self, name: str, parent: "Timing | None" = None):
        self.name = name
        self._count = 0
        self._total = 0.0
        self._min = None
        self._max = None
        self._lock = threading.Lock()
        self._parent = parent

    def observe(self, seconds: float) -> None:
        """Fold one measured duration in (thread-safe, parent-propagating)."""
        s = float(seconds)
        with self._lock:
            self._count += 1
            self._total += s
            self._min = s if self._min is None else min(self._min, s)
            self._max = s if self._max is None else max(self._max, s)
        if self._parent is not None:
            self._parent.observe(s)

    def summary(self) -> dict:
        """``{"count", "total_s", "mean_s", "min_s", "max_s"}``."""
        with self._lock:
            mean = self._total / self._count if self._count else 0.0
            return {"count": self._count, "total_s": self._total,
                    "mean_s": mean, "min_s": self._min, "max_s": self._max}


class MetricsRegistry:
    """Get-or-create named metrics, optionally chained to a parent.

    ``MetricsRegistry(parent=registry(), prefix="stream.")`` makes every
    local metric mirror into the parent under the prefixed name on each
    increment (but not on :meth:`Counter.restore`)."""

    def __init__(self, parent: "MetricsRegistry | None" = None,
                 prefix: str = ""):
        self._parent = parent
        self._prefix = prefix
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                up = None
                if self._parent is not None:
                    up = self._parent._get(self._prefix + name, cls)
                m = self._metrics[name] = cls(name, up)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        """Get or create the named :class:`Counter`."""
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        """Get or create the named :class:`Gauge`."""
        return self._get(name, Gauge)

    def timing(self, name: str) -> Timing:
        """Get or create the named :class:`Timing`."""
        return self._get(name, Timing)

    def counters(self) -> dict:
        """``{name: value}`` for every counter in this registry."""
        with self._lock:
            items = list(self._metrics.items())
        return {n: m.value for n, m in items if isinstance(m, Counter)}

    def scalars(self) -> dict:
        """``{name: value}`` for every counter and every set gauge."""
        with self._lock:
            items = list(self._metrics.items())
        out = {}
        for n, m in items:
            if isinstance(m, Counter):
                out[n] = m.value
            elif isinstance(m, Gauge) and m.value is not None:
                out[n] = m.value
        return out

    def snapshot(self) -> dict:
        """Full view: counter/gauge values and timing summaries by name."""
        with self._lock:
            items = list(self._metrics.items())
        out = {}
        for n, m in items:
            out[n] = m.summary() if isinstance(m, Timing) else m.value
        return out

    def reset(self) -> None:
        """Drop every metric in this registry (parents are untouched)."""
        with self._lock:
            self._metrics.clear()


_GLOBAL = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry — parent of every per-run registry."""
    return _GLOBAL


def engine_snapshot() -> dict:
    """One unified engine-telemetry view: the global registry's metrics,
    the shared plan/compiled-op cache stats
    (``repro.plan.executor.cache_stats``), and the kernel backend."""
    from ..kernels import registry as _kernels
    from ..plan import executor as _executor

    return {"metrics": _GLOBAL.snapshot(),
            "caches": _executor.cache_stats(),
            "kernel_backend": _kernels.get_backend()}
