"""Unified observability: tracing spans, typed metrics, cost-model checks.

Three cooperating pieces (see docs/OBSERVABILITY.md):

- :mod:`repro.obs.trace` — nestable, thread-safe spans with a
  process-wide recorder and Chrome/Perfetto ``trace_event`` export;
  near-zero cost while disabled.
- :mod:`repro.obs.metrics` — a typed registry (counters, gauges, timing
  summaries) unifying the engine's previously ad-hoc counters; per-run
  sub-registries propagate into process totals (except on checkpoint
  restore, which must not double-count).
- :mod:`repro.obs.model_check` — predicted-vs-observed accounting for
  every planned shuffle/groupby/scan, with :func:`model_report`
  summarizing cost-model error per paper pattern.

The wiring lives in the layers themselves: the plan executor and
streaming runner emit spans + model records, ``QueryService`` exposes
``stats()["trace"]``, the kernel registry attaches dispatch decisions to
the enclosing span, and ``LazyDDF.collect(profile=True)`` /
``explain(analyze=True)`` use :func:`profiled` to scope a per-query
profile.
"""

from __future__ import annotations

from . import metrics, model_check, trace
from .metrics import MetricsRegistry, engine_snapshot, registry
from .model_check import ModelRecord, model_report
from .trace import Trace, get_trace, span, tracing

__all__ = [
    "MetricsRegistry",
    "ModelRecord",
    "Profile",
    "Trace",
    "engine_snapshot",
    "get_trace",
    "metrics",
    "model_check",
    "model_report",
    "profiled",
    "registry",
    "span",
    "trace",
    "tracing",
]


class Profile:
    """The result of one :func:`profiled` block.

    ``records`` are the block's :class:`ModelRecord` samples; ``trace`` is
    the block's :class:`Trace` slice. :meth:`report` returns the
    structured summary, :meth:`render` a human-readable per-node profile
    (what ``LazyDDF.explain(analyze=True)`` appends to the plan)."""

    def __init__(self):
        self.records: list = []
        self.trace: Trace | None = None

    def report(self) -> dict:
        """``{"model": model_report(...), "spans": per-name aggregates}``."""
        return {"model": model_report(self.records),
                "spans": self.trace.summary() if self.trace else {}}

    def render(self) -> str:
        """Human-readable per-operator profile: predicted vs observed wall
        time per planned operator (aggregated across morsel dispatches of
        the same operator), then the per-pattern error summary."""
        agg: dict[tuple, dict] = {}
        for r in self.records:
            d = agg.setdefault((r.op, r.pattern),
                               {"n": 0, "pred": 0.0, "obs": 0.0})
            d["n"] += 1
            d["pred"] += r.predicted_s
            d["obs"] += r.observed_s
        lines = ["-- profile (predicted vs observed) --"]
        for (op, pattern), d in sorted(agg.items()):
            ratio = d["obs"] / max(d["pred"], 1e-9)
            lines.append(
                f"{op:<22} {pattern:<24} x{d['n']:<4d} "
                f"predicted {d['pred'] * 1e3:9.3f} ms  "
                f"observed {d['obs'] * 1e3:9.3f} ms  (x{ratio:.2f})")
        rep = model_report(self.records)
        if rep:
            lines.append("-- per-pattern model error --")
            for pattern, d in sorted(rep.items()):
                lines.append(
                    f"{pattern:<24} n={d['count']:<5d} "
                    f"bias x{d['bias']:.2f}  "
                    f"mean |rel err| {d['mean_abs_rel_err']:.2f}")
        return "\n".join(lines)


class _Profiled:
    __slots__ = ("_prof", "_tracing", "_mark", "_tmark")

    def __enter__(self):
        self._prof = Profile()
        self._mark = model_check.mark()
        self._tmark = trace.mark()
        self._tracing = trace.tracing()
        self._tracing.__enter__()
        return self._prof

    def __exit__(self, *exc):
        self._tracing.__exit__(*exc)
        self._prof.records = model_check.records(since=self._mark)
        self._prof.trace = trace.get_trace(since=self._tmark)
        return False


def profiled() -> _Profiled:
    """Enable tracing for a ``with`` block and scope a :class:`Profile` to
    it::

        with obs.profiled() as prof:
            lz.collect()
        print(prof.render())

    The prior tracing state is restored on exit; the yielded profile is
    filled with the block's model samples and trace slice when the block
    closes."""
    return _Profiled()
