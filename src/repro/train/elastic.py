"""Elastic scaling + failure handling (fault tolerance, DESIGN.md §2).

TPU/BSP reality: a failed chip kills the SPMD program — recovery is
restore-and-resume, not in-flight patching (the paper makes the same point
about MPI, §8). What we provide:

1. ``rescale_state``: restore a checkpoint onto a *different* mesh — params
   and optimizer state re-device_put with the new plan's shardings, the DDF
   data pipeline re-partitioned with ``core.operators.rebalance`` (the
   paper's sample-based repartitioning).
2. ``StepGuard``: per-step watchdog that triggers an emergency checkpoint if
   a step exceeds a straggler threshold (host-side; on real pods this hooks
   the multislice heartbeat).
3. Straggler mitigation inside a step is structural: BSP supersteps make a
   straggler == load imbalance, and the pipeline's rebalance bounds
   partition skew to <=1 row (see operators.rebalance).
"""

from __future__ import annotations

import time
from typing import Callable

import jax

from .. import sharding as shard_mod
from . import checkpoint

__all__ = ["rescale_state", "StepGuard"]


def rescale_state(ckpt_dir: str, step: int, state_specs, new_mesh, mode: str = "train"):
    """Restore a checkpoint onto ``new_mesh`` (different worker count OK)."""
    plan = shard_mod.make_plan(new_mesh, mode=mode)
    shardings = {
        "params": shard_mod.param_shardings(state_specs["params"], plan),
        "opt": {
            "mu": shard_mod.param_shardings(state_specs["opt"]["mu"], plan),
            "nu": shard_mod.param_shardings(state_specs["opt"]["nu"], plan),
            "step": plan.ns(),
        },
    }
    return checkpoint.restore(ckpt_dir, step, state_specs, shardings)


class StepGuard:
    """Watchdog: emergency-checkpoint when a step exceeds the straggler
    threshold (factor x trailing-mean step time).

    ``time_fn`` injects the clock (tests drive straggler detection with a
    fake clock; production uses ``time.monotonic``). Emergency saves go
    through ``checkpoint.save``'s atomic tmp-dir-rename publish, so a
    straggler that turns into a crash mid-save never corrupts the previous
    checkpoint; ``last_emergency_step`` records the most recent trigger."""

    def __init__(self, ckpt_dir: str, threshold_factor: float = 3.0,
                 min_history: int = 5, time_fn: Callable[[], float] = time.monotonic):
        self.ckpt_dir = ckpt_dir
        self.factor = threshold_factor
        self.min_history = min_history
        self.time_fn = time_fn
        self.history: list[float] = []
        self.emergency_saves = 0
        self.last_emergency_step: int | None = None

    def step(self, step_idx: int, fn: Callable, state, *args):
        t0 = self.time_fn()
        out = fn(state, *args)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        dt = self.time_fn() - t0
        if len(self.history) >= self.min_history:
            mean = sum(self.history[-20:]) / len(self.history[-20:])
            if dt > self.factor * mean:
                checkpoint.save(self.ckpt_dir, step_idx, out[0] if isinstance(out, tuple) else out)
                self.emergency_saves += 1
                self.last_emergency_step = step_idx
        self.history.append(dt)
        return out
