"""Chunked cross-entropy: never materializes the full (tokens x vocab)
logits tensor (critical for gemma2's 256k vocab at 1M tokens — DESIGN.md §7.3).

The sequence is scanned in chunks; each chunk computes logits against the
(possibly vocab-sharded) embedding, a stable logsumexp, and the label logit.
Under GSPMD the per-chunk reductions over a TP-sharded vocab lower to
all-reduces of (B, chunk) scalars instead of (B, S, V) tensors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.common import softcap

__all__ = ["chunked_cross_entropy"]


def chunked_cross_entropy(
    hidden: jax.Array,        # (B, S, d)
    embedding: jax.Array,     # (V, d)
    labels: jax.Array,        # (B, S) int32
    loss_mask: jax.Array,     # (B, S) {0,1}
    chunk: int = 512,
    final_softcap: float | None = None,
    plan=None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (mean nll over masked tokens, total masked tokens).

    §Perf iteration 6: each hidden chunk is explicitly replicated over TP
    (a 6MB gather) before the logits einsum, and the logits constrained
    vocab-sharded. Without this GSPMD contracts the TP-sharded d dim and
    all-reduces FULL-VOCAB f32 logit chunks (0.8GB x n_chunks x microbatches
    for granite; 4GB for gemma2's 256k vocab)."""
    B, S, d = hidden.shape
    V = embedding.shape[0]
    n_chunks = max(S // chunk, 1)
    chunk = S // n_chunks
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"

    hs = hidden.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    ms = loss_mask.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    vocab_sharded = (plan is not None and V % plan.axis_size(plan.tp) == 0
                     and B % plan.axis_size(plan.dp) == 0)

    def body(carry, xs):
        nll_sum, tok_sum = carry
        h, lab, m = xs
        if vocab_sharded:
            h = jax.lax.with_sharding_constraint(h, plan.ns(plan.dp, None, None))
        logits = jnp.einsum("bcd,vd->bcv", h, embedding.astype(h.dtype))
        if vocab_sharded:
            logits = jax.lax.with_sharding_constraint(logits, plan.ns(plan.dp, None, plan.tp))
        logits = softcap(logits, final_softcap).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (nll_sum + jnp.sum(nll), tok_sum + jnp.sum(m)), None

    (nll_sum, tok_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ls, ms))
    return nll_sum / jnp.maximum(tok_sum, 1.0), tok_sum
