from .loss import chunked_cross_entropy  # noqa: F401
from .optimizer import adamw_init, adamw_update  # noqa: F401
from .train_step import TrainState, make_train_step  # noqa: F401
