"""Gradient compression for DP all-reduce: int8 quantization with error
feedback (1-bit-Adam-family trick, adapted to BSP data parallelism).

Beyond-paper distributed-optimization feature (task spec): in the manual-DP
training path (shard_map over the dp axes), per-worker gradients are
quantized to int8 with a per-tensor scale, all-reduced in int32, and
dequantized; the quantization residual is carried to the next step (error
feedback), which keeps convergence close to exact all-reduce while cutting
gradient traffic 4x vs fp32 (2x vs bf16).

The pure-jit GSPMD path can't express this (its reductions are implicit in
backward), so compression lives in `manual_dp_train_step` — the same split
the paper draws between library-provided collectives and channel-level
custom communication (paper §3.2/§3.3).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize", "dequantize", "compressed_psum", "init_error_feedback"]


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp -> (int8, scale). Symmetric per-tensor."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis, error: Any = None):
    """All-reduce a gradient pytree in int8+scale with error feedback.

    Must run inside shard_map over ``axis``. Returns (mean grads, new error).
    """
    from repro.compat import axis_size
    P = axis_size(axis)

    def one(g, e):
        g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
        # agree on a shared scale first (one scalar pmax), so the int8
        # payloads are commensurable and the int32 sum is exact
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_e = g32 - q.astype(jnp.float32) * scale  # residual kept locally
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        return qsum.astype(jnp.float32) * scale / P, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error) if error is not None else [None] * len(flat_g)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
