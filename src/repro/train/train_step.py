"""Train step factory: loss -> grad -> AdamW, with optional microbatch
gradient accumulation (scan) and the sharding plan applied to params,
optimizer state and batch.

Fault-tolerance notes (DESIGN.md §2): the step is a pure function of
(state, batch); combined with the sharded checkpointer (checkpoint.py) and
the elastic re-partitioner (elastic.py + core.operators.rebalance), a node
failure is handled by restore -> re-mesh -> resume. Straggler mitigation in
the BSP setting is per-step: the data pipeline rebalances partitions
(paper §8) so no worker carries outsized local work.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model_zoo import Model
from .loss import chunked_cross_entropy
from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_step", "make_loss_fn"]


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    opt: AdamWConfig = AdamWConfig()
    loss_chunk: int = 512
    moe_aux_weight: float = 0.01
    microbatches: int = 1          # gradient accumulation steps


class TrainState(dict):
    """{params, opt, step}: plain dict so pytree/sharding handling is trivial."""


def init_train_state(model: Model, rng) -> dict:
    params = model.init_params(rng)
    return {"params": params, "opt": adamw_init(params)}


def train_state_specs(model: Model) -> dict:
    specs = model.param_specs()
    return {"params": specs, "opt": jax.eval_shape(adamw_init, specs)}


def make_loss_fn(model: Model, hp: TrainHParams, plan=None) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        hidden, moe_aux = model.forward(params, batch, plan=plan)
        # gathered-over-fsdp, still vocab(TP)-sharded for the chunked loss;
        # custom-vjp reshard keeps the embedding grad in storage layout
        from .. import sharding as shard_mod
        if "unembed" in params:
            emb = shard_mod.use_param(params["unembed"], plan, "unembed")
        else:
            emb = shard_mod.use_param(params["embed"], plan, "embed")
        labels = batch["labels"]
        mask = batch["loss_mask"].astype(jnp.float32)
        # vlm: hidden includes the image prefix; score text positions only
        if hidden.shape[1] != labels.shape[1]:
            hidden = hidden[:, hidden.shape[1] - labels.shape[1]:]
        nll, ntok = chunked_cross_entropy(
            hidden, emb, labels, mask, chunk=min(hp.loss_chunk, labels.shape[1]),
            final_softcap=cfg.final_logit_softcap, plan=plan)
        loss = nll + hp.moe_aux_weight * moe_aux
        return loss, {"nll": nll, "ntok": ntok, "moe_aux": moe_aux}

    return loss_fn


def make_train_step(model: Model, hp: TrainHParams = TrainHParams(), plan=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    With hp.microbatches > 1, the leading batch dim is split and gradients
    accumulate in fp32 through a scan — the standard compute/memory trade
    (and the hook where DP all-reduce naturally overlaps the next
    microbatch's backward under XLA's latency-hiding scheduler).
    """
    loss_fn = make_loss_fn(model, hp, plan=plan)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, aux), grads = grad_fn(params, batch)
        return loss, aux, grads

    def accumulated(params, batch):
        mb = hp.microbatches
        split = jax.tree.map(lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

        def body(carry, mbatch):
            gsum, lsum = carry
            if plan is not None:
                mbatch = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, plan.ns(*([plan.dp] + [None] * (x.ndim - 1))))
                    if x.shape[0] % plan.axis_size(plan.dp) == 0 else x,
                    mbatch)
            (loss, aux), grads = grad_fn(params, mbatch)
            gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, lsum + loss), aux

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), auxs = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), split)
        grads = jax.tree.map(lambda g: g / mb, gsum)
        aux = jax.tree.map(lambda x: x[-1], auxs)
        return lsum / mb, aux, grads

    def train_step(state, batch):
        params = state["params"]
        if hp.microbatches > 1:
            loss, aux, grads = accumulated(params, batch)
        else:
            loss, aux, grads = single(params, batch)
        new_params, new_opt, opt_metrics = adamw_update(hp.opt, params, grads, state["opt"])
        metrics = {"loss": loss, **aux, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
