"""Sharded checkpoint / restore (fault tolerance, paper §8's future work).

Layout: <dir>/step_<N>/
  manifest.json            — step, flat key list, shapes/dtypes, mesh info
  shard_<proc>.npz         — this process's addressable shard of every leaf

Single-process (this container): one shard holding everything; the format
is nevertheless per-process so the same code runs under multi-host
jax.distributed. Restore validates shapes against the target state specs and
re-device_puts with the current plan's shardings — which is exactly what
elastic re-scale needs (restore onto a *different* mesh: params re-shard via
device_put; the data pipeline re-partitions via core.operators.rebalance).

Emergency checkpointing: ``save`` is atomic (write to tmp dir, rename), so a
checkpoint interrupted by a failure never corrupts the previous one.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]


def _flatten(tree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path)
        out[key] = leaf
    return out


def save(directory: str, step: int, state, process_index: int = 0) -> str:
    flat = _flatten(state)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp_{process_index}"
    os.makedirs(tmp, exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, f"shard_{process_index}.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in arrays.items()},
        "process_count": jax.process_count(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp_0")]
    return max(steps) if steps else None


def restore(directory: str, step: int, state_specs, shardings=None, process_index: int = 0):
    """Load into the structure of ``state_specs``; device_put with
    ``shardings`` (same tree) if given — this is the elastic-rescale hook."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"shard_{process_index}.npz"))

    flat_specs = jax.tree_util.tree_flatten_with_path(state_specs)
    leaves = []
    shard_flat = jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    for i, (kpath, spec) in enumerate(flat_specs[0]):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in kpath)
        arr = data[key]
        if tuple(arr.shape) != tuple(spec.shape):
            raise ValueError(f"checkpoint leaf {key}: shape {arr.shape} != expected {spec.shape}")
        arr = arr.astype(spec.dtype)
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[i])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_specs[1], leaves), manifest["step"]
