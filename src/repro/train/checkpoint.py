"""Sharded checkpoint / restore (fault tolerance, paper §8's future work).

Layout: <dir>/step_<N>/
  manifest.json            — step, flat key list, shapes/dtypes, mesh info
  shard_<proc>.npz         — this process's addressable shard of every leaf

Single-process (this container): one shard holding everything; the format
is nevertheless per-process so the same code runs under multi-host
jax.distributed. Restore validates shapes against the target state specs and
re-device_puts with the current plan's shardings — which is exactly what
elastic re-scale needs (restore onto a *different* mesh: params re-shard via
device_put; the data pipeline re-partitions via core.operators.rebalance).

Emergency checkpointing: ``save`` is atomic (write to tmp dir, rename), so a
checkpoint interrupted by a failure never corrupts the previous one.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "publish_dir", "list_steps"]


def publish_dir(tmp: str, final: str) -> str:
    """Atomically publish a staged directory: replace ``final`` with ``tmp``
    via rename. A crash before the rename leaves only a ``*.tmp_*`` dir
    (ignored and cleaned by :func:`list_steps`); a crash after it leaves the
    complete new version. Shared by trainer checkpoints and the streaming
    engine's ``StreamCheckpoint``."""
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def list_steps(directory: str, prefix: str = "step_",
               clean_stale: bool = True) -> list[int]:
    """Valid checkpoint step numbers under ``directory``, ascending.

    A subdirectory counts only when it is ``<prefix><int>`` **and** holds a
    ``manifest.json`` — a partial dir from a crashed non-atomic writer must
    never be selected for restore. Leftover ``*.tmp_*`` staging dirs from a
    crash mid-publish are ignored and (by default) deleted."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        path = os.path.join(directory, name)
        if ".tmp_" in name:
            if clean_stale and os.path.isdir(path):
                shutil.rmtree(path, ignore_errors=True)
            continue
        if not (name.startswith(prefix) and os.path.isdir(path)):
            continue
        try:
            step = int(name[len(prefix):])
        except ValueError:
            continue
        if not os.path.exists(os.path.join(path, "manifest.json")):
            continue  # partial dir (no atomic publish): never restorable
        steps.append(step)
    return sorted(steps)


def _flatten(tree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path)
        out[key] = leaf
    return out


def save(directory: str, step: int, state, process_index: int = 0) -> str:
    flat = _flatten(state)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp_{process_index}"
    if os.path.exists(tmp):  # stale staging dir from a crashed save
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, f"shard_{process_index}.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in arrays.items()},
        "process_count": jax.process_count(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return publish_dir(tmp, final)


def latest_step(directory: str) -> int | None:
    """Newest restorable step in ``directory`` (None when there is none).

    Robust to crash debris: leftover ``*.tmp_*`` staging dirs from a save
    interrupted mid-publish are ignored and cleaned, and a partial
    ``step_*`` dir without a ``manifest.json`` is never selected."""
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, state_specs, shardings=None, process_index: int = 0):
    """Load into the structure of ``state_specs``; device_put with
    ``shardings`` (same tree) if given — this is the elastic-rescale hook."""
    path = os.path.join(directory, f"step_{step:08d}")
    manifest_path = os.path.join(path, "manifest.json")
    if not os.path.exists(manifest_path):
        raise FileNotFoundError(
            f"no restorable checkpoint for step {step} under {directory!r} "
            f"(valid steps: {list_steps(directory, clean_stale=False)})")
    with open(manifest_path) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"shard_{process_index}.npz"))

    flat_specs = jax.tree_util.tree_flatten_with_path(state_specs)
    leaves = []
    shard_flat = jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    for i, (kpath, spec) in enumerate(flat_specs[0]):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in kpath)
        arr = data[key]
        if tuple(arr.shape) != tuple(spec.shape):
            raise ValueError(f"checkpoint leaf {key}: shape {arr.shape} != expected {spec.shape}")
        arr = arr.astype(spec.dtype)
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[i])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_specs[1], leaves), manifest["step"]
