"""GQA attention: training (full-sequence causal / bidirectional / sliding
window / logit-softcap) and single-token cached decode.

The XLA einsum path is the default (and the one the multi-pod dry-run
lowers); ``repro.kernels.ops.flash_attention`` is the TPU Pallas fast path,
selected via ``use_kernel`` when running on TPU.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, rope, softcap
from .config import ModelConfig

__all__ = ["attn_init", "attention", "attention_decode", "init_kv_cache"]


def attn_init(rng, cfg: ModelConfig) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd)),
        "wk": dense_init(ks[1], (d, KV, hd)),
        "wv": dense_init(ks[2], (d, KV, hd)),
        "wo": dense_init(ks[3], (H, hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((KV, hd), jnp.float32)
        p["bv"] = jnp.zeros((KV, hd), jnp.float32)
    return p


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _scale(cfg: ModelConfig) -> float:
    if cfg.query_scale is not None:
        return cfg.query_scale ** -0.5
    return cfg.head_dim ** -0.5


def attention(
    p: dict,
    x: jax.Array,              # (B, S, d)
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: int | None = None,  # sliding window override (None = cfg/full)
    positions: jax.Array | None = None,
    kv_x: jax.Array | None = None,  # cross-attention source (B, S_kv, d)
) -> jax.Array:
    """Full-sequence attention. GQA via head-group einsum; O(S^2) masked."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    dt = x.dtype

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)

    if kv_x is None and cfg.head_dim and positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    if kv_x is None:  # rope only for self-attention
        cos, sin = rope(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    Sk = k.shape[1]
    q = q.reshape(B, S, KV, G, hd)

    if kv_x is None and S * Sk > _CHUNK_THRESHOLD:
        out = _chunked_attention(q, k, v, cfg, causal=causal, window=window)
    else:
        scores = jnp.einsum("bqhgc,bthc->bhgqt", q, k)
        scores = scores.astype(jnp.float32) * _scale(cfg)
        scores = softcap(scores, cfg.attn_logit_softcap)
        if kv_x is None:
            qi = jnp.arange(S)[:, None]
            ki = jnp.arange(Sk)[None, :]
            mask = jnp.ones((S, Sk), bool)
            if causal:
                mask &= ki <= qi
            if window is not None:
                mask &= ki > qi - window
            scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        out = jnp.einsum("bhgqt,bthc->bqhgc", probs, v)
    out = out.reshape(B, S, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


# above this many score elements per (b,h) pair, switch to the
# flash-style chunked path (never materialize S x S scores)
_CHUNK_THRESHOLD = 2048 * 2048
_Q_BLOCK = 512
_KV_BLOCK = 1024


def _chunked_attention(q, k, v, cfg: ModelConfig, *, causal: bool, window):
    """Flash-style online-softmax attention in XLA ops (the dry-run path;
    the Pallas kernel in repro.kernels.flash_attention is the TPU fast path).

    q: (B, S, KV, G, hd); k/v: (B, S, KV, hd). Scans query blocks; for a
    *static* sliding window only the kv blocks inside the window are read
    (real FLOP savings for mistral/llava prefill). Causal-only models mask
    (upper-triangle compute is spent — recorded as roofline waste, addressed
    by the Pallas kernel / §Perf).
    """
    B, S, KV, G, hd = q.shape
    dt = q.dtype
    qb, kvb = _Q_BLOCK, _KV_BLOCK
    n_q = -(-S // qb)
    assert S % qb == 0, f"S={S} must divide q block {qb}"
    static_window = window if isinstance(window, int) else None

    if static_window is not None and causal:
        # kv span needed per q block: window + current block
        n_kv = min(-(-(static_window + qb) // kvb) + 1, -(-S // kvb))
        sliding = True
    else:
        n_kv = -(-S // kvb)
        sliding = False
    kv_span = n_kv * kvb

    scale = _scale(cfg)

    def q_block_body(_, qi):
        # qi: scalar block index
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * qb, qb, axis=1)
        q_pos = qi * qb + jnp.arange(qb)
        if sliding:
            start = jnp.clip((qi + 1) * qb - kv_span, 0, S - kv_span)
        else:
            start = 0
        k_blk = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
        k_pos = start + jnp.arange(kv_span)

        s = jnp.einsum("bqhgc,bthc->bhgqt", q_blk, k_blk).astype(jnp.float32) * scale
        s = softcap(s, cfg.attn_logit_softcap)
        mask = jnp.ones((qb, kv_span), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, -1e30)
        # block-local softmax is exact: every key this block attends to is
        # inside [start, start+kv_span)
        p_ = jax.nn.softmax(s, axis=-1).astype(dt)
        o = jnp.einsum("bhgqt,bthc->bqhgc", p_, v_blk)
        return None, o

    # remat the per-q-block compute: backward recomputes scores/probs
    # (flash-attention-style) instead of saving an (n_q, B, H, qb, kv) stack
    _, outs = jax.lax.scan(
        jax.checkpoint(q_block_body, policy=jax.checkpoint_policies.nothing_saveable),
        None, jnp.arange(n_q))
    # outs: (n_q, B, qb, KV, G, hd) -> (B, S, KV, G, hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, G, hd)


class KVCache(NamedTuple):
    k: jax.Array  # (B, T, KV, hd) — bf16, or int8 when quantized
    v: jax.Array
    # per-token-per-head dequant scales; () placeholders when not quantized
    k_scale: jax.Array = jnp.zeros(())  # (B, T, KV, 1) f32
    v_scale: jax.Array = jnp.zeros(())
    # Cache is pre-filled to `length`; decode writes at `length` (same for
    # all batch rows — continuous batching handled at the engine layer).

    @property
    def quantized(self) -> bool:
        return self.k.dtype == jnp.int8


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.bfloat16, quantized: bool = False):
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if quantized:
        # KIVI-style per-token symmetric int8 (beyond-paper serving feature:
        # 2x cache memory + bandwidth vs bf16)
        sshape = (n_layers, batch, max_len, cfg.n_kv_heads, 1)
        return KVCache(jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                       jnp.zeros(sshape, jnp.float32), jnp.zeros(sshape, jnp.float32))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B, 1, KV, hd) -> (int8 values, (B,1,KV,1) f32 scale). Symmetric
    per-(token, head)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def attention_decode(
    p: dict,
    x: jax.Array,               # (B, 1, d) new-token hidden
    cache_k: jax.Array,         # (B, T, KV, hd) — this layer's cache
    cache_v: jax.Array,
    length: jax.Array,          # scalar int32: #valid cache entries
    cfg: ModelConfig,
    *,
    window: int | None = None,
    k_scale: jax.Array | None = None,   # (B, T, KV, 1) when int8 cache
    v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array | None, jax.Array | None]:
    """One decode step: append new KV at `length`, attend over [0, length].

    Supports bf16 or int8 (KIVI-style per-token-scale) caches. Returns
    (out (B,1,d), new_k, new_v, new_k_scale, new_v_scale).
    """
    B, _, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    T = cache_k.shape[1]
    dt = x.dtype
    quantized = cache_k.dtype == jnp.int8

    q, k, v = _qkv(p, x, cfg)
    pos = jnp.full((B, 1), length, dtype=jnp.int32)
    cos, sin = rope(pos, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if quantized:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache_k = jax.lax.dynamic_update_slice(cache_k, kq, (0, length, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, vq, (0, length, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(k_scale, ks, (0, length, 0, 0))
        v_scale = jax.lax.dynamic_update_slice(v_scale, vs, (0, length, 0, 0))
        keys = cache_k.astype(dt) * k_scale.astype(dt)
        vals = cache_v.astype(dt) * v_scale.astype(dt)
    else:
        cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, length, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, length, 0, 0))
        keys = cache_k.astype(dt)
        vals = cache_v.astype(dt)

    qg = q.reshape(B, 1, KV, G, hd)
    scores = jnp.einsum("bqhgc,bthc->bhgqt", qg, keys)
    scores = scores.astype(jnp.float32) * _scale(cfg)
    scores = softcap(scores, cfg.attn_logit_softcap)
    ti = jnp.arange(T)[None, None, None, None, :]
    mask = ti <= length
    if window is not None:
        mask &= ti > length - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bhgqt,bthc->bqhgc", probs, vals).reshape(B, 1, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return out, cache_k, cache_v, k_scale, v_scale
