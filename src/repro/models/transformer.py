"""Model assembly: init / train-forward / cached decode for every family.

Families (DESIGN.md §5):
- dense / moe / vlm: uniform decoder stack, scanned over layers
  (gemma2's local/global alternation rides through the scan as a per-layer
  window scalar; llava consumes a precomputed patch-embedding prefix).
- ssm: pure Mamba2 stack (scanned).
- hybrid (zamba2): Mamba2 backbone with ONE shared attention block invoked
  every k layers (weight reuse across invocations — the Zamba trick).
- encdec (whisper): bidirectional encoder over precomputed frames (conv
  frontend stubbed per the assignment), causal decoder with cross-attention.

All stacks use lax.scan over stacked layer params + jax.checkpoint (remat)
so the HLO stays compact for 95-layer configs and activation memory stays
O(sqrt-ish) for the dry-run memory analysis.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.compat import optimization_barrier
import numpy as np

from . import attention as attn_mod
from . import mlp as mlp_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import dense_init, norm_apply, norm_init, softcap
from .. import sharding as shard_mod
from .config import ModelConfig

__all__ = [
    "init_params", "param_specs", "forward", "decode_step",
    "init_decode_state", "decode_state_specs",
]

_BIG_WINDOW = jnp.iinfo(jnp.int32).max // 2


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(rng, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(rng, 4)
    p: dict[str, Any] = {}
    if kind == "mamba":
        p["ln1"] = norm_init(cfg.norm, cfg.d_model)
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg)
        return p
    p["ln1"] = norm_init(cfg.norm, cfg.d_model)
    p["attn"] = attn_mod.attn_init(ks[0], cfg)
    p["ln2"] = norm_init(cfg.norm, cfg.d_model)
    if kind == "attn_moe":
        p["moe"] = moe_mod.moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_mod.mlp_init(ks[1], cfg)
    if cfg.use_post_norm:
        p["ln1_post"] = norm_init(cfg.norm, cfg.d_model)
        p["ln2_post"] = norm_init(cfg.norm, cfg.d_model)
    if kind == "cross":  # whisper decoder block: self + cross + mlp
        p["lnx"] = norm_init(cfg.norm, cfg.d_model)
        p["xattn"] = attn_mod.attn_init(ks[2], cfg)
    return p


def _decoder_kind(cfg: ModelConfig) -> str:
    if cfg.family == "moe":
        return "attn_moe"
    if cfg.family == "ssm":
        return "mamba"
    if cfg.family == "hybrid":
        return "mamba"
    if cfg.family == "encdec":
        return "cross"
    return "attn_mlp"


def layer_windows(cfg: ModelConfig, n_layers: int) -> np.ndarray:
    """Per-layer attention window (int32; _BIG_WINDOW = full attention)."""
    if cfg.local_global_pattern:
        w = [cfg.sliding_window if i % 2 == 0 else _BIG_WINDOW for i in range(n_layers)]
    elif cfg.sliding_window is not None:
        w = [cfg.sliding_window] * n_layers
    else:
        w = [_BIG_WINDOW] * n_layers
    return np.asarray(w, np.int32)


def init_params(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 8)
    p: dict[str, Any] = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model)),
        "final_norm": norm_init(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.vocab_size, cfg.d_model))
    if cfg.learned_positions:
        p["pos_embed"] = dense_init(ks[2], (cfg.max_seq, cfg.d_model))

    kind = _decoder_kind(cfg)
    # stacked decoder layers
    p["layers"] = jax.vmap(lambda k: _layer_init(k, cfg, kind))(
        jax.random.split(ks[3], cfg.n_layers))

    if cfg.family == "hybrid" and cfg.shared_attn_every:
        p["shared"] = _layer_init(ks[4], cfg, "attn_mlp")
    if cfg.family == "encdec":
        p["enc_layers"] = jax.vmap(lambda k: _layer_init(k, cfg, "attn_mlp"))(
            jax.random.split(ks[5], cfg.n_enc_layers))
        p["enc_norm"] = norm_init(cfg.norm, cfg.d_model)
        p["enc_pos"] = dense_init(ks[6], (cfg.enc_positions, cfg.d_model))
    if cfg.family == "vlm" and cfg.n_patches:
        # anyres projector stub: patch embeds arrive pre-projected; a single
        # linear adapter stands in for the 2-layer MLP projector.
        p["vis_proj"] = dense_init(ks[7], (cfg.d_model, cfg.d_model))
    return p


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStruct tree — no allocation (dry-run entry point)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _attn_block(lp: dict, h: jax.Array, cfg: ModelConfig, window, positions=None, plan=None):
    lp = shard_mod.gather_params(lp, plan)
    a_in = norm_apply(lp["ln1"], h, cfg.norm)
    a = attn_mod.attention(lp["attn"], a_in, cfg, causal=True, window=window,
                           positions=positions)
    if cfg.use_post_norm:
        a = norm_apply(lp["ln1_post"], a, cfg.norm)
    h = h + a
    m_in = norm_apply(lp["ln2"], h, cfg.norm)
    if "moe" in lp:
        m, aux = moe_mod.moe_forward(lp["moe"], m_in, cfg, plan=plan)
    else:
        m, aux = mlp_mod.mlp_forward(lp["mlp"], m_in, cfg), 0.0
    if cfg.use_post_norm:
        m = norm_apply(lp["ln2_post"], m, cfg.norm)
    return h + m, aux


def _mamba_block(lp: dict, h: jax.Array, cfg: ModelConfig, plan=None) -> jax.Array:
    lp = shard_mod.gather_params(lp, plan)
    a_in = norm_apply(lp["ln1"], h, cfg.norm)
    out, _ = ssm_mod.ssd_forward(lp["ssm"], a_in, cfg, plan=plan)
    return h + out


def _scan_layers(layers: dict, h: jax.Array, body: Callable, n: int, extra_xs=None,
                 remat: bool = True):
    """scan h through stacked layer params (+ optional per-layer scalars)."""
    def f(carry, xs):
        # barrier: keeps XLA from hoisting per-iteration converts of the
        # saved carry stack out of the loop (materializes the whole stack in
        # f32 otherwise — +12.7GB/device on deepseek-67b)
        carry = optimization_barrier(carry)
        if extra_xs is None:
            lp, = (xs,)
            out = body(carry, lp, None)
        else:
            lp, ex = xs
            out = body(carry, lp, ex)
        return out, None

    if remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    xs = layers if extra_xs is None else (layers, extra_xs)
    h, _ = jax.lax.scan(f, h, xs, length=n)
    return h


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------

def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig, dtype, plan=None) -> jax.Array:
    emb = shard_mod.use_param(params["embed"], plan, "embed")
    h = emb.astype(dtype)[tokens]
    if cfg.scale_embeddings:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return h


def _encoder_forward(params: dict, frames: jax.Array, cfg: ModelConfig, plan=None) -> jax.Array:
    """whisper encoder over precomputed conv-frontend frames (B, T, d)."""
    dt = frames.dtype
    T = frames.shape[1]
    h = frames + params["enc_pos"][:T].astype(dt)[None]

    def body(h, lp, _):
        lp = shard_mod.gather_params(lp, plan)
        a_in = norm_apply(lp["ln1"], h, cfg.norm)
        a = attn_mod.attention(lp["attn"], a_in, cfg, causal=False, window=None)
        h = h + a
        m_in = norm_apply(lp["ln2"], h, cfg.norm)
        return h + mlp_mod.mlp_forward(lp["mlp"], m_in, cfg)

    h = _scan_layers(params["enc_layers"], h, body, cfg.n_enc_layers)
    return norm_apply(params["enc_norm"], h, cfg.norm)


def forward(params: dict, batch: dict, cfg: ModelConfig, plan=None) -> tuple[jax.Array, jax.Array]:
    """Training forward -> (hidden (B,S,d), moe_aux_loss). Loss (chunked
    xent against the embedding) lives in repro.train.loss."""
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    h = embed_tokens(params, tokens, cfg, dtype, plan=plan)
    if plan is not None:
        h = jax.lax.with_sharding_constraint(h, plan.ns(plan.dp, None, None))

    if cfg.family == "vlm" and cfg.n_patches:
        vp = shard_mod.use_param(params["vis_proj"], plan, "vis_proj")
        pe = batch["patch_embeds"].astype(dtype) @ vp.astype(dtype)
        h = jnp.concatenate([pe, h], axis=1)  # image prefix
    if cfg.learned_positions:
        S = h.shape[1]
        h = h + params["pos_embed"][:S].astype(dtype)[None]

    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "vlm"):
        windows = jnp.asarray(layer_windows(cfg, cfg.n_layers))

        def body(carry, lp, win):
            h, aux = carry
            h, a = _attn_block(lp, h, cfg, window=win, plan=plan)
            return (shard_mod.act_seq(h, plan), aux + a)

        (h, aux_total) = _scan_layers(params["layers"], (h, aux_total), body,
                                      cfg.n_layers, extra_xs=windows)
    elif cfg.family == "ssm":
        def body(h, lp, _):
            return shard_mod.act_seq(_mamba_block(lp, h, cfg, plan=plan), plan)
        h = _scan_layers(params["layers"], h, body, cfg.n_layers)
    elif cfg.family == "hybrid":
        h = _hybrid_forward(params, h, cfg, plan=plan)
    elif cfg.family == "encdec":
        enc = _encoder_forward(params, batch["enc_frames"].astype(dtype), cfg, plan=plan)

        def body(h, lp, _):
            lp = shard_mod.gather_params(lp, plan)
            a_in = norm_apply(lp["ln1"], h, cfg.norm)
            h = h + attn_mod.attention(lp["attn"], a_in, cfg, causal=True)
            x_in = norm_apply(lp["lnx"], h, cfg.norm)
            h = h + attn_mod.attention(lp["xattn"], x_in, cfg, kv_x=enc)
            m_in = norm_apply(lp["ln2"], h, cfg.norm)
            return shard_mod.act_seq(h + mlp_mod.mlp_forward(lp["mlp"], m_in, cfg), plan)

        h = _scan_layers(params["layers"], h, body, cfg.n_layers)
    else:
        raise ValueError(cfg.family)

    h = norm_apply(params["final_norm"], h, cfg.norm)
    return h, aux_total


def _hybrid_forward(params: dict, h: jax.Array, cfg: ModelConfig, plan=None) -> jax.Array:
    """zamba2: mamba backbone, ONE shared attn block every k layers.

    Structured as scan-of-scan: the outer scan iterates segments, each inner
    scan runs k mamba layers, then the shared block applies with the SAME
    closed-over weights (the Zamba weight-reuse trick — its gradient
    accumulates across outer iterations naturally). Avoids python-loop
    slicing of stacked params, whose transpose scatters into full-size zero
    stacks per segment (45GB/device before this restructure).
    """
    k = cfg.shared_attn_every
    L = cfg.n_layers
    n_seg, rem = divmod(L, k)

    def inner_body(h, lp, _):
        return shard_mod.act_seq(_mamba_block(lp, h, cfg, plan=plan), plan)

    seg_params = jax.tree.map(lambda x: x[: n_seg * k].reshape((n_seg, k) + x.shape[1:]),
                              params["layers"])

    def outer_body(h, seg_lp):
        h = _scan_layers(seg_lp, h, inner_body, k)
        h, _ = _attn_block(params["shared"], h, cfg, window=_BIG_WINDOW, plan=plan)
        return shard_mod.act_seq(h, plan), None

    h, _ = jax.lax.scan(outer_body, h, seg_params, length=n_seg)
    if rem:
        tail = jax.tree.map(lambda x: x[n_seg * k:], params["layers"])
        h = _scan_layers(tail, h, inner_body, rem)
    return h


def unembed(params: dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    emb = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", h, emb.astype(h.dtype))
    return softcap(logits, cfg.final_logit_softcap)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
                      enc_frames: jax.Array | None = None, params: dict | None = None):
    """Mutable-through-functional-update decode state (KV caches / SSM states).

    ``length`` counts the valid prefix. For encdec, the encoder output is
    computed once at prefill and carried in the state.
    """
    st: dict[str, Any] = {"length": jnp.zeros((), jnp.int32)}
    L = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        st["kv"] = attn_mod.init_kv_cache(cfg, batch, max_len, L, dtype,
                                          quantized=cfg.kv_quant_decode)
    elif cfg.family == "ssm":
        st["ssm"] = ssm_mod.init_ssm_state(cfg, batch, L)
    elif cfg.family == "hybrid":
        st["ssm"] = ssm_mod.init_ssm_state(cfg, batch, L)
        n_shared = L // cfg.shared_attn_every
        st["kv"] = attn_mod.init_kv_cache(cfg, batch, max_len, n_shared, dtype)
    elif cfg.family == "encdec":
        st["kv"] = attn_mod.init_kv_cache(cfg, batch, max_len, L, dtype)
        st["enc_out"] = jnp.zeros((batch, cfg.enc_positions, cfg.d_model), dtype)
    return st


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(partial(init_decode_state, cfg, batch, max_len, dtype))


def decode_step(params: dict, state: dict, batch: dict, cfg: ModelConfig, plan=None):
    """One token for the whole batch: (logits (B,V), new_state)."""
    dtype = jnp.dtype(cfg.dtype)
    tok = batch["token"]  # (B, 1)
    length = state["length"]
    h = embed_tokens(params, tok, cfg, dtype, plan=plan)
    if cfg.learned_positions:
        pos = jax.lax.dynamic_slice_in_dim(params["pos_embed"], length, 1, 0)  # (1, d)
        h = h + pos.astype(dtype)[None]

    new_state = dict(state)
    if cfg.family in ("dense", "moe", "vlm"):
        windows = jnp.asarray(layer_windows(cfg, cfg.n_layers))

        kv = state["kv"]
        quantized = kv.quantized

        # cache rides the CARRY (updated in place per layer) so XLA aliases
        # the donated buffers through the loop — the xs/ys form copies the
        # whole stacked cache instead (+10GB/device for deepseek decode_32k).
        def body(carry, xs):
            h, ck_all, cv_all, ks_all, vs_all = carry
            lp, win, i = xs
            lp = shard_mod.gather_params(lp, plan)
            ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
            ks = vs = None
            if quantized:
                ks = jax.lax.dynamic_index_in_dim(ks_all, i, 0, keepdims=False)
                vs = jax.lax.dynamic_index_in_dim(vs_all, i, 0, keepdims=False)
            a_in = norm_apply(lp["ln1"], h, cfg.norm)
            a, nk, nv, nks, nvs = attn_mod.attention_decode(
                lp["attn"], a_in, ck, cv, length, cfg, window=win,
                k_scale=ks, v_scale=vs)
            if cfg.use_post_norm:
                a = norm_apply(lp["ln1_post"], a, cfg.norm)
            h = h + a
            m_in = norm_apply(lp["ln2"], h, cfg.norm)
            if "moe" in lp:
                m, _ = moe_mod.moe_forward(lp["moe"], m_in, cfg, plan=plan)
            else:
                m = mlp_mod.mlp_forward(lp["mlp"], m_in, cfg)
            if cfg.use_post_norm:
                m = norm_apply(lp["ln2_post"], m, cfg.norm)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, nk, i, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, nv, i, 0)
            if quantized:
                ks_all = jax.lax.dynamic_update_index_in_dim(ks_all, nks, i, 0)
                vs_all = jax.lax.dynamic_update_index_in_dim(vs_all, nvs, i, 0)
            return (h + m, ck_all, cv_all, ks_all, vs_all), None

        (h, nk, nv, nks, nvs), _ = jax.lax.scan(
            body, (h, kv.k, kv.v, kv.k_scale, kv.v_scale),
            (params["layers"], windows, jnp.arange(cfg.n_layers, dtype=jnp.int32)))
        new_state["kv"] = attn_mod.KVCache(nk, nv, nks, nvs)

    elif cfg.family == "ssm":
        def body(h, xs):
            lp, ls = xs
            lp = shard_mod.gather_params(lp, plan)
            a_in = norm_apply(lp["ln1"], h, cfg.norm)
            out, ns = ssm_mod.ssd_decode_step(lp["ssm"], a_in, ls, cfg)
            return h + out, ns

        h, ns = jax.lax.scan(body, h, (params["layers"], state["ssm"]))
        new_state["ssm"] = ns

    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every
        L = cfg.n_layers
        ssm_states = state["ssm"]
        new_ssm = jax.tree.map(jnp.zeros_like, ssm_states)
        ck, cv = state["kv"].k, state["kv"].v
        nk, nv = [], []
        shared_i = 0
        done = 0
        while done < L:
            seg = min(k, L - done)
            for i in range(done, done + seg):
                lp = jax.tree.map(lambda x: x[i], params["layers"])
                ls = jax.tree.map(lambda x: x[i], ssm_states)
                a_in = norm_apply(lp["ln1"], h, cfg.norm)
                out, ns = ssm_mod.ssd_decode_step(lp["ssm"], a_in, ls, cfg)
                h = h + out
                new_ssm = jax.tree.map(lambda acc, v, i=i: acc.at[i].set(v), new_ssm, ns)
            done += seg
            if done < L or seg == k:
                lp = params["shared"]
                a_in = norm_apply(lp["ln1"], h, cfg.norm)
                a, k_new, v_new, _, _ = attn_mod.attention_decode(
                    lp["attn"], a_in, ck[shared_i], cv[shared_i], length, cfg, window=None)
                h = h + a
                m_in = norm_apply(lp["ln2"], h, cfg.norm)
                h = h + mlp_mod.mlp_forward(lp["mlp"], m_in, cfg)
                nk.append(k_new)
                nv.append(v_new)
                shared_i += 1
        new_state["ssm"] = new_ssm
        new_state["kv"] = attn_mod.KVCache(jnp.stack(nk), jnp.stack(nv))

    elif cfg.family == "encdec":
        enc = state["enc_out"].astype(dtype)

        def body(carry, xs):
            h, ck_all, cv_all = carry
            lp, i = xs
            lp = shard_mod.gather_params(lp, plan)
            ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
            a_in = norm_apply(lp["ln1"], h, cfg.norm)
            a, nk, nv, _, _ = attn_mod.attention_decode(lp["attn"], a_in, ck, cv, length, cfg)
            h = h + a
            x_in = norm_apply(lp["lnx"], h, cfg.norm)
            h = h + attn_mod.attention(lp["xattn"], x_in, cfg, kv_x=enc)
            m_in = norm_apply(lp["ln2"], h, cfg.norm)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, nk, i, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, nv, i, 0)
            return (h + mlp_mod.mlp_forward(lp["mlp"], m_in, cfg), ck_all, cv_all), None

        (h, nk, nv), _ = jax.lax.scan(
            body, (h, state["kv"].k, state["kv"].v),
            (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
        new_state["kv"] = attn_mod.KVCache(nk, nv)
    else:
        raise ValueError(cfg.family)

    h = norm_apply(params["final_norm"], h, cfg.norm)
    logits = unembed(params, h, cfg)[:, 0]
    new_state["length"] = length + 1
    return logits, new_state
