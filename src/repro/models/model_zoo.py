"""Model registry: config -> callable bundle."""

from __future__ import annotations

import dataclasses
from typing import Callable

from . import transformer
from .config import ModelConfig

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable
    param_specs: Callable
    forward: Callable          # (params, batch) -> (hidden, aux)
    unembed: Callable          # (params, hidden) -> logits
    decode_step: Callable      # (params, state, batch) -> (logits, state)
    init_decode_state: Callable
    decode_state_specs: Callable


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init_params=lambda rng: transformer.init_params(rng, cfg),
        param_specs=lambda: transformer.param_specs(cfg),
        forward=lambda params, batch, plan=None: transformer.forward(params, batch, cfg, plan=plan),
        unembed=lambda params, h: transformer.unembed(params, h, cfg),
        decode_step=lambda params, state, batch, plan=None: transformer.decode_step(params, state, batch, cfg, plan=plan),
        init_decode_state=lambda batch, max_len, **kw: transformer.init_decode_state(cfg, batch, max_len, **kw),
        decode_state_specs=lambda batch, max_len, **kw: transformer.decode_state_specs(cfg, batch, max_len, **kw),
    )
