"""Shared model primitives: norms, rotary embeddings, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["norm_init", "norm_apply", "rope", "apply_rope", "softcap", "dense_init"]


def dense_init(rng, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (kept fp32; cast at use)."""
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return std * jax.random.truncated_normal(rng, -2.0, 2.0, shape, dtype)


def norm_init(kind: str, d: int):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    if kind == "nonparametric":
        return {}
    raise ValueError(kind)


def norm_apply(p: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"]).astype(x.dtype)
    if kind in ("layernorm", "nonparametric"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            xf = xf * p["scale"] + p["bias"]
        return xf.astype(x.dtype)
    raise ValueError(kind)


def rope(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (..., S) int32 -> cos/sin of shape (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, head_dim); cos/sin: (..., S, half). Rotate-half form.

    §Perf iteration 1b: the rotation runs in x's dtype (cos/sin precomputed
    in f32 then cast) so no f32 copy of q/k is ever materialized — the f32
    intermediates were what GSPMD all-gathered at 2x cost."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)  # broadcast over head axis
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
