"""Top-k MoE with capacity-based, group-sharded dispatch (granite-moe).

The dispatch/combine is the paper's Combine-Shuffle-Reduce pattern
(DESIGN.md §5) rendered in GSPMD: tokens are *partitioned* by expert id
(router top-k ≙ key), laid into fixed quota buffers (≙ shuffle quota;
overflowing tokens drop exactly like over-quota shuffle rows), expert FFNs
run as one batched einsum (≙ local core operator), and results
scatter-combine back weighted by router probabilities (≙ reduce).

§Perf iteration 5 (group alignment): dispatch groups are (batch-row x
seq-chunk) blocks, where seq chunks match the TP sharding of the residual
stream — a pure dimension SPLIT that GSPMD supports natively. The earlier
flat (G, n, d) regrouping merged dp- and tp-sharded dims and triggered
"involuntary full rematerialization": six full-batch (19GB) all-gathers per
layer. Group-local state never leaves its device now.

Capacity keeps compiled FLOPs ≈ capacity_factor x active FLOPs, which is
what makes the MoE cells' roofline numbers honest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init
from .config import ModelConfig

__all__ = ["moe_init", "moe_forward", "expert_capacity"]


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)


def moe_init(rng, cfg: ModelConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)
    return {
        "router": dense_init(ks[0], (d, E)),
        "w_gate": dense_init(ks[1], (E, d, ff)),
        "w_up": dense_init(ks[2], (E, d, ff)),
        "w_down": dense_init(ks[3], (E, ff, d)),
    }


def _dispatch_one_group(xt, top_e, top_p, E: int, C: int):
    """xt: (n, d); top_e/top_p: (n, K). Returns (buf (E,C,d), slots...)."""
    n, d = xt.shape
    K = top_e.shape[1]
    flat_e = top_e.reshape(n * K)
    flat_tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), K)
    flat_w = top_p.reshape(n * K)
    order = jnp.argsort(flat_e, stable=True)
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
    group_start = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(n * K, dtype=jnp.int32) - group_start.astype(jnp.int32)
    keep = rank < C
    slot_e = jnp.where(keep, se, E)
    slot_r = jnp.where(keep, rank, C)
    buf = jnp.zeros((E, C, xt.shape[1]), xt.dtype).at[slot_e, slot_r].set(xt[stok], mode="drop")
    return buf, slot_e, slot_r, stok, sw * keep


def moe_forward(p: dict, x: jax.Array, cfg: ModelConfig, plan=None) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (out, aux_loss)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    dt = x.dtype

    # groups = (batch row, seq chunk); chunks align with the TP seq sharding
    if plan is not None and S % plan.axis_size(plan.tp) == 0:
        n_seq = plan.axis_size(plan.tp)
    else:
        n_seq = max(1, min(cfg.moe_groups, S))
        while S % n_seq:
            n_seq -= 1
    n = S // n_seq
    C = expert_capacity(cfg, n)

    def gcstr(t):
        if plan is None or B % plan.axis_size(plan.dp) or S % plan.axis_size(plan.tp):
            return t
        spec = [plan.dp, plan.tp] + [None] * (t.ndim - 2)
        return jax.lax.with_sharding_constraint(t, plan.ns(*spec))

    xt = gcstr(x.reshape(B, n_seq, n, d))

    logits = jnp.einsum("bgnd,de->bgne", xt, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                       # (B,n_seq,n,K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balance aux (Switch): E * sum_e f_e * p_e, over all tokens
    me = jnp.mean(probs, axis=(0, 1, 2))
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1, 2))
    aux = E * jnp.sum(me * ce)

    disp = jax.vmap(jax.vmap(lambda xg, eg, pg: _dispatch_one_group(xg, eg, pg, E, C)))
    buf, slot_e, slot_r, stok, w = disp(xt, top_e, top_p)
    buf = gcstr(buf)                                              # (B,n_seq,E,C,d)

    g = jnp.einsum("bgecd,edf->bgecf", buf, p["w_gate"].astype(dt))
    u = jnp.einsum("bgecd,edf->bgecf", buf, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("bgecf,efd->bgecd", h, p["w_down"].astype(dt))
    eo = gcstr(eo)

    def combine(eo_g, slot_e_g, slot_r_g, stok_g, w_g):
        contrib = eo_g[slot_e_g.clip(0, E - 1), slot_r_g.clip(0, C - 1)]
        contrib = contrib * w_g.astype(dt)[:, None]
        return jnp.zeros((n, d), dt).at[stok_g].add(contrib)

    out = jax.vmap(jax.vmap(combine))(eo, slot_e, slot_r, stok, w)
    out = gcstr(out)
    return out.reshape(B, S, d), aux
