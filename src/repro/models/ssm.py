"""Mamba2 (state-space duality, arXiv:2405.21060) in chunked-scan form.

Training path is the SSD block-decomposition: quadratic attention-like
compute inside chunks of length Q, linear recurrence across chunks
(jax.lax.scan). This is the TPU-native adaptation — the chunk matmuls are
MXU-shaped (Q x Q and Q x d_state), while the cross-chunk recurrence is a
tiny scan — mirroring how the paper's patterns map local compute + a thin
communication/carry structure.

Decode path is the classic selective-SSM recurrence on a (B, H, dh, ds)
state — O(1) per token, no KV cache (why mamba2/zamba2 run the long_500k
shape).

``repro.kernels.ssd_scan`` provides the Pallas kernel for the intra-chunk
part; this module is the XLA reference path used by the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, norm_apply
from .config import ModelConfig

__all__ = ["ssm_init", "ssd_forward", "ssd_decode_step", "init_ssm_state"]


def ssm_init(rng, cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, s, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.ssm_conv_width
    ks = jax.random.split(rng, 8)
    return {
        "w_x": dense_init(ks[0], (d, di)),
        "w_z": dense_init(ks[1], (d, di)),
        "w_b": dense_init(ks[2], (d, g * s)),
        "w_c": dense_init(ks[3], (d, g * s)),
        "w_dt": dense_init(ks[4], (d, h)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "conv_x": dense_init(ks[5], (cw, di)),
        "conv_b": dense_init(ks[6], (cw, g * s)),
        "conv_c": dense_init(ks[7], (cw, g * s)),
        "norm": {"scale": jnp.ones((di,), jnp.float32)},
        "w_out": dense_init(jax.random.fold_in(rng, 99), (di, d)),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is 4 — unrolled taps beat conv_general for tiny K
        out = out + pad[:, i: i + x.shape[1], :] * w[i]
    return out


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise sums: out[..., i, j] = sum_{j<k<=i} a[..., k].

    Standard SSD helper; -inf above the diagonal.
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan_ref(x, dt, A, B, C, chunk: int, init_state=None):
    """SSD chunked algorithm (Mamba2 paper listing 1, jnp).

    x:  (b, l, h, dh)   inputs (already conv'd/activated)
    dt: (b, l, h)       positive step sizes
    A:  (h,)            negative decay rates
    B:  (b, l, g, ds)   input projections (g groups broadcast over h)
    C:  (b, l, g, ds)   output projections
    Returns (y (b,l,h,dh), final_state (b,h,dh,ds)).
    """
    b, l, h, dh = x.shape
    g, ds = B.shape[2], B.shape[3]
    nc = l // chunk
    rep = h // g

    xb = x * dt[..., None]                       # discretized input
    a = A[None, None, :] * dt                    # (b,l,h) log-decay per step
    # chunked views
    xc = xb.reshape(b, nc, chunk, h, dh)
    ac = a.reshape(b, nc, chunk, h)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, ds), rep, axis=3)   # (b,nc,q,h,ds)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, ds), rep, axis=3)

    ac_t = ac.transpose(0, 1, 3, 2)              # (b,nc,h,q)
    L = jnp.exp(_segsum(ac_t))                   # (b,nc,h,q,q)
    # intra-chunk (diagonal blocks)
    scores = jnp.einsum("bnqhs,bnths->bnhqt", Cc, Bc)
    y_diag = jnp.einsum("bnhqt,bnhqt,bnthp->bnqhp", scores, L, xc)

    # per-chunk final-state contribution
    acum = jnp.cumsum(ac_t, axis=-1)             # (b,nc,h,q)
    decay_states = jnp.exp(acum[..., -1:] - acum)  # (b,nc,h,q)
    states = jnp.einsum("bnqhs,bnhq,bnqhp->bnhps", Bc, decay_states, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(acum[..., -1])         # (b,nc,h)
    s0 = jnp.zeros((b, h, dh, ds), x.dtype) if init_state is None else init_state

    def step(carry, inp):
        st_in = carry
        dec, s_new = inp
        st_out = st_in * dec[:, :, None, None] + s_new
        return st_out, st_in  # emit state *entering* the chunk

    final, prev_states = jax.lax.scan(
        step,
        s0,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,dh,ds)

    # inter-chunk output (low-rank off-diagonal blocks)
    state_decay = jnp.exp(acum)                   # (b,nc,h,q)
    y_off = jnp.einsum("bnqhs,bnhps,bnhq->bnqhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, dh)
    return y, final


def ssd_forward(p: dict, x: jax.Array, cfg: ModelConfig, init_state=None, plan=None):
    """Full Mamba2 mixer block: proj -> conv -> SSD -> gated norm -> out.

    x: (B,S,d) -> (B,S,d); also returns the final SSM state.

    §Perf iteration 2 (head-parallel SSD): the chunked scan iterates the
    chunk axis, so that axis must NOT be sharded (a sharded scan axis makes
    GSPMD all-gather every per-chunk tensor: 3 x 17GB per layer for
    mamba2-1.3b prefill_32k). Instead the SSM *heads* shard over TP — every
    SSD einsum is per-head independent — and the only cross-shard movement
    is one seq->head reshard (all-to-all) per layer."""
    B_, S, d = x.shape
    h, dh, g, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    dt_ = x.dtype

    def _head_sharded(t, *, head_axis):
        if plan is None or h % plan.axis_size(plan.tp) or B_ % max(plan.axis_size(plan.dp), 1):
            return t
        import jax as _jax
        spec = [None] * t.ndim
        spec[0] = plan.dp
        spec[head_axis] = plan.tp
        return _jax.lax.with_sharding_constraint(t, plan.ns(*spec))

    xs = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(dt_))
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(dt_))
    Bp = jnp.einsum("bsd,de->bse", x, p["w_b"].astype(dt_))
    Cp = jnp.einsum("bsd,de->bse", x, p["w_c"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt_))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    # d_inner = h*dh: head-shard before the conv/scan region
    xs = _head_sharded(xs.reshape(B_, S, h, dh), head_axis=2).reshape(B_, S, cfg.d_inner)
    dt = _head_sharded(dt, head_axis=2)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x"].astype(dt_)))
    Bp = jax.nn.silu(_causal_conv(Bp, p["conv_b"].astype(dt_)))
    Cp = jax.nn.silu(_causal_conv(Cp, p["conv_c"].astype(dt_)))

    A = -jnp.exp(p["A_log"])  # (h,) negative
    # pad sequence to a chunk multiple; padded steps have dt=0 (decay=1,
    # zero input) so they are identity on the carried state
    Sp = -(-S // cfg.ssm_chunk) * cfg.ssm_chunk
    pad = Sp - S
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xs_p, dt_p, B_p, C_p = (zpad(xs), zpad(dt), zpad(Bp), zpad(Cp))
    else:
        xs_p, dt_p, B_p, C_p = xs, dt, Bp, Cp
    y, state = ssd_scan_ref(
        xs_p.reshape(B_, Sp, h, dh).astype(jnp.float32),
        dt_p,
        A,
        B_p.reshape(B_, Sp, g, ds).astype(jnp.float32),
        C_p.reshape(B_, Sp, g, ds).astype(jnp.float32),
        cfg.ssm_chunk,
        init_state,
    )
    y = y[:, :S]
    y = y + xs.reshape(B_, S, h, dh).astype(jnp.float32) * p["D"][None, None, :, None]
    y = _head_sharded(y, head_axis=2)
    y = y.reshape(B_, S, cfg.d_inner).astype(dt_)
    y = y * jax.nn.silu(z)
    y = norm_apply(p["norm"], y, "rmsnorm")
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_)), state


def init_ssm_state(cfg: ModelConfig, batch: int, n_layers: int, dtype=jnp.float32):
    return {
        "state": jnp.zeros((n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        "conv_x": jnp.zeros((n_layers, batch, cfg.ssm_conv_width - 1, cfg.d_inner), dtype),
        "conv_b": jnp.zeros((n_layers, batch, cfg.ssm_conv_width - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
        "conv_c": jnp.zeros((n_layers, batch, cfg.ssm_conv_width - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
    }


def ssd_decode_step(p: dict, x: jax.Array, layer_state: dict, cfg: ModelConfig):
    """One-token recurrent step. x: (B,1,d). layer_state: {state (B,h,dh,ds),
    conv_x/b/c rolling buffers (B, K-1, C)}. Returns (out (B,1,d), new state)."""
    B_, _, d = x.shape
    h, dh, g, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    dt_ = x.dtype
    xt = x[:, 0]

    xs = xt @ p["w_x"].astype(dt_)
    z = xt @ p["w_z"].astype(dt_)
    Bp = xt @ p["w_b"].astype(dt_)
    Cp = xt @ p["w_c"].astype(dt_)
    dt = jax.nn.softplus((xt @ p["w_dt"].astype(dt_)).astype(jnp.float32) + p["dt_bias"])  # (B,h)

    def conv_step(buf, new, w):
        # buf: (B, K-1, C), new: (B, C), w: (K, C)
        seq = jnp.concatenate([buf, new[:, None, :]], axis=1)  # (B,K,C)
        out = jnp.einsum("bkc,kc->bc", seq.astype(jnp.float32), w.astype(jnp.float32))
        return jax.nn.silu(out).astype(dt_), seq[:, 1:]

    xs, new_cx = conv_step(layer_state["conv_x"], xs, p["conv_x"])
    Bp, new_cb = conv_step(layer_state["conv_b"], Bp, p["conv_b"])
    Cp, new_cc = conv_step(layer_state["conv_c"], Cp, p["conv_c"])

    A = -jnp.exp(p["A_log"])                       # (h,)
    xh = xs.reshape(B_, h, dh).astype(jnp.float32)
    Bh = jnp.repeat(Bp.reshape(B_, g, ds), h // g, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cp.reshape(B_, g, ds), h // g, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * A[None, :])               # (B,h)
    st = layer_state["state"].astype(jnp.float32)
    st = st * decay[:, :, None, None] + jnp.einsum(
        "bh,bhs,bhp->bhps", dt, Bh, xh)
    y = jnp.einsum("bhs,bhps->bhp", Ch, st)        # (B,h,dh)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B_, cfg.d_inner).astype(dt_)
    y = y * jax.nn.silu(z)
    y = norm_apply(p["norm"], y, "rmsnorm")
    out = (y @ p["w_out"].astype(dt_))[:, None, :]
    new_state = {"state": st.astype(layer_state["state"].dtype),
                 "conv_x": new_cx, "conv_b": new_cb, "conv_c": new_cc}
    return out, new_state
