from .config import ModelConfig  # noqa: F401
from .model_zoo import build_model  # noqa: F401
