"""Dense MLP variants (SwiGLU / GeGLU / GELU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init
from .config import ModelConfig

__all__ = ["mlp_init", "mlp_forward"]


def mlp_init(rng, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d, ff)),
            "w_up": dense_init(ks[1], (d, ff)),
            "w_down": dense_init(ks[2], (ff, d)),
        }
    return {"w_up": dense_init(ks[0], (d, ff)), "w_down": dense_init(ks[1], (ff, d))}


def mlp_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if cfg.mlp in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        act = jax.nn.silu(g) if cfg.mlp == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = act * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jax.nn.gelu(u, approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
