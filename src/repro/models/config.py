"""Unified model configuration for the assigned architecture pool."""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0                 # 0 for attention-free
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000

    # block flavour
    norm: str = "rmsnorm"            # rmsnorm | layernorm | nonparametric
    mlp: str = "swiglu"              # swiglu | geglu | gelu
    use_post_norm: bool = False      # gemma2 sandwich norms
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    scale_embeddings: bool = False   # gemma2: h *= sqrt(d)
    query_scale: float | None = None # gemma2 query_pre_attn_scalar

    # attention variants
    sliding_window: int | None = None          # SWA width (mistral/llava)
    local_global_pattern: bool = False         # gemma2 alternating local/global
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1   # dispatch groups (launcher sets to #mesh shards)
    kv_quant_decode: bool = False  # int8 KV cache at decode (serving)

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_groups: int = 1

    # hybrid (zamba2): one shared attention block invoked every k layers
    shared_attn_every: int = 0

    # encoder-decoder (whisper): encoder layers + precomputed-frame length
    n_enc_layers: int = 0
    enc_positions: int = 1500

    # VLM (llava): prefix patch embeddings (anyres stub)
    n_patches: int = 0

    tie_embeddings: bool = True
    max_seq: int = 8192               # learned-position table size if used
    learned_positions: bool = False   # whisper
    dtype: str = "bfloat16"

    # attention-free?
    @property
    def is_ssm_only(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md §5): SSM/hybrid, SWA, local+global."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None
                or self.local_global_pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (whisper is enc-dec)

    def num_params(self) -> int:
        """Analytic parameter count (for 6ND model FLOPs)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        n = 0
        n += V * d  # embedding
        if not self.tie_embeddings:
            n += V * d
        per_layer = 0
        if self.family == "ssm":
            per_layer = self._ssm_layer_params()
        elif self.family == "hybrid":
            per_layer = self._ssm_layer_params()
        else:
            per_layer = self._attn_params() + self._mlp_params()
        n += self.n_layers * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            n += self._attn_params() + self._mlp_params()  # one shared block
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            n += self.n_enc_layers * (self._attn_params() + self._mlp_params())
            n += self.n_layers * self._attn_params()  # cross-attn in decoder
        return n

    def _attn_params(self) -> int:
        d, H, KV, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        return d * H * hd + 2 * d * KV * hd + H * hd * d

    def _mlp_params(self) -> int:
        d, ff = self.d_model, self.d_ff
        if self.family == "moe":
            return self.n_experts * 3 * d * ff + d * self.n_experts
        if self.mlp in ("swiglu", "geglu"):
            return 3 * d * ff
        return 2 * d * ff

    def _ssm_layer_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g, s, h = self.ssm_groups, self.ssm_state, self.ssm_heads
        return 2 * d * di + 2 * d * g * s + d * h + di * d + 4 * di

    def num_active_params(self) -> int:
        """Active per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.num_params()
        d, ff = self.d_model, self.d_ff
        dense = self.num_params() - self.n_layers * self.n_experts * 3 * d * ff
        return dense + self.n_layers * self.top_k * 3 * d * ff
