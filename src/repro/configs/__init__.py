"""Assigned architecture configs (+ the paper's own dataframe workload).

Each module exposes ``CONFIG`` (full-size, exercised only via the dry-run)
and ``smoke_config()`` (reduced same-family config for CPU smoke tests).
``get_config(name)`` / ``ARCHS`` are the registry.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "llava_next_mistral_7b",
    "zamba2_1p2b",
    "whisper_tiny",
    "mamba2_1p3b",
    "gemma2_9b",
    "stablelm_3b",
    "deepseek_67b",
    "olmo_1b",
    "granite_moe_3b",
    "granite_moe_1b",
]

_ALIASES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-1.2b": "zamba2_1p2b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-1.3b": "mamba2_1p3b",
    "gemma2-9b": "gemma2_9b",
    "stablelm-3b": "stablelm_3b",
    "deepseek-67b": "deepseek_67b",
    "olmo-1b": "olmo_1b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "granite-moe-1b-a400m": "granite_moe_1b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke_config()
