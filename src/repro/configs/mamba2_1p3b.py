"""mamba2-1.3b [ssm] — pure SSD stack, attention-free.

48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    norm="rmsnorm",
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
)


def smoke_config():
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=3, d_model=64, vocab_size=256,
        norm="rmsnorm",
        ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
    )
