"""deepseek-67b [dense] — llama-arch at depth.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400. [arXiv:2401.02954]
Most collective-bound assigned config (TP at d=8192, 95 layers).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400,
    norm="rmsnorm", mlp="swiglu", rope_theta=1e4,
    tie_embeddings=False,
)


def smoke_config():
    return ModelConfig(
        name="deepseek-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=256,
        norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
    )
