"""olmo-1b [dense] — non-parametric LayerNorm.

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304. [arXiv:2402.00838; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab_size=50304,
    norm="nonparametric", mlp="swiglu",
    tie_embeddings=True,
)


def smoke_config():
    return ModelConfig(
        name="olmo-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        norm="nonparametric", mlp="swiglu",
    )
