"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
[arXiv:2411.15242; hf]
Simplifications vs HF zamba2 (DESIGN.md §5): per-invocation LoRA on the
shared block omitted; shared block is a plain pre-norm attn+MLP reused every
6 layers.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    norm="rmsnorm", mlp="gelu",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    shared_attn_every=6,
)


def smoke_config():
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        norm="rmsnorm", mlp="gelu",
        ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
        shared_attn_every=2,
    )
