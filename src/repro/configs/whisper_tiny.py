"""whisper-tiny [audio] — enc-dec; conv frontend STUB (precomputed frames).

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865. [arXiv:2212.04356]
decode_32k exceeds whisper's trained 448 positions — lowered as a dry-run
shape exercise only (DESIGN.md §5). long_500k skipped (full attention).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    head_dim=64, d_ff=1536, vocab_size=51865,
    norm="layernorm", mlp="gelu", qkv_bias=True,
    learned_positions=True, max_seq=32768 + 8, enc_positions=1500,
    tie_embeddings=True,
)


def smoke_config():
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256,
        norm="layernorm", mlp="gelu", qkv_bias=True,
        learned_positions=True, max_seq=64, enc_positions=16,
    )
