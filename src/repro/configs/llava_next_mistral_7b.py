"""llava-next-mistral-7b [vlm] — Mistral-7B backbone + anyres patch prefix.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, sliding window 4096.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
The vision tower/anyres tiling is a STUB: input_specs supply precomputed
patch embeddings (B, n_patches, d_model); a linear adapter stands in for the
projector (DESIGN.md §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    norm="rmsnorm", mlp="swiglu", rope_theta=1e6,
    sliding_window=4096,
    n_patches=576,
    tie_embeddings=False,
)


def smoke_config():
    return ModelConfig(
        name="llava-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        norm="rmsnorm", mlp="swiglu", sliding_window=8,
        n_patches=4, tie_embeddings=False,
    )
