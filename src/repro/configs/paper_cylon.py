"""The paper's own workload config: two-int64-column uniform tables at 90%
cardinality (paper §6), driving the DDF operator benchmarks."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class CylonWorkload:
    rows_per_worker: int = 25_000_000   # paper weak-scaling: 25M/worker
    n_columns: int = 2
    dtype: str = "int64"                # int32 under default jax x64=off
    cardinality: float = 0.9            # worst case for key ops (paper §6)
    key_column: str = "c0"


CONFIG = CylonWorkload()


def smoke_config():
    return CylonWorkload(rows_per_worker=2000)
