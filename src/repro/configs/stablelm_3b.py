"""stablelm-3b [dense] — MHA, LayerNorm, SwiGLU.

32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304.
[hf:stabilityai/stablelm-2-1_6b family; unverified — full-rotary variant]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab_size=50304,
    norm="layernorm", mlp="swiglu",
    tie_embeddings=False,
)


def smoke_config():
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        norm="layernorm", mlp="swiglu", tie_embeddings=False,
    )
