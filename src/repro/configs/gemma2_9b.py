"""gemma2-9b [dense] — local/global alternating attention, logit softcaps.

42L d_model=3584 16H (GQA kv=8, head_dim 256) d_ff=14336 vocab=256000.
[arXiv:2408.00118; hf] Sandwich norms (pre+post), embedding scaling,
query_pre_attn_scalar=256, attn softcap 50, final softcap 30, SWA 4096 on
even layers. long_500k runs: local layers are SWA; global layers cost O(L)
per decoded token (DESIGN.md §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    norm="rmsnorm", mlp="geglu", use_post_norm=True,
    scale_embeddings=True, query_scale=256.0,
    sliding_window=4096, local_global_pattern=True,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    tie_embeddings=True,
)


def smoke_config():
    return ModelConfig(
        name="gemma2-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        norm="rmsnorm", mlp="geglu", use_post_norm=True,
        scale_embeddings=True, query_scale=16.0,
        sliding_window=8, local_global_pattern=True,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
    )
