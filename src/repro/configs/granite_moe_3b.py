"""granite-moe-3b-a800m [moe] — 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155.
[hf:ibm-granite/granite-3.0-*-base family]
MoE dispatch/combine maps onto the paper's Combine-Shuffle-Reduce pattern
(DESIGN.md §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    norm="rmsnorm", mlp="swiglu",
    n_experts=40, top_k=8, capacity_factor=1.25,
    tie_embeddings=True,
)


def smoke_config():
    return ModelConfig(
        name="granite3b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256,
        norm="rmsnorm", mlp="swiglu",
        n_experts=8, top_k=2, capacity_factor=1.5,
    )
