"""granite-moe-1b-a400m [moe] — 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    norm="rmsnorm", mlp="swiglu",
    n_experts=32, top_k=8, capacity_factor=1.25,
    tie_embeddings=True,
)


def smoke_config():
    return ModelConfig(
        name="granite1b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256,
        norm="rmsnorm", mlp="swiglu",
        n_experts=4, top_k=2, capacity_factor=1.5,
    )
