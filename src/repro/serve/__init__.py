from .serve_step import make_serve_step, make_prefill  # noqa: F401
from .engine import ServeEngine  # noqa: F401
