"""Serving front door: batched LM inference + concurrent dataframe queries.

Two long-lived entry points live here:

- :class:`ServeEngine` — batched greedy decoding for the model zoo
  (token-at-a-time prefill, static batch, per-lane correctness for
  uneven prompt lengths);
- :class:`QueryService` (re-exported from ``repro.service``) — the
  concurrent dataframe query service: many lazy/streaming queries
  multiplexed over one shared mesh at morsel granularity, with admission
  control, fair scheduling and shared compiled-program caches. See
  docs/SERVICE.md.

Both follow the same shape: construct once, submit many requests, read
telemetry, shut down cleanly — the serving layer the ROADMAP's
"millions of users" direction builds on.
"""

from .serve_step import make_serve_step, make_prefill  # noqa: F401
from .engine import ServeEngine  # noqa: F401
from ..service import QueryService  # noqa: F401

__all__ = ["ServeEngine", "QueryService", "make_serve_step", "make_prefill"]
