"""Minimal batched serving engine: static batch, greedy decode, request
queue. Demonstrates the serving path end-to-end on CPU for the examples; the
dry-run exercises the production-mesh sharding of the same serve_step."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model_zoo import Model
from .serve_step import make_serve_step

__all__ = ["ServeEngine"]


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: dict
    max_len: int = 256

    def __post_init__(self):
        self._step = jax.jit(make_serve_step(self.model))
        self._decode_one = jax.jit(self.model.decode_step)

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32) -> list[list[int]]:
        """Greedy-decode a batch of token prompts (token-at-a-time prefill —
        uniform across families)."""
        B = len(prompts)
        cfg = self.model.cfg
        state = self.model.init_decode_state(B, self.max_len)
        if cfg.family == "encdec":
            state["enc_out"] = jnp.zeros((B, cfg.enc_positions, cfg.d_model), jnp.bfloat16)

        maxp = max(len(p) for p in prompts)
        toks = np.zeros((B, maxp), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p

        # prefill token-at-a-time (correct for every family incl. hybrid)
        last = None
        for t in range(maxp):
            logits, state = self._decode_one(self.params, state, {"token": jnp.asarray(toks[:, t: t + 1])})
            last = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        outs = [list(p) for p in prompts]
        cur = last
        for _ in range(max_new):
            for i in range(B):
                outs[i].append(int(cur[i]))
            cur, state = self._step(self.params, state, {"token": cur[:, None]})
        return outs
