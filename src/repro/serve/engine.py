"""Minimal batched serving engine: static batch, greedy decode, request
queue. Demonstrates the serving path end-to-end on CPU for the examples; the
dry-run exercises the production-mesh sharding of the same serve_step."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model_zoo import Model
from .serve_step import make_serve_step

__all__ = ["ServeEngine"]


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: dict
    max_len: int = 256

    def __post_init__(self):
        self._step = jax.jit(make_serve_step(self.model))

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32) -> list[list[int]]:
        """Greedy-decode a batch of token prompts (token-at-a-time prefill —
        uniform across families).

        Prompts may have different lengths: each lane feeds its own next
        token every step — a real prompt token while that lane is still
        prefilling, its previously generated token afterwards — so a lane's
        first generated token comes from the logits at its *own* last
        prompt token, never from another lane's padding, and every lane's
        output is bit-identical to a solo run of that prompt.
        """
        B = len(prompts)
        if any(len(p) == 0 for p in prompts):
            raise ValueError("every prompt must contain at least one token")
        cfg = self.model.cfg
        state = self.model.init_decode_state(B, self.max_len)
        if cfg.family == "encdec":
            state["enc_out"] = jnp.zeros((B, cfg.enc_positions, cfg.d_model), jnp.bfloat16)

        lens = [len(p) for p in prompts]
        maxp = max(lens)
        toks = np.zeros((B, maxp), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p

        outs = [list(p) for p in prompts]
        ngen = [0] * B
        feed = toks[:, 0].copy()
        # one unified loop covers prefill and generation: after the step
        # that consumed lane i's token at position t, the model's argmax is
        # lane i's token for position t+1 — a later prompt token (ignored,
        # the real one is fed) or a generated one (recorded and fed back)
        for t in range(maxp + max_new - 1):
            nxt, state = self._step(self.params, state, {"token": jnp.asarray(feed[:, None])})
            nxt = np.asarray(nxt).reshape(B)
            for i in range(B):
                if t + 1 < lens[i]:
                    feed[i] = toks[i, t + 1]
                else:
                    if ngen[i] < max_new:
                        outs[i].append(int(nxt[i]))
                        ngen[i] += 1
                    feed[i] = nxt[i]
        return outs
