"""Serving steps: prefill (full-sequence forward, cache build) and decode
(one token for the whole batch).

``serve_step`` is what the decode_* / long_* dry-run shapes lower: one new
token against a KV cache (or SSM state) of ``seq_len`` (task spec). Sampling
is greedy argmax — the batching/queueing logic lives in engine.py.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models.model_zoo import Model

__all__ = ["make_serve_step", "make_prefill"]


def make_serve_step(model: Model, plan=None) -> Callable:
    """serve_step(params, state, batch{token (B,1)}) -> (next_token (B,), state)."""

    def serve_step(params, state, batch):
        logits, state = model.decode_step(params, state, batch, plan=plan)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, state

    return serve_step


def make_prefill(model: Model, plan=None) -> Callable:
    """prefill(params, state, batch{tokens (B,S)}) -> (next_token, state).

    Builds the cache by running the train-forward then bulk-writing K/V —
    for attention models this reuses the full-sequence path (one pass), for
    SSM models it runs the chunked scan and keeps the final state.
    """
    cfg = model.cfg

    def prefill(params, state, batch):
        # NOTE: bulk cache construction is family-specific; the engine uses
        # token-at-a-time prefill for hybrid archs (correct if slower).
        hidden, _ = model.forward(params, batch, plan=plan)
        logits = model.unembed(params, hidden)[:, -1]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        state = dict(state)
        state["length"] = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
        return nxt, state

    return prefill
