import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run (task spec deliverable (e)).

For every (architecture x input shape x mesh) cell:
  jax.jit(step, in_shardings=..., out_shardings=...)
      .lower(**input_specs).compile()
and record memory_analysis() + cost_analysis() + the collective-byte
census parsed from the compiled HLO (feeding EXPERIMENTS.md §Dry-run and
§Roofline). Params/caches enter as ShapeDtypeStructs — nothing is allocated.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  (writes JSON per cell under experiments/dryrun/)
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, canonical
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_applicable, input_specs
from repro.launch.roofline import collective_bytes_from_hlo, roofline_terms
from repro.launch import hlo_cost
from repro.models.model_zoo import build_model
from repro import sharding as shard_mod
from repro.train.optimizer import adamw_init
from repro.train.train_step import TrainHParams, make_train_step
from repro.serve.serve_step import make_serve_step, make_prefill

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")
CACHE_PAD = 512  # decode cache length padding (divisibility over seq shards)

# Per-arch gradient-accumulation microbatches for train_4k (production
# memory configuration: live remat carries scale with B/microbatches).
# §Perf iteration 7: FSDP weight-gather traffic scales with microbatch
# count (gathers per layer per pass per microbatch). These are the minimum
# counts that keep every cell under 16GB/device (gemma2 at mb=1 hits 17.5GB).
MICROBATCHES = {
    "deepseek-67b": 2,
    "gemma2-9b": 2,
    "llava-next-mistral-7b": 1,
    "zamba2-1.2b": 1,
    "stablelm-3b": 1,
    "mamba2-1.3b": 1,
    "granite-moe-3b-a800m": 1,
    "granite-moe-1b-a400m": 1,
    "olmo-1b": 1,
    "whisper-tiny": 1,
}


def _shape_tree(tree):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def _bf16_params(specs):
    """Serving keeps bf16 weights (production inference memory layout)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        specs)


def build_cell(arch: str, shape: str, multi_pod: bool, overrides: dict | None = None):
    """Returns (jitted_fn, abstract_args, mesh) for one dry-run cell."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    nshards = int(np.prod(list(mesh.shape.values())))
    plan = shard_mod.make_plan(mesh, mode="serve" if cell.kind == "decode" else "train")
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_groups=nshards)
    if cell.kind == "decode" and cfg.family in ("dense", "moe", "vlm"):
        # production serving default: int8 KV (2x cache memory/bandwidth);
        # accuracy validated in tests/test_serve.py
        cfg = dataclasses.replace(cfg, kv_quant_decode=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    batch_sh = shard_mod.batch_shardings(specs, plan)

    if cell.kind == "train":
        pspecs = model.param_specs()
        state_specs = {"params": pspecs, "opt": jax.eval_shape(adamw_init, pspecs)}
        state_sh = {
            "params": shard_mod.param_shardings(pspecs, plan),
            "opt": {"mu": shard_mod.param_shardings(state_specs["opt"]["mu"], plan),
                    "nu": shard_mod.param_shardings(state_specs["opt"]["nu"], plan),
                    "step": plan.ns()},
        }
        hp = TrainHParams(microbatches=MICROBATCHES.get(cfg.name, 1))
        step = make_train_step(model, hp, plan=plan)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
        args = (state_specs, specs)
    elif cell.kind == "prefill":
        pspecs = _bf16_params(model.param_specs())
        psh = shard_mod.param_shardings(pspecs, plan)
        prefill = make_prefill(model, plan=plan)
        # prefill consumes tokens + builds a fresh state of cell length
        st_specs = model.decode_state_specs(cell.global_batch, cell.seq_len + CACHE_PAD)
        st_sh = shard_mod.decode_state_shardings(st_specs, plan, long_context=False)
        fn = jax.jit(prefill, in_shardings=(psh, st_sh, batch_sh),
                     out_shardings=None, donate_argnums=(1,))
        args = (pspecs, st_specs, specs)
    else:  # decode
        pspecs = _bf16_params(model.param_specs())
        psh = shard_mod.param_shardings(pspecs, plan)
        long_ctx = cell.global_batch == 1
        st_specs = model.decode_state_specs(cell.global_batch, cell.seq_len + CACHE_PAD)
        st_sh = shard_mod.decode_state_shardings(st_specs, plan, long_context=long_ctx)
        step = make_serve_step(model, plan=plan)
        fn = jax.jit(step, in_shardings=(psh, st_sh, batch_sh),
                     out_shardings=(None, st_sh), donate_argnums=(1,))
        args = (pspecs, st_specs, specs)

    return fn, args, mesh, cfg


def run_cell(arch: str, shape: str, multi_pod: bool, save: bool = True,
             verbose: bool = True, overrides: dict | None = None,
             tag: str = "") -> dict:
    cfg0 = get_config(arch)
    ok, reason = cell_applicable(cfg0, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": cfg0.name, "shape": shape, "mesh": mesh_name, "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=reason)
        if verbose:
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: SKIP ({reason})")
        if save:
            _save(rec)
        return rec

    t0 = time.time()
    try:
        fn, args, mesh, cfg = build_cell(arch, shape, multi_pod, overrides)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # older jax: one dict per computation
                cost = cost[0] if cost else None
            hlo = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo)  # loop-unscaled (reference)
            walked = hlo_cost.analyze(hlo)         # trip-count-scaled

        nchips = int(np.prod(list(mesh.shape.values())))
        mem_dict = {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
                                + getattr(mem, "argument_size_in_bytes", 0)
                                + getattr(mem, "output_size_in_bytes", 0)
                                - getattr(mem, "alias_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        }
        xla_flops = float(cost.get("flops", 0.0)) if cost else 0.0
        flops = walked.flops
        bytes_accessed = walked.bytes
        coll_scaled = {"per_op": walked.collective_counts,
                       "total_bytes": walked.collective_bytes_tpu,
                       "total_bytes_raw_cpu": walked.collective_bytes,
                       "total_count": sum(v["count"] for v in walked.collective_counts.values())}
        roof = roofline_terms(cfg, SHAPES[shape], flops=flops,
                              bytes_accessed=bytes_accessed,
                              collective=coll_scaled, n_chips=nchips)
        rec.update(
            status="ok",
            n_devices=nchips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem_dict,
            flops=flops,
            xla_cost_analysis_flops=xla_flops,
            bytes_accessed=bytes_accessed,
            collectives=coll_scaled,
            collectives_unscaled=coll,
            roofline=roof,
        )
        if verbose:
            hbm = mem_dict["bytes_per_device"] / 1e9
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: OK  "
                  f"mem/dev={hbm:.2f}GB  flops={flops:.3e}  "
                  f"coll={coll['total_bytes']:.3e}B  "
                  f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
    except Exception as e:  # noqa: BLE001 — report per-cell failures
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: FAIL {type(e).__name__}: {e}")
    if save:
        _save(rec)
    return rec


def _save(rec: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    name = f"{canonical(rec['arch'])}__{rec['shape']}__{rec['mesh'].replace('x','_')}{tag}.json"
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    args = ap.parse_args()

    if args.all:
        fails = 0
        for arch in ARCHS:
            for shape in SHAPES:
                meshes = [False, True]
                if args.single_pod_only:
                    meshes = [False]
                if args.multi_pod_only:
                    meshes = [True]
                for mp in meshes:
                    rec = run_cell(arch, shape, mp)
                    fails += rec["status"] == "error"
        sys.exit(1 if fails else 0)

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    sys.exit(1 if rec["status"] == "error" else 0)


if __name__ == "__main__":
    main()
