"""Production mesh construction (task spec: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod); 2x16x16 = 512 across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(axes: tuple[str, ...] = ("data",)):
    """All locally visible devices on the given axes (CPU tests/benches)."""
    n = len(jax.devices())
    if len(axes) == 1:
        return jax.make_mesh((n,), axes)
    assert len(axes) == 2
    import math
    a = int(math.sqrt(n))
    while n % a:
        a -= 1
    return jax.make_mesh((a, n // a), axes)
