import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Dry-run of the paper's flagship workload: distributed hash-shuffle JOIN
of two uniformly-random two-int32-column tables (paper §6: 90% cardinality,
25M rows/worker weak-scaling point) on the production mesh, all mesh axes
carrying row partitions (P=256 single-pod / P=512 two-pod).

Records the same roofline terms as the LM cells PLUS the Hockney cost-model
prediction for the shuffle stage — the at-scale validation of the paper's
§5 model against the compiled collective bytes.

Usage: python -m repro.launch.dryrun_ddf [--rows-per-worker 25000000] [--multi-pod]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core.comm.communicator import make_communicator
from repro.core.cost_model import CostParams, t_shuffle
from repro.core.dataframe import Table
from repro.core.operators import dist_join_shuffle
from repro.core.partition import default_quota
from repro.launch import hlo_cost
from repro.launch.dryrun import OUT_DIR, _save
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HW


def build_join(rows_per_worker: int, multi_pod: bool, quota: int | None = None,
               capacity_factor: float = 2.0):
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names                     # every axis carries partitions
    P = int(np.prod([mesh.shape[a] for a in axes]))
    cap = int(rows_per_worker * capacity_factor)
    quota = quota or default_quota(cap, P)
    cap_out = 2 * cap
    spec = jax.sharding.PartitionSpec(axes)
    comm = make_communicator(axes if len(axes) > 1 else axes[0])

    def join_step(lk, lv, rk, rv, ln, rn):
        left = Table({"k": lk, "v": lv}, ln.reshape(()))
        right = Table({"k": rk, "w": rv}, rn.reshape(()))
        out, info = dist_join_shuffle(comm, left, right, ("k",), quota, cap_out)
        # summary outputs keep the lowering honest but small
        return out.nvalid.reshape(1), jax.tree.map(lambda x: jnp.asarray(x).reshape(1), info)

    sm = shard_map(join_step, mesh=mesh,
                   in_specs=(spec,) * 6, out_specs=spec, check_vma=False)
    col = jax.ShapeDtypeStruct((P * cap,), jnp.int32)
    cnt = jax.ShapeDtypeStruct((P,), jnp.int32)
    args = (col, col, col, col, cnt, cnt)
    return jax.jit(sm), args, mesh, P, cap, quota


def run(rows_per_worker: int, multi_pod: bool, tag: str = "", quota: int | None = None,
        capacity_factor: float = 2.0, save: bool = True, verbose: bool = True) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": "cylon-join", "shape": f"weak_{rows_per_worker // 1_000_000}M",
           "mesh": mesh_name, "tag": tag}
    t0 = time.time()
    fn, args, mesh, P, cap, quota = build_join(rows_per_worker, multi_pod, quota, capacity_factor)
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        walked = hlo_cost.analyze(compiled.as_text())
    bytes_dev = (getattr(mem, "temp_size_in_bytes", 0)
                 + getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0)
                 - getattr(mem, "alias_size_in_bytes", 0))

    # Hockney prediction for the two shuffles (bytes per worker):
    n_bytes = rows_per_worker * 8.0  # 2 x int32 per row
    params = CostParams()
    pred = 2 * sum(t_shuffle(P, n_bytes, params))
    t_coll = walked.collective_bytes_tpu / HW["ici_bw"]
    rec.update(
        status="ok", n_devices=P, quota=quota,
        rows_per_worker=rows_per_worker,
        memory={"bytes_per_device": bytes_dev,
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0)},
        flops=walked.flops,
        bytes_accessed=walked.bytes,
        collectives={"per_op": walked.collective_counts,
                     "total_bytes": walked.collective_bytes_tpu,
                     "total_bytes_raw_cpu": walked.collective_bytes},
        roofline={
            "t_compute_s": walked.flops / HW["peak_flops"],
            "t_memory_s": walked.bytes / HW["hbm_bw"],
            "t_collective_s": t_coll,
            "dominant": "collective" if t_coll > walked.bytes / HW["hbm_bw"] else "memory",
            "hockney_predicted_shuffle_s": pred,
            "model_flops_total": 0.0,
            "model_flops_per_chip": 0.0,
            "useful_flops_ratio": 0.0,
            "roofline_fraction": min(pred / t_coll, t_coll / pred) if t_coll > 0 else 0.0,
        },
        compile_s=round(time.time() - t0, 1),
    )
    if verbose:
        print(f"[dryrun-ddf] join x {mesh_name} P={P}: mem/dev={bytes_dev / 1e9:.2f}GB "
              f"coll={walked.collective_bytes:.3e}B t_coll={t_coll * 1e3:.1f}ms "
              f"hockney_shuffle={pred * 1e3:.1f}ms")
    if save:
        _save(rec)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows-per-worker", type=int, default=25_000_000)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quota", type=int, default=None)
    ap.add_argument("--capacity-factor", type=float, default=2.0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    run(args.rows_per_worker, args.multi_pod, quota=args.quota,
        capacity_factor=args.capacity_factor, tag=args.tag)


if __name__ == "__main__":
    main()
