"""HLO cost analyzer with while-loop trip-count scaling.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers / microbatch-accumulation module under-reports FLOPs,
bytes, and collective traffic by the trip count (16-95x here). This walks
the optimized HLO text instead:

- per computation: dot/convolution FLOPs from operand/result shapes,
  elementwise-ish byte traffic from instruction results, collective bytes
  from result shapes of all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute;
- ``while`` ops multiply their body+condition cost by the parsed trip count
  (jax scans lower to `compare(counter, constant N, LT)` conditions);
- ``fusion``/``call``/``conditional`` recurse into called computations
  (fusion counts one result write + operand reads, matching the
  roofline convention that fused elementwise traffic is one pass).

Validated against analytic 6ND model FLOPs in tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

__all__ = ["analyze", "Cost"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0            # raw, as compiled (CPU backend)
    collective_bytes_tpu: float = 0.0        # dtype-projected (see analyze())
    collective_counts: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        self.collective_bytes_tpu += o.collective_bytes_tpu
        for k, v in o.collective_counts.items():
            d = self.collective_counts.setdefault(k, {"count": 0, "bytes": 0.0, "bytes_tpu": 0.0})
            d["count"] += v["count"]
            d["bytes"] += v["bytes"]
            d["bytes_tpu"] += v.get("bytes_tpu", v["bytes"])
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f, self.collective_bytes * f,
                    self.collective_bytes_tpu * f,
                    {k: {"count": v["count"] * f, "bytes": v["bytes"] * f,
                         "bytes_tpu": v.get("bytes_tpu", v["bytes"]) * f}
                     for k, v in self.collective_counts.items()})


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: list
    line: str


_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_dims(shape: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_TOK.findall(shape):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape: str) -> float:
    total = 0
    for dt, dims in _shape_dims(shape):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return float(total)


_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\((?:[^()]|\([^()]*\))*\)|[\w.\-]+\[[\d,]*\](?:\{[\d,]*\})?))\s*"
    r"([\w\-]+)\((.*)$")

def parse_module(hlo: str) -> tuple[dict[str, list[Instr]], str]:
    comps: dict[str, list[Instr]] = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        # computation header: column-0 line "name (params) -> ret {"
        # (params may contain nested tuple parens, so match loosely)
        if line and not line[0].isspace() and line.rstrip().endswith("{") and " -> " in line:
            head = line.lstrip()
            is_entry = head.startswith("ENTRY")
            if is_entry:
                head = head[len("ENTRY"):].lstrip()
            name = head.split()[0].lstrip("%")
            cur = name
            comps[cur] = []
            if is_entry:
                entry = cur
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, shape, op, rest = mi.groups()
            operands = re.findall(r"%([\w.\-]+)", rest.split(" calls=")[0].split("condition=")[0])
            comps[cur].append(Instr(name, shape, op, operands, line))
    if entry is None:
        entry = next(iter(comps))
    return comps, entry


def _called(line: str, key: str) -> str | None:
    m = re.search(rf"{key}=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _called_list(line: str, key: str) -> list[str]:
    m = re.search(rf"{key}=\{{([^}}]*)\}}", line)
    if not m:
        return []
    return [x.strip().lstrip("%") for x in m.group(1).split(",") if x.strip()]


def _int_const(line: str) -> int | None:
    m = re.search(r"constant\((\d+)\)", line)
    return int(m.group(1)) if m else None


def _resolve_compare(ins: Instr, local_consts: dict, arg_consts: dict) -> float | None:
    m = re.search(r"direction=(\w+)", ins.line)
    d = m.group(1) if m else "LT"
    for opnd in ins.operands:
        n = local_consts.get(opnd)
        if n is None and opnd in arg_consts:
            n = arg_consts[opnd]
        if n is not None:
            if d in ("LE", "GE"):
                return float(max(n + 1, 1))
            return float(max(n, 1))
    return None


def _trip_count(comps: dict, cond_name: str) -> float:
    """jax scan conditions: compare(counter, constant(N), LT) -> N trips.
    The compare may be fused; follow one level of fusion with positional
    parameter -> caller-operand constant mapping."""
    instrs = comps.get(cond_name, [])
    consts = {i.name: _int_const(i.line) for i in instrs if _int_const(i.line) is not None}
    for ins in instrs:
        if ins.op == "compare":
            v = _resolve_compare(ins, consts, {})
            if v is not None:
                return v
    for ins in instrs:
        if ins.op == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", ins.line)
            if not m:
                continue
            cinstrs = comps.get(m.group(1), [])
            # map called params -> caller operand constants
            param_names = {}
            for ci in cinstrs:
                pm = re.search(r"parameter\((\d+)\)", ci.line)
                if pm:
                    idx = int(pm.group(1))
                    if idx < len(ins.operands) and ins.operands[idx] in consts:
                        param_names[ci.name] = consts[ins.operands[idx]]
            clocal = {ci.name: _int_const(ci.line) for ci in cinstrs
                      if _int_const(ci.line) is not None}
            for ci in cinstrs:
                if ci.op == "compare":
                    v = _resolve_compare(ci, clocal, param_names)
                    if v is not None:
                        return v
    return 1.0


def _dot_flops(ins: Instr, symtab: dict[str, str]) -> float:
    out_elems = 1
    dims_list = _shape_dims(ins.shape)
    if dims_list:
        for d in dims_list[0][1]:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contract = 1
    if m and ins.operands:
        lhs_shape = symtab.get(ins.operands[0])
        if lhs_shape:
            ldims = _shape_dims(lhs_shape)
            if ldims:
                dims = ldims[0][1]
                for i in [int(x) for x in m.group(1).split(",") if x]:
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * out_elems * contract


def _is_widened_bf16(ins: Instr, instr_map: dict, comps: dict, hops: int = 4) -> bool:
    """True if this (f32) value is transitively a convert/fusion of a bf16
    value — the XLA-CPU float-normalization artifact. TPU keeps these ops in
    bf16, so collectives over such values are projected at half width
    (EXPERIMENTS.md §Dry-run notes)."""
    if "f32" not in ins.shape:
        return False
    cur = ins
    for _ in range(hops):
        if not cur.operands:
            return False
        src = instr_map.get(cur.operands[0])
        if src is None:
            return False
        if "bf16" in src.shape:
            return True
        if src.op == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", src.line)
            if m and m.group(1) in comps:
                # any bf16 parameter feeding the fusion?
                if any("bf16" in i.shape for i in comps[m.group(1)] if i.op == "parameter"):
                    return True
        if src.op not in ("convert", "copy", "bitcast", "get-tuple-element",
                          "fusion", "transpose", "reshape"):
            return False
        cur = src
    return False


def analyze(hlo: str) -> Cost:
    comps, entry = parse_module(hlo)

    symtabs: dict[str, dict[str, str]] = {
        cname: {i.name: i.shape for i in instrs} for cname, instrs in comps.items()
    }
    instr_maps: dict[str, dict[str, Instr]] = {
        cname: {i.name: i for i in instrs} for cname, instrs in comps.items()
    }

    memo: dict[str, Cost] = {}

    def comp_cost(cname: str, depth: int = 0) -> Cost:
        if cname in memo:
            return memo[cname]
        if depth > 64 or cname not in comps:
            return Cost()
        total = Cost()
        symtab = symtabs[cname]
        for ins in comps[cname]:
            op = ins.op
            if op == "while":
                body = _called(ins.line, "body")
                cond = _called(ins.line, "condition")
                trips = _trip_count(comps, cond)
                inner = Cost()
                if body:
                    inner += comp_cost(body, depth + 1)
                if cond:
                    inner += comp_cost(cond, depth + 1)
                total += inner.scaled(trips)
            elif op == "fusion":
                called = _called(ins.line, "calls")
                if called:
                    inner = comp_cost(called, depth + 1)
                    # fused elementwise internals don't touch HBM: keep inner
                    # flops/collectives, replace traffic with the fusion's
                    # boundary (result write + operand reads)
                    reads = sum(_shape_bytes(symtab.get(o, "")) for o in ins.operands)
                    total += Cost(flops=inner.flops,
                                  bytes=_shape_bytes(ins.shape) + reads,
                                  collective_bytes=inner.collective_bytes,
                                  collective_counts=inner.collective_counts)
                else:
                    total += Cost(bytes=_shape_bytes(ins.shape))
            elif op in ("call", "custom-call", "async-start"):
                called = _called(ins.line, "calls") or _called(ins.line, "to_apply")
                if called:
                    total += comp_cost(called, depth + 1)
            elif op == "conditional":
                branches = _called_list(ins.line, "branch_computations")
                if not branches:
                    tb = _called(ins.line, "true_computation")
                    fb = _called(ins.line, "false_computation")
                    branches = [b for b in (tb, fb) if b]
                if branches:
                    costs = [comp_cost(b, depth + 1) for b in branches]
                    total += max(costs, key=lambda c: c.flops + c.bytes)
            elif op in ("dot", "dot-general"):
                f = _dot_flops(ins, symtab)
                total += Cost(flops=f, bytes=_shape_bytes(ins.shape))
            elif op == "convolution":
                # approximate: 2 * out_elems * kernel_elems
                out_b = _shape_bytes(ins.shape)
                kshape = symtab.get(ins.operands[1]) if len(ins.operands) > 1 else None
                kelems = 1
                if kshape:
                    for dt, dims in _shape_dims(kshape):
                        for d in dims:
                            kelems *= d
                dims_list = _shape_dims(ins.shape)
                out_elems = 1
                if dims_list:
                    for d in dims_list[0][1]:
                        out_elems *= d
                total += Cost(flops=2.0 * out_elems * kelems, bytes=out_b)
            else:
                base = op.replace("-start", "")
                if base in _COLLECTIVES and not op.endswith("-done"):
                    b = _shape_bytes(ins.shape)
                    bt = b / 2 if _is_widened_bf16(ins, instr_maps[cname], comps) else b
                    total += Cost(collective_bytes=b, collective_bytes_tpu=bt,
                                  collective_counts={base: {"count": 1, "bytes": b,
                                                            "bytes_tpu": bt}})
                elif op not in ("parameter", "constant", "get-tuple-element",
                                "tuple", "bitcast", "copy-start", "copy-done"):
                    # elementwise / reduce / dus etc: count result write
                    total += Cost(bytes=_shape_bytes(ins.shape))
        memo[cname] = total
        return total

    return comp_cost(entry)
