"""Assigned input-shape sets and ShapeDtypeStruct input specs (dry-run step 2).

LM transformer shapes (task spec):
  train_4k     seq 4096,    global_batch 256   -> train_step
  prefill_32k  seq 32768,   global_batch 32    -> prefill forward
  decode_32k   seq 32768,   global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288,  global_batch 1     -> serve_step; sub-quadratic
                                                  archs only (DESIGN.md §5)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig

__all__ = ["SHAPES", "ShapeCell", "input_specs", "cell_applicable"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (skip documented in DESIGN.md §5)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §5)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
    shardable, no device allocation."""
    cell = SHAPES[shape]
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    f32 = jnp.float32

    if cell.kind in ("train", "prefill"):
        S_text = S
        specs: dict = {}
        if cfg.family == "vlm" and cfg.n_patches:
            S_text = S - cfg.n_patches
            specs["patch_embeds"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), f32)
        if cfg.family == "encdec":
            specs["enc_frames"] = jax.ShapeDtypeStruct((B, cfg.enc_positions, cfg.d_model), f32)
        specs["tokens"] = jax.ShapeDtypeStruct((B, S_text), i32)
        if cell.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), f32)
        return specs

    # decode: one new token against a cache of length S
    return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
