"""Roofline analysis from compiled dry-run artifacts (deliverable (g)).

Three terms per (arch x shape x mesh), TPU v5e constants from the task spec:

  compute    = HLO_FLOPs        / (chips x 197e12 FLOP/s)
  memory     = HLO_bytes        / (chips x 819e9  B/s)
  collective = collective_bytes / (chips x 50e9   B/s per ICI link)

cost_analysis() reports per-device FLOPs/bytes for the SPMD module, so the
per-chip time is flops / peak directly; we normalize both conventions by
recording chips alongside. collective_bytes is parsed from the compiled HLO:
the sum of operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (task spec formula).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""

from __future__ import annotations

import re
from typing import Mapping

import numpy as np

__all__ = ["collective_bytes_from_hlo", "roofline_terms", "HW"]

HW = {
    "peak_flops": 197e12,     # bf16 / chip
    "hbm_bw": 819e9,          # B/s / chip
    "ici_bw": 50e9,           # B/s / link
}

_COLL_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+\s*=\s*)?"
    r"((?:\([^)]*\)|[\w\[\],{}\s/]+?))\s*"           # result shape(s)
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.MULTILINE)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
                "u16": 2, "u8": 1, "pred": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Census of collective ops: per-kind {count, bytes} + total.

    Uses the *result* shapes on the op line (for these collectives result
    bytes ~ operand bytes moved per device; -start/-done pairs counted once
    via -start and bare forms counted directly)."""
    per: dict[str, dict] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo):
        shapes, kind = m.group(1), m.group(2)
        line = hlo[m.start(): hlo.find("\n", m.start())]
        if f"{kind}-done" in line:
            continue  # counted at -start
        b = _shape_bytes(shapes)
        d = per.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    total = sum(d["bytes"] for d in per.values())
    return {"per_op": per, "total_bytes": float(total),
            "total_count": sum(d["count"] for d in per.values())}


def model_flops(cfg, cell) -> float:
    """6*N*D with N = active params (excluding embeddings' lookup side) and
    D = trained tokens. For decode cells D = global_batch (one token each)."""
    n_active = cfg.num_active_params()
    if cell.kind == "train":
        d_tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * d_tokens
    if cell.kind == "prefill":
        d_tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * d_tokens  # forward only
    return 2.0 * n_active * cell.global_batch  # decode: fwd, 1 token/seq


def roofline_terms(cfg, cell, *, flops: float, bytes_accessed: float,
                   collective: Mapping, n_chips: int) -> dict:
    """cost_analysis is per-device for SPMD modules; collective bytes parsed
    from HLO are also per-device."""
    t_compute = flops / HW["peak_flops"]
    t_memory = bytes_accessed / HW["hbm_bw"]
    t_coll = float(collective["total_bytes"]) / HW["ici_bw"]
    mf = model_flops(cfg, cell)
    mf_per_chip = mf / n_chips
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1])[0]
    useful_ratio = mf_per_chip / flops if flops else 0.0
    # roofline fraction: useful-model-compute time over the dominating term
    t_dom = max(t_compute, t_memory, t_coll)
    t_model = mf_per_chip / HW["peak_flops"]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_total": mf,
        "model_flops_per_chip": mf_per_chip,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": (t_model / t_dom) if t_dom > 0 else 0.0,
    }
