"""SCAN builders: open chunked datasets (or CSV files) as lazy pipelines.

``scan_dataset`` wraps a ``DatasetManifest`` as a ``LazyDDF`` whose leaf is
a ``SCAN`` plan node; ``scan_csv`` first ingests CSV files into a chunked
dataset (``data.dataset.csv_to_dataset`` — chunked columnar parsing, never
the whole file at once) and then scans it. Neither touches a device: the
batch capacity recorded on the ``SCAN`` node comes from the cost model
(``choose_batch_rows``) using only the manifest's schema and row count.
"""

from __future__ import annotations

import tempfile
from typing import Iterable, Mapping

from ..core import cost_model
from ..core.api import DDFContext
from ..data.dataset import (
    DEFAULT_CHUNK_ROWS,
    DatasetManifest,
    csv_to_dataset,
    open_dataset,
)
from ..plan import frame as _frame
from ..plan.logical import Scan

__all__ = ["scan_dataset", "scan_csv"]


def _batch_capacity(manifest: DatasetManifest, ctx: DDFContext,
                    batch_rows: int | None,
                    memory_budget_bytes: float | None) -> int:
    P = ctx.nworkers
    if batch_rows is None:
        kw = {}
        if memory_budget_bytes is not None:
            kw["memory_budget_bytes"] = memory_budget_bytes
        batch_rows = cost_model.choose_batch_rows(
            P, manifest.row_bytes(),
            cost_model.params_for_fabric(ctx.fabric),
            total_rows=max(manifest.num_rows, 1), **kw)
    return max(-(-int(batch_rows) // P), 1)


def scan_dataset(dataset, ctx: DDFContext, batch_rows: int | None = None,
                 memory_budget_bytes: float | None = None) -> "_frame.LazyDDF":
    """Open a chunked dataset as a lazy out-of-core pipeline source.

    Args:
      dataset: a ``DatasetManifest`` or a dataset directory path.
      ctx: execution environment (mesh + row-partition axes).
      batch_rows: global rows per streamed batch; default from
        ``cost_model.choose_batch_rows`` (memory ceiling vs per-batch
        dispatch-overhead amortization).
      memory_budget_bytes: per-device batch working-set budget forwarded to
        the batch-sizing model when ``batch_rows`` is not pinned.

    Returns:
      A ``LazyDDF`` whose plan root is a ``SCAN`` leaf. Terminal calls
      route through the streaming engine (``collect_stream``/``to_batches``).
    """
    manifest = dataset if isinstance(dataset, DatasetManifest) \
        else open_dataset(str(dataset))
    cap = _batch_capacity(manifest, ctx, batch_rows, memory_budget_bytes)
    sid = next(_frame._SIDS)
    root = Scan(sid=sid, schema=manifest.schema, capacity=cap)
    return _frame.LazyDDF(root, ctx, {}, scans={sid: manifest})


def scan_csv(files: Iterable[str], schema: Mapping, ctx: DDFContext,
             directory: str | None = None,
             chunk_rows: int = DEFAULT_CHUNK_ROWS,
             batch_rows: int | None = None,
             memory_budget_bytes: float | None = None) -> "_frame.LazyDDF":
    """Scan CSV files out-of-core: chunked ingestion + ``scan_dataset``.

    Files are converted once into a chunked dataset under ``directory``
    (a fresh temporary directory when None — pass a path to keep/reuse the
    converted dataset) and scanned from there, so repeated pipelines pay
    CSV parsing once. Header/schema mismatches raise ``ValueError`` at
    ingestion time. Unlike ``read_csv_dist`` nothing is materialized on
    device here; dataset size is bounded by disk, not device memory.
    """
    if directory is None:
        directory = tempfile.mkdtemp(prefix="repro-scan-csv-")
    manifest = csv_to_dataset(files, schema, directory, chunk_rows=chunk_rows)
    return scan_dataset(manifest, ctx, batch_rows=batch_rows,
                        memory_budget_bytes=memory_budget_bytes)
