"""SCAN builders: open chunked datasets (or CSV files) as lazy pipelines.

``scan_dataset`` wraps a ``DatasetManifest`` as a ``LazyDDF`` whose leaf is
a ``SCAN`` plan node; ``scan_csv`` first ingests CSV files into a chunked
dataset (``data.dataset.csv_to_dataset`` — chunked columnar parsing, never
the whole file at once) and then scans it. Neither touches a device: the
batch capacity recorded on the ``SCAN`` node comes from the cost model
(``choose_batch_rows``) using only the manifest's schema and row count.
"""

from __future__ import annotations

import tempfile
from typing import Iterable, Mapping

from .. import expr as _expr
from ..core import cost_model
from ..core.api import DDFContext
from ..core.vocab import storage_schema
from ..data.dataset import (
    DEFAULT_CHUNK_ROWS,
    DatasetManifest,
    csv_to_dataset,
    open_dataset,
)
from ..plan import frame as _frame
from ..plan.logical import Scan, Select, schema_names

__all__ = ["scan_dataset", "scan_csv"]


def _batch_capacity(manifest: DatasetManifest, ctx: DDFContext,
                    batch_rows: int | None,
                    memory_budget_bytes: float | None) -> int:
    P = ctx.nworkers
    if batch_rows is None:
        kw = {}
        if memory_budget_bytes is not None:
            kw["memory_budget_bytes"] = memory_budget_bytes
        batch_rows = cost_model.choose_batch_rows(
            P, manifest.row_bytes(),
            cost_model.params_for_fabric(ctx.fabric),
            total_rows=max(manifest.num_rows, 1), **kw)
    return max(-(-int(batch_rows) // P), 1)


def scan_dataset(dataset, ctx: DDFContext, batch_rows: int | None = None,
                 memory_budget_bytes: float | None = None,
                 columns: Iterable[str] | None = None,
                 predicate=None) -> "_frame.LazyDDF":
    """Open a chunked dataset as a lazy out-of-core pipeline source.

    Args:
      dataset: a ``DatasetManifest`` or a dataset directory path.
      ctx: execution environment (mesh + row-partition axes).
      batch_rows: global rows per streamed batch; default from
        ``cost_model.choose_batch_rows`` (memory ceiling vs per-batch
        dispatch-overhead amortization).
      memory_budget_bytes: per-device batch working-set budget forwarded to
        the batch-sizing model when ``batch_rows`` is not pinned.
      columns: projection pushed straight into the scan — only these
        ``.npz`` members are decoded per batch (same effect as a
        ``.project()`` the optimizer would absorb).
      predicate: a ``repro.expr`` boolean expression — exactly equivalent
        to chaining ``.select(predicate)``. Host-portable predicates
        (``repro.expr.host_portable``) are absorbed into the scan and
        evaluated host-side on each decoded chunk *before* rows are
        admitted to the device (referenced columns outside ``columns`` are
        decoded transiently and dropped after filtering); non-portable
        ones (float arithmetic, 64-bit columns) become a device SELECT
        above the scan so results never diverge from the eager path.
        When the dataset manifest carries per-chunk sketches
        (``repro.stats``, the write-time default), absorbed predicates
        additionally drive *chunk skipping*: chunks whose min/max bounds
        prove zero matching rows are never decoded at all — see
        docs/STATISTICS.md for the conservatism contract.

    Returns:
      A ``LazyDDF`` whose plan root is a ``SCAN`` leaf. Terminal calls
      route through the streaming engine (``collect_stream``/``to_batches``).
    """
    manifest = dataset if isinstance(dataset, DatasetManifest) \
        else open_dataset(str(dataset))
    cap = _batch_capacity(manifest, ctx, batch_rows, memory_budget_bytes)
    sid = next(_frame._SIDS)
    # the plan/device layers only ever see the STORAGE schema: dict-encoded
    # string columns appear as their int32 code columns, with the vocab
    # riding on the LazyDDF as host metadata
    vocabs = manifest.vocab_map
    stored = storage_schema(manifest.schema)
    have = schema_names(manifest.schema)
    cols = None
    if columns is not None:
        cols = tuple(sorted(str(c) for c in columns))
        missing = [c for c in cols if c not in have]
        if missing:
            raise KeyError(f"scan: unknown column(s) {missing}; "
                           f"available schema: {sorted(have)}")
    preds = ((), (), ())
    device_pred = None
    if predicate is not None:
        if not (isinstance(predicate, _expr.Expr)
                or _expr.is_when_builder(predicate)):
            raise TypeError(
                "scan predicate must be a repro.expr expression (e.g. "
                "col('v') > 3); for legacy callables chain .select() and "
                "let the optimizer probe it")
        e = _expr.prepare_row_expr(predicate, have, "scan",
                                   vocabs=vocabs or None)
        if _expr.host_portable(e, stored):
            preds = (("pred",), (e,), (_expr.to_numpy_fn(e),))
        else:
            # host numpy would evaluate this differently than the device
            # (float promotion / 64-bit truncation): keep it as a device
            # SELECT so predicate= stays exactly equivalent to .select()
            refs = _expr.referenced_columns(e)
            if cols is not None and not refs <= set(cols):
                raise ValueError(
                    f"scan: predicate {e} is not host-portable (it must "
                    "run on device) but references column(s) "
                    f"{sorted(refs - set(cols))} outside columns={cols}; "
                    "include them in columns= or use a host-portable "
                    "(integer/comparison) predicate")
            device_pred = e
    root = Scan(sid=sid, schema=stored, capacity=cap, columns=cols,
                pred_names=preds[0], pred_sigs=preds[1], pred_fns=preds[2])
    if device_pred is not None:
        root = Select(root, _expr.to_jax_fn(device_pred), "pred",
                      tuple(sorted(_expr.referenced_columns(device_pred))),
                      expr=device_pred)
    return _frame.LazyDDF(root, ctx, {}, scans={sid: manifest},
                          vocabs=vocabs)


def scan_csv(files: Iterable[str], schema: Mapping, ctx: DDFContext,
             directory: str | None = None,
             chunk_rows: int = DEFAULT_CHUNK_ROWS,
             batch_rows: int | None = None,
             memory_budget_bytes: float | None = None,
             columns: Iterable[str] | None = None,
             predicate=None) -> "_frame.LazyDDF":
    """Scan CSV files out-of-core: chunked ingestion + ``scan_dataset``.

    Files are converted once into a chunked dataset under ``directory``
    (a fresh temporary directory when None — pass a path to keep/reuse the
    converted dataset) and scanned from there, so repeated pipelines pay
    CSV parsing once. Header/schema mismatches raise ``ValueError`` at
    ingestion time. Unlike ``read_csv_dist`` nothing is materialized on
    device here; dataset size is bounded by disk, not device memory.
    """
    if directory is None:
        directory = tempfile.mkdtemp(prefix="repro-scan-csv-")
    manifest = csv_to_dataset(files, schema, directory, chunk_rows=chunk_rows)
    return scan_dataset(manifest, ctx, batch_rows=batch_rows,
                        memory_budget_bytes=memory_budget_bytes,
                        columns=columns, predicate=predicate)
