"""StreamCheckpoint: atomic snapshots of a streaming query's state.

The streaming runner's per-query state is already explicit — scan cursor
(batch index, equivalently dataset chunk index + in-chunk offset),
device-resident carry tables (groupby partials, unique carry), spill-file
manifests, partial concat outputs, and the folded overflow counters. A
checkpoint is one consistent snapshot of all of it, taken at a morsel
boundary, so a killed query can resume *mid-stream* and produce output
bit-identical to an uninterrupted run. Adaptive streams
(``collect(..., adaptive=True)``) additionally snapshot their
``repro.stats.AdaptiveController`` decision state inside the
active-stage metadata, so a resumed query re-enters the exact corrected
plan and makes the same future re-planning decisions it would have made
uninterrupted.

Layout (one directory per query)::

    <dir>/
      ckpt_00000004/          one snapshot, atomically published
        manifest.json         step, query_key, stage/cursor, completed-stage
                              metadata, JSON-able info counters
        arrays.npz            namespaced numpy payloads: ``active/...`` for
                              the in-flight phase (e.g. carry-table columns
                              + per-worker counts), ``completed/<stage>/...``
                              for finished stages, ``info/...`` counters
      spill/                  persistent spill datasets (sort runs, join
                              hash buckets) — referenced by manifests inside
                              the snapshots, deleted on query success

Publication reuses the trainer checkpoint's atomic tmp-dir-rename
(``repro.train.checkpoint.publish_dir``): a crash mid-save leaves only a
``*.tmp_*`` staging dir, which :meth:`latest` ignores and cleans — the
previous snapshot stays restorable. The ``checkpoint_publish`` fault site
fires between staging and publication, so chaos tests can prove exactly
that property.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Mapping

import numpy as np

from ..testing import faults as _faults

__all__ = ["StreamCheckpoint"]

_PREFIX = "ckpt_"


class StreamCheckpoint:
    """Atomic store of streaming-query snapshots under one directory.

    ``save``/``load`` move a ``(manifest dict, arrays dict)`` pair; the
    manifest must be JSON-serializable, arrays are numpy. ``latest`` is
    crash-robust: staging dirs and partial snapshots are never selected.
    """

    def __init__(self, directory: str):
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"{_PREFIX}{step:08d}")

    @property
    def spill_root(self) -> str:
        """Parent dir for spill datasets that must survive a crash."""
        return os.path.join(self.directory, "spill")

    def spill_dir(self, tag: str) -> str:
        """Create (if needed) and return a persistent spill directory."""
        path = os.path.join(self.spill_root, tag)
        os.makedirs(path, exist_ok=True)
        return path

    def save(self, step: int, manifest: Mapping,
             arrays: Mapping[str, np.ndarray]) -> str:
        """Atomically publish snapshot ``step``. The ``checkpoint_publish``
        fault site fires after staging, before the rename — an injected
        crash there leaves the previous snapshot intact."""
        final = self._path(step)
        tmp = final + ".tmp_0"
        if os.path.exists(tmp):  # stale staging dir from a crashed save
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: np.asarray(v) for k, v in arrays.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": int(step), **dict(manifest)}, f)
        _faults.check("checkpoint_publish")
        from ..train.checkpoint import publish_dir
        return publish_dir(tmp, final)

    def steps(self) -> list[int]:
        """Restorable snapshot steps, ascending (cleans ``*.tmp_*`` debris)."""
        from ..train.checkpoint import list_steps
        return list_steps(self.directory, prefix=_PREFIX)

    def latest(self) -> int | None:
        """Newest restorable snapshot step, or None."""
        steps = self.steps()
        return steps[-1] if steps else None

    def load(self, step: int | None = None) -> tuple[dict, dict]:
        """Read snapshot ``step`` (default: latest) as
        ``(manifest, arrays)`` with arrays materialized on host."""
        if step is None:
            step = self.latest()
            if step is None:
                raise FileNotFoundError(
                    f"no restorable stream checkpoint under {self.directory!r}")
        path = self._path(step)
        manifest_path = os.path.join(path, "manifest.json")
        if not os.path.exists(manifest_path):
            raise FileNotFoundError(
                f"no restorable stream checkpoint for step {step} under "
                f"{self.directory!r} (valid steps: {self.steps()})")
        with open(manifest_path) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        return manifest, arrays

    def prune(self, keep_last: int = 1) -> None:
        """Delete all but the newest ``keep_last`` snapshots."""
        for step in self.steps()[:-keep_last or None]:
            shutil.rmtree(self._path(step), ignore_errors=True)

    def clear(self) -> None:
        """Remove every snapshot and all persistent spill data (called on
        query success — checkpoints are crash artifacts, not results)."""
        for step in self.steps():
            shutil.rmtree(self._path(step), ignore_errors=True)
        shutil.rmtree(self.spill_root, ignore_errors=True)
