"""Morsel-driven out-of-core batch runner (the streaming engine's core).

The runner executes a lazy plan whose leaves include ``SCAN`` nodes over
chunked on-disk datasets (``repro.data.dataset``). The dataset is sliced
into cost-model-sized batches (``SCAN.capacity`` per worker, from
``cost_model.choose_batch_rows``); every batch is decoded host-side
(projection + pushed-down predicates applied *before* admission), laid out
as a fixed-capacity device table, and driven through the **same** compiled
shard_map program (``executor.run_planned`` — one trace/compile per
pipeline, every later batch is a compiled-op cache hit). Host-side decode
of batch *k+1* overlaps device execution of batch *k* via a double-buffered
prefetch thread, mirroring the PR-1 pipelined shuffle at the I/O layer.

**Streamable vs blocking.** A subtree is *streamable* when evaluating it on
a contiguous scan batch equals the global evaluation restricted to that
batch: embarrassingly-parallel ops, rebalance, joins whose other side is
scan-free. Blocking ops (groupby / unique / sort / set ops / scan x scan
joins) need cross-batch state:

- **carry state** — ``groupby`` runs per batch with ``emit_partials`` and
  the partial aggregates are merged into a device-resident carry table
  (``local_groupby(merge=True)``; hash placement is identical across
  batches, so the merge is worker-local). ``unique`` carries the distinct
  rows seen so far. One finalize pass at the end.
- **host-side spill** — ``sort_values`` streams its input to an on-disk
  spill dataset and runs one final stable host merge by the sort key;
  joins with scans on *both* sides spill each side into key-hash buckets
  and join bucket pairs (build side never has to fit device capacity).

Plans mixing these compose by staged materialization: the deepest blocking
node is finalized first, substituted back as an in-memory ``Source``, and
the rewritten plan streams again until no scans remain.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import shutil
import tempfile
import threading
from typing import Iterator, Mapping

import jax.numpy as jnp
import numpy as np

from .. import expr as _expr
from ..core import cost_model
from ..core.api import DDF, DDFContext
from ..core.dataframe import Table, concat
from ..core.local_ops import finalize_groupby, local_groupby, local_unique
from ..core.partition import default_quota
from ..data.dataset import DatasetManifest, DatasetWriter, read_rows
from ..plan import executor, optimizer
from ..plan.logical import (
    Fused,
    GroupBy,
    Join,
    MapColumns,
    Node,
    Project,
    Rebalance,
    Rename,
    Scan,
    Select,
    Sort,
    Source,
    Unique,
    WithColumn,
    schema_of,
    walk,
)

__all__ = ["collect", "to_batches"]

_EPLIKE = (Select, Project, Rename, MapColumns, WithColumn, Fused, Rebalance)
_SIDS = itertools.count(1 << 20)  # runner-created Source ids, disjoint range

_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)


# -- plan analysis -------------------------------------------------------------

def _has_scan(node: Node) -> bool:
    return any(isinstance(n, Scan) for n in walk(node))


def _streamable(node: Node) -> bool:
    """True when per-batch evaluation == global evaluation per batch."""
    if not _has_scan(node):
        return True
    if isinstance(node, Scan):
        return True
    if isinstance(node, _EPLIKE):
        return _streamable(node.child)
    if isinstance(node, Join):
        lh, rh = _has_scan(node.left), _has_scan(node.right)
        if lh and rh:
            return False  # cross-batch matches: needs the spill join
        return _streamable(node.left if lh else node.right)
    # GroupBy / Unique / Sort / Union / Difference: cross-batch state
    # (set ops deduplicate, so even a probe-side scan cannot stream)
    return False


def _find_blocking(root: Node) -> Node | None:
    """Deepest non-streamable scan-bearing node whose children are each
    scan-free or streamable (post-order walk => deepest first)."""
    for n in walk(root):
        if _has_scan(n) and not _streamable(n):
            if all((not _has_scan(c)) or _streamable(c) for c in n.children):
                return n
    return None


def _replace_node(root: Node, target: Node, repl: Node) -> Node:
    memo: dict = {}

    def rec(n: Node) -> Node:
        if n is target:
            return repl
        if id(n) in memo:
            return memo[id(n)]
        kids = tuple(rec(c) for c in n.children)
        out = n if kids == n.children else n.with_children(kids)
        memo[id(n)] = out
        return out

    return rec(root)


def _set_batch_caps(root: Node, cap: int) -> Node:
    def rec(n: Node) -> Node:
        if isinstance(n, Scan):
            return dataclasses.replace(n, capacity=cap)
        kids = tuple(rec(c) for c in n.children)
        return n if kids == n.children else n.with_children(kids)

    return rec(root)


def _ddf_schema(ddf: DDF) -> tuple:
    return tuple(sorted((n, str(v.dtype), tuple(v.shape[1:]))
                        for n, v in ddf.columns.items()))


# -- host-side hashing (spill-join bucketing) ----------------------------------

def _np_hash32(x: np.ndarray) -> np.ndarray:
    """numpy replica of ``partition.hash32`` (lowbias32), for host bucketing."""
    x = np.asarray(x)
    if x.dtype in (np.int64, np.uint64):
        u = x.astype(np.uint64)
        x = (u ^ (u >> np.uint64(32))).astype(np.uint32)
    elif x.dtype == np.bool_:
        x = x.astype(np.uint32)
    elif np.issubdtype(x.dtype, np.floating):
        x = np.ascontiguousarray(x.astype(np.float32)).view(np.uint32)
    else:
        x = x.astype(np.uint32)
    with np.errstate(over="ignore"):
        x = x ^ (x >> np.uint32(16))
        x = x * _M1
        x = x ^ (x >> np.uint32(15))
        x = x * _M2
        x = x ^ (x >> np.uint32(16))
    return x


def _np_hash_columns(host: Mapping[str, np.ndarray], cols) -> np.ndarray:
    n = len(next(iter(host.values())))
    h = np.zeros((n,), np.uint32)
    with np.errstate(over="ignore"):
        for name in cols:
            hk = _np_hash32(host[name])
            h = h ^ (hk + np.uint32(0x9E3779B9) + (h << np.uint32(6))
                     + (h >> np.uint32(2)))
    return h


# -- prefetch (double buffering) -----------------------------------------------

def _prefetched(gen: Iterator, depth: int = 2) -> Iterator:
    """Run ``gen`` on a background thread with a bounded queue, so host
    decode of the next batch overlaps device execution of the current one.

    Abandoning the iterator early (consumer ``break``/``close``) sets a
    stop flag the producer polls between puts, so the thread exits instead
    of blocking forever on a full queue."""
    q: queue.Queue = queue.Queue(maxsize=max(depth, 1))
    done = object()
    stop = threading.Event()

    def put(item) -> bool:
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def work():
        try:
            for item in gen:
                if not put(item):
                    return
            put(done)
        except BaseException as e:  # surfaced on the consumer thread
            put(e)

    t = threading.Thread(target=work, name="repro-stream-prefetch", daemon=True)
    t.start()
    try:
        while True:
            item = q.get()
            if item is done:
                return
            if isinstance(item, BaseException):
                raise item
            yield item
    finally:
        stop.set()


# -- the runner ---------------------------------------------------------------

class _Runner:
    def __init__(self, lazy, batch_rows=None, prefetch=True,
                 carry_capacity=None, spill_dir=None, spill_compress=False,
                 strict_overflow=True):
        self.ctx: DDFContext = lazy._ctx
        self.P = self.ctx.nworkers
        self.params = cost_model.params_for_fabric(self.ctx.fabric)
        self.sources = dict(lazy._sources)
        self.scans: dict[int, DatasetManifest] = dict(lazy._scans)
        self.prefetch = bool(prefetch)
        self.carry_capacity = carry_capacity
        self.spill_dir = spill_dir
        self.spill_compress = bool(spill_compress)
        self.strict_overflow = bool(strict_overflow)
        root = lazy._root
        if batch_rows is not None:
            root = _set_batch_caps(root, max(-(-int(batch_rows) // self.P), 1))
        self.root = root
        caps = [n.capacity for n in walk(root) if isinstance(n, Scan)]
        self.nominal_batch_rows = (max(caps) * self.P) if caps else None
        # the kernel backend override threads through unchanged: every
        # per-batch program goes through cached_op, whose keys carry the
        # dispatch signature — recorded here so run info shows which
        # backend the stream executed under.
        from ..kernels import registry as _kernel_registry

        self.info: dict = {"batches": 0,
                           "kernel_backend": _kernel_registry.get_backend()}

    # -- info bookkeeping ------------------------------------------------------
    def _fold_aux(self, aux_list: list) -> None:
        for aux in aux_list:
            for k, v in aux.items():
                v = np.asarray(v)
                if "overflow" in k:
                    prev = self.info.get(k)
                    self.info[k] = v if prev is None else prev + v
                else:
                    self.info[k] = v
        if self.strict_overflow:
            bad = {k: int(np.sum(v)) for k, v in self.info.items()
                   if "overflow" in k and np.sum(v) > 0}
            if bad:
                raise RuntimeError(
                    f"streaming run overflowed static buffers: {bad} rows "
                    "dropped — results would silently diverge from eager "
                    "execution. Pin larger quota/capacity on the offending "
                    "op, lower batch_rows, or pass strict_overflow=False to "
                    "accept eager-style truncation semantics.")

    # -- batch iteration over one streamable subtree ---------------------------
    def _prep(self, root: Node):
        scans = [n for n in walk(root) if isinstance(n, Scan)]
        sids = {s.sid for s in scans}
        if len(sids) != 1:
            raise ValueError(f"streamable subtree must hold exactly one scan, "
                             f"got {sorted(sids)}")
        scan = scans[0]
        man = self.scans[scan.sid]
        batch_rows = scan.capacity * self.P
        srcs = {n.sid: self.sources[n.sid] for n in walk(root)
                if isinstance(n, Source)}
        src_rows = executor.source_row_counts(srcs)
        src_rows[scan.sid] = max(min(man.num_rows, batch_rows), 1)
        plan = optimizer.optimize(root, self.P, src_rows, self.params)
        scan_opt = next(n for n in walk(plan) if isinstance(n, Scan))
        return plan, scan_opt, man, batch_rows, srcs

    def _host_batches(self, man: DatasetManifest, scan: Scan,
                      batch_rows: int) -> Iterator[dict]:
        cols = scan.columns
        # expression predicates may reference columns outside the scan's
        # projected output (the optimizer narrows the decode set past them
        # because the reference set is exact): decode the superset, filter,
        # then drop the pred-only columns before admission
        read_cols = cols
        if cols is not None:
            extra = set()
            for sig in scan.pred_sigs:
                if isinstance(sig, _expr.Expr):
                    extra |= _expr.referenced_columns(sig)
            extra -= set(cols)
            if extra:
                read_cols = tuple(sorted(set(cols) | extra))
        total = man.num_rows
        nb = max(-(-total // batch_rows), 1)
        for k in range(nb):
            lo, hi = k * batch_rows, min((k + 1) * batch_rows, total)
            data = read_rows(man, lo, hi, columns=read_cols)
            for fn in scan.pred_fns:
                mask = np.asarray(fn(data)).astype(bool)
                data = {n: v[mask] for n, v in data.items()}
            if read_cols is not cols:
                data = {n: data[n] for n in cols}
            yield data

    def _iter_batches(self, root: Node, prep=None):
        """Yield (result DDF, aux) per streamed batch of a streamable subtree."""
        plan, scan_opt, man, batch_rows, srcs = prep or self._prep(root)
        gen = self._host_batches(man, scan_opt, batch_rows)
        if self.prefetch:
            gen = _prefetched(gen)
        for data in gen:
            bddf = DDF.from_numpy(data, self.ctx, capacity=scan_opt.capacity,
                                  mode="eager")
            out, aux = executor.run_planned(
                plan, self.ctx, {**srcs, scan_opt.sid: bddf})
            self.info["batches"] += 1
            yield out, aux

    # -- streamable whole-plan paths -------------------------------------------
    def _stream_host(self, root: Node) -> Iterator[dict]:
        # aux folds per batch: a strict_overflow violation raises BEFORE the
        # truncated batch is handed out (and early iterator abandon cannot
        # skip the check). The per-batch device sync this implies is free
        # here — to_numpy() syncs on the same results anyway.
        for out, aux in self._iter_batches(root):
            self._fold_aux([aux])
            yield out.to_numpy()

    def _from_host(self, host: dict, schema: tuple) -> DDF:
        if not host:
            host = {n: np.zeros((0,) + tuple(tail), np.dtype(dt))
                    for n, dt, tail in schema}
        total = len(next(iter(host.values())))
        cap = max(-(-total // self.P), 1)
        return DDF.from_numpy(host, self.ctx, capacity=cap, mode="eager")

    def _stream_concat(self, root: Node) -> DDF:
        outs = list(self._stream_host(root))
        schema = schema_of(root)
        host = {n: np.concatenate([o[n] for o in outs])
                for n, _, _ in schema} if outs else {}
        return self._from_host(host, schema)

    # -- carry-state tails ------------------------------------------------------
    def _carry_cap(self, node: Node, scan_total: int) -> int:
        if self.carry_capacity:
            return int(self.carry_capacity)
        if getattr(node, "capacity", None):
            return int(node.capacity)
        return max(-(-max(scan_total, 1) // self.P), 1)

    def _empty_carry(self, schema: tuple, cap: int) -> DDF:
        host = {n: np.zeros((0,) + tuple(tail), np.dtype(dt))
                for n, dt, tail in schema}
        return DDF.from_numpy(host, self.ctx, capacity=cap, mode="eager")

    @staticmethod
    def _truncate_with_overflow(full: Table, cap: int):
        """Cut a compacted table down to the carry capacity, reporting how
        many live rows (groups) the cut drops — the carry-state analogue of
        the shuffle overflow counters, so ``strict_overflow`` sees it."""
        cols = {k: v[:cap] for k, v in full.columns.items()}
        ov = jnp.maximum(full.nvalid - cap, 0)
        return Table(cols, jnp.minimum(full.nvalid, cap)), {"overflow_carry": ov}

    def _run_carry(self, B: Node, batch_root: Node, merge_key: tuple, merge):
        """Shared carry-state drive loop: stream batches through the
        compiled per-batch plan, folding each result into the carry DDF."""
        prep = self._prep(batch_root)
        plan = prep[0]
        cap = self._carry_cap(B, prep[2].num_rows)
        carry = self._empty_carry(schema_of(plan), cap)
        aux_list = []
        for out, aux in self._iter_batches(batch_root, prep=prep):
            aux_list.append(aux)
            carry, carry_ov = carry._run(merge_key + (cap,), merge(cap), out)
            aux_list.append({"carry:overflow_carry": carry_ov["overflow_carry"]})
        self._fold_aux(aux_list)
        return carry, cap

    def _stream_groupby(self, B: GroupBy) -> DDF:
        aggs = {k: v for k, v in B.aggs}
        batch_root = dataclasses.replace(B, emit_partials=True, quota=None,
                                         capacity=None, num_chunks=None)
        by, aggs_t = B.by, B.aggs

        def merge(cap):
            def fn(comm, c, b):
                # merge at full concat capacity (groups <= rows, so no
                # truncation), then cut to the carry capacity with an
                # explicit overflow counter
                full = local_groupby(concat(c, b), by, aggs, merge=True)
                return self._truncate_with_overflow(full, cap)
            return fn

        carry, cap = self._run_carry(B, batch_root,
                                     ("stream-gb-merge", by, aggs_t), merge)
        return carry._run(("stream-gb-fin", aggs_t, cap),
                          lambda comm, t: finalize_groupby(t, aggs))

    def _stream_unique(self, B: Unique) -> DDF:
        batch_root = dataclasses.replace(B, quota=None, capacity=None,
                                         num_chunks=None)
        subset = B.subset

        def merge(cap):
            def fn(comm, c, b):
                # carry rows concat first: earliest-batch occurrence wins,
                # matching local_unique's stable first-occurrence contract
                full = local_unique(concat(c, b), subset)
                return self._truncate_with_overflow(full, cap)
            return fn

        carry, _ = self._run_carry(B, batch_root,
                                   ("stream-uq-merge", subset), merge)
        return carry

    # -- spill tails ------------------------------------------------------------
    def _spill_writer(self, schema: tuple) -> DatasetWriter:
        d = tempfile.mkdtemp(prefix="repro-spill-",
                             dir=self.spill_dir)
        rows = self.nominal_batch_rows or 65536
        return DatasetWriter(d, schema=schema, chunk_rows=rows,
                             compress=self.spill_compress)

    def _stream_sort(self, B: Sort) -> DDF:
        """Spill the sort's input to disk while streaming, then one stable
        host merge by the key. The spill bounds host RSS *during* the
        streaming phase (batches land on disk, not in a growing list); the
        final merge necessarily materializes on host — the sorted result
        becomes a device DDF anyway, so that peak is unavoidable. A k-way
        merge of pre-sorted runs would only change the merge's working set,
        not the result materialization."""
        prefix = B.child
        writer = self._spill_writer(schema_of(prefix))
        try:
            for host in self._stream_host(prefix):
                writer.append(host)
            man = writer.close()
            host = read_rows(man, 0, man.num_rows)
        finally:
            shutil.rmtree(writer.directory, ignore_errors=True)
        key = host[B.by]
        if B.descending:
            # the same order-reversing map local_sort uses: exact for ints,
            # sign-flip for floats; stable argsort keeps global row order
            # among equal keys (matching the eager shuffle arrival order)
            key = -key if np.issubdtype(key.dtype, np.floating) \
                else np.bitwise_not(key)
        order = np.argsort(key, kind="stable")
        host = {k: v[order] for k, v in host.items()}
        return self._from_host(host, schema_of(prefix))

    def _spill_buckets(self, side: Node, on: tuple, nb: int):
        """Stream (or eagerly compute) one join side into key-hash buckets."""
        if not _has_scan(side):
            raise AssertionError(
                "spill join is only reachable with scans on both sides")
        schema = schema_of(side)
        writers = [self._spill_writer(schema) for _ in range(nb)]
        for host in self._stream_host(side):
            if not len(next(iter(host.values()))):
                continue
            h = _np_hash_columns(host, on) % np.uint32(nb)
            for b in range(nb):
                m = h == b
                if m.any():
                    writers[b].append({k: v[m] for k, v in host.items()})
        return [w.close() for w in writers]

    def _stream_join_spill(self, B: Join) -> DDF:
        """Out-of-core join with scans on both sides: hash-bucket spill.

        Each side spills into ``nb`` key-hash buckets (equal keys share a
        bucket), then bucket pairs are joined on device one at a time —
        neither side's build table ever has to fit device capacity. Output
        order is bucket-major (row-set equal to the eager join; a downstream
        sort/groupby canonicalizes it)."""
        on = B.on
        per_side_rows = []
        for side in (B.left, B.right):
            sids = [n.sid for n in walk(side) if isinstance(n, Scan)]
            per_side_rows.append(sum(self.scans[s].num_rows for s in sids))
        br = self.nominal_batch_rows or max(max(per_side_rows), 1)
        nb = max(-(-2 * max(per_side_rows) // br), 1)
        mans_l = self._spill_buckets(B.left, on, nb)
        mans_r = self._spill_buckets(B.right, on, nb)
        try:
            cap_l = max(max((m.num_rows for m in mans_l), default=0) // self.P + 1, 1)
            cap_r = max(max((m.num_rows for m in mans_r), default=0) // self.P + 1, 1)
            sid_l, sid_r = next(_SIDS), next(_SIDS)
            quota = B.quota or default_quota(max(cap_l, cap_r), self.P)
            cap_out = B.capacity or 2 * max(cap_l, cap_r)
            outs = []
            for ml, mr in zip(mans_l, mans_r):
                if ml.num_rows == 0 or mr.num_rows == 0:
                    continue
                dl = DDF.from_numpy(read_rows(ml, 0, ml.num_rows), self.ctx,
                                    capacity=cap_l, mode="eager")
                dr = DDF.from_numpy(read_rows(mr, 0, mr.num_rows), self.ctx,
                                    capacity=cap_r, mode="eager")
                while True:
                    # adaptive sizing: join multiplicity is data-dependent,
                    # so grow the static buffers and retry the bucket when
                    # pairs (capacity) or skewed keys (quota) overflow
                    jroot = Join(Source(sid_l, mans_l[0].schema, cap_l),
                                 Source(sid_r, mans_r[0].schema, cap_r),
                                 on, strategy="auto", quota=quota,
                                 capacity=cap_out)
                    out, aux = executor.execute(
                        jroot, self.ctx, {sid_l: dl, sid_r: dr},
                        src_rows={sid_l: cap_l * self.P, sid_r: cap_r * self.P})
                    ovj = sum(int(np.sum(v)) for k, v in aux.items()
                              if "overflow_join" in k)
                    ovs = sum(int(np.sum(v)) for k, v in aux.items()
                              if "overflow" in k and "overflow_join" not in k)
                    if not ovj and not ovs:
                        self._fold_aux([aux])
                        break
                    if ovj:
                        cap_out *= 2
                    if ovs:
                        quota *= 2
                outs.append(out.to_numpy())
        finally:
            for m in mans_l + mans_r:
                shutil.rmtree(m.directory, ignore_errors=True)
        schema = schema_of(B)
        host = {n: np.concatenate([o[n] for o in outs])
                for n, _, _ in schema} if outs else {}
        return self._from_host(host, schema)

    # -- staged materialization --------------------------------------------------
    def _collect_scanfree(self, root: Node):
        srcs = {n.sid: self.sources[n.sid] for n in walk(root)
                if isinstance(n, Source)}
        if isinstance(root, Source):
            return srcs[root.sid], {}
        return executor.execute(root, self.ctx, srcs)

    def _materialize_blocking(self, B: Node) -> DDF:
        if isinstance(B, GroupBy) and _streamable(B.child) and _has_scan(B.child):
            return self._stream_groupby(B)
        if isinstance(B, Unique) and _streamable(B.child) and _has_scan(B.child):
            return self._stream_unique(B)
        if isinstance(B, Sort) and _streamable(B.child) and _has_scan(B.child):
            return self._stream_sort(B)
        if (isinstance(B, Join) and _has_scan(B.left) and _has_scan(B.right)
                and _streamable(B.left) and _streamable(B.right)):
            return self._stream_join_spill(B)
        # generic fallback: materialize scan-bearing children individually,
        # then run the (now scan-free) blocking op eagerly
        kids = []
        for c in B.children:
            if _has_scan(c):
                d = self._collect_node(c)
                sid = next(_SIDS)
                self.sources[sid] = d
                kids.append(Source(sid, _ddf_schema(d), d.capacity))
            else:
                kids.append(c)
        out, aux = self._collect_scanfree(B.with_children(kids))
        self._fold_aux([aux])
        return out

    def _drain_blocking(self, root: Node) -> Node:
        """Finalize blocking nodes bottom-up until the plan is streamable
        (or scan-free), substituting each result back as a Source."""
        while _has_scan(root) and not _streamable(root):
            B = _find_blocking(root)
            if B is None:  # cannot happen; guard against infinite loop
                raise RuntimeError("unstreamable plan with no blocking node")
            mat = self._materialize_blocking(B)
            sid = next(_SIDS)
            self.sources[sid] = mat
            root = _replace_node(root, B, Source(sid, _ddf_schema(mat),
                                                 mat.capacity))
        return root

    def _collect_node(self, root: Node) -> DDF:
        root = self._drain_blocking(root)
        if _has_scan(root):
            return self._stream_concat(root)
        out, aux = self._collect_scanfree(root)
        self._fold_aux([aux])
        return out

    # -- public entry points -----------------------------------------------------
    def run(self):
        out = self._collect_node(self.root)
        return out, dict(self.info)

    def batches(self) -> Iterator[dict]:
        root = self._drain_blocking(self.root)
        if _has_scan(root):
            yield from self._stream_host(root)
            return
        out, aux = self._collect_scanfree(root)
        self._fold_aux([aux])
        host = out.to_numpy()
        total = len(next(iter(host.values()))) if host else 0
        step = self.nominal_batch_rows or max(total, 1)
        for lo in range(0, max(total, 1), step):
            yield {k: v[lo:lo + step] for k, v in host.items()}


def collect(lazy, batch_rows: int | None = None, prefetch: bool = True,
            carry_capacity: int | None = None, spill_dir: str | None = None,
            spill_compress: bool = False, strict_overflow: bool = True):
    """Run a scan-bearing lazy plan through the streaming engine.

    Args:
      lazy: the ``LazyDDF`` to execute (``repro.stream.scan_*`` leaves).
      batch_rows: override the cost-model batch size (global rows/batch).
      prefetch: overlap host decode of batch k+1 with device execution of
        batch k (double buffering); False decodes serially (A/B baseline).
      carry_capacity: per-worker capacity of groupby/unique carry state
        (default: scan rows / workers, the eager-equivalent bound).
      spill_dir: parent directory for spill datasets (default: system tmp).
      spill_compress: compress spilled chunks (saves disk, costs CPU).
      strict_overflow: raise when any static shuffle/join buffer overflowed
        (rows dropped) instead of silently diverging from eager results.

    Returns:
      ``(result DDF, info dict)`` — info carries ``batches`` plus summed
      per-batch overflow counters.
    """
    r = _Runner(lazy, batch_rows=batch_rows, prefetch=prefetch,
                carry_capacity=carry_capacity, spill_dir=spill_dir,
                spill_compress=spill_compress, strict_overflow=strict_overflow)
    return r.run()


def to_batches(lazy, batch_rows: int | None = None, prefetch: bool = True,
               carry_capacity: int | None = None, spill_dir: str | None = None,
               spill_compress: bool = False,
               strict_overflow: bool = True) -> Iterator[dict]:
    """Stream a lazy plan's result as host column-dict batches.

    Fully-streamable plans yield one dict per morsel without materializing
    the whole result (true out-of-core iteration); plans needing carry or
    spill finalization finalize first and yield ``batch_rows``-sized slices
    of the final table. Args as :func:`collect`.
    """
    r = _Runner(lazy, batch_rows=batch_rows, prefetch=prefetch,
                carry_capacity=carry_capacity, spill_dir=spill_dir,
                spill_compress=spill_compress, strict_overflow=strict_overflow)
    yield from r.batches()
