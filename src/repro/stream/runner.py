"""Morsel-driven out-of-core batch runner (the streaming engine's core).

The runner executes a lazy plan whose leaves include ``SCAN`` nodes over
chunked on-disk datasets (``repro.data.dataset``). The dataset is sliced
into cost-model-sized batches (``SCAN.capacity`` per worker, from
``cost_model.choose_batch_rows``); every batch is decoded host-side
(projection + pushed-down predicates applied *before* admission), laid out
as a fixed-capacity device table, and driven through the **same** compiled
shard_map program (``executor.run_planned`` — one trace/compile per
pipeline, every later batch is a compiled-op cache hit). Host-side decode
of batch *k+1* overlaps device execution of batch *k* via a double-buffered
prefetch thread, mirroring the PR-1 pipelined shuffle at the I/O layer.

**Streamable vs blocking.** A subtree is *streamable* when evaluating it on
a contiguous scan batch equals the global evaluation restricted to that
batch: embarrassingly-parallel ops, rebalance, joins whose other side is
scan-free. Blocking ops (groupby / unique / sort / set ops / scan x scan
joins) need cross-batch state:

- **carry state** — ``groupby`` runs per batch with ``emit_partials`` and
  the partial aggregates are merged into a device-resident carry table
  (``local_groupby(merge=True)``; hash placement is identical across
  batches, so the merge is worker-local). ``unique`` carries the distinct
  rows seen so far. One finalize pass at the end.
- **host-side spill** — ``sort_values`` streams its input to an on-disk
  spill dataset and runs one final stable host merge by the sort key;
  joins with scans on *both* sides spill each side into key-hash buckets
  and join bucket pairs (build side never has to fit device capacity).

Plans mixing these compose by staged materialization: the deepest blocking
node is finalized first, substituted back as an in-memory ``Source``, and
the rewritten plan streams again until no scans remain.

**Fault tolerance** (docs/FAULT_TOLERANCE.md). Every hot-path unit of work
passes a named fault site (``repro.testing.faults``) and a bounded-backoff
retry (``repro.stream.recovery``): ``chunk_decode`` around each batch's
host decode, ``device_op`` around each compiled device execution,
``spill_write`` around each spill append, ``checkpoint_publish`` inside
snapshot publication, and ``prefetch`` in the producer thread (kill-only —
a dead prefetch thread propagates its error instead of hanging the
consumer). Retryable failures (injected faults, I/O errors, torn npz
reads) re-execute in place; fatal errors (``strict_overflow``, schema
mismatches) propagate immediately.

**Externally drivable morsel steps.** The runner's execution is decomposed
into value-returning *step generators*: every internal loop yields one
event string per morsel of work (a scan batch through the compiled plan, a
spilled bucket joined, a scan-free device dispatch) and carries its result
back through ``return``. :func:`collect` / :func:`to_batches` simply drain
the generator; :class:`StreamExecution` hands the same generator to
external drivers — the concurrent query service (``repro.service``)
interleaves cost-model-sized morsels from many queries over one mesh by
round-robining ``next()`` across their step generators, and cancels a
query cooperatively by closing its generator (``GeneratorExit`` unwinds
the runner's ``finally`` blocks, cleaning up spill state).

With ``checkpoint_dir`` set, the runner snapshots its whole per-query
state — scan cursor, device carry tables, spill-writer manifests,
partially-joined bucket outputs, folded info counters — every
``checkpoint_every`` morsels through :class:`~repro.stream.StreamCheckpoint`
(atomic tmp-dir-rename publish). The execution is decomposed into
deterministically numbered *stages* (one per blocking materialization /
final concat), allocated in plan order, so a resumed run (``resume=True``)
skips completed stages by restoring their materialized outputs, fast-
forwards to the snapshotted cursor of the in-flight stage, and recomputes
only the tail — producing output bit-identical to an uninterrupted run.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import queue
import shutil
import tempfile
import threading
import time
from typing import Callable, Iterator, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from .. import expr as _expr
from ..core import cost_model
from ..core.api import DDF, DDFContext
from ..core.dataframe import Table, concat
from ..core.local_ops import finalize_groupby, local_groupby, local_unique
from ..core.partition import default_quota
from ..data.dataset import (
    DatasetManifest,
    DatasetWriter,
    normalize_schema,
    read_rows,
)
from ..obs import metrics as _metrics
from ..obs import model_check as _model
from ..obs import trace as _trace
from ..plan import executor, optimizer
from ..plan.logical import (
    Fused,
    GroupBy,
    Join,
    MapColumns,
    Node,
    Project,
    Rebalance,
    Recode,
    Rename,
    Scan,
    Select,
    Sort,
    Source,
    Unique,
    WithColumn,
    plan_signature,
    row_bytes_of,
    schema_of,
    walk,
)
from ..testing import faults as _faults
from . import recovery as _recovery
from .checkpoint import StreamCheckpoint

__all__ = ["collect", "to_batches", "StreamExecution"]

_EPLIKE = (Select, Project, Rename, MapColumns, WithColumn, Fused, Rebalance,
           Recode)
_SIDS = itertools.count(1 << 20)  # runner-created Source ids, disjoint range

_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)


# -- plan analysis -------------------------------------------------------------

def _has_scan(node: Node) -> bool:
    return any(isinstance(n, Scan) for n in walk(node))


def _streamable(node: Node) -> bool:
    """True when per-batch evaluation == global evaluation per batch."""
    if not _has_scan(node):
        return True
    if isinstance(node, Scan):
        return True
    if isinstance(node, _EPLIKE):
        return _streamable(node.child)
    if isinstance(node, Join):
        lh, rh = _has_scan(node.left), _has_scan(node.right)
        if lh and rh:
            return False  # cross-batch matches: needs the spill join
        return _streamable(node.left if lh else node.right)
    # GroupBy / Unique / Sort / Union / Difference: cross-batch state
    # (set ops deduplicate, so even a probe-side scan cannot stream)
    return False


def _find_blocking(root: Node) -> Node | None:
    """Deepest non-streamable scan-bearing node whose children are each
    scan-free or streamable (post-order walk => deepest first)."""
    for n in walk(root):
        if _has_scan(n) and not _streamable(n):
            if all((not _has_scan(c)) or _streamable(c) for c in n.children):
                return n
    return None


def _replace_node(root: Node, target: Node, repl: Node) -> Node:
    memo: dict = {}

    def rec(n: Node) -> Node:
        if n is target:
            return repl
        if id(n) in memo:
            return memo[id(n)]
        kids = tuple(rec(c) for c in n.children)
        out = n if kids == n.children else n.with_children(kids)
        memo[id(n)] = out
        return out

    return rec(root)


def _set_batch_caps(root: Node, cap: int) -> Node:
    def rec(n: Node) -> Node:
        if isinstance(n, Scan):
            return dataclasses.replace(n, capacity=cap)
        kids = tuple(rec(c) for c in n.children)
        return n if kids == n.children else n.with_children(kids)

    return rec(root)


def _ddf_schema(ddf: DDF) -> tuple:
    return tuple(sorted((n, str(v.dtype), tuple(v.shape[1:]))
                        for n, v in ddf.columns.items()))


# -- host-side hashing (spill-join bucketing) ----------------------------------

def _np_hash32(x: np.ndarray) -> np.ndarray:
    """numpy replica of ``partition.hash32`` (lowbias32), for host bucketing."""
    x = np.asarray(x)
    if x.dtype in (np.int64, np.uint64):
        u = x.astype(np.uint64)
        x = (u ^ (u >> np.uint64(32))).astype(np.uint32)
    elif x.dtype == np.bool_:
        x = x.astype(np.uint32)
    elif np.issubdtype(x.dtype, np.floating):
        x = np.ascontiguousarray(x.astype(np.float32)).view(np.uint32)
    else:
        x = x.astype(np.uint32)
    with np.errstate(over="ignore"):
        x = x ^ (x >> np.uint32(16))
        x = x * _M1
        x = x ^ (x >> np.uint32(15))
        x = x * _M2
        x = x ^ (x >> np.uint32(16))
    return x


def _np_hash_columns(host: Mapping[str, np.ndarray], cols) -> np.ndarray:
    n = len(next(iter(host.values())))
    h = np.zeros((n,), np.uint32)
    with np.errstate(over="ignore"):
        for name in cols:
            hk = _np_hash32(host[name])
            h = h ^ (hk + np.uint32(0x9E3779B9) + (h << np.uint32(6))
                     + (h >> np.uint32(2)))
    return h


def _drain(gen):
    """Run a step generator to completion, returning its ``return`` value.

    The synchronous entry points (:func:`collect`, the blocking prefix of
    :func:`to_batches`) drive the same generators the query service steps
    externally — draining is just "schedule every morsel back to back".
    """
    while True:
        try:
            next(gen)
        except StopIteration as e:
            return e.value


# -- prefetch (double buffering) -----------------------------------------------

_ITEM, _ERR, _DONE = "item", "err", "done"


def _prefetched(gen: Iterator, depth: int = 2) -> Iterator:
    """Run ``gen`` on a background thread with a bounded queue, so host
    decode of the next batch overlaps device execution of the current one.

    Queue traffic is tagged ``(kind, payload)`` tuples, so a decoder
    exception is an explicit ``_ERR`` item re-raised on the consumer thread
    (never confused with data), and the ``prefetch`` fault site fires in
    the producer. The consumer polls with a timeout and checks producer
    liveness: a prefetch thread that dies without enqueueing anything
    raises instead of blocking ``q.get()`` forever. Abandoning the
    iterator early (consumer ``break``/``close``) sets a stop flag the
    producer polls between puts, so the thread exits instead of blocking
    forever on a full queue."""
    q: queue.Queue = queue.Queue(maxsize=max(depth, 1))
    stop = threading.Event()

    def put(kind, payload) -> bool:
        while not stop.is_set():
            try:
                q.put((kind, payload), timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def work():
        try:
            for item in gen:
                _faults.check("prefetch")
                if not put(_ITEM, item):
                    return
            put(_DONE, None)
        except BaseException as e:  # surfaced on the consumer thread
            put(_ERR, e)

    t = threading.Thread(target=work, name="repro-stream-prefetch", daemon=True)
    t.start()
    try:
        while True:
            try:
                kind, payload = q.get(timeout=1.0)
            except queue.Empty:
                if not t.is_alive():
                    raise RuntimeError(
                        "stream prefetch thread died without yielding a "
                        "result or an error (see docs/FAULT_TOLERANCE.md)")
                continue
            if kind == _DONE:
                return
            if kind == _ERR:
                raise payload
            yield payload
    finally:
        stop.set()


# -- checkpoint session --------------------------------------------------------

class _CkptSession:
    """Per-run view of a :class:`StreamCheckpoint` store.

    Tracks completed-stage outputs (restored on resume instead of
    recomputed), the in-flight stage's snapshot callback, and the periodic
    publish cadence (every ``every`` morsel ticks). A snapshot is one
    consistent view: every completed stage's arrays + the active stage's
    cursor/state + the runner's folded info counters."""

    def __init__(self, runner: "_Runner", store: StreamCheckpoint,
                 every: int, resume: bool):
        self.runner = runner
        self.store = store
        self.every = max(int(every), 1)
        self.query_key = runner._query_key()
        # stage -> {"meta": json-able, "stage_end": int, "arrays": {name: np}}
        self.completed: dict[int, dict] = {}
        self.active_stage: int | None = None
        self.active_meta: dict | None = None
        self.active_arrays: dict | None = None
        self.resumed = False
        self._ticks = 0
        self._step = 0
        self._cur_stage: int | None = None
        self._snapshot_fn: Callable[[], tuple[dict, dict]] | None = None
        if resume and self.store.latest() is not None:
            self._restore()

    def _restore(self) -> None:
        manifest, arrays = self.store.load()
        if manifest.get("query_key") != self.query_key:
            raise ValueError(
                "resume=True but the checkpoint under "
                f"{self.store.directory!r} belongs to a different query "
                "(plan / worker count / scanned dataset changed)")
        want = {n: list(v.words)
                for n, v in sorted(self.runner.vocabs.items())}
        got = manifest.get("vocabs", want)
        if got != want:
            raise ValueError(
                "resume=True but the checkpoint's string vocabularies do "
                "not match this query's (carried code columns would decode "
                f"to different strings): checkpoint has {sorted(got)}, "
                f"query has {sorted(want)}")
        self.resumed = True
        self._step = int(manifest["step"]) + 1
        self._ticks = int(manifest.get("ticks", 0))
        for s, entry in manifest.get("completed", {}).items():
            s = int(s)
            pre = f"completed/{s}/"
            self.completed[s] = {
                "meta": entry["meta"],
                "stage_end": int(entry["stage_end"]),
                "arrays": {k[len(pre):]: v for k, v in arrays.items()
                           if k.startswith(pre)},
            }
        if manifest.get("active_stage") is not None:
            self.active_stage = int(manifest["active_stage"])
            self.active_meta = manifest.get("active_meta") or {}
            self.active_arrays = {k[len("active/"):]: v
                                  for k, v in arrays.items()
                                  if k.startswith("active/")}
        self.runner._info_restore(
            manifest.get("info", {}),
            {k[len("info/"):]: v for k, v in arrays.items()
             if k.startswith("info/")})

    def take_active(self, stage: int):
        """Consume the snapshot's in-flight state if it belongs to
        ``stage`` (returns ``(meta, arrays)`` once, else None)."""
        if self.active_stage == stage and self.active_meta is not None:
            meta, arrays = self.active_meta, self.active_arrays or {}
            self.active_stage = None
            self.active_meta = None
            self.active_arrays = None
            return meta, arrays
        return None

    def set_active(self, stage: int, snapshot_fn) -> None:
        """Register the in-flight stage's state provider:
        ``snapshot_fn() -> (json-able meta, numpy arrays)``."""
        self._cur_stage = stage
        self._snapshot_fn = snapshot_fn

    def complete(self, stage: int, meta: dict, arrays: dict) -> None:
        """Record a finished stage's output; it rides along the next
        periodic publish (resume recomputes any unpublished tail)."""
        self.completed[stage] = {"meta": dict(meta),
                                 "stage_end": int(self.runner._stage),
                                 "arrays": dict(arrays)}
        if self._cur_stage == stage:
            self._cur_stage = None
            self._snapshot_fn = None

    def tick(self) -> None:
        """One morsel of progress; publishes every ``every`` ticks."""
        self._ticks += 1
        if self._ticks % self.every == 0:
            self.publish()

    def publish(self) -> None:
        meta, active_arrays = (self._snapshot_fn() if self._snapshot_fn
                               else ({}, {}))
        info_scalars, info_arrays = self.runner._info_state()
        arrays: dict[str, np.ndarray] = {}
        completed_meta = {}
        for s, entry in self.completed.items():
            completed_meta[str(s)] = {"meta": entry["meta"],
                                      "stage_end": entry["stage_end"]}
            for name, v in entry["arrays"].items():
                arrays[f"completed/{s}/{name}"] = v
        for name, v in active_arrays.items():
            arrays[f"active/{name}"] = v
        for name, v in info_arrays.items():
            arrays[f"info/{name}"] = v
        manifest = {
            "query_key": self.query_key,
            "ticks": self._ticks,
            "completed": completed_meta,
            "active_stage": self._cur_stage,
            "active_meta": meta,
            "info": info_scalars,
            # dict-column vocabs: carried/completed-stage code arrays are
            # meaningless without these, so they are snapshot state too
            "vocabs": {n: list(v.words)
                       for n, v in sorted(self.runner.vocabs.items())},
        }
        step = self._step
        # the checkpoint_publish fault site fires inside store.save (between
        # staging and the atomic rename), so the retry wraps save directly
        self.runner._retry_call(
            "checkpoint_publish",
            lambda: self.store.save(step, manifest, arrays))
        self._step += 1
        self.runner.metrics.counter("checkpoints").add(1)
        _trace.instant("stream.checkpoint", step=step,
                       arrays=len(arrays))

    def finish(self) -> None:
        """Query succeeded: snapshots and spill are crash artifacts only."""
        self.store.clear()


# -- the runner ---------------------------------------------------------------

class _Runner:
    def __init__(self, lazy, batch_rows=None, prefetch=True,
                 carry_capacity=None, spill_dir=None, spill_compress=False,
                 strict_overflow=True, checkpoint_dir=None, checkpoint_every=4,
                 resume=False, max_retries=2, retry_backoff_s=0.05,
                 adaptive=False, replan_every=None):
        self.ctx: DDFContext = lazy._ctx
        self.P = self.ctx.nworkers
        self.params = cost_model.params_for_fabric(self.ctx.fabric)
        self.sources = dict(lazy._sources)
        self.scans: dict[int, DatasetManifest] = dict(lazy._scans)
        # dict-encoded string columns: host-side vocab metadata riding the
        # LazyDDF — folded into the checkpoint query_key (codes only mean
        # something under one vocab) and persisted/validated across resume
        self.vocabs = dict(getattr(lazy, "_vocabs", {}) or {})
        self.prefetch = bool(prefetch)
        self.carry_capacity = carry_capacity
        self.spill_dir = spill_dir
        self.spill_compress = bool(spill_compress)
        self.strict_overflow = bool(strict_overflow)
        self.adaptive = bool(adaptive)
        self.replan_every = replan_every
        # per-batch shuffle-key observation channel: _host_batches fills
        # self._obs[k] = (rows, histogram) on the decode (prefetch) thread
        # when _obs_keys is set; the consuming carry loop pops by batch
        # index (dict item assignment is GIL-atomic)
        self._obs: dict[int, tuple] = {}
        self._obs_keys: tuple | None = None
        root = lazy._root
        if batch_rows is not None:
            root = _set_batch_caps(root, max(-(-int(batch_rows) // self.P), 1))
        self.root = root
        caps = [n.capacity for n in walk(root) if isinstance(n, Scan)]
        self.nominal_batch_rows = (max(caps) * self.P) if caps else None
        # the kernel backend override threads through unchanged: every
        # per-batch program goes through cached_op, whose keys carry the
        # dispatch signature — recorded here so run info shows which
        # backend the stream executed under.
        from ..kernels import registry as _kernel_registry

        self.info: dict = {"kernel_backend": _kernel_registry.get_backend()}
        # typed counters for everything numeric the run used to keep as
        # ad-hoc info keys (batches, retries:<site>, checkpoints, peak
        # working set). Parenting under the global registry means process
        # totals aggregate across runs while each run reads its own values;
        # the info dict keeps only non-metric payloads (arrays, strings).
        self.metrics = _metrics.MetricsRegistry(parent=_metrics.registry(),
                                                prefix="stream.")
        self.metrics.counter("batches")  # pre-create: info always has it
        self.metrics.counter("chunks_decoded")   # chunk-skip visibility:
        self.metrics.counter("chunks_skipped")   # info always carries both
        self.metrics.counter("replans")
        self.retry = _recovery.RetryPolicy(max_retries=int(max_retries),
                                           backoff_s=float(retry_backoff_s))
        self._stage = 0
        if resume and checkpoint_dir is None:
            raise ValueError("resume=True requires checkpoint_dir")
        self.session: _CkptSession | None = None
        if checkpoint_dir is not None:
            self.session = _CkptSession(self, StreamCheckpoint(checkpoint_dir),
                                        checkpoint_every, resume)

    # -- fault sites + retry ---------------------------------------------------
    def _note_retry(self, site: str, attempt: int, exc: BaseException) -> None:
        # Counter.add is internally locked — safe from the prefetch thread
        # and the service driver thread without a runner-level lock.
        self.metrics.counter(f"retries:{site}").add(1)
        _trace.instant("stream.retry", site=site, attempt=int(attempt),
                       error=type(exc).__name__)

    def _retry_call(self, site: str, fn):
        """Retry ``fn`` under the site's policy (fault check is inside fn)."""
        return _recovery.call_with_retry(fn, self.retry, site,
                                         on_retry=self._note_retry)

    def _guarded(self, site: str, fn):
        """One unit of work at a named fault site: the injected-fault check
        fires before each (re-)execution, and retryable failures re-run
        with bounded backoff."""
        def unit():
            _faults.check(site)
            return fn()
        return self._retry_call(site, unit)

    # -- info bookkeeping ------------------------------------------------------
    def _fold_aux(self, aux_list: list, scope: str | None = None) -> None:
        """Fold per-batch aux dicts into run info.

        ``scope`` namespaces the keys (``"{scope}:{k}"``). Aux keys are
        ``n{i}:{name}`` with ``i`` the node's post-order index *within that
        stage's plan* — two different stages can both emit ``n0:overflow_agg``
        for unrelated operators, and on a resumed run the restored info
        already holds the crashed process's totals. Scoping keeps those
        identically named counters from alias-summing (double counting)."""
        for aux in aux_list:
            for k, v in aux.items():
                if scope is not None:
                    k = f"{scope}:{k}"
                v = np.asarray(v)
                if "overflow" in k:
                    prev = self.info.get(k)
                    self.info[k] = v if prev is None else prev + v
                else:
                    self.info[k] = v
        if self.strict_overflow:
            bad = {k: int(np.sum(v)) for k, v in self.info.items()
                   if isinstance(v, np.ndarray) and "overflow" in k
                   and np.sum(v) > 0}
            if bad:
                raise RuntimeError(
                    f"streaming run overflowed static buffers: {bad} rows "
                    "dropped — results would silently diverge from eager "
                    "execution. Pin larger quota/capacity on the offending "
                    "op, lower batch_rows, or pass strict_overflow=False to "
                    "accept eager-style truncation semantics.")

    def _info_view(self) -> dict:
        """The run-info mapping handed to callers: non-metric payloads from
        the info dict merged with this run's metric values (counters plus
        any set gauges). The metrics registry is the single source of truth
        for every numeric counter."""
        out = dict(self.info)
        out.update(self.metrics.scalars())
        return out

    def _info_state(self) -> tuple[dict, dict]:
        """Split run info into (JSON-able scalars, numpy arrays) for the
        checkpoint manifest."""
        scalars, arrays = {}, {}
        for k, v in self._info_view().items():
            if isinstance(v, np.ndarray):
                arrays[k] = v
            elif isinstance(v, (np.integer, np.floating)):
                scalars[k] = v.item()
            else:
                scalars[k] = v
        return scalars, arrays

    # gauge-typed info keys: restored with .restore (set, don't accumulate)
    _GAUGE_KEYS = frozenset({"peak_working_set_bytes"})

    def _info_restore(self, scalars: dict, arrays: dict) -> None:
        """Rehydrate run info from a checkpoint manifest.

        Numeric scalars route into this run's metric registry via
        ``restore`` — a *local-only* set. The restored counts were earned
        by the crashed process; re-adding them here would propagate to the
        parent (process-global) registry a second time and double-count
        identically named counters across the resume. ``kernel_backend``
        stays whatever the *current* process runs under."""
        for k, v in scalars.items():
            if k == "kernel_backend":
                continue
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                if k in self._GAUGE_KEYS:
                    self.metrics.gauge(k).restore(v)
                else:
                    self.metrics.counter(k).restore(int(v))
            else:
                self.info[k] = v
        self.info.update(arrays)

    # -- checkpoint/stage machinery --------------------------------------------
    def _query_key(self) -> str:
        """Identity of the work a checkpoint belongs to: the (pre-optimizer)
        plan shape, the worker count, and every scanned dataset's schema +
        chunk list. Resuming under a different key is refused — the cursor
        would index different data."""
        h = hashlib.sha256()
        h.update(plan_signature(self.root).encode())
        h.update(f"P={self.P}".encode())
        done = set()
        for n in walk(self.root):
            if isinstance(n, Scan) and n.sid not in done:
                done.add(n.sid)
                m = self.scans[n.sid]
                # capacity: the cursor's meaning depends on the batch size
                h.update(repr((len(done), int(n.capacity), m.schema,
                               m.chunks)).encode())
                # dict columns: carried codes only decode under this vocab
                h.update(repr(getattr(m, "vocabs", ())).encode())
        h.update(repr(sorted((n, v.words)
                             for n, v in self.vocabs.items())).encode())
        return h.hexdigest()

    def _stage_enter(self, kind: str):
        """Allocate the next stage id (deterministic plan-order numbering).

        Returns ``(stage, completed_entry, active_resume)``. The stage id
        is always allocated — it scopes aux counters and trace spans even
        without a checkpoint session; ``completed_entry`` is set when this
        stage already finished in the snapshot (the counter fast-forwards
        past any child stages via the recorded ``stage_end``);
        ``active_resume = (meta, arrays)`` when the snapshot died inside
        this stage."""
        i = self._stage
        self._stage += 1
        if self.session is None:
            return i, None, None
        entry = self.session.completed.get(i)
        if entry is not None:
            if entry["meta"].get("kind") != kind:
                raise ValueError(
                    f"checkpoint stage {i} is a {entry['meta'].get('kind')!r} "
                    f"stage, expected {kind!r} — snapshot does not match "
                    "this query")
            self._stage = int(entry["stage_end"])
            return i, entry, None
        return i, None, self.session.take_active(i)

    def _stage_done(self, stage, kind: str, meta: dict, arrays: dict) -> None:
        if self.session is not None and stage is not None:
            meta = dict(meta)
            meta["kind"] = kind
            self.session.complete(stage, meta, arrays)

    def _tick(self) -> None:
        if self.session is not None:
            self.session.tick()

    def _stage_span(self, stage, kind: str, t0: float, **attrs) -> None:
        """Record a retroactive span for one finished streaming stage.

        Stage drivers are generators the query service suspends between
        morsels, so a stack-scoped span would misnest across interleaved
        queries — a ``trace.complete`` from captured timestamps cannot.
        The duration therefore includes any time spent suspended."""
        if _trace.enabled():
            _trace.complete("stream.stage", t0, kind=kind, stage=stage,
                            **attrs)

    def _resident_bytes(self) -> float:
        """Padded bytes of the always-resident inputs (non-scanned
        sources)."""
        return sum(float(d.capacity) * self.P * row_bytes_of(_ddf_schema(d))
                   for d in self.sources.values())

    def _note_working_set(self, extra_bytes: float) -> None:
        """Fold one observation into the run's peak-working-set gauge: the
        resident sources plus the active stage's padded batch/carry/bucket
        tables. The admission controller learns per-query-key corrections
        from this peak (see ``repro.service.admission``)."""
        self.metrics.gauge("peak_working_set_bytes").max(
            self._resident_bytes() + float(extra_bytes))

    # -- DDF <-> checkpoint arrays ---------------------------------------------
    def _ddf_arrays(self, ddf: DDF) -> tuple[dict, dict]:
        """Faithful snapshot of a DDF: the padded global columns + the
        per-worker counts, verbatim. (A to_numpy/from_numpy round-trip
        would re-partition rows contiguously and break worker-local carry
        merges — hash placement must survive the snapshot.)"""
        arrays = {"counts": np.asarray(ddf.counts)}
        for n, v in ddf.columns.items():
            arrays[f"col/{n}"] = np.asarray(v)
        return arrays, {"capacity": int(ddf.capacity)}

    def _ddf_from_arrays(self, arrays: Mapping[str, np.ndarray]) -> DDF:
        sh = self.ctx.sharding()
        cols = {k[len("col/"):]: jax.device_put(v, sh)
                for k, v in arrays.items() if k.startswith("col/")}
        counts = jax.device_put(np.asarray(arrays["counts"], np.int32), sh)
        return DDF(cols, counts, self.ctx)

    def _restore_ddf(self, entry: dict) -> DDF:
        return self._ddf_from_arrays(entry["arrays"])

    # -- batch iteration over one streamable subtree ---------------------------
    def _prep(self, root: Node):
        from ..stats import chunk_skip_mask, plan_stats  # local: avoid cycle

        scans = [n for n in walk(root) if isinstance(n, Scan)]
        sids = {s.sid for s in scans}
        if len(sids) != 1:
            raise ValueError(f"streamable subtree must hold exactly one scan, "
                             f"got {sorted(sids)}")
        scan = scans[0]
        man = self.scans[scan.sid]
        batch_rows = scan.capacity * self.P
        srcs = {n.sid: self.sources[n.sid] for n in walk(root)
                if isinstance(n, Source)}
        src_rows = executor.source_row_counts(srcs)
        src_rows[scan.sid] = max(min(man.num_rows, batch_rows), 1)
        stats = plan_stats({scan.sid: man})
        plan = optimizer.optimize(root, self.P, src_rows, self.params,
                                  stats=stats)
        scan_opt = next(n for n in walk(plan) if isinstance(n, Scan))
        # chunk-skip mask from the *optimized* scan (post predicate
        # absorption): conservative — never flags a chunk that could
        # contribute a matching row, so skipping is bit-identical
        skips = chunk_skip_mask(man, scan_opt.pred_sigs)
        return plan, scan_opt, man, batch_rows, srcs, skips

    def _host_batches(self, man: DatasetManifest, scan: Scan,
                      batch_rows: int, start: int = 0,
                      skips=None) -> Iterator[tuple]:
        cols = scan.columns
        # expression predicates may reference columns outside the scan's
        # projected output (the optimizer narrows the decode set past them
        # because the reference set is exact): decode the superset, filter,
        # then drop the pred-only columns before admission
        read_cols = cols
        if cols is not None:
            extra = set()
            for sig in scan.pred_sigs:
                if isinstance(sig, _expr.Expr):
                    extra |= _expr.referenced_columns(sig)
            extra -= set(cols)
            if extra:
                read_cols = tuple(sorted(set(cols) | extra))
        total = man.num_rows
        nb = max(-(-total // batch_rows), 1)
        # per-chunk global offsets, for attributing skip/decode counts to
        # the batch whose row range covers each chunk
        chunk_offs = np.cumsum([0] + [r for _, r in man.chunks])
        obs_keys = self._obs_keys
        for k in range(start, nb):
            lo, hi = k * batch_rows, min((k + 1) * batch_rows, total)

            def decode(lo=lo, hi=hi, k=k):
                # spans carry the prefetch thread's tid when prefetching —
                # decode/compute overlap is visible in the trace timeline
                t0 = _trace.now()
                data = read_rows(man, lo, hi, columns=read_cols,
                                 skip_chunks=skips)
                n_over = n_skip = 0
                for i in range(len(man.chunks)):
                    if chunk_offs[i] < hi and chunk_offs[i + 1] > lo:
                        n_over += 1
                        if skips is not None and skips[i]:
                            n_skip += 1
                # Counter.add is locked: safe from the prefetch thread
                self.metrics.counter("chunks_skipped").add(n_skip)
                self.metrics.counter("chunks_decoded").add(n_over - n_skip)
                for fn in scan.pred_fns:
                    mask = np.asarray(fn(data)).astype(bool)
                    data = {n: v[mask] for n, v in data.items()}
                if read_cols is not cols:
                    data = {n: data[n] for n in cols}
                if obs_keys is not None and data \
                        and all(c in data for c in obs_keys):
                    # host mirror of the device shuffle's key->partition
                    # map: the observed per-partition histogram the
                    # adaptive controller and quota accounting consume
                    rows_out = len(next(iter(data.values())))
                    dest = _np_hash_columns(data, obs_keys) % np.uint32(self.P)
                    self._obs[k] = (rows_out,
                                    np.bincount(dest, minlength=self.P))
                if _trace.enabled():
                    out_rows = (len(next(iter(data.values())))
                                if data else hi - lo)
                    nbytes = sum(int(v.nbytes) for v in data.values())
                    _trace.complete("stream.decode", t0, batch=k,
                                    rows_read=hi - lo, rows_out=out_rows,
                                    bytes=nbytes)
                    pred = _model.scan_prediction(
                        hi - lo, row_bytes_of(schema_of(scan)), self.P,
                        self.params)
                    _model.record(
                        "partitioned_io", "stream.Scan", pred["predicted_s"],
                        _trace.now() - t0,
                        predicted_rows=pred["predicted_rows"],
                        observed_rows=out_rows,
                        predicted_bytes=pred["predicted_bytes"],
                        observed_bytes=nbytes, meta={"batch": k})
                return data

            yield k, self._guarded("chunk_decode", decode)

    def _iter_batches(self, root: Node, prep=None, start: int = 0):
        """Yield ``(batch index, result DDF, aux)`` per streamed batch of a
        streamable subtree (``start`` skips already-folded batches on
        resume — the scan cursor)."""
        plan, scan_opt, man, batch_rows, srcs, skips = prep or self._prep(root)
        batch_bytes = (scan_opt.capacity * self.P
                       * row_bytes_of(schema_of(scan_opt)))
        self._note_working_set(batch_bytes)
        preds = None
        if _trace.enabled():
            src_rows = executor.source_row_counts(srcs)
            src_rows[scan_opt.sid] = max(min(man.num_rows, batch_rows), 1)
            # the scan's partitioned_io cost is host-side decode, recorded
            # per batch in _host_batches — keep only the device program's
            # patterns here or scans would be double-counted
            preds = [p for p in _model.predict_plan(plan, self.P, src_rows,
                                                    self.params)
                     if p["pattern"] != "partitioned_io"]
        gen = self._host_batches(man, scan_opt, batch_rows, start=start,
                                 skips=skips)
        if self.prefetch:
            gen = _prefetched(gen)
        for k, data in gen:
            def run(data=data):
                bddf = DDF.from_numpy(data, self.ctx,
                                      capacity=scan_opt.capacity, mode="eager")
                return executor.run_planned(
                    plan, self.ctx, {**srcs, scan_opt.sid: bddf})

            if preds is not None:
                t0 = _trace.now()
                out, aux = self._guarded("device_op", run)
                jax.block_until_ready(out.counts)
                t1 = _trace.now()
                rows = int(np.asarray(out.counts).sum())
                _trace.complete("stream.device_op", t0, t1, batch=k,
                                ops=len(preds), out_rows=rows)
                _model.record_program(preds, t1 - t0, observed_rows=rows,
                                      op_prefix="stream.")
            else:
                out, aux = self._guarded("device_op", run)
            self.metrics.counter("batches").add(1)
            yield k, out, aux

    # -- streamable whole-plan paths -------------------------------------------
    def _stream_host(self, root: Node, start: int = 0, prep=None,
                     scope: str | None = None) -> Iterator[tuple]:
        # aux folds per batch: a strict_overflow violation raises BEFORE the
        # truncated batch is handed out (and early iterator abandon cannot
        # skip the check). The per-batch device sync this implies is free
        # here — to_numpy() syncs on the same results anyway.
        for k, out, aux in self._iter_batches(root, prep=prep, start=start):
            self._fold_aux([aux], scope=scope)
            yield k, out.to_numpy()

    def _from_host(self, host: dict, schema: tuple) -> DDF:
        if not host:
            host = {n: np.zeros((0,) + tuple(tail), np.dtype(dt))
                    for n, dt, tail in schema}
        total = len(next(iter(host.values())))
        cap = max(-(-total // self.P), 1)
        return DDF.from_numpy(host, self.ctx, capacity=cap, mode="eager")

    def _stream_concat(self, root: Node) -> DDF:
        stage, entry, resume = self._stage_enter("concat")
        if entry is not None:
            return self._restore_ddf(entry)
        t0 = _trace.now()
        schema = schema_of(root)
        outs: list[dict] = []
        cursor = {"k": 0}
        if resume is not None:
            rmeta, rarr = resume
            cursor["k"] = int(rmeta["k"])
            acc = {n: rarr[f"acc/{n}"] for n, _, _ in schema
                   if f"acc/{n}" in rarr}
            if acc:
                outs.append(acc)

        def snap():
            host = {n: np.concatenate([o[n] for o in outs])
                    for n, _, _ in schema} if outs else {}
            return ({"k": cursor["k"]},
                    {f"acc/{n}": v for n, v in host.items()})

        if self.session is not None:
            self.session.set_active(stage, snap)
        for k, host in self._stream_host(root, start=cursor["k"],
                                         scope=f"s{stage}"):
            outs.append(host)
            cursor["k"] = k + 1
            self._tick()
            yield "concat"
        host = {n: np.concatenate([o[n] for o in outs])
                for n, _, _ in schema} if outs else {}
        out = self._from_host(host, schema)
        self._stage_span(stage, "concat", t0, batches=cursor["k"])
        arrays, meta = self._ddf_arrays(out)
        self._stage_done(stage, "concat", meta, arrays)
        return out

    # -- carry-state tails ------------------------------------------------------
    def _carry_cap(self, node: Node, scan_total: int) -> int:
        if self.carry_capacity:
            return int(self.carry_capacity)
        if getattr(node, "capacity", None):
            return int(node.capacity)
        return max(-(-max(scan_total, 1) // self.P), 1)

    def _empty_carry(self, schema: tuple, cap: int) -> DDF:
        host = {n: np.zeros((0,) + tuple(tail), np.dtype(dt))
                for n, dt, tail in schema}
        return DDF.from_numpy(host, self.ctx, capacity=cap, mode="eager")

    @staticmethod
    def _truncate_with_overflow(full: Table, cap: int):
        """Cut a compacted table down to the carry capacity, reporting how
        many live rows (groups) the cut drops — the carry-state analogue of
        the shuffle overflow counters, so ``strict_overflow`` sees it."""
        cols = {k: v[:cap] for k, v in full.columns.items()}
        ov = jnp.maximum(full.nvalid - cap, 0)
        return Table(cols, jnp.minimum(full.nvalid, cap)), {"overflow_carry": ov}

    @staticmethod
    def _keys_direct(node: Node) -> bool:
        """True when every node below a shuffle passes the scan's columns
        through untouched — the condition under which the host hash
        mirror over decoded rows equals the device shuffle's
        key->partition map (the observation the adaptive controller
        feeds on)."""
        return all(isinstance(n, (Scan, Select, Project, Rebalance))
                   for n in walk(node))

    def _run_carry(self, B: Node, batch_root: Node, merge_key: tuple, merge,
                   stage=None, resume=None):
        """Shared carry-state drive loop: stream batches through the
        compiled per-batch plan, folding each result into the carry DDF.
        The carry table (padded columns + per-worker counts) plus the scan
        cursor *is* the whole cross-batch state, so it is exactly what the
        checkpoint session snapshots.

        With ``adaptive=True`` an :class:`~repro.stats.AdaptiveController`
        watches each batch's observed key histogram (host mirror of the
        device shuffle) and per-worker group counts; at its decision
        cadence it may re-pin quota/capacity on the batch plan for all
        *later* morsels. Corrections only resize static buffers, so
        results stay bit-identical (undersized corrections raise under
        ``strict_overflow`` rather than truncate silently). Controller
        state snapshots into the checkpoint's active-stage meta, so a
        resumed stream re-enters the exact corrected plan and makes the
        same future decisions."""
        from ..stats import AdaptiveController  # local: avoid import cycle

        prep = self._prep(batch_root)
        plan = prep[0]
        cap = self._carry_cap(B, prep[2].num_rows)
        nb = max(-(-prep[2].num_rows // prep[3]), 1)
        shuffle_node = next((n for n in walk(plan)
                             if isinstance(n, (GroupBy, Unique))), None)
        plan_quota = getattr(shuffle_node, "quota", None)
        keys = getattr(B, "by", None) or getattr(B, "subset", None)
        keys_direct = bool(keys) and self._keys_direct(batch_root.children[0])
        ctrl = None
        if (self.adaptive and plan_quota
                and getattr(shuffle_node, "capacity", None)):
            ctrl = AdaptiveController(self.P, plan_quota,
                                      int(shuffle_node.capacity),
                                      replan_every=self.replan_every)
        state = {"k": 0, "carry": None}
        if resume is not None:
            rmeta, rarr = resume
            state["k"] = int(rmeta["k"])
            cap = int(rmeta["cap"])
            state["carry"] = self._ddf_from_arrays(rarr)
            if ctrl is not None and rmeta.get("adaptive"):
                ctrl = AdaptiveController.restore(rmeta["adaptive"])
        else:
            state["carry"] = self._empty_carry(schema_of(plan), cap)
        cur_root = batch_root
        if ctrl is not None and (ctrl.quota_override is not None
                                 or ctrl.capacity_override is not None):
            # resumed mid-correction: re-enter the corrected plan exactly
            cur_root = ctrl.pin(batch_root)
            prep = self._prep(cur_root)
            plan = prep[0]
        # active set here = the carry table plus one batch's partial result
        self._note_working_set((cap + prep[1].capacity) * self.P
                               * row_bytes_of(schema_of(plan)))

        def snap():
            arrays, _ = self._ddf_arrays(state["carry"])
            meta = {"k": state["k"], "cap": cap}
            if ctrl is not None:
                meta["adaptive"] = ctrl.state_dict()
            return meta, arrays

        if self.session is not None:
            self.session.set_active(stage, snap)
        scope = f"s{stage}"
        if keys_direct and (ctrl is not None or _trace.enabled()):
            self._obs_keys = tuple(keys)
        try:
            while state["k"] < nb:
                gen = self._iter_batches(cur_root, prep=prep,
                                         start=state["k"])
                for k, out, aux in gen:
                    carry, carry_ov = state["carry"]._run(
                        merge_key + (cap,), merge(cap), out)
                    state["carry"] = carry
                    self._fold_aux([aux, {"carry:overflow_carry":
                                          carry_ov["overflow_carry"]}],
                                   scope=scope)
                    state["k"] = k + 1
                    obs = self._obs.pop(k, None)
                    if obs is not None:
                        rows_in, hist = obs
                        quota_now = (ctrl.current_quota if ctrl is not None
                                     else plan_quota)
                        if _trace.enabled() and quota_now:
                            # quota accuracy, in rows: planned per-partition
                            # allowance vs the batch's observed max cell
                            _model.record(
                                "shuffle_quota",
                                f"stream.{type(B).__name__}",
                                float(quota_now),
                                float(max(int(hist.max()), 1)),
                                observed_rows=int(rows_in),
                                meta={"batch": k})
                        if ctrl is not None:
                            counts = np.asarray(out.counts)
                            ctrl.observe(rows_in, hist=hist,
                                         groups_out=int(counts.sum()),
                                         max_worker_groups=int(counts.max()))
                    self._tick()
                    yield "carry"
                    if (ctrl is not None and state["k"] < nb
                            and ctrl.should_replan()):
                        gen.close()  # stop the prefetch thread cleanly
                        cur_root = ctrl.apply(batch_root)
                        prep = self._prep(cur_root)
                        plan = prep[0]
                        self.metrics.counter("replans").add(1)
                        _trace.instant("stream.replan", batch=state["k"],
                                       quota=int(ctrl.current_quota))
                        break
                else:
                    break  # generator exhausted: all batches folded
        finally:
            self._obs_keys = None
            self._obs.clear()
        return state["carry"], cap

    def _stream_groupby(self, B: GroupBy) -> DDF:
        stage, entry, resume = self._stage_enter("groupby")
        if entry is not None:
            return self._restore_ddf(entry)
        t0 = _trace.now()
        aggs = {k: v for k, v in B.aggs}
        batch_root = dataclasses.replace(B, emit_partials=True, quota=None,
                                         capacity=None, num_chunks=None)
        by, aggs_t = B.by, B.aggs

        def merge(cap):
            def fn(comm, c, b):
                # merge at full concat capacity (groups <= rows, so no
                # truncation), then cut to the carry capacity with an
                # explicit overflow counter
                full = local_groupby(concat(c, b), by, aggs, merge=True)
                return self._truncate_with_overflow(full, cap)
            return fn

        carry, cap = yield from self._run_carry(
            B, batch_root, ("stream-gb-merge", by, aggs_t), merge,
            stage=stage, resume=resume)
        out = carry._run(("stream-gb-fin", aggs_t, cap),
                         lambda comm, t: finalize_groupby(t, aggs))
        self._stage_span(stage, "groupby", t0)
        arrays, meta = self._ddf_arrays(out)
        self._stage_done(stage, "groupby", meta, arrays)
        return out

    def _stream_unique(self, B: Unique) -> DDF:
        stage, entry, resume = self._stage_enter("unique")
        if entry is not None:
            return self._restore_ddf(entry)
        t0 = _trace.now()
        batch_root = dataclasses.replace(B, quota=None, capacity=None,
                                         num_chunks=None)
        subset = B.subset

        def merge(cap):
            def fn(comm, c, b):
                # carry rows concat first: earliest-batch occurrence wins,
                # matching local_unique's stable first-occurrence contract
                full = local_unique(concat(c, b), subset)
                return self._truncate_with_overflow(full, cap)
            return fn

        carry, _ = yield from self._run_carry(
            B, batch_root, ("stream-uq-merge", subset), merge,
            stage=stage, resume=resume)
        self._stage_span(stage, "unique", t0)
        arrays, meta = self._ddf_arrays(carry)
        self._stage_done(stage, "unique", meta, arrays)
        return carry

    # -- spill tails ------------------------------------------------------------
    def _spill_chunk_rows(self) -> int:
        return self.nominal_batch_rows or 65536

    def _spill_writer(self, schema: tuple) -> DatasetWriter:
        d = tempfile.mkdtemp(prefix="repro-spill-",
                             dir=self.spill_dir)
        # stats=False: spill runs are consumed once in full — sketching
        # them would cost write-time work with no pruning to gain
        return DatasetWriter(d, schema=schema, chunk_rows=self._spill_chunk_rows(),
                             compress=self.spill_compress, stats=False)

    def _stage_spill_writer(self, tag: str, schema: tuple,
                            chunks=None, buffered=None) -> DatasetWriter:
        """A spill writer whose files live under the checkpoint store's
        persistent spill root (they must survive a crash); ``chunks`` +
        ``buffered`` rebuild it from an active-stage snapshot — chunk files
        written after the snapshot are overwritten by index as the resumed
        stream re-appends."""
        d = self.session.store.spill_dir(tag)
        if chunks is None:
            return DatasetWriter(d, schema=schema,
                                 chunk_rows=self._spill_chunk_rows(),
                                 compress=self.spill_compress, stats=False)
        return DatasetWriter.resume(d, schema, chunks, buffered=buffered,
                                    chunk_rows=self._spill_chunk_rows(),
                                    compress=self.spill_compress)

    def _spill_append(self, writer: DatasetWriter, host: dict) -> None:
        self._guarded("spill_write", lambda: writer.append(host))

    def _stream_sort(self, B: Sort) -> DDF:
        """Spill the sort's input to disk while streaming, then one stable
        host merge by the key. The spill bounds host RSS *during* the
        streaming phase (batches land on disk, not in a growing list); the
        final merge necessarily materializes on host — the sorted result
        becomes a device DDF anyway, so that peak is unavoidable. A k-way
        merge of pre-sorted runs would only change the merge's working set,
        not the result materialization."""
        stage, entry, resume = self._stage_enter("sort")
        if entry is not None:
            return self._restore_ddf(entry)
        t0 = _trace.now()
        prefix = B.child
        schema = schema_of(prefix)
        cursor = {"k": 0}
        if self.session is not None:
            if resume is not None:
                rmeta, rarr = resume
                cursor["k"] = int(rmeta["k"])
                chunks = [(f, int(r)) for f, r in rmeta["chunks"]]
                buffered = {k[len("buf/"):]: v for k, v in rarr.items()
                            if k.startswith("buf/")}
                writer = self._stage_spill_writer(f"stage{stage}", schema,
                                                  chunks=chunks,
                                                  buffered=buffered)
            else:
                writer = self._stage_spill_writer(f"stage{stage}", schema)
            cleanup = False
        else:
            writer = self._spill_writer(schema)
            cleanup = True

        def snap():
            chunks, buf = writer.state()
            return ({"k": cursor["k"], "chunks": [[f, int(r)] for f, r in chunks]},
                    {f"buf/{n}": v for n, v in buf.items()})

        if self.session is not None:
            self.session.set_active(stage, snap)
        try:
            for k, host in self._stream_host(prefix, start=cursor["k"],
                                             scope=f"s{stage}"):
                self._spill_append(writer, host)
                cursor["k"] = k + 1
                self._tick()
                yield "sort-spill"
            man = writer.close()
            host = read_rows(man, 0, man.num_rows)
        finally:
            if cleanup:
                shutil.rmtree(writer.directory, ignore_errors=True)
        key = host[B.by]
        if B.descending:
            # the same order-reversing map local_sort uses: exact for ints,
            # sign-flip for floats; stable argsort keeps global row order
            # among equal keys (matching the eager shuffle arrival order)
            key = -key if np.issubdtype(key.dtype, np.floating) \
                else np.bitwise_not(key)
        order = np.argsort(key, kind="stable")
        host = {k: v[order] for k, v in host.items()}
        out = self._from_host(host, schema)
        self._stage_span(stage, "sort", t0, batches=cursor["k"])
        arrays, meta = self._ddf_arrays(out)
        self._stage_done(stage, "sort", meta, arrays)
        return out

    def _spill_buckets(self, side: Node, on: tuple, nb: int):
        """Stream (or eagerly compute) one join side into key-hash buckets."""
        if not _has_scan(side):
            raise AssertionError(
                "spill join is only reachable with scans on both sides")
        stage, entry, resume = self._stage_enter("buckets")
        schema = schema_of(side)
        norm = normalize_schema(schema)
        if entry is not None:
            return [DatasetManifest(d, norm,
                                    tuple((f, int(r)) for f, r in ch))
                    for d, ch in zip(entry["meta"]["dirs"],
                                     entry["meta"]["chunks"])]
        t0 = _trace.now()
        cursor = {"k": 0}
        if self.session is not None:
            chunks_by_b = [None] * nb
            buf_by_b: list = [None] * nb
            if resume is not None:
                rmeta, rarr = resume
                cursor["k"] = int(rmeta["k"])
                for b in range(nb):
                    chunks_by_b[b] = [(f, int(r)) for f, r in rmeta["chunks"][b]]
                    pre = f"b{b}/"
                    buf = {k[len(pre):]: v for k, v in rarr.items()
                           if k.startswith(pre)}
                    buf_by_b[b] = buf or None
            writers = [self._stage_spill_writer(f"stage{stage}/b{b}", schema,
                                                 chunks=chunks_by_b[b],
                                                 buffered=buf_by_b[b])
                       for b in range(nb)]
        else:
            writers = [self._spill_writer(schema) for _ in range(nb)]

        def snap():
            metas, arrays = [], {}
            for b, w in enumerate(writers):
                chunks, buf = w.state()
                metas.append([[f, int(r)] for f, r in chunks])
                for n, v in buf.items():
                    arrays[f"b{b}/{n}"] = v
            return {"k": cursor["k"], "chunks": metas}, arrays

        if self.session is not None:
            self.session.set_active(stage, snap)
        for k, host in self._stream_host(side, start=cursor["k"],
                                         scope=f"s{stage}"):
            cursor["k"] = k + 1
            if len(next(iter(host.values()))):
                h = _np_hash_columns(host, on) % np.uint32(nb)
                for b in range(nb):
                    m = h == b
                    if m.any():
                        self._spill_append(writers[b],
                                           {c: v[m] for c, v in host.items()})
            self._tick()
            yield "bucket-spill"
        mans = [w.close() for w in writers]
        self._stage_span(stage, "buckets", t0, batches=cursor["k"],
                         buckets=nb)
        self._stage_done(stage, "buckets",
                         {"dirs": [m.directory for m in mans],
                          "chunks": [[[f, int(r)] for f, r in m.chunks]
                                     for m in mans]}, {})
        return mans

    def _stream_join_spill(self, B: Join) -> DDF:
        """Out-of-core join with scans on both sides: hash-bucket spill.

        Each side spills into ``nb`` key-hash buckets (equal keys share a
        bucket), then bucket pairs are joined on device one at a time —
        neither side's build table ever has to fit device capacity. Output
        order is bucket-major (row-set equal to the eager join; a downstream
        sort/groupby canonicalizes it). Under a checkpoint session the two
        bucket spills and the bucket-join loop are three separate stages —
        the join loop's snapshot carries the bucket cursor, the adaptive
        ``cap_out``/``quota`` (their growth is deterministic, so a resumed
        run continues with the same buffer sizes), and the concatenated
        output accumulated so far."""
        on = B.on
        per_side_rows = []
        for side in (B.left, B.right):
            sids = [n.sid for n in walk(side) if isinstance(n, Scan)]
            per_side_rows.append(sum(self.scans[s].num_rows for s in sids))
        br = self.nominal_batch_rows or max(max(per_side_rows), 1)
        nb = max(-(-2 * max(per_side_rows) // br), 1)
        mans_l = yield from self._spill_buckets(B.left, on, nb)
        mans_r = yield from self._spill_buckets(B.right, on, nb)
        stage, entry, resume = self._stage_enter("bucketjoin")
        if entry is not None:
            return self._restore_ddf(entry)
        t0 = _trace.now()
        schema = schema_of(B)
        cap_l = max(max((m.num_rows for m in mans_l), default=0) // self.P + 1, 1)
        cap_r = max(max((m.num_rows for m in mans_r), default=0) // self.P + 1, 1)
        sid_l, sid_r = next(_SIDS), next(_SIDS)
        state = {"j": 0,
                 "quota": int(B.quota or default_quota(max(cap_l, cap_r),
                                                       self.P)),
                 "cap_out": int(B.capacity or 2 * max(cap_l, cap_r))}
        outs: list[dict] = []
        if resume is not None:
            rmeta, rarr = resume
            state.update(j=int(rmeta["j"]), quota=int(rmeta["quota"]),
                         cap_out=int(rmeta["cap_out"]))
            acc = {n: rarr[f"acc/{n}"] for n, _, _ in schema
                   if f"acc/{n}" in rarr}
            if acc:
                outs.append(acc)

        def snap():
            host = {n: np.concatenate([o[n] for o in outs])
                    for n, _, _ in schema} if outs else {}
            return ({"j": state["j"], "quota": state["quota"],
                     "cap_out": state["cap_out"]},
                    {f"acc/{n}": v for n, v in host.items()})

        if self.session is not None:
            self.session.set_active(stage, snap)
        rb_l = row_bytes_of(schema_of(B.left))
        rb_r = row_bytes_of(schema_of(B.right))
        rb_out = row_bytes_of(schema)
        try:
            for j in range(state["j"], nb):
                self._note_working_set(
                    self.P * (cap_l * rb_l + cap_r * rb_r
                              + state["cap_out"] * rb_out))
                ml, mr = mans_l[j], mans_r[j]
                if ml.num_rows == 0 or mr.num_rows == 0:
                    state["j"] = j + 1
                    continue
                dl = DDF.from_numpy(read_rows(ml, 0, ml.num_rows), self.ctx,
                                    capacity=cap_l, mode="eager")
                dr = DDF.from_numpy(read_rows(mr, 0, mr.num_rows), self.ctx,
                                    capacity=cap_r, mode="eager")
                while True:
                    # adaptive sizing: join multiplicity is data-dependent,
                    # so grow the static buffers and retry the bucket when
                    # pairs (capacity) or skewed keys (quota) overflow
                    jroot = Join(Source(sid_l, mans_l[0].schema, cap_l),
                                 Source(sid_r, mans_r[0].schema, cap_r),
                                 on, strategy="auto", quota=state["quota"],
                                 capacity=state["cap_out"])

                    def run(jroot=jroot, dl=dl, dr=dr):
                        return executor.execute(
                            jroot, self.ctx, {sid_l: dl, sid_r: dr},
                            src_rows={sid_l: cap_l * self.P,
                                      sid_r: cap_r * self.P})

                    out, aux = self._guarded("device_op", run)
                    ovj = sum(int(np.sum(v)) for k, v in aux.items()
                              if "overflow_join" in k)
                    ovs = sum(int(np.sum(v)) for k, v in aux.items()
                              if "overflow" in k and "overflow_join" not in k)
                    if not ovj and not ovs:
                        self._fold_aux([aux], scope=f"s{stage}")
                        break
                    if ovj:
                        state["cap_out"] *= 2
                    if ovs:
                        state["quota"] *= 2
                outs.append(out.to_numpy())
                state["j"] = j + 1
                self._tick()
                yield "bucket-join"
        finally:
            if self.session is None:
                for m in mans_l + mans_r:
                    shutil.rmtree(m.directory, ignore_errors=True)
        host = {n: np.concatenate([o[n] for o in outs])
                for n, _, _ in schema} if outs else {}
        out = self._from_host(host, schema)
        self._stage_span(stage, "bucketjoin", t0, buckets=nb)
        arrays, meta = self._ddf_arrays(out)
        self._stage_done(stage, "bucketjoin", meta, arrays)
        return out

    # -- staged materialization --------------------------------------------------
    def _collect_scanfree(self, root: Node):
        srcs = {n.sid: self.sources[n.sid] for n in walk(root)
                if isinstance(n, Source)}
        if isinstance(root, Source):
            return srcs[root.sid], {}
        return self._guarded("device_op",
                             lambda: executor.execute(root, self.ctx, srcs))

    def _materialize_blocking(self, B: Node):
        """Step generator: finalize one blocking node, returning its DDF."""
        if isinstance(B, GroupBy) and _streamable(B.child) and _has_scan(B.child):
            return (yield from self._stream_groupby(B))
        if isinstance(B, Unique) and _streamable(B.child) and _has_scan(B.child):
            return (yield from self._stream_unique(B))
        if isinstance(B, Sort) and _streamable(B.child) and _has_scan(B.child):
            return (yield from self._stream_sort(B))
        if (isinstance(B, Join) and _has_scan(B.left) and _has_scan(B.right)
                and _streamable(B.left) and _streamable(B.right)):
            return (yield from self._stream_join_spill(B))
        # generic fallback: materialize scan-bearing children individually,
        # then run the (now scan-free) blocking op eagerly. The wrapping
        # stage completes after its recursive child stages, so its recorded
        # stage_end fast-forwards the counter past them on resume.
        stage, entry, _ = self._stage_enter("blocking")
        if entry is not None:
            return self._restore_ddf(entry)
        kids = []
        for c in B.children:
            if _has_scan(c):
                d = yield from self._collect_node(c)
                sid = next(_SIDS)
                self.sources[sid] = d
                kids.append(Source(sid, _ddf_schema(d), d.capacity))
            else:
                kids.append(c)
        out, aux = self._collect_scanfree(B.with_children(kids))
        self._fold_aux([aux], scope=f"s{stage}")
        yield "device"
        arrays, meta = self._ddf_arrays(out)
        self._stage_done(stage, "blocking", meta, arrays)
        return out

    def _drain_blocking(self, root: Node):
        """Step generator: finalize blocking nodes bottom-up until the plan
        is streamable (or scan-free), substituting each result back as a
        Source; returns the rewritten plan root."""
        while _has_scan(root) and not _streamable(root):
            B = _find_blocking(root)
            if B is None:  # cannot happen; guard against infinite loop
                raise RuntimeError("unstreamable plan with no blocking node")
            mat = yield from self._materialize_blocking(B)
            sid = next(_SIDS)
            self.sources[sid] = mat
            root = _replace_node(root, B, Source(sid, _ddf_schema(mat),
                                                 mat.capacity))
        return root

    def _collect_node(self, root: Node):
        """Step generator: evaluate a plan subtree, returning its DDF."""
        root = yield from self._drain_blocking(root)
        if _has_scan(root):
            return (yield from self._stream_concat(root))
        out, aux = self._collect_scanfree(root)
        self._fold_aux([aux])
        yield "device"
        return out

    # -- public entry points -----------------------------------------------------
    def steps(self):
        """The whole query as one externally drivable step generator.

        Yields one event string per morsel of work (the scheduling quantum:
        a scan batch, a spilled bucket join, a scan-free device dispatch)
        and returns ``(result DDF, info dict)``. Closing the generator
        mid-run cancels the query cooperatively — the runner's ``finally``
        blocks release spill/prefetch resources on the way out."""
        out = yield from self._collect_node(self.root)
        if self.session is not None:
            self.session.finish()
        return out, self._info_view()

    def run(self):
        return _drain(self.steps())

    def batches(self) -> Iterator[dict]:
        root = _drain(self._drain_blocking(self.root))
        if _has_scan(root):
            stage, entry, resume = self._stage_enter("emit")
            if entry is None:
                cursor = {"k": int(resume[0]["k"]) if resume is not None else 0}
                if self.session is not None:
                    self.session.set_active(
                        stage, lambda: ({"k": cursor["k"]}, {}))
                for k, host in self._stream_host(root, start=cursor["k"],
                                                 scope=f"s{stage}"):
                    yield host
                    cursor["k"] = k + 1
                    self._tick()
                self._stage_done(stage, "emit", {}, {})
            if self.session is not None:
                self.session.finish()
            return
        out, aux = self._collect_scanfree(root)
        self._fold_aux([aux])
        host = out.to_numpy()
        total = len(next(iter(host.values()))) if host else 0
        step = self.nominal_batch_rows or max(total, 1)
        for lo in range(0, max(total, 1), step):
            yield {k: v[lo:lo + step] for k, v in host.items()}
        if self.session is not None:
            self.session.finish()


class StreamExecution:
    """Externally drivable streaming execution of one lazy query.

    Where :func:`collect` drives every morsel back to back,
    ``StreamExecution`` exposes the runner's step generator so an external
    scheduler (``repro.service.QueryService``) can interleave cost-model-
    sized morsels from *many* queries over one shared mesh::

        ex = StreamExecution(lazy, batch_rows=..., checkpoint_dir=...)
        for event in ex.steps():   # one event per morsel — yield here to
            ...                    # run a morsel of some *other* query
        out, info = ex.result, ex.info

    Args match :func:`collect`. ``steps()`` may be called once; the result
    DDF and info counters are populated when the generator is exhausted.
    Closing the generator early cancels the query cooperatively (spill and
    prefetch state is released by the runner's ``finally`` blocks).
    """

    def __init__(self, lazy, **opts):
        self._runner = _Runner(lazy, **opts)
        self._started = False
        self.result: DDF | None = None
        self.info: dict | None = None

    @property
    def nominal_batch_rows(self) -> int | None:
        """Cost-model global rows per morsel (None for scan-free plans)."""
        return self._runner.nominal_batch_rows

    def steps(self) -> Iterator[str]:
        """Yield one event string per morsel; populates ``result``/``info``
        on exhaustion. Single-shot: a second call raises ``RuntimeError``."""
        if self._started:
            raise RuntimeError("StreamExecution.steps() may only be called "
                               "once per execution")
        self._started = True
        self.result, self.info = yield from self._runner.steps()


def collect(lazy, batch_rows: int | None = None, prefetch: bool = True,
            carry_capacity: int | None = None, spill_dir: str | None = None,
            spill_compress: bool = False, strict_overflow: bool = True,
            checkpoint_dir: str | None = None, checkpoint_every: int = 4,
            resume: bool = False, max_retries: int = 2,
            retry_backoff_s: float = 0.05, adaptive: bool = False,
            replan_every: int | None = None):
    """Run a scan-bearing lazy plan through the streaming engine.

    Args:
      lazy: the ``LazyDDF`` to execute (``repro.stream.scan_*`` leaves).
      batch_rows: override the cost-model batch size (global rows/batch).
      prefetch: overlap host decode of batch k+1 with device execution of
        batch k (double buffering); False decodes serially (A/B baseline).
      carry_capacity: per-worker capacity of groupby/unique carry state
        (default: scan rows / workers, the eager-equivalent bound).
      spill_dir: parent directory for spill datasets (default: system tmp).
      spill_compress: compress spilled chunks (saves disk, costs CPU).
      strict_overflow: raise when any static shuffle/join buffer overflowed
        (rows dropped) instead of silently diverging from eager results.
      checkpoint_dir: enable fault-tolerant execution — snapshot the full
        per-query state (scan cursor, carry tables, spill manifests, info
        counters) into this directory every ``checkpoint_every`` morsels
        via an atomic publish; cleared on success.
      checkpoint_every: morsels between snapshots (lower = less recompute
        after a crash, more publish overhead).
      resume: restart from the newest snapshot under ``checkpoint_dir``
        (falls back to a fresh run when none exists; raises ``ValueError``
        if the snapshot belongs to a different query). The resumed result
        is bit-identical to an uninterrupted run.
      max_retries: in-place re-executions per failed unit of work (morsel
        decode / device op / spill append / checkpoint publish) before the
        error propagates; only retryable errors are retried (see
        ``repro.stream.recovery.RETRYABLE_EXCEPTIONS``).
      retry_backoff_s: base of the bounded exponential retry backoff.
      adaptive: enable mid-stream re-planning — an
        ``repro.stats.AdaptiveController`` corrects quota/capacity for
        later morsels of carry-fold stages (groupby/unique) from observed
        batch key histograms; results stay bit-identical (corrections
        only resize static buffers), ``info["replans"]`` counts the
        plan revisions, and the controller state rides the checkpoint so
        resumed runs make the same decisions. See docs/STATISTICS.md.
      replan_every: batches between adaptive re-plan decision points
        (default ``cost_model.ADAPTIVE_REPLAN_EVERY``).

    Returns:
      ``(result DDF, info dict)`` — info carries ``batches`` plus summed
      per-batch overflow counters (namespaced ``s<stage>:`` per streaming
      stage), ``retries:<site>`` counts, ``checkpoints`` published,
      ``chunks_decoded`` / ``chunks_skipped`` (statistics-layer chunk
      skipping on absorbed scan predicates), ``replans``, and the
      observed ``peak_working_set_bytes`` (which the query service's
      admission controller learns from). The numeric counters come from a
      per-run ``repro.obs`` metrics registry parented to the global one.
    """
    r = _Runner(lazy, batch_rows=batch_rows, prefetch=prefetch,
                carry_capacity=carry_capacity, spill_dir=spill_dir,
                spill_compress=spill_compress, strict_overflow=strict_overflow,
                checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
                resume=resume, max_retries=max_retries,
                retry_backoff_s=retry_backoff_s, adaptive=adaptive,
                replan_every=replan_every)
    return r.run()


def to_batches(lazy, batch_rows: int | None = None, prefetch: bool = True,
               carry_capacity: int | None = None, spill_dir: str | None = None,
               spill_compress: bool = False, strict_overflow: bool = True,
               checkpoint_dir: str | None = None, checkpoint_every: int = 4,
               resume: bool = False, max_retries: int = 2,
               retry_backoff_s: float = 0.05, adaptive: bool = False,
               replan_every: int | None = None) -> Iterator[dict]:
    """Stream a lazy plan's result as host column-dict batches.

    Fully-streamable plans yield one dict per morsel without materializing
    the whole result (true out-of-core iteration); plans needing carry or
    spill finalization finalize first and yield ``batch_rows``-sized slices
    of the final table. Args as :func:`collect`; with ``resume=True`` the
    iterator re-yields from the last snapshotted cursor (batches already
    consumed after that snapshot are yielded again).
    """
    r = _Runner(lazy, batch_rows=batch_rows, prefetch=prefetch,
                carry_capacity=carry_capacity, spill_dir=spill_dir,
                spill_compress=spill_compress, strict_overflow=strict_overflow,
                checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
                resume=resume, max_retries=max_retries,
                retry_backoff_s=retry_backoff_s, adaptive=adaptive,
                replan_every=replan_every)
    yield from r.batches()
