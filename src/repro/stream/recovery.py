"""Error classification + bounded-backoff retry for the streaming runner.

A morsel-driven stream fails in two fundamentally different ways:

- **retryable** — transient environment faults: injected chaos faults
  (``repro.testing.InjectedFault``), I/O errors during chunk decode or
  spill write (``OSError``/``EOFError``), and corrupt-archive decode
  errors (``zipfile.BadZipFile`` from a torn ``.npz`` read). Re-executing
  the same unit of work is safe (decode and the compiled device op are
  pure; spill appends only mutate state after a successful write), so the
  runner retries in place with bounded exponential backoff.
- **fatal** — deterministic program errors that would recur on every
  attempt: ``strict_overflow`` violations (``RuntimeError``), schema
  mismatches (``ValueError``/``KeyError``), plan bugs. Retrying these only
  delays the failure, so they propagate immediately; recovery is
  checkpoint/restore (fix the query, then ``resume=True``).

The classification is a total function over exceptions (default: fatal),
mirroring the retry-pattern guidance in the resilience literature: never
retry on errors the caller caused.
"""

from __future__ import annotations

import dataclasses
import time
import zipfile
from typing import Callable

from ..testing.faults import InjectedFault

__all__ = ["RETRYABLE_EXCEPTIONS", "RetryPolicy", "call_with_retry",
           "classify_error"]

#: Exception types the runner re-executes in place (transient faults).
RETRYABLE_EXCEPTIONS = (InjectedFault, OSError, EOFError, zipfile.BadZipFile)


def classify_error(exc: BaseException) -> str:
    """``"retryable"`` for transient I/O / injected faults, ``"fatal"``
    for deterministic errors (strict_overflow, schema mismatch, bugs)."""
    return "retryable" if isinstance(exc, RETRYABLE_EXCEPTIONS) else "fatal"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for retryable morsel failures.

    ``max_retries`` bounds re-executions *per unit of work* (a morsel
    decode, one device op, one spill append, one checkpoint publish), not
    per stream; attempt ``k`` sleeps ``backoff_s * backoff_factor**k``
    capped at ``max_backoff_s``."""

    max_retries: int = 2
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    max_backoff_s: float = 2.0

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (0-based)."""
        return min(self.backoff_s * self.backoff_factor ** attempt,
                   self.max_backoff_s)


def call_with_retry(fn: Callable, policy: RetryPolicy, site: str,
                    on_retry: Callable[[str, int, BaseException], None] | None = None,
                    sleep: Callable[[float], None] = time.sleep):
    """Run ``fn()``; on a retryable failure, back off and re-run, up to
    ``policy.max_retries`` times. Fatal errors and exhausted budgets
    propagate the original exception. ``on_retry(site, attempt, exc)`` is
    invoked before each re-execution (the runner counts retries per site
    into its info dict)."""
    attempt = 0
    while True:
        try:
            return fn()
        except Exception as exc:
            if classify_error(exc) != "retryable" or attempt >= policy.max_retries:
                raise
            if on_retry is not None:
                on_retry(site, attempt, exc)
            sleep(policy.delay(attempt))
            attempt += 1
