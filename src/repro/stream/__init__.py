"""Out-of-core streaming execution engine (ISSUE 3 tentpole).

Runs lazy ``repro.plan`` pipelines over chunked on-disk datasets larger
than aggregate device capacity:

- ``scan``   — ``scan_csv`` / ``scan_dataset`` build ``LazyDDF`` handles
  whose leaves are ``SCAN`` plan nodes over a ``DatasetManifest``;
- ``runner`` — the morsel-driven batch runner: slices manifests into
  cost-model-sized batches (``cost_model.choose_batch_rows``), drives each
  batch through the one compiled shard_map program, overlaps host-side
  chunk decode of batch *k+1* with device execution of batch *k*
  (double-buffered prefetch), and finalizes non-EP tails via carry-state
  merges (groupby/unique) or host-side spill + merge (sort, scan x scan
  joins);
- ``checkpoint`` — ``StreamCheckpoint``, atomic snapshots of the runner's
  whole per-query state (scan cursor, carry tables, spill manifests) so a
  killed query resumes mid-stream bit-identically (ISSUE 6 tentpole);
- ``StreamExecution`` — the runner's morsel loop exposed as an externally
  drivable step generator (one event per morsel), so the concurrent query
  service (``repro.service``) can interleave morsels from many queries
  over one shared mesh (ISSUE 7 tentpole);
- ``recovery`` — retryable-vs-fatal error classification
  (``classify_error``, ``RETRYABLE_EXCEPTIONS``) and the bounded-backoff
  ``RetryPolicy`` / ``call_with_retry`` used at every runner fault site.

Entry points: ``repro.stream.scan_csv(...)`` / ``scan_dataset(...)``
returning a ``LazyDDF``; then ``.collect_stream()`` / ``.to_batches()``
(plain ``.collect()`` on a scan-bearing plan routes here automatically).
Fault tolerance is opt-in per run via ``checkpoint_dir=`` / ``resume=``;
see docs/FAULT_TOLERANCE.md.
"""

from .checkpoint import StreamCheckpoint  # noqa: F401
from .recovery import (  # noqa: F401
    RETRYABLE_EXCEPTIONS,
    RetryPolicy,
    call_with_retry,
    classify_error,
)
from .runner import StreamExecution, collect, to_batches  # noqa: F401
from .scan import scan_csv, scan_dataset  # noqa: F401

__all__ = [
    "scan_csv",
    "scan_dataset",
    "collect",
    "to_batches",
    "StreamExecution",
    "StreamCheckpoint",
    "RetryPolicy",
    "RETRYABLE_EXCEPTIONS",
    "call_with_retry",
    "classify_error",
]
