"""Pattern registry + host-side planner (paper §4.3, §5.4).

The planner is the cost-model-driven strategy selector: given table sizes /
sampled cardinality (host-known, outside jit), it picks the pattern variant
the operator should execute — exactly how the paper argues runtimes should
choose between hash-shuffle vs broadcast joins and combine-shuffle-reduce vs
shuffle-compute groupbys. Execution stays single-path inside jit.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from . import cost_model

__all__ = ["PATTERNS", "Plan", "plan_join", "plan_groupby", "sampled_quota",
           "sampled_cardinality", "quota_from_histogram"]

# Pattern -> (operators, result semantic, communication ops) — paper Table 2.
PATTERNS: dict[str, dict] = {
    "embarrassingly_parallel": dict(
        operators=("select", "project", "map", "row_aggregation"),
        result="partitioned", comm=()),
    "shuffle_compute": dict(
        operators=("union", "difference", "join", "transpose"),
        result="partitioned", comm=("shuffle",)),
    "combine_shuffle_reduce": dict(
        operators=("unique", "groupby"),
        result="partitioned", comm=("shuffle",)),
    "broadcast_compute": dict(
        operators=("broadcast_join",),
        result="partitioned", comm=("bcast",)),
    "globally_reduce": dict(
        operators=("column_aggregation", "length", "equality"),
        result="replicated", comm=("allreduce",)),
    "sample_shuffle_compute": dict(
        operators=("sort",),
        result="partitioned", comm=("gather", "bcast", "shuffle", "allreduce")),
    "halo_exchange": dict(
        operators=("window",),
        result="partitioned", comm=("send_recv",)),
    "partitioned_io": dict(
        operators=("read", "write", "rebalance"),
        result="partitioned", comm=("send_recv", "scatter", "gather")),
}


@dataclasses.dataclass(frozen=True)
class Plan:
    """Host-side execution plan for one distributed operator.

    Attributes:
      strategy: pattern variant to execute (e.g. "shuffle" vs "broadcast").
      quota: per-destination shuffle slots (static-shape contract).
      capacity: output table capacity.
      details: free-form planning inputs for diagnostics.
      num_chunks: pipeline depth K for the shuffle; 1 = monolithic
        all-to-all, K > 1 = the pipelined chunked engine
        (``collectives.shuffle_table_pipelined``).
    """

    strategy: str
    quota: int
    capacity: int
    details: dict
    num_chunks: int = 1


def quota_from_histogram(
    hist: np.ndarray,
    capacity: int,
    num_partitions: int,
    sample_fraction: float = 1.0,
    safety: float = 1.5,
) -> int:
    """Quota from a destination histogram (paper §5.4.2). ``hist`` counts
    rows per destination partition — either a full histogram (the Pallas
    ``hash_partition``/``partition_histogram`` kernel output, or the
    streaming runner's host mirror; ``sample_fraction=1.0``) or one built
    from a row sample scaled back up by ``sample_fraction``. The quota is
    the (scaled) largest cell with ``safety`` headroom, clipped to
    ``capacity`` and floored at 16."""
    hist = np.asarray(hist)
    if hist.size == 0 or hist.max() <= 0:
        from .partition import default_quota
        return default_quota(capacity, num_partitions)
    est_max = hist.max() / max(sample_fraction, 1e-9)
    return int(min(capacity, max(est_max * safety, 16)))


def sampled_quota(
    dest_sample: np.ndarray,
    capacity: int,
    num_partitions: int,
    sample_fraction: float,
    safety: float = 1.5,
) -> int:
    """Quota from a sampled destination histogram (paper §5.4.2: data
    distribution drives partitioing decisions). dest_sample: sampled
    destination ids for a fraction of local rows."""
    if dest_sample.size == 0:
        from .partition import default_quota
        return default_quota(capacity, num_partitions)
    hist = np.bincount(dest_sample, minlength=num_partitions)
    return quota_from_histogram(hist, capacity, num_partitions,
                                sample_fraction, safety)


def sampled_cardinality(key_sample: np.ndarray) -> float:
    """C-hat = unique/total from a host-side sample (paper §5.4.1)."""
    if key_sample.size == 0:
        return 1.0
    return float(len(np.unique(key_sample))) / float(key_sample.size)


def plan_join(
    n_left: int,
    n_right: int,
    P: int,
    capacity: int,
    row_bytes: float = 16.0,
    params: cost_model.CostParams = cost_model.CostParams(),
    cardinality: float = 1.0,
) -> Plan:
    """Plan a join: hash-shuffle vs broadcast, plus shuffle pipeline depth.

    Strategy selection follows paper §5.4.2 (broadcast wins when replicating
    the small side beats shuffling both). For the shuffle strategy the plan
    also carries ``num_chunks``: the cost-model-chosen pipeline depth that
    overlaps the per-chunk all-to-all against the local hash-join leg
    (``cost_model.choose_chunk_count``).
    """
    strategy = cost_model.choose_join_strategy(n_left, n_right, P, row_bytes, params)
    from .partition import default_quota
    quota = default_quota(capacity, P)
    # expected output rows/partition ~ matches; bound by n/(P*C)
    exp_out = (max(n_left, n_right) / max(P, 1)) / max(cardinality, 1e-9)
    cap_out = int(min(max(2 * exp_out, capacity), 4 * capacity))
    num_chunks = 1
    if strategy == "shuffle":
        n_rows_w = (n_left + n_right) / max(P, 1)
        core_s = cost_model.t_local("hash_join", n_rows_w, cardinality, params)
        num_chunks = cost_model.choose_chunk_count(
            P, n_rows_w * row_bytes, params, core_s=core_s)
    return Plan(strategy, quota, cap_out, dict(n_left=n_left, n_right=n_right),
                num_chunks=num_chunks)


def plan_groupby(
    cardinality: float,
    P: int,
    capacity: int,
    n_rows: int | None = None,
    row_bytes: float = 16.0,
    params: cost_model.CostParams = cost_model.CostParams(),
    pre_combine: bool | None = None,
) -> Plan:
    """Plan a groupby: combine-shuffle-reduce vs shuffle-compute (paper
    §5.4.1) plus the shuffle pipeline depth.

    ``n_rows`` (global row count) enables chunk-count selection; when omitted
    the plan keeps the monolithic shuffle (K=1). Pre-combining shrinks the
    shuffled payload by the cardinality fraction C before chunking; pass
    ``pre_combine`` to pin the caller's choice so the payload estimate
    matches what actually executes (None = derive from cardinality).
    """
    if pre_combine is None:
        pre_combine = cost_model.choose_groupby_strategy(cardinality)
    from .partition import default_quota
    quota = default_quota(capacity, P)
    num_chunks = 1
    if n_rows is not None:
        n_rows_w = n_rows / max(P, 1)
        # cardinality 0.0 is the "unknown" sentinel: size the shuffle for the
        # full payload rather than a zero-byte one.
        card_payload = cardinality if 0.0 < cardinality <= 1.0 else 1.0
        shuffled = n_rows_w * (card_payload if pre_combine else 1.0)
        core_s = cost_model.t_local("groupby", n_rows_w, cardinality, params)
        num_chunks = cost_model.choose_chunk_count(
            P, shuffled * row_bytes, params, core_s=core_s)
    return Plan("combine_shuffle_reduce" if pre_combine else "shuffle_compute",
                quota, capacity, dict(cardinality=cardinality),
                num_chunks=num_chunks)
