"""Table/array/scalar collectives over ``jax.lax`` primitives (paper §3, Table 1).

The Cylon communication model exposes composite-data-structure collectives
(shuffle/gather/allgather/bcast/(all)reduce on tables, arrays, scalars) built
on buffer-level primitives. The TPU adaptation implements each table
collective as the corresponding ``jax.lax`` collective applied per column
buffer *inside a ``shard_map`` region* — the abstract-collectives layer of the
paper, with XLA's compiler-scheduled collectives replacing hand-progressed
MPI requests (DESIGN.md §2).

All functions here expect to run inside ``shard_map`` with ``axis`` naming
the (possibly tuple of) mesh axes that carry the row partitions.
"""

from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from ...compat import axis_size as _compat_axis_size
from ..dataframe import Table, valid_mask
from ..partition import build_shuffle_buffers

__all__ = [
    "axis_size",
    "axis_index",
    "shuffle_table",
    "shuffle_table_pipelined",
    "allgather_table",
    "gather_table",
    "broadcast_table",
    "allreduce_array",
    "reduce_scatter_array",
    "allgather_array",
    "barrier",
]


def axis_size(axis) -> int:
    """Static number of workers on the row-partition axis (Python int)."""
    return _compat_axis_size(axis)


def axis_index(axis) -> jax.Array:
    """This worker's rank along the row-partition axis (traced scalar)."""
    return jax.lax.axis_index(axis)


# -- array / scalar collectives ----------------------------------------------

def allreduce_array(x: jax.Array, axis, op: str = "sum") -> jax.Array:
    """AllReduce an array across workers: sum | max | min | mean (Table 1)."""
    if op == "sum":
        return jax.lax.psum(x, axis)
    if op == "max":
        return jax.lax.pmax(x, axis)
    if op == "min":
        return jax.lax.pmin(x, axis)
    if op == "mean":
        return jax.lax.pmean(x, axis)
    raise ValueError(f"unknown reduce op {op}")


def reduce_scatter_array(x: jax.Array, axis) -> jax.Array:
    """Sum-reduce then scatter tiles: worker i gets slice i of the sum."""
    return jax.lax.psum_scatter(x, axis, tiled=True)


def allgather_array(x: jax.Array, axis, tiled: bool = False) -> jax.Array:
    """AllGather an array; tiled=True concatenates along axis 0."""
    return jax.lax.all_gather(x, axis, tiled=tiled)


def barrier(axis) -> None:
    """Explicit barrier (paper Table 1): a zero-byte psum. BSP supersteps are
    implicit at shard_map boundaries; this exists for tests."""
    jax.lax.psum(jnp.zeros((), jnp.int32), axis)


# -- table collectives ---------------------------------------------------------

def _all_to_all(x: jax.Array, axis) -> jax.Array:
    """(P, quota, ...) -> (P, quota, ...) where out[j] came from peer j."""
    return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)


def shuffle_table(table: Table, dest: jax.Array, axis, quota: int,
                  capacity: int | None = None, algorithm: str = "native") -> tuple[Table, jax.Array]:
    """AllToAll shuffle of live rows to ``dest`` partitions (paper §3.1/§5.1).

    Cylon implements shuffle on p2p channels because of "a mismatch in
    traditional MPI_Alltoall"; on TPU the mismatch disappears once rows sit in
    fixed quota buffers, so we use the native all-to-all (the paper's own
    future-work recommendation: offload shuffle to the library).

    ``algorithm``: "native" (XLA all-to-all) or "bruck" (paper §6.1.1 /
    Table 3: O(log P) startup, O(log P * n/2) transfer — the latency-bound
    choice for small payloads at large P, built from log2(P) ppermute
    rounds; see ``choose_shuffle_algorithm``).

    Returns (received table with capacity P*quota (or ``capacity``), overflow
    count). Received rows are compacted to the front, grouped by source rank
    (stable), preserving within-source order.
    """
    P = axis_size(axis)
    bufs = build_shuffle_buffers(table, dest, P, quota)
    if algorithm == "bruck":
        recv_cols, recv_counts = _bruck_all_to_all(bufs.columns, bufs.counts, axis)
    else:
        recv_cols = {k: _all_to_all(v, axis) for k, v in bufs.columns.items()}
        recv_counts = _all_to_all(bufs.counts.reshape(P, 1), axis).reshape(P)
    # validity of the (P, quota) grid
    keep = jnp.arange(quota, dtype=jnp.int32)[None, :] < recv_counts[:, None]
    flat_keep = keep.reshape(P * quota)
    out = Table({k: v.reshape((P * quota,) + v.shape[2:]) for k, v in recv_cols.items()},
                jnp.asarray(P * quota, jnp.int32))
    from ..dataframe import compact  # local import to avoid cycle at module load
    out = compact(out, flat_keep, capacity=capacity)
    return out, bufs.overflow


def shuffle_table_pipelined(
    table: Table,
    dest: jax.Array,
    axis,
    quota: int,
    num_chunks: int,
    capacity: int | None = None,
) -> tuple[Table, jax.Array]:
    """Pipelined chunked AllToAll shuffle (cost model §5 + comm/compute overlap).

    Splits every per-destination quota buffer into ``num_chunks`` chunks and
    issues chunk ``i+1``'s ``all_to_all`` before merging chunk ``i`` into the
    output partition, so XLA's async collectives can overlap transfer with the
    local merge (double buffering). This is the chunked-pipeline technique
    that drives Cylon/UCX scaling (arXiv:2301.07896) and combine-shuffle-
    reduce aggregation overlap (arXiv:2010.14596), adapted to static shapes.

    Contract (identical to :func:`shuffle_table` with ``algorithm="native"``):

    - Output rows are **bit-exact** equal to the monolithic path — compacted
      to the front, grouped by source rank (stable), preserving within-source
      order; the tail is zero padding.
    - The returned overflow counter counts rows dropped because a destination
      exceeded ``quota`` — unchanged by chunking (chunking splits the same
      quota buffers; it never adds or removes capacity).

    Each in-flight collective message shrinks from ``P * quota`` rows to
    ``P * ceil(quota/K)`` (the staging buffers themselves are still built at
    full size, so peak *live* memory in the jit region matches the
    monolithic path — the win is smaller transfers overlapping compute, not
    a lower high-water mark).

    Args:
      table: local row partition (inside ``shard_map``).
      dest: (capacity,) int32 destination partition per row; invalid rows
        carry ``P`` (drop bucket).
      axis: mesh axis name (or tuple) carrying the row partitions.
      quota: per-destination slot count (static).
      num_chunks: K >= 1 pipeline chunks; K=1 degenerates to one all_to_all.
        Clamped to ``quota`` (beyond that, extra chunks carry only padding).
      capacity: output capacity (defaults to ``P * quota``).

    Returns:
      (received table, overflow count) exactly as :func:`shuffle_table`.
    """
    P = axis_size(axis)
    K = max(min(int(num_chunks), quota), 1)
    cq = -(-quota // K)  # per-chunk quota (ceil)
    bufs = build_shuffle_buffers(table, dest, P, quota)
    cap_out = (P * quota) if capacity is None else capacity

    # Counts travel first (one tiny all_to_all): the receiver then knows the
    # final position of every incoming row before any payload chunk lands.
    recv_counts = _all_to_all(bufs.counts.reshape(P, 1), axis).reshape(P)
    src_offset = jnp.cumsum(recv_counts) - recv_counts  # exclusive prefix

    # Pad the (P, quota) buffers to (P, K*cq) so chunks are equal-sized; the
    # pad slots sit above ``quota`` and are never valid (counts <= quota).
    pad = K * cq - quota
    cols = bufs.columns
    if pad:
        cols = {
            k: jnp.concatenate(
                [v, jnp.zeros((P, pad) + v.shape[2:], v.dtype)], axis=1)
            for k, v in cols.items()
        }
    chunks = {k: v.reshape((P, K, cq) + v.shape[2:]) for k, v in cols.items()}

    out_cols = {
        k: jnp.zeros((cap_out,) + v.shape[2:], v.dtype)
        for k, v in bufs.columns.items()
    }

    def _send(k: int):
        return {name: _all_to_all(c[:, k], axis) for name, c in chunks.items()}

    # Software pipeline: the all_to_all for chunk k+1 has no data dependence
    # on chunk k's merge, so the scheduler may run them concurrently.
    recv = _send(0)
    for k in range(K):
        nxt = _send(k + 1) if k + 1 < K else None
        # Rows of chunk k occupy quota slots [k*cq, (k+1)*cq) of each source;
        # slot q of source s is valid iff q < recv_counts[s] and lands at
        # final position src_offset[s] + q (source-major, within-source
        # stable — the monolithic compact order).
        q = k * cq + jnp.arange(cq, dtype=jnp.int32)  # (cq,)
        valid = q[None, :] < recv_counts[:, None]  # (P, cq)
        pos = src_offset[:, None].astype(jnp.int32) + q[None, :]
        pos = jnp.where(valid, pos, cap_out).reshape(P * cq)
        for name, v in recv.items():
            flat = v.reshape((P * cq,) + v.shape[2:])
            out_cols[name] = out_cols[name].at[pos].set(flat, mode="drop")
        recv = nxt

    nvalid = jnp.minimum(jnp.sum(recv_counts), cap_out).astype(jnp.int32)
    return Table(out_cols, nvalid), bufs.overflow


def _bruck_all_to_all(columns: dict, counts: jax.Array, axis):
    """Bruck all-to-all over ppermute rounds (Bruck et al. 1997; paper
    Table 3). Blocks are first rotated to relative order (slot j = block for
    rank+j), then round k ships every slot with bit k set to rank + 2^k —
    the slot sets are STATIC, so each round moves exactly P/2 quota-blocks.
    After ceil(log2 P) rounds slot j holds the block FROM rank-j; a final
    inverse rotation restores source order (matching the native layout)."""
    P = axis_size(axis)
    rank = axis_index(axis)

    rot = (jnp.arange(P) + rank) % P               # slot j <- block for rank+j
    cols = {k: v[rot] for k, v in columns.items()}
    cnts = counts[rot]

    nbits = max((P - 1).bit_length(), 1)
    for k in range(nbits):
        bit = 1 << k
        slots = [j for j in range(P) if j & bit]   # static slot set
        if not slots:
            continue
        idx = jnp.asarray(slots, jnp.int32)
        perm = [(i, (i + bit) % P) for i in range(P)]
        new_cols = {}
        for name, v in cols.items():
            send = v[idx]                          # (|slots|, quota, ...)
            recv = jax.lax.ppermute(send, axis, perm=perm)
            new_cols[name] = v.at[idx].set(recv)
        cnt_recv = jax.lax.ppermute(cnts[idx], axis, perm=perm)
        cnts = cnts.at[idx].set(cnt_recv)
        cols = new_cols

    inv = (rank - jnp.arange(P)) % P               # out[s] = slot (rank - s)
    return {k: v[inv] for k, v in cols.items()}, cnts[inv]


def allgather_table(table: Table, axis, capacity: int | None = None) -> Table:
    """AllGather a table: every worker ends with all live rows (paper Table 1)."""
    P = axis_size(axis)
    cap = table.capacity
    cols = {k: jax.lax.all_gather(v, axis) for k, v in table.columns.items()}  # (P, cap, ...)
    counts = jax.lax.all_gather(table.nvalid, axis)  # (P,)
    keep = (jnp.arange(cap, dtype=jnp.int32)[None, :] < counts[:, None]).reshape(P * cap)
    out = Table({k: v.reshape((P * cap,) + v.shape[2:]) for k, v in cols.items()},
                jnp.asarray(P * cap, jnp.int32))
    from ..dataframe import compact
    return compact(out, keep, capacity=capacity)


def gather_table(table: Table, axis, root: int = 0, capacity: int | None = None) -> Table:
    """Gather to ``root``; non-root workers receive an empty table."""
    out = allgather_table(table, axis, capacity=capacity)
    me = axis_index(axis)
    n = jnp.where(me == root, out.nvalid, 0)
    return Table(out.columns, n.astype(jnp.int32))


def broadcast_table(table: Table, axis, root: int = 0) -> Table:
    """Broadcast root's partition to all workers (paper Table 1; used by the
    broadcast-join pattern §5.3.7).

    Implemented as masked psum (zero everywhere but root, then sum): a single
    reduction-tree collective, which XLA lowers to an all-reduce. Costs match
    the paper's binomial-tree broadcast asymptotics in the log-P term.
    """
    me = axis_index(axis)
    sel = (me == root)
    cols = {}
    for k, v in table.columns.items():
        contrib = jnp.where(sel, v.astype(jnp.float32) if v.dtype == jnp.bool_ else v, jnp.zeros_like(v))
        out = jax.lax.psum(contrib, axis)
        cols[k] = out.astype(v.dtype)
    n = jax.lax.psum(jnp.where(sel, table.nvalid, 0), axis)
    return Table(cols, n.astype(jnp.int32))


def scatter_table(table: Table, axis, root: int = 0, quota: int | None = None) -> tuple[Table, jax.Array]:
    """Scatter root's live rows round-robin across workers (partitioned I/O)."""
    P = axis_size(axis)
    quota = quota if quota is not None else -(-table.capacity // P)
    me = axis_index(axis)
    # Non-root contributes no rows: zero out nvalid off-root.
    n = jnp.where(me == root, table.nvalid, 0).astype(jnp.int32)
    t = Table(table.columns, n)
    idx = jnp.arange(table.capacity, dtype=jnp.int32)
    dest = jnp.where(idx < n, idx % P, P)
    return shuffle_table(t, dest, axis, quota)
