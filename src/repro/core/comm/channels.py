"""Point-to-point channels (paper §3.2) as ``ppermute`` rings.

Cylon's channels are non-blocking tag-matched send/recv pairs with metadata
exchange followed by payload exchange. On TPU the analogous primitive is
``jax.lax.ppermute`` — a compiler-scheduled neighbor permutation on the ICI
torus. We expose:

- ``shift``: send a fixed-size buffer k hops along the partition ring
  (the halo-exchange building block, paper §5.3.6);
- ``send_recv``: arbitrary permutation of fixed-size buffers + their
  valid-counts (metadata travels with the payload, mirroring the channel's
  two-phase metadata/payload protocol).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from ...compat import axis_size
from ..dataframe import Table

__all__ = ["shift", "send_recv", "halo_exchange"]


def _ring_perm(P: int, offset: int) -> list[tuple[int, int]]:
    return [(i, (i + offset) % P) for i in range(P)]


def shift(x: jax.Array, axis, offset: int = 1) -> jax.Array:
    """Every worker sends ``x`` to rank+offset (mod P) and receives from
    rank-offset."""
    P = axis_size(axis)
    return jax.lax.ppermute(x, axis, perm=_ring_perm(P, offset))


def send_recv(x: jax.Array, axis, perm: Sequence[tuple[int, int]]) -> jax.Array:
    """General p2p: perm is a list of (src, dst) pairs; ranks not receiving
    get zeros (channel with no matching recv)."""
    return jax.lax.ppermute(x, axis, perm=list(perm))


def halo_exchange(tail: jax.Array, head: jax.Array, axis) -> tuple[jax.Array, jax.Array]:
    """Exchange boundary halos with ring neighbors (paper §5.3.6, windows).

    ``tail``: this worker's last rows (sent rightward), ``head``: first rows
    (sent leftward). Returns (left_halo, right_halo) = previous worker's tail
    and next worker's head. Edge workers receive zeros (non-wrapping windows),
    which callers mask by global position.
    """
    P = axis_size(axis)
    left = jax.lax.ppermute(tail, axis, perm=[(i, i + 1) for i in range(P - 1)])
    right = jax.lax.ppermute(head, axis, perm=[(i + 1, i) for i in range(P - 1)])
    return left, right
