from .communicator import Communicator, make_communicator  # noqa: F401
from . import collectives, channels  # noqa: F401
