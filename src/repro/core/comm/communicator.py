"""The pluggable Communicator (paper §3.1, Fig. 4).

Cylon plugs OpenMPI / Gloo / UCX under one communicator interface. On TPU
there is exactly one transport (XLA collectives over ICI/DCN), so the
pluggability axis that *transfers* is the **fabric profile**: the same
``jax.lax`` lowering annotated with per-fabric Hockney parameters
(alpha, beta) used by the cost model for strategy selection — ICI within a
pod, DCN across pods, HOST for the CPU-device benchmarking backend. This
keeps the paper's architecture (user-facing table/array/scalar routines ->
abstract collectives -> buffer primitives) while being honest that TPU
collectives are compiler-issued, not library-issued (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from ..dataframe import Table
from . import collectives, channels

__all__ = ["FabricProfile", "ICI", "DCN", "HOST", "Communicator", "make_communicator"]


@dataclasses.dataclass(frozen=True)
class FabricProfile:
    """Hockney (alpha, beta) per fabric + name, feeding the cost model."""

    name: str
    alpha_s: float          # startup latency per message [s]
    beta_s_per_byte: float  # transfer time per byte [s/B]

    def t_msg(self, nbytes: float) -> float:
        return self.alpha_s + nbytes * self.beta_s_per_byte


# TPU v5e figures (task spec + public multislice docs); HOST is calibrated by
# benchmarks/bench_comm.py at runtime.
ICI = FabricProfile("ici", alpha_s=1e-6, beta_s_per_byte=1.0 / 50e9)
DCN = FabricProfile("dcn", alpha_s=10e-6, beta_s_per_byte=1.0 / 25e9)
HOST = FabricProfile("host", alpha_s=5e-6, beta_s_per_byte=1.0 / 10e9)


@dataclasses.dataclass(frozen=True)
class Communicator:
    """Bundles the mesh axes carrying row partitions with a fabric profile.

    Methods mirror paper Table 1 (operations x {table, array, scalar}).
    All methods must be called inside a ``shard_map`` over ``axis``.
    """

    axis: object  # axis name or tuple of names (e.g. ("pod", "data"))
    fabric: FabricProfile = ICI

    # -- metadata
    @property
    def nworkers_static(self) -> int | None:
        return None  # only known inside shard_map

    def size(self) -> int:
        return collectives.axis_size(self.axis)

    def rank(self) -> jax.Array:
        return collectives.axis_index(self.axis)

    # -- table routines (paper Table 1 "Common" column)
    def shuffle(self, table: Table, dest, quota: int, capacity: int | None = None,
                algorithm: str = "native", num_chunks: int = 1):
        """Shuffle live rows to ``dest`` partitions.

        ``num_chunks > 1`` routes through the pipelined chunked engine
        (bit-exact with the monolithic path; see
        :func:`collectives.shuffle_table_pipelined`). ``algorithm`` selects
        the monolithic all-to-all flavor and only applies at ``num_chunks=1``
        — combining a non-native algorithm with chunking is an error rather
        than a silent fallback.
        """
        if num_chunks > 1:
            if algorithm != "native":
                raise ValueError(
                    f"algorithm={algorithm!r} is only available for the "
                    "monolithic shuffle (num_chunks=1); the pipelined engine "
                    "is native all-to-all only")
            return collectives.shuffle_table_pipelined(
                table, dest, self.axis, quota, num_chunks, capacity)
        return collectives.shuffle_table(table, dest, self.axis, quota, capacity,
                                         algorithm=algorithm)

    def shuffle_pipelined(self, table: Table, dest, quota: int, num_chunks: int,
                          capacity: int | None = None):
        """Pipelined chunked shuffle (always chunked, even at K=1 — unlike
        :meth:`shuffle`, which uses the monolithic engine at K=1). Covered
        by test_shuffle_pipelined as the forced-chunked reference path."""
        return collectives.shuffle_table_pipelined(
            table, dest, self.axis, quota, num_chunks, capacity)

    def allgather(self, table: Table, capacity: int | None = None) -> Table:
        return collectives.allgather_table(table, self.axis, capacity)

    def gather(self, table: Table, root: int = 0, capacity: int | None = None) -> Table:
        return collectives.gather_table(table, self.axis, root, capacity)

    def broadcast(self, table: Table, root: int = 0) -> Table:
        return collectives.broadcast_table(table, self.axis, root)

    def scatter(self, table: Table, root: int = 0, quota: int | None = None):
        return collectives.scatter_table(table, self.axis, root, quota)

    # -- array / scalar routines
    def allreduce(self, x, op: str = "sum"):
        return collectives.allreduce_array(x, self.axis, op)

    def reduce_scatter(self, x):
        return collectives.reduce_scatter_array(x, self.axis)

    def allgather_array(self, x, tiled: bool = False):
        return collectives.allgather_array(x, self.axis, tiled)

    # -- channels (p2p)
    def shift(self, x, offset: int = 1):
        return channels.shift(x, self.axis, offset)

    def halo_exchange(self, tail, head):
        return channels.halo_exchange(tail, head, self.axis)

    def barrier(self):
        collectives.barrier(self.axis)


def make_communicator(axis, fabric: str | FabricProfile = "ici") -> Communicator:
    """Communicator over mesh ``axis`` with fabric "ici" | "dcn" | "host"."""
    if isinstance(fabric, str):
        fabric = {"ici": ICI, "dcn": DCN, "host": HOST}[fabric]
    return Communicator(axis=axis, fabric=fabric)
