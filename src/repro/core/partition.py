"""Auxiliary partition operators (paper §4.2).

Hash partition, range partition, and the static-shape shuffle-buffer builder.
These are the paper's "auxiliary local sub-operators": they decide, per live
row, a destination partition, and lay rows out into fixed per-destination
quota buffers so that ``jax.lax.all_to_all`` (the TPU shuffle) can move them.

Dynamic Arrow buffers -> static quota buffers is the key hardware adaptation
(DESIGN.md §2): per-destination message sizes become a fixed ``quota`` with
explicit overflow accounting, and the quota is chosen from sampled histograms
per the paper's runtime-data-distribution discussion (§5.4.2).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .dataframe import Table, valid_mask

__all__ = [
    "u32_normalize",
    "hash32",
    "hash_columns",
    "hash_partition_ids",
    "range_partition_ids",
    "build_shuffle_buffers",
    "ShuffleBuffers",
]

_M1 = jnp.uint32(0x7FEB352D)
_M2 = jnp.uint32(0x846CA68B)


def u32_normalize(x: jax.Array) -> jax.Array:
    """Canonical uint32 view of any column dtype, pre-hash.

    64-bit ints fold hi^lo (so the engine is independent of
    ``jax_enable_x64``), bools widen, floats bitcast (equal floats hash
    equal). Shared by the jnp hash chain below and the Pallas
    ``kernels.hash_partition`` build side, so both hash bit-identically.
    """
    if x.dtype in (jnp.int64, jnp.uint64):
        u = x.astype(jnp.uint64)
        return (u ^ (u >> jnp.uint64(32))).astype(jnp.uint32)
    if x.dtype == jnp.bool_:
        return x.astype(jnp.uint32)
    if jnp.issubdtype(x.dtype, jnp.floating):
        return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return x.astype(jnp.uint32)


def hash32(x: jax.Array) -> jax.Array:
    """lowbias32 integer hash (Prospecting-for-hash-functions constants).

    Works on any dtype via :func:`u32_normalize`.
    """
    x = u32_normalize(x)
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash_columns(table: Table, key_columns: Sequence[str]) -> jax.Array:
    """(capacity,) uint32 combined hash over the key columns."""
    h = jnp.zeros((table.capacity,), jnp.uint32)
    for name in key_columns:
        hk = hash32(table.columns[name])
        # boost-style hash_combine
        h = h ^ (hk + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2))
    return h


def hash_partition_ids(table: Table, key_columns: Sequence[str], num_partitions: int) -> jax.Array:
    """Destination partition per row; invalid rows get ``num_partitions``
    (a drop bucket).

    This is the shuffle build side for every shuffle-based operator
    (join/groupby/unique/set ops). It routes through the Pallas
    ``kernels.hash_partition`` kernel when the dispatch registry says so
    (TPU + profitable row count, or a forced ``set_backend("pallas")``);
    the jnp hash chain below is the fallback. Both paths share
    :func:`u32_normalize` and the same lowbias32 mixing, so destinations
    are bit-identical across backends.
    """
    mode = _registry().resolve("hash_partition", table.capacity)
    if mode != "jnp":
        from .. import kernels

        ku = jnp.stack([u32_normalize(table.columns[n]) for n in key_columns],
                       axis=1)
        dest, _ = kernels.hash_partition(ku, num_partitions, force=mode,
                                         with_hist=False)
    else:
        h = hash_columns(table, key_columns)
        dest = (h % jnp.uint32(num_partitions)).astype(jnp.int32)
    return jnp.where(valid_mask(table), dest, num_partitions)


def _registry():
    # deferred: repro.kernels.registry imports repro.core.cost_model, so a
    # module-level import here would cycle during package init
    from ..kernels import registry

    return registry


def range_partition_ids(
    table: Table, key_column: str, pivots: jax.Array, num_partitions: int, descending: bool = False
) -> jax.Array:
    """Ordered partition ids from (P-1) pivots (sample-sort, paper §5.3.3)."""
    keys = table.columns[key_column]
    if descending:
        dest = jnp.searchsorted(-pivots, -keys, side="left").astype(jnp.int32)
    else:
        dest = jnp.searchsorted(pivots, keys, side="right").astype(jnp.int32)
    dest = jnp.clip(dest, 0, num_partitions - 1)
    return jnp.where(valid_mask(table), dest, num_partitions)


class ShuffleBuffers(dict):
    """columns: name -> (P, quota, ...) buffers; counts: (P,) rows per dest;
    overflow: scalar int32 rows dropped because a destination exceeded quota."""

    def __init__(self, columns, counts, overflow):
        super().__init__(columns)
        self.columns = columns
        self.counts = counts
        self.overflow = overflow


def build_shuffle_buffers(table: Table, dest: jax.Array, num_partitions: int, quota: int) -> ShuffleBuffers:
    """Lay live rows into fixed (P, quota) per-destination buffers.

    Stable within destination (preserves row order). Rows whose destination
    bucket is full are counted in ``overflow`` and dropped — callers size
    ``quota`` from sampled histograms (see ``repro.core.patterns``) so that
    overflow is zero in practice, and can assert on it.
    """
    P, cap = num_partitions, table.capacity
    order = jnp.argsort(dest, stable=True)  # groups rows by destination
    sdest = dest[order]
    # rank of each row within its destination group
    group_start = jnp.searchsorted(sdest, sdest, side="left")
    rank = jnp.arange(cap, dtype=jnp.int32) - group_start.astype(jnp.int32)
    is_row = sdest < P  # drop-bucket (==P) excluded
    keep = is_row & (rank < quota)
    # raw per-destination counts (including overflowing rows)
    raw = jnp.bincount(jnp.where(is_row, sdest, P), length=P + 1)[:P]
    counts = jnp.minimum(raw, quota).astype(jnp.int32)
    overflow = jnp.sum(raw - counts, dtype=jnp.int32)

    scatter_d = jnp.where(keep, sdest, P)  # out-of-bounds rows -> dropped
    scatter_r = jnp.where(keep, rank, quota)
    cols = {}
    for name, col in table.columns.items():
        buf = jnp.zeros((P, quota) + col.shape[1:], col.dtype)
        cols[name] = buf.at[scatter_d, scatter_r].set(col[order], mode="drop")
    return ShuffleBuffers(cols, counts, overflow)


def default_quota(capacity: int, num_partitions: int, safety: float = 2.0) -> int:
    """Quota heuristic for uniformly distributed keys: E[rows/dest] x safety.

    The paper's uniform-data experiments give n/P rows per destination; the
    safety factor absorbs hash variance. Skewed data should use
    ``patterns.sampled_quota`` instead (sample -> histogram -> quota).
    """
    base = -(-capacity // num_partitions)  # ceil
    q = int(base * safety) + 8
    return min(q, capacity)
