"""Cost model for distributed dataframe operator patterns (paper §5).

T_total = T_core + T_aux + T_comm, with the Hockney model T = alpha + n*beta
per message. We reproduce paper Table 3 (collective algorithms), Table 4
(core local operator complexities), and the §5.3 per-pattern totals, then
re-parameterize for the TPU fabrics (ICI/DCN) so the planner can select
pattern variants at plan time (paper §5.4).

Units: seconds, bytes, rows. ``n`` follows the paper's bold-n convention:
work per process in *bytes* for communication terms and in *rows* for local
terms (row width ``row_bytes`` converts between them).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

from .comm.communicator import DCN, HOST, ICI, FabricProfile

__all__ = [
    "CostParams",
    "t_shuffle",
    "t_allgather",
    "t_broadcast",
    "t_reduce",
    "t_allreduce",
    "LOCAL_COSTS",
    "t_local",
    "pattern_cost",
    "choose_join_strategy",
    "choose_groupby_strategy",
    "choose_shuffle_algorithm",
]


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Hockney (alpha, beta) + local-compute calibration.

    gamma_s_per_row: per-row local processing constant (calibrated by
    benchmarks/bench_local_ops.py; default from CPU microbenchmarks).
    """

    fabric: FabricProfile = ICI
    gamma_s_per_row: float = 2e-9

    @property
    def alpha(self) -> float:
        return self.fabric.alpha_s

    @property
    def beta(self) -> float:
        return self.fabric.beta_s_per_byte


# -- Table 3: collective communication costs ------------------------------------
# Each returns (T_startup, T_transfer, T_reduce) in seconds for per-worker
# payload of n bytes across P workers.

def t_shuffle(P: int, n_bytes: float, p: CostParams, algorithm: str = "isend-irecv"):
    a, b = p.alpha, p.beta
    if algorithm == "isend-irecv":
        return ((P - 1) * a, (P - 1) / P * n_bytes * b, 0.0)
    if algorithm == "ring":
        return (P * a, P * n_bytes * b, 0.0)
    if algorithm == "pairwise":
        return (P * a, n_bytes * b, 0.0)
    if algorithm == "bruck":
        lg = math.log2(max(P, 2))
        return (lg * a, lg * n_bytes / 2 * b, 0.0)
    raise ValueError(algorithm)


def t_allgather(P: int, n_bytes: float, p: CostParams, algorithm: str = "ring"):
    a, b = p.alpha, p.beta
    total = P * n_bytes  # paper's N: allgather moves the whole table
    if algorithm == "ring":
        return (P * a, (P - 1) / P * total * b, 0.0)
    if algorithm in ("recursive-doubling", "bruck"):
        return (math.log2(max(P, 2)) * a, (P - 1) / P * total * b, 0.0)
    raise ValueError(algorithm)


def t_broadcast(P: int, n_bytes: float, p: CostParams, algorithm: str = "binomial"):
    a, b = p.alpha, p.beta
    lg = math.log2(max(P, 2))
    if algorithm == "binomial":
        return (lg * a, lg * n_bytes * b, 0.0)
    if algorithm == "scatter-allgather":
        return ((lg + P) * a, (P - 1) / P * n_bytes * b, 0.0)
    raise ValueError(algorithm)


def t_reduce(P: int, n_bytes: float, p: CostParams, algorithm: str = "binomial"):
    a, b = p.alpha, p.beta
    lg = math.log2(max(P, 2))
    if algorithm == "binomial":
        return (lg * a, lg * n_bytes * b, lg * n_bytes * b)
    if algorithm == "reduce-scatter-gather":
        return (lg * a, (P - 1) / P * n_bytes * b, (P - 1) / P * n_bytes * b)
    raise ValueError(algorithm)


def t_allreduce(P: int, n_bytes: float, p: CostParams, algorithm: str = "reduce-scatter-allgather"):
    a, b = p.alpha, p.beta
    lg = math.log2(max(P, 2))
    if algorithm == "binomial":
        return (lg * a, lg * n_bytes * b, lg * n_bytes * b)
    if algorithm == "recursive-doubling":
        return (lg * a, lg * n_bytes * b, lg * n_bytes * b)
    if algorithm == "reduce-scatter-allgather":
        return (lg * a, 2 * (P - 1) / P * n_bytes * b, (P - 1) / P * n_bytes * b)
    raise ValueError(algorithm)


def _sum3(t):
    return t[0] + t[1] + t[2]


# -- Table 4: core local operator costs ------------------------------------------
# cost(n_rows, cardinality C) -> seconds, using the calibrated gamma.

LOCAL_COSTS: dict[str, Callable[[float, float, CostParams], float]] = {
    "selection": lambda n, C, p: p.gamma_s_per_row * n,
    "map": lambda n, C, p: p.gamma_s_per_row * n,
    "row_aggregation": lambda n, C, p: p.gamma_s_per_row * n,
    "projection": lambda n, C, p: p.gamma_s_per_row * 1.0,  # O(c)
    "union": lambda n, C, p: p.gamma_s_per_row * n,
    "set_difference": lambda n, C, p: p.gamma_s_per_row * n,
    # paper Table 4: Hash-Join O(n) + O(n/C); Sort-Join O(n log n) + O(n/C)
    "hash_join": lambda n, C, p: p.gamma_s_per_row * (n + n / max(C, 1e-9)),
    "sort_join": lambda n, C, p: p.gamma_s_per_row * (n * math.log2(max(n, 2)) + n / max(C, 1e-9)),
    "transpose": lambda n, C, p: p.gamma_s_per_row * n,
    "unique": lambda n, C, p: p.gamma_s_per_row * n,
    "groupby": lambda n, C, p: p.gamma_s_per_row * n,
    "column_aggregation": lambda n, C, p: p.gamma_s_per_row * n,
    "sort": lambda n, C, p: p.gamma_s_per_row * n * math.log2(max(n, 2)),
}


def t_local(op: str, n_rows: float, cardinality: float = 1.0, p: CostParams = CostParams()) -> float:
    return LOCAL_COSTS[op](n_rows, cardinality, p)


# -- §5.3 per-pattern totals -------------------------------------------------------

def pattern_cost(
    pattern: str,
    *,
    P: int,
    n_rows: float,
    row_bytes: float,
    cardinality: float = 1.0,
    core_op: str = "map",
    params: CostParams = CostParams(),
    shuffle_algorithm: str = "isend-irecv",
) -> dict[str, float]:
    """Estimated wall time breakdown {core, aux, comm, total} per worker."""
    p = params
    n_bytes = n_rows * row_bytes
    C = cardinality
    if pattern == "embarrassingly_parallel":
        core = t_local(core_op, n_rows, C, p)
        return _pack(core, 0.0, 0.0)
    if pattern == "shuffle_compute":
        aux = t_local("map", n_rows, C, p)  # hash partition is a map
        comm = _sum3(t_shuffle(P, n_bytes, p, shuffle_algorithm))
        core = t_local(core_op, n_rows, C, p)
        return _pack(core, aux, comm)
    if pattern == "sample_shuffle_compute":
        aux = t_local("sort", n_rows, C, p) + t_local("map", n_rows, C, p)
        comm = _sum3(t_allreduce(P, 8.0 * P, p)) + _sum3(t_shuffle(P, n_bytes, p, shuffle_algorithm))
        core = t_local("sort", n_rows, C, p)  # local merge
        return _pack(core, aux, comm)
    if pattern == "combine_shuffle_reduce":
        core1 = t_local(core_op, n_rows, C, p)
        aux = t_local("map", n_rows * C, C, p)
        comm = _sum3(t_shuffle(P, n_bytes * C, p, shuffle_algorithm))
        core2 = t_local(core_op, n_rows * C, C, p)
        return _pack(core1 + core2, aux, comm)
    if pattern == "broadcast_compute":
        # broadcast the small relation (n here = small side), join locally
        comm = _sum3(t_allgather(P, n_bytes, p))
        core = t_local(core_op, n_rows, C, p)
        return _pack(core, 0.0, comm)
    if pattern == "globally_reduce":
        core = t_local("column_aggregation", n_rows, C, p)
        comm = _sum3(t_allreduce(P, row_bytes, p))
        return _pack(core, 0.0, comm)
    if pattern == "halo_exchange":
        core = t_local("map", n_rows, C, p)
        comm = p.alpha + row_bytes * p.beta  # one neighbor message
        return _pack(core, 0.0, comm)
    if pattern == "partitioned_io":
        core = t_local("map", n_rows, C, p)
        comm = _sum3(t_shuffle(P, n_bytes, p, shuffle_algorithm))
        return _pack(core, 0.0, comm)
    raise ValueError(pattern)


def _pack(core, aux, comm):
    return {"core": core, "aux": aux, "comm": comm, "total": core + aux + comm}


# -- §5.4 runtime strategy selection ----------------------------------------------

def choose_join_strategy(
    n_left_rows: float,
    n_right_rows: float,
    P: int,
    row_bytes: float,
    params: CostParams = CostParams(),
    broadcast_budget_bytes: float = 256e6,
) -> str:
    """Broadcast-join beats shuffle-join when one relation is small enough
    that replicating it costs less than shuffling both (paper §5.3.7/§5.4.2).

    A memory guard rejects broadcast when the replicated relation exceeds
    ``broadcast_budget_bytes`` per worker — the paper's observation that
    Modin's broadcast-only joins OOM on same-order relations is a memory
    failure, not just a bandwidth one."""
    small = min(n_left_rows, n_right_rows)
    if small * row_bytes > broadcast_budget_bytes:
        return "shuffle"
    shuffle_cost = (
        _sum3(t_shuffle(P, n_left_rows / P * row_bytes, params))
        + _sum3(t_shuffle(P, n_right_rows / P * row_bytes, params))
    )
    bcast_cost = _sum3(t_allgather(P, small / P * row_bytes, params))
    return "broadcast" if bcast_cost < shuffle_cost else "shuffle"


def choose_groupby_strategy(cardinality: float, threshold: float = 0.5) -> bool:
    """pre_combine? Combine-Shuffle-Reduce wins at low cardinality; at C->1 it
    degrades below plain Shuffle-Compute because the core op runs twice
    (paper §5.4.1). Returns True for pre-combine."""
    return cardinality < threshold


def choose_shuffle_algorithm(P: int, n_bytes: float, params: CostParams = CostParams()) -> str:
    """Latency-bound (small n, large P) -> Bruck; else pairwise/isend
    (paper §6.1.1 recommendation)."""
    best, best_t = None, float("inf")
    for alg in ("isend-irecv", "ring", "pairwise", "bruck"):
        t = _sum3(t_shuffle(P, n_bytes, params, alg))
        if t < best_t:
            best, best_t = alg, t
    return best
