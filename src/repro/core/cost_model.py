"""Cost model for distributed dataframe operator patterns (paper §5).

T_total = T_core + T_aux + T_comm, with the Hockney model T = alpha + n*beta
per message. We reproduce paper Table 3 (collective algorithms), Table 4
(core local operator complexities), and the §5.3 per-pattern totals, then
re-parameterize for the TPU fabrics (ICI/DCN) so the planner can select
pattern variants at plan time (paper §5.4).

Units: seconds, bytes, rows. ``n`` follows the paper's bold-n convention:
work per process in *bytes* for communication terms and in *rows* for local
terms (row width ``row_bytes`` converts between them).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping

from .comm.communicator import DCN, HOST, ICI, FabricProfile

__all__ = [
    "CostParams",
    "KernelParams",
    "kernel_params",
    "params_for_fabric",
    "t_shuffle",
    "t_shuffle_pipelined",
    "t_allgather",
    "t_broadcast",
    "t_reduce",
    "t_allreduce",
    "LOCAL_COSTS",
    "t_local",
    "pattern_cost",
    "choose_join_strategy",
    "choose_groupby_strategy",
    "choose_shuffle_algorithm",
    "choose_chunk_count",
    "choose_batch_rows",
    "ADAPTIVE_REPLAN_EVERY",
    "ADAPTIVE_DRIFT",
    "ADAPTIVE_QUOTA_SAFETY",
    "ADAPTIVE_CAPACITY_SAFETY",
]


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Hockney (alpha, beta) + local-compute calibration.

    Attributes:
      fabric: the interconnect profile supplying alpha [s/message] and
        beta [s/byte] (ICI within a pod, DCN across pods, HOST on CPU).
      gamma_s_per_row: per-row local processing constant [s/row]
        (calibrated by benchmarks/bench_local_ops.py; default from CPU
        microbenchmarks).
    """

    fabric: FabricProfile = ICI
    gamma_s_per_row: float = 2e-9

    @property
    def alpha(self) -> float:
        """Per-message startup latency in seconds (Hockney alpha)."""
        return self.fabric.alpha_s

    @property
    def beta(self) -> float:
        """Per-byte transfer time in seconds/byte (Hockney beta = 1/BW)."""
        return self.fabric.beta_s_per_byte


_FABRIC_PROFILES = {"ici": ICI, "dcn": DCN, "host": HOST}


# -- Pallas kernel dispatch parameters (ISSUE 5) ---------------------------------
#
# The paper's cost breakdown (T_core + T_aux + T_comm) puts the local kernels
# — hash partitioning (the shuffle build side) and segment aggregation (the
# groupby combine leg) — on the critical path once shuffles are pipelined.
# ``kernel_params`` models when the Pallas implementations of those kernels
# beat the plain jnp lowering: each ``pallas_call`` pays a fixed launch
# overhead that only amortizes past a per-kernel row threshold, and the
# kernels support a fixed dtype set (everything else stays on jnp).

# Fixed per-launch overhead of a pallas_call (dispatch + VMEM staging), and
# the fraction of the jnp per-row cost the Pallas path saves on TPU (the
# one-hot-matmul kernels replace scatter-adds the TPU lowers to serialized
# updates). Both are calibration constants in the same spirit as
# ``CostParams.gamma_s_per_row``; ``benchmarks/bench_kernels.py`` reports
# measured speedups next to the thresholds these produce.
_KERNEL_LAUNCH_S = 2e-6
_KERNEL_SAVING_FRACTION = 0.5

# dtypes each kernel lowers for. hash_partition normalizes every engine
# dtype (ints, floats, bools) to uint32 host-side before the kernel, so it
# is unrestricted; segment_reduce computes in the value dtype (exact
# integer sums, f32 floats) and only lowers the dtypes listed here.
_KERNEL_DTYPES = {
    "hash_partition": None,  # None = any dtype (normalized to uint32)
    "segment_reduce": ("int32", "uint32", "float32"),
}

# per-kernel pallas block sizes: rows per grid step. segment_reduce uses a
# smaller block because its exactness contract sizes the one-hot matmul as
# (block x block) (dense contiguous segment ids span <= block per block).
_KERNEL_BLOCKS = {"hash_partition": 1024, "segment_reduce": 256}


# -- Adaptive mid-stream re-planning knobs (ISSUE 9) -----------------------------
#
# The streaming runner's AdaptiveController (repro.stats.adaptive) corrects
# quota/capacity for later morsels from observed batch histograms. These are
# policy constants, not calibration: re-plans recompile the pipeline for new
# static shapes, so the controller acts only at a coarse cadence and only on
# substantial drift, and always leaves safety headroom over observed maxima
# (an undersized buffer raises under strict_overflow; an oversized one just
# wastes a bounded slice of memory).

#: batches between adaptive re-plan decision points
ADAPTIVE_REPLAN_EVERY = 4

#: relative quota drift (|target - current| / current) that triggers a re-plan
ADAPTIVE_DRIFT = 0.25

#: headroom multiplier over the max observed per-partition histogram cell
ADAPTIVE_QUOTA_SAFETY = 1.5

#: headroom multiplier over the max observed per-worker partial-group count
ADAPTIVE_CAPACITY_SAFETY = 2.0


@dataclasses.dataclass(frozen=True)
class KernelParams:
    """Dispatch inputs for the Pallas kernel layer (one per jax backend).

    Attributes:
      backend: jax default backend the parameters describe ("tpu", "cpu",
        "gpu").
      native: True when Pallas lowers natively on this backend (TPU). On
        every other backend the Pallas path exists only as the
        ``interpret=True`` correctness mode, which is never profitable —
        ``auto`` dispatch then always picks jnp and ``interpret`` is
        reserved for forced parity testing (``set_backend("pallas")``).
      min_rows: kernel name -> row-count threshold above which the Pallas
        launch overhead is amortized (``_KERNEL_LAUNCH_S`` against the
        per-row saving over jnp).
      supported_dtypes: kernel name -> tuple of dtype names the kernel
        lowers for (``None`` = unrestricted).
      block: kernel name -> pallas grid block size in rows.
    """

    backend: str
    native: bool
    min_rows: Mapping[str, int]
    supported_dtypes: Mapping[str, tuple | None]
    block: Mapping[str, int]

    def dtype_supported(self, kernel: str, dtype) -> bool:
        """True when ``kernel`` lowers for ``dtype`` (name, numpy/jnp dtype
        or scalar type)."""
        allowed = self.supported_dtypes.get(kernel)
        if allowed is None:
            return True
        import numpy as np

        try:
            name = np.dtype(dtype).name
        except TypeError:
            name = str(dtype)
        return name in allowed

    def profitable(self, kernel: str, n_rows: int, dtype=None) -> bool:
        """True when the native Pallas ``kernel`` beats jnp for ``n_rows``
        rows of ``dtype`` on this backend (the ``auto`` dispatch test)."""
        if not self.native:
            return False
        if dtype is not None and not self.dtype_supported(kernel, dtype):
            return False
        return n_rows >= self.min_rows.get(kernel, 0)


def kernel_params(backend: str | None = None,
                  p: CostParams = CostParams()) -> KernelParams:
    """Kernel-dispatch parameters for a jax backend (default: the current
    one).

    The row thresholds come from amortizing the fixed pallas_call launch
    overhead against the modeled per-row saving over the jnp lowering:
    ``min_rows = launch_s / (gamma * saving_fraction)``. The registry
    (``repro.kernels.registry``) consults this for every ``auto`` dispatch;
    ``benchmarks/bench_kernels.py`` checks the decisions against measured
    timings."""
    if backend is None:
        import jax  # deferred: cost_model is otherwise jax-free

        backend = jax.default_backend()
    saving = p.gamma_s_per_row * _KERNEL_SAVING_FRACTION
    threshold = int(math.ceil(_KERNEL_LAUNCH_S / max(saving, 1e-30)))
    return KernelParams(
        backend=backend,
        native=(backend == "tpu"),
        min_rows={k: threshold for k in _KERNEL_BLOCKS},
        supported_dtypes=dict(_KERNEL_DTYPES),
        block=dict(_KERNEL_BLOCKS),
    )


def params_for_fabric(fabric: str) -> CostParams:
    """CostParams for a DDFContext fabric name ("ici" | "dcn" | "host").

    Both the eager per-method planners and the lazy plan optimizer route
    through this so the same context yields the same cost-model constants."""
    return CostParams(fabric=_FABRIC_PROFILES.get(fabric, ICI))


# -- Table 3: collective communication costs ------------------------------------
# Each returns (T_startup, T_transfer, T_reduce) in seconds for per-worker
# payload of n bytes across P workers.

def t_shuffle(P: int, n_bytes: float, p: CostParams, algorithm: str = "isend-irecv"):
    """All-to-all shuffle cost (paper Table 3).

    Args:
      P: number of workers.
      n_bytes: per-worker payload in bytes (the paper's bold-n).
      p: Hockney/compute calibration (alpha [s], beta [s/B]).
      algorithm: "isend-irecv" | "ring" | "pairwise" | "bruck".

    Returns:
      (T_startup, T_transfer, T_reduce) in seconds; sum for wall time.
    """
    a, b = p.alpha, p.beta
    if algorithm == "isend-irecv":
        return ((P - 1) * a, (P - 1) / P * n_bytes * b, 0.0)
    if algorithm == "ring":
        return (P * a, P * n_bytes * b, 0.0)
    if algorithm == "pairwise":
        return (P * a, n_bytes * b, 0.0)
    if algorithm == "bruck":
        lg = math.log2(max(P, 2))
        return (lg * a, lg * n_bytes / 2 * b, 0.0)
    raise ValueError(algorithm)


def t_shuffle_pipelined(
    P: int,
    n_bytes: float,
    num_chunks: int,
    p: CostParams,
    core_s: float = 0.0,
    algorithm: str = "isend-irecv",
) -> float:
    """Wall time of the K-chunk pipelined shuffle (comm/compute overlap).

    With the payload split into K chunks, chunk ``i+1``'s transfer overlaps
    chunk ``i``'s local merge/compute, so the steady state runs at
    ``max(T_comm_chunk, T_core_chunk)`` per chunk and only the pipeline
    fill/drain is exposed:

        T ≈ t_comm + t_core + (K-1) * max(t_comm, t_core)

    where ``t_comm = T_startup + T_transfer/K`` (every chunk pays the full
    per-message startup — the alpha term that bounds useful K) and
    ``t_core = core_s / K``.

    Args:
      P: number of workers.
      n_bytes: per-worker *total* payload in bytes.
      num_chunks: pipeline depth K >= 1 (K=1 is the monolithic shuffle).
      p: Hockney/compute calibration.
      core_s: total local compute to overlap against, in seconds (e.g. the
        merge/compact leg of the pattern using the shuffle).
      algorithm: monolithic collective flavor used per chunk.

    Returns:
      Estimated wall seconds for the shuffle + overlapped compute.
    """
    K = max(int(num_chunks), 1)
    s, x, r = t_shuffle(P, n_bytes / K, p, algorithm)
    t_comm = s + x + r  # startup is paid per chunk: t_shuffle already has it
    t_core = core_s / K
    return t_comm + t_core + (K - 1) * max(t_comm, t_core)


def choose_chunk_count(
    P: int,
    n_bytes: float,
    p: CostParams = CostParams(),
    core_s: float = 0.0,
    max_chunks: int = 32,
    min_chunk_bytes: float = 4096.0,
) -> int:
    """Pick the pipeline depth K minimizing :func:`t_shuffle_pipelined`.

    Scans K over powers of two up to ``max_chunks``, rejecting chunk sizes
    below ``min_chunk_bytes`` (tiny chunks are pure startup overhead and
    their timing is noise-dominated). Returns K=1 (monolithic) whenever
    pipelining does not beat the single all-to-all — the planner can treat
    ``K > 1`` as "use the pipelined engine".

    Args:
      P: number of workers.
      n_bytes: per-worker total shuffle payload in bytes.
      p: Hockney/compute calibration.
      core_s: overlappable local compute in seconds.
      max_chunks: largest K considered.
      min_chunk_bytes: smallest per-chunk payload worth a message.

    Returns:
      The chosen chunk count K >= 1.
    """
    best_k, best_t = 1, t_shuffle_pipelined(P, n_bytes, 1, p, core_s)
    k = 2
    while k <= max_chunks:
        if n_bytes / k >= min_chunk_bytes:
            t = t_shuffle_pipelined(P, n_bytes, k, p, core_s)
            if t < best_t:
                best_k, best_t = k, t
        k *= 2
    return best_k


def choose_batch_rows(
    P: int,
    row_bytes: float,
    p: CostParams = CostParams(),
    total_rows: int | None = None,
    memory_budget_bytes: float = 32e6,
    working_set_factor: float = 4.0,
    dispatch_overhead_s: float = 1e-3,
    overhead_fraction: float = 0.05,
    min_rows: int = 256,
) -> int:
    """Pick the global row count per streamed batch (morsel size).

    Two forces bound the choice (the streaming analogue of
    :func:`choose_chunk_count`'s alpha-vs-beta tradeoff):

    - **memory ceiling** (hard): a batch's per-device working set —
      ``row_bytes * rows / P`` inflated by ``working_set_factor`` for
      shuffle buffers and operator intermediates — must fit
      ``memory_budget_bytes``;
    - **overhead amortization** (soft): each batch pays a fixed host-side
      cost ``dispatch_overhead_s`` (decode setup, cache lookups, one
      program dispatch), so batches should be large enough that this stays
      under ``overhead_fraction`` of per-batch device work, modeled as
      ``rows/P * (gamma + row_bytes * beta)`` seconds.

    The intra-batch shuffle pipeline depth is planned separately per
    shuffle op by :func:`choose_chunk_count` once batch-scale row estimates
    are known (``repro.plan.optimizer.plan_shuffles``).

    Args:
      P: number of workers.
      row_bytes: bytes per row of the scanned schema (post-pushdown).
      p: Hockney/compute calibration.
      total_rows: dataset rows, to clamp the batch to the data.
      memory_budget_bytes: per-device budget for one batch's working set.
      working_set_factor: working-set inflation over raw batch bytes.
      dispatch_overhead_s: fixed per-batch host overhead.
      overhead_fraction: target ceiling for overhead / device work.
      min_rows: floor on the returned batch size.

    Returns:
      Global rows per batch (>= 1).
    """
    P = max(int(P), 1)
    row_bytes = max(float(row_bytes), 1.0)
    mem_rows = P * memory_budget_bytes / (row_bytes * max(working_set_factor, 1.0))
    t_row = p.gamma_s_per_row + row_bytes * p.beta  # device seconds/row/worker
    amort_rows = dispatch_overhead_s * P / (max(overhead_fraction, 1e-6) * t_row)
    rows = min(mem_rows, max(amort_rows, float(min_rows)))
    if total_rows is not None:
        rows = min(rows, float(max(int(total_rows), 1)))
    return max(int(rows), 1)


def t_allgather(P: int, n_bytes: float, p: CostParams, algorithm: str = "ring"):
    """AllGather cost (paper Table 3): every worker ends with all N bytes.

    Args/returns as :func:`t_shuffle`; total moved is ``P * n_bytes``.
    """
    a, b = p.alpha, p.beta
    total = P * n_bytes  # paper's N: allgather moves the whole table
    if algorithm == "ring":
        return (P * a, (P - 1) / P * total * b, 0.0)
    if algorithm in ("recursive-doubling", "bruck"):
        return (math.log2(max(P, 2)) * a, (P - 1) / P * total * b, 0.0)
    raise ValueError(algorithm)


def t_broadcast(P: int, n_bytes: float, p: CostParams, algorithm: str = "binomial"):
    """Broadcast cost (paper Table 3): root's n bytes reach all P workers.

    Returns (T_startup, T_transfer, T_reduce) in seconds.
    """
    a, b = p.alpha, p.beta
    lg = math.log2(max(P, 2))
    if algorithm == "binomial":
        return (lg * a, lg * n_bytes * b, 0.0)
    if algorithm == "scatter-allgather":
        return ((lg + P) * a, (P - 1) / P * n_bytes * b, 0.0)
    raise ValueError(algorithm)


def t_reduce(P: int, n_bytes: float, p: CostParams, algorithm: str = "binomial"):
    """Reduce-to-root cost (paper Table 3); third term is reduction compute.

    Returns (T_startup, T_transfer, T_reduce) in seconds.
    """
    a, b = p.alpha, p.beta
    lg = math.log2(max(P, 2))
    if algorithm == "binomial":
        return (lg * a, lg * n_bytes * b, lg * n_bytes * b)
    if algorithm == "reduce-scatter-gather":
        return (lg * a, (P - 1) / P * n_bytes * b, (P - 1) / P * n_bytes * b)
    raise ValueError(algorithm)


def t_allreduce(P: int, n_bytes: float, p: CostParams, algorithm: str = "reduce-scatter-allgather"):
    """AllReduce cost (paper Table 3): all workers end with the reduction.

    Returns (T_startup, T_transfer, T_reduce) in seconds.
    """
    a, b = p.alpha, p.beta
    lg = math.log2(max(P, 2))
    if algorithm == "binomial":
        return (lg * a, lg * n_bytes * b, lg * n_bytes * b)
    if algorithm == "recursive-doubling":
        return (lg * a, lg * n_bytes * b, lg * n_bytes * b)
    if algorithm == "reduce-scatter-allgather":
        return (lg * a, 2 * (P - 1) / P * n_bytes * b, (P - 1) / P * n_bytes * b)
    raise ValueError(algorithm)


def _sum3(t):
    return t[0] + t[1] + t[2]


# -- Table 4: core local operator costs ------------------------------------------
# cost(n_rows, cardinality C) -> seconds, using the calibrated gamma.

LOCAL_COSTS: dict[str, Callable[[float, float, CostParams], float]] = {
    "selection": lambda n, C, p: p.gamma_s_per_row * n,
    "map": lambda n, C, p: p.gamma_s_per_row * n,
    "row_aggregation": lambda n, C, p: p.gamma_s_per_row * n,
    "projection": lambda n, C, p: p.gamma_s_per_row * 1.0,  # O(c)
    "union": lambda n, C, p: p.gamma_s_per_row * n,
    "set_difference": lambda n, C, p: p.gamma_s_per_row * n,
    # paper Table 4: Hash-Join O(n) + O(n/C); Sort-Join O(n log n) + O(n/C)
    "hash_join": lambda n, C, p: p.gamma_s_per_row * (n + n / max(C, 1e-9)),
    "sort_join": lambda n, C, p: p.gamma_s_per_row * (n * math.log2(max(n, 2)) + n / max(C, 1e-9)),
    "transpose": lambda n, C, p: p.gamma_s_per_row * n,
    "unique": lambda n, C, p: p.gamma_s_per_row * n,
    "groupby": lambda n, C, p: p.gamma_s_per_row * n,
    "column_aggregation": lambda n, C, p: p.gamma_s_per_row * n,
    "sort": lambda n, C, p: p.gamma_s_per_row * n * math.log2(max(n, 2)),
}


def t_local(op: str, n_rows: float, cardinality: float = 1.0, p: CostParams = CostParams()) -> float:
    """Core local operator cost (paper Table 4).

    Args:
      op: a key of :data:`LOCAL_COSTS` (e.g. "hash_join", "sort", "groupby").
      n_rows: local rows processed (the paper's bold-n, in rows).
      cardinality: key cardinality fraction C in (0, 1].
      p: calibration; uses ``gamma_s_per_row`` [s/row].

    Returns:
      Estimated local seconds.
    """
    return LOCAL_COSTS[op](n_rows, cardinality, p)


# -- §5.3 per-pattern totals -------------------------------------------------------

def pattern_cost(
    pattern: str,
    *,
    P: int,
    n_rows: float,
    row_bytes: float,
    cardinality: float = 1.0,
    core_op: str = "map",
    params: CostParams = CostParams(),
    shuffle_algorithm: str = "isend-irecv",
    num_chunks: int = 1,
) -> dict[str, float]:
    """Estimated wall time breakdown {core, aux, comm, total} per worker.

    Args:
      pattern: a key of :data:`repro.core.patterns.PATTERNS`.
      P: number of workers.
      n_rows: rows per worker (bold-n in rows).
      row_bytes: bytes per row (converts rows -> bytes for comm terms).
      cardinality: key cardinality fraction C in (0, 1].
      core_op: the core local operator (a :data:`LOCAL_COSTS` key).
      params: Hockney + gamma calibration.
      shuffle_algorithm: collective flavor for shuffle-based patterns.
      num_chunks: pipeline depth K for shuffle-based patterns. With K > 1
        the shuffle and the core op overlap
        (:func:`t_shuffle_pipelined`), so ``total < core + aux + comm``;
        the component terms still report the unoverlapped costs.

    Returns:
      {"core", "aux", "comm", "total"} in seconds.
    """
    p = params
    n_bytes = n_rows * row_bytes
    C = cardinality
    if pattern == "embarrassingly_parallel":
        core = t_local(core_op, n_rows, C, p)
        return _pack(core, 0.0, 0.0)
    if pattern == "shuffle_compute":
        aux = t_local("map", n_rows, C, p)  # hash partition is a map
        comm = _sum3(t_shuffle(P, n_bytes, p, shuffle_algorithm))
        core = t_local(core_op, n_rows, C, p)
        if num_chunks > 1:
            piped = t_shuffle_pipelined(P, n_bytes, num_chunks, p,
                                        core_s=core, algorithm=shuffle_algorithm)
            return {"core": core, "aux": aux, "comm": comm, "total": aux + piped}
        return _pack(core, aux, comm)
    if pattern == "sample_shuffle_compute":
        aux = t_local("sort", n_rows, C, p) + t_local("map", n_rows, C, p)
        comm = _sum3(t_allreduce(P, 8.0 * P, p)) + _sum3(t_shuffle(P, n_bytes, p, shuffle_algorithm))
        core = t_local("sort", n_rows, C, p)  # local merge
        return _pack(core, aux, comm)
    if pattern == "combine_shuffle_reduce":
        core1 = t_local(core_op, n_rows, C, p)
        aux = t_local("map", n_rows * C, C, p)
        comm = _sum3(t_shuffle(P, n_bytes * C, p, shuffle_algorithm))
        core2 = t_local(core_op, n_rows * C, C, p)
        if num_chunks > 1:
            piped = t_shuffle_pipelined(P, n_bytes * C, num_chunks, p,
                                        core_s=core2, algorithm=shuffle_algorithm)
            return {"core": core1 + core2, "aux": aux, "comm": comm,
                    "total": core1 + aux + piped}
        return _pack(core1 + core2, aux, comm)
    if pattern == "broadcast_compute":
        # broadcast the small relation (n here = small side), join locally
        comm = _sum3(t_allgather(P, n_bytes, p))
        core = t_local(core_op, n_rows, C, p)
        return _pack(core, 0.0, comm)
    if pattern == "globally_reduce":
        core = t_local("column_aggregation", n_rows, C, p)
        comm = _sum3(t_allreduce(P, row_bytes, p))
        return _pack(core, 0.0, comm)
    if pattern == "halo_exchange":
        core = t_local("map", n_rows, C, p)
        comm = p.alpha + row_bytes * p.beta  # one neighbor message
        return _pack(core, 0.0, comm)
    if pattern == "partitioned_io":
        core = t_local("map", n_rows, C, p)
        comm = _sum3(t_shuffle(P, n_bytes, p, shuffle_algorithm))
        return _pack(core, 0.0, comm)
    raise ValueError(pattern)


def _pack(core, aux, comm):
    return {"core": core, "aux": aux, "comm": comm, "total": core + aux + comm}


# -- §5.4 runtime strategy selection ----------------------------------------------

def choose_join_strategy(
    n_left_rows: float,
    n_right_rows: float,
    P: int,
    row_bytes: float,
    params: CostParams = CostParams(),
    broadcast_budget_bytes: float = 256e6,
) -> str:
    """Broadcast-join beats shuffle-join when one relation is small enough
    that replicating it costs less than shuffling both (paper §5.3.7/§5.4.2).

    A memory guard rejects broadcast when the replicated relation exceeds
    ``broadcast_budget_bytes`` per worker — the paper's observation that
    Modin's broadcast-only joins OOM on same-order relations is a memory
    failure, not just a bandwidth one."""
    small = min(n_left_rows, n_right_rows)
    if small * row_bytes > broadcast_budget_bytes:
        return "shuffle"
    shuffle_cost = (
        _sum3(t_shuffle(P, n_left_rows / P * row_bytes, params))
        + _sum3(t_shuffle(P, n_right_rows / P * row_bytes, params))
    )
    bcast_cost = _sum3(t_allgather(P, small / P * row_bytes, params))
    return "broadcast" if bcast_cost < shuffle_cost else "shuffle"


def choose_groupby_strategy(cardinality: float, threshold: float = 0.5) -> bool:
    """pre_combine? Combine-Shuffle-Reduce wins at low cardinality; at C->1 it
    degrades below plain Shuffle-Compute because the core op runs twice
    (paper §5.4.1). Returns True for pre-combine."""
    return cardinality < threshold


def choose_shuffle_algorithm(P: int, n_bytes: float, params: CostParams = CostParams()) -> str:
    """Latency-bound (small n, large P) -> Bruck; else pairwise/isend
    (paper §6.1.1 recommendation)."""
    best, best_t = None, float("inf")
    for alg in ("isend-irecv", "ring", "pairwise", "bruck"):
        t = _sum3(t_shuffle(P, n_bytes, params, alg))
        if t < best_t:
            best, best_t = alg, t
    return best
