"""Dictionary encoding for string columns (ISSUE 10).

A dict-encoded column is a host-side *vocabulary* — a sorted, deduplicated
tuple of strings — paired with a device ``int32`` *codes* array. Because the
vocab is sorted, codes are order-isomorphic with the strings they stand for:
``codes_a < codes_b  <=>  strings_a < strings_b``. Every existing shuffle
pattern therefore composes unchanged — ``hash_partition_ids`` and
``local_groupby`` already key on arbitrary int columns, and ``sort_values``
on codes sorts the decoded strings.

The distributed subtlety is *vocab unification*: two relations carrying
different vocabs for the same column must be recoded into one merged vocab
space before a Join/Union/Difference compares their codes. The merge is
host-side (vocabs are tiny next to data) and each side's remap is a single
monotone ``np.searchsorted`` gather — planned as an explicit ``Recode``
step in the lazy layer so ``explain()`` shows it and the cost model charges
it (see ``repro.plan.logical.Recode``).

This module is deliberately numpy-only (no jax, no engine imports) so the
expression layer, dataset layer and plan layer can all import it without
cycles.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "DICT_DTYPE",
    "DictVocab",
    "encode_strings",
    "is_string_array",
    "storage_dtype",
    "storage_schema",
    "unify_vocabs",
]

#: schema dtype string marking a dict-encoded column in dataset manifests
#: and user-facing schemas. The device/plan layers never see it — they see
#: the *storage* dtype ``int32`` (see :func:`storage_dtype`).
DICT_DTYPE = "dict"


def is_string_array(arr) -> bool:
    """True when ``arr`` is a numpy array of strings (unicode/bytes kind)."""
    return isinstance(arr, np.ndarray) and arr.dtype.kind in ("U", "S")


def storage_dtype(dt: str) -> str:
    """Map a schema dtype string to the on-device storage dtype.

    ``"dict"`` columns are stored as ``int32`` codes; every other dtype is
    its own storage. The plan layer, cost model and streaming runner only
    ever see storage dtypes — ``"dict"`` lives in dataset manifests and
    user schemas, with the vocab riding alongside as host metadata."""
    return "int32" if str(dt) == DICT_DTYPE else dt


def storage_schema(schema) -> tuple:
    """Rewrite a ``((name, dtype, tail), ...)`` schema to storage dtypes."""
    return tuple((n, storage_dtype(dt), tuple(tail)) for n, dt, tail in schema)


@dataclasses.dataclass(frozen=True)
class DictVocab:
    """Sorted, deduplicated vocabulary of one dict-encoded column.

    ``words`` is a tuple of unique strings in ascending order, so the code
    of a word is its index and code order equals string order. Instances
    are immutable and hashable (usable in cache keys and plan nodes).
    """

    words: tuple

    def __post_init__(self):
        w = tuple(str(s) for s in self.words)
        if any(w[i] >= w[i + 1] for i in range(len(w) - 1)):
            w = tuple(sorted(set(w)))
        object.__setattr__(self, "words", w)

    @classmethod
    def from_values(cls, values) -> "DictVocab":
        """Build a vocab from any iterable/array of strings."""
        return cls(tuple(sorted(set(str(s) for s in np.asarray(values).ravel()))))

    @property
    def values(self) -> np.ndarray:
        """The vocabulary as a numpy unicode array (index = code)."""
        return np.asarray(self.words, dtype=np.str_)

    def __len__(self) -> int:
        return len(self.words)

    def __contains__(self, s) -> bool:
        i = int(np.searchsorted(self.values, str(s)))
        return i < len(self.words) and self.words[i] == str(s)

    def code_of(self, s) -> int | None:
        """Code of ``s`` in this vocab, or None when absent."""
        i = int(np.searchsorted(self.values, str(s)))
        return i if i < len(self.words) and self.words[i] == str(s) else None

    def bound(self, s, side: str = "left") -> int:
        """``np.searchsorted`` boundary of ``s`` — the code-space threshold
        for compiling ordered string comparisons (``<``/``<=``/``>``/``>=``)
        against a literal that may be absent from the vocab."""
        return int(np.searchsorted(self.values, str(s), side=side))

    def merge(self, other: "DictVocab") -> "DictVocab":
        """Union of two vocabs (sorted, deduplicated)."""
        if other.words == self.words:
            return self
        return DictVocab(tuple(sorted(set(self.words) | set(other.words))))

    def recode_map(self, merged: "DictVocab") -> np.ndarray:
        """int32 gather map from this vocab's code space into ``merged``'s.

        ``merged`` must be a superset; the map is monotone because both
        vocabs are sorted. ``new_codes = recode_map(merged)[old_codes]``."""
        if not self.words:
            return np.zeros(0, np.int32)
        m = np.searchsorted(merged.values, self.values).astype(np.int32)
        if (np.asarray(merged.values)[m] != self.values).any():
            raise ValueError("recode target vocab is not a superset")
        return m

    def is_identity_into(self, merged: "DictVocab") -> bool:
        """True when recoding into ``merged`` would not change any code."""
        return merged.words[: len(self.words)] == self.words

    def encode(self, values) -> np.ndarray:
        """Strings -> int32 codes. Raises ``KeyError`` naming the first
        value absent from the vocab."""
        arr = np.asarray(values).astype(np.str_)
        codes = np.searchsorted(self.values, arr)
        codes = np.minimum(codes, max(len(self.words) - 1, 0))
        if arr.size and (len(self.words) == 0 or
                         (self.values[codes] != arr).any()):
            if len(self.words) == 0:
                raise KeyError(f"value {arr.ravel()[0]!r} not in empty vocab")
            bad = arr[self.values[codes] != arr].ravel()[0]
            raise KeyError(f"value {bad!r} not in vocab")
        return codes.astype(np.int32)

    def decode(self, codes) -> np.ndarray:
        """int32 codes -> numpy string array (inverse of :meth:`encode`)."""
        c = np.asarray(codes)
        if c.size == 0:
            return np.zeros(c.shape, dtype=self.values.dtype if self.words
                            else np.dtype("<U1"))
        return self.values[c]


def encode_strings(values) -> tuple:
    """Dict-encode a string array: ``(int32 codes, DictVocab)``.

    Uses ``np.unique(return_inverse=True)``, whose unique output is sorted —
    exactly the vocab invariant."""
    arr = np.asarray(values)
    if arr.dtype.kind not in ("U", "S", "O"):
        raise TypeError(f"cannot dict-encode non-string array of dtype "
                        f"{arr.dtype}")
    uniq, inv = np.unique(arr.astype(np.str_), return_inverse=True)
    return inv.astype(np.int32).reshape(arr.shape), DictVocab(tuple(uniq))


def unify_vocabs(*vocabs: DictVocab) -> DictVocab:
    """Merge any number of vocabs into one (sorted union)."""
    out = DictVocab(())
    for v in vocabs:
        out = out.merge(v)
    return out
