"""Core local operators (paper §4.1, Table 4) under static shapes.

Single-partition implementations of the primitive operators that the
distributed patterns promote: sort, hash-join (sort-based under XLA),
groupby segment-reduction, unique, set membership. Every output is
capacity-bounded with an explicit ``nvalid`` and, where the true output size
can exceed capacity, an ``overflow`` counter.

Design notes (DESIGN.md §7.1):
- Join expansion uses ``jnp.repeat(..., total_repeat_length)`` — the
  static-shape equivalent of Arrow's variable-length take.
- Rows are matched on a 32-bit key hash and *verified on emission* against the
  actual key columns, so hash collisions cost capacity, never correctness.
- Multi-column keys sort lexicographically (hash, col1, col2, ...), which
  makes equal keys adjacent for dedup/groupby adjacency logic.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from .dataframe import Table, compact, max_sentinel, min_sentinel, valid_mask
from .partition import hash_columns

__all__ = [
    "local_sort",
    "local_join",
    "local_groupby",
    "local_unique",
    "local_anti_join",
    "select",
    "project",
    "with_column",
    "row_aggregate",
    "column_aggregate_local",
]

_AGG_OPS = ("sum", "count", "min", "max", "mean")

# canonical definitions live in dataframe.py (shared with the kernel layer)
_max_sentinel = max_sentinel
_min_sentinel = min_sentinel


# -- embarrassingly-parallel primitives (paper §5.3.1) -------------------------

def select(table: Table, pred) -> Table:
    """Filter rows by a predicate over the column dict. O(n)."""
    return compact(table, pred(table.columns))


def project(table: Table, names: Sequence[str]) -> Table:
    """Column projection. O(c) — zero-copy column selection."""
    return table.select_columns(names)


def with_column(table: Table, name: str, fn) -> Table:
    """Add (or overwrite) one column computed by ``fn`` over the column
    dict; all other columns pass through. A scalar result (literal-only
    expression) broadcasts to the table capacity. Shared by the eager
    ``DDF.with_column`` body and the plan executor's ``WithColumn`` step so
    the two layers cannot diverge."""
    v = jnp.asarray(fn(table.columns))
    if v.ndim == 0:
        v = jnp.full((table.capacity,), v)
    return Table({**table.columns, name: v}, table.nvalid)


def row_aggregate(table: Table, names: Sequence[str], out: str, op: str = "sum") -> Table:
    """Per-row aggregate across columns -> new column ``out`` (paper §5.3.1)."""
    cols = [table.columns[n] for n in names]
    stack = jnp.stack(cols, axis=0)
    if op == "sum":
        v = jnp.sum(stack, axis=0)
    elif op == "min":
        v = jnp.min(stack, axis=0)
    elif op == "max":
        v = jnp.max(stack, axis=0)
    elif op == "mean":
        v = jnp.mean(stack.astype(jnp.float32), axis=0)
    else:
        raise ValueError(op)
    return table.replace(**{out: v})


def column_aggregate_local(table: Table, name: str, op: str):
    """Local leg of the Globally-Reduce pattern (paper §5.3.5)."""
    v = table.columns[name]
    m = valid_mask(table)
    if op in ("sum", "mean"):
        s = jnp.sum(jnp.where(m, v, jnp.zeros_like(v)).astype(jnp.float64 if v.dtype == jnp.float64 else jnp.float32))
        cnt = jnp.sum(m, dtype=jnp.int32)
        return s, cnt
    if op == "min":
        return jnp.min(jnp.where(m, v, _max_sentinel(v.dtype))), jnp.sum(m, dtype=jnp.int32)
    if op == "max":
        return jnp.max(jnp.where(m, v, _min_sentinel(v.dtype))), jnp.sum(m, dtype=jnp.int32)
    if op == "count":
        return jnp.sum(m, dtype=jnp.int32), jnp.sum(m, dtype=jnp.int32)
    raise ValueError(op)


# -- sorting -------------------------------------------------------------------

def local_sort(table: Table, key_columns: Sequence[str], descending: bool = False) -> Table:
    """O(n log n) local sort; invalid rows stay at the tail (stable)."""
    inv = ~valid_mask(table)
    keys = []
    for name in reversed(key_columns):
        k = table.columns[name]
        if descending:
            # order-reversing map: -x for floats, ~x for ints (exact, no
            # INT_MIN overflow).
            k = -k if jnp.issubdtype(k.dtype, jnp.floating) else jnp.bitwise_not(k)
        keys.append(k)
    keys.append(inv)  # primary: invalid rows last
    order = jnp.lexsort(tuple(keys))
    cols = {k: v[order] for k, v in table.columns.items()}
    return Table(cols, table.nvalid)


def _sorted_by_key_hash(table: Table, key_columns: Sequence[str]):
    """Sort rows by (valid desc, key hash, key columns...). Returns
    (sorted_table, sorted_hash, order). Invalid rows at tail with hash=MAX."""
    h = hash_columns(table, key_columns)
    m = valid_mask(table)
    h = jnp.where(m, h, jnp.uint32(0xFFFFFFFF))
    keys = [table.columns[n] for n in reversed(key_columns)] + [h, ~m]
    order = jnp.lexsort(tuple(keys))
    cols = {k: v[order] for k, v in table.columns.items()}
    return Table(cols, table.nvalid), h[order], order


def _adjacent_new_group(sorted_table: Table, key_columns: Sequence[str]) -> jax.Array:
    """is_new[i]: row i starts a new key group (rows sorted by key)."""
    cap = sorted_table.capacity
    is_new = jnp.zeros((cap,), bool).at[0].set(True)
    for name in key_columns:
        v = sorted_table.columns[name]
        neq = v[1:] != v[:-1]
        is_new = is_new.at[1:].max(neq)
    return is_new


# -- unique (hash dedup, paper Table 4: O(n), output O(nC)) --------------------

def local_unique(table: Table, key_columns: Sequence[str],
                 capacity: int | None = None, with_overflow: bool = False):
    """Deduplicate rows by key columns (first occurrence wins; hash-exact).

    ``with_overflow=True`` additionally returns how many distinct rows did
    not fit in ``capacity`` (``compact`` truncates silently otherwise —
    the distributed wrappers surface this so ``strict_overflow`` can turn
    a capacity misestimate into a loud error instead of dropped rows)."""
    st, _, _ = _sorted_by_key_hash(table, key_columns)
    keep = _adjacent_new_group(st, key_columns) & valid_mask(st)
    out = compact(st, keep, capacity=capacity)
    if not with_overflow:
        return out
    cap_out = st.capacity if capacity is None else capacity
    ov = jnp.maximum(jnp.sum(keep, dtype=jnp.int32) - cap_out, 0)
    return out, ov


# -- groupby (combine / reduce legs, paper §5.3.4) ------------------------------

def _seg_reduce_dispatch(vals: jax.Array, seg: jax.Array, nseg: int, op: str) -> jax.Array:
    """One segment reduction, routed to the Pallas kernel or jnp.

    ``vals`` is (cap,) already masked/sentinel-filled by the caller; ``seg``
    is non-decreasing dense ids with ``nseg-1`` as the invalid bucket.
    ``kernels.segment_reduce`` resolves the backend per (row count, dtype);
    both paths return the same (nseg,) result — bit-identical for integer
    ops and min/max, and for float sums up to summation order
    (docs/KERNELS.md)."""
    from ..kernels import ops as kernel_ops

    return kernel_ops.segment_reduce(vals[:, None], seg, nseg, op=op)[:, 0]


def agg_schema(aggs: Mapping[str, Sequence[str]]) -> list[tuple[str, str, str]]:
    """[(value_col, op, out_col)] with mean decomposed into sum+count."""
    out = []
    for col, ops in aggs.items():
        for op in ops:
            if op not in _AGG_OPS:
                raise ValueError(f"unsupported agg {op}")
            out.append((col, op, f"{col}_{op}"))
    return out


def local_groupby(
    table: Table,
    key_columns: Sequence[str],
    aggs: Mapping[str, Sequence[str]],
    capacity: int | None = None,
    merge: bool = False,
    with_overflow: bool = False,
):
    """Hash-groupby via sort + segment reduction. O(n log n) under XLA (the
    paper's O(n) hash table does not map to static shapes; the extra log n is
    a documented hardware-adaptation cost, DESIGN.md §2).

    merge=False: input is raw rows; emits key cols + <col>_<op> partials
    (mean contributes <col>_sum & <col>_count; finalization happens in the
    distributed wrapper).
    merge=True: input columns are partials named <col>_<op>; re-reduces with
    the merge semantics (sum of sums, min of mins, ...).
    with_overflow=True: additionally return how many groups did not fit in
    ``capacity`` (``compact`` truncates silently otherwise; the distributed
    wrappers surface this so ``strict_overflow`` turns a reduce-side
    capacity misestimate into a loud error instead of dropped groups).
    """
    cap = table.capacity
    cap_out = cap if capacity is None else capacity
    st, _, _ = _sorted_by_key_hash(table, key_columns)
    m = valid_mask(st)
    is_new = _adjacent_new_group(st, key_columns) & m
    gid = jnp.cumsum(is_new.astype(jnp.int32)) - 1  # valid rows: [0, ngroups)
    seg = jnp.where(m, gid, cap)  # invalid -> overflow bucket
    nseg = cap + 1

    spec = agg_schema(aggs)
    out_cols: dict[str, jax.Array] = {}
    # group representative row (first row of each group) for key columns
    first_idx = jax.ops.segment_min(jnp.arange(cap, dtype=jnp.int32), seg, num_segments=nseg)[:cap]
    first_idx = jnp.minimum(first_idx, cap - 1)
    for name in key_columns:
        out_cols[name] = st.columns[name][first_idx]

    def seg_reduce(vals, op):
        # combine leg of Combine-Shuffle-Reduce: dispatched to the Pallas
        # segment_reduce kernel when profitable (registry + cost model).
        # seg is dense, contiguous and non-decreasing (cumsum of is_new;
        # invalid rows -> the cap bucket at the tail), which is exactly the
        # kernel path's exactness contract (max_segments = block).
        if op == "min":
            vals = jnp.where(m, vals, _max_sentinel(vals.dtype))
        elif op == "max":
            vals = jnp.where(m, vals, _min_sentinel(vals.dtype))
        elif op != "sum":
            raise ValueError(op)
        return _seg_reduce_dispatch(vals, seg, nseg, op)[:cap]

    needed: dict[str, tuple[str, str]] = {}  # out partial name -> (src col partial, merge op)
    for col, op, out_name in spec:
        if op == "mean":
            needed[f"{col}_sum"] = (f"{col}_sum" if merge else col, "sum")
            needed[f"{col}_count"] = (f"{col}_count" if merge else col, "count")
        elif op == "count":
            needed[f"{col}_count"] = (f"{col}_count" if merge else col, "count")
        else:
            needed[out_name] = (out_name if merge else col, op)

    ones = m.astype(jnp.int32)
    for out_name, (src, op) in needed.items():
        if op == "count":
            if merge:
                vals = st.columns[src]
                vals = jnp.where(m, vals, jnp.zeros_like(vals))
                out_cols[out_name] = seg_reduce(vals, "sum")
            else:
                out_cols[out_name] = _seg_reduce_dispatch(ones, seg, nseg, "sum")[:cap]
        else:
            base = st.columns[src]
            vals = jnp.where(m, base, jnp.zeros_like(base)) if op == "sum" else base
            out_cols[out_name] = seg_reduce(vals, op)

    ngroups = jnp.sum(is_new, dtype=jnp.int32)
    out = Table(out_cols, jnp.asarray(cap, jnp.int32))
    keep = jnp.arange(cap, dtype=jnp.int32) < ngroups
    out = compact(out, keep, capacity=cap_out)
    if not with_overflow:
        return out
    return out, jnp.maximum(ngroups - cap_out, 0)


def finalize_groupby(table: Table, aggs: Mapping[str, Sequence[str]]) -> Table:
    """Compute mean = sum/count and drop helper partials not requested."""
    spec = agg_schema(aggs)
    cols = dict(table.columns)
    requested = set()
    for col, op, out_name in spec:
        if op == "mean":
            s = cols[f"{col}_sum"]
            c = jnp.maximum(cols[f"{col}_count"], 1)
            cols[out_name] = s.astype(jnp.float32) / c.astype(jnp.float32)
        requested.add(out_name)
    # keep key columns + requested outputs
    keys = [n for n in table.columns if not any(n == f"{c}_{o}" for c, ops in aggs.items() for o in _AGG_OPS)]
    keep_names = set(keys) | requested
    cols = {k: v for k, v in cols.items() if k in keep_names}
    return Table(cols, table.nvalid)


# -- join (sort-based hash join, paper Table 4 Sort-Join) ----------------------

def local_join(
    left: Table,
    right: Table,
    key_columns: Sequence[str],
    capacity: int,
    suffix: str = "_r",
) -> tuple[Table, jax.Array]:
    """Inner equi-join. Returns (result, overflow = pairs beyond capacity).

    Left is sorted by key hash; each right row binary-searches its hash run;
    pair expansion via total_repeat_length; emitted pairs verified against the
    real key columns (collision-exact).
    """
    ls, lh, lorder = _sorted_by_key_hash(left, key_columns)
    rm = valid_mask(right)
    rh = hash_columns(right, key_columns)
    rh = jnp.where(rm, rh, jnp.uint32(0xFFFFFFFE))  # differs from left's pad
    lo = jnp.searchsorted(lh, rh, side="left")
    hi = jnp.searchsorted(lh, rh, side="right")
    counts = (hi - lo).astype(jnp.int32)
    offs = jnp.cumsum(counts) - counts  # exclusive prefix
    total = offs[-1] + counts[-1]

    cap_r = right.capacity
    out_pos = jnp.arange(capacity, dtype=jnp.int32)
    out_r = jnp.repeat(jnp.arange(cap_r, dtype=jnp.int32), counts, total_repeat_length=capacity)
    within = out_pos - offs[out_r]
    out_l = jnp.clip(lo[out_r].astype(jnp.int32) + within, 0, left.capacity - 1)

    emit = out_pos < total
    # verify true key equality (hash-collision guard) + validity
    lvalid = jnp.arange(left.capacity, dtype=jnp.int32) < ls.nvalid
    for name in key_columns:
        emit = emit & (ls.columns[name][out_l] == right.columns[name][out_r])
    emit = emit & lvalid[out_l] & rm[out_r]

    cols: dict[str, jax.Array] = {}
    for name in key_columns:
        cols[name] = ls.columns[name][out_l]
    for name, v in ls.columns.items():
        if name not in key_columns:
            cols[name] = v[out_l]
    for name, v in right.columns.items():
        if name not in key_columns:
            out_name = name if name not in cols else f"{name}{suffix}"
            cols[out_name] = v[out_r]

    res = Table(cols, jnp.asarray(capacity, jnp.int32))
    res = compact(res, emit, capacity=capacity)
    overflow = jnp.maximum(total - capacity, 0)
    return res, overflow


def local_anti_join(
    left: Table,
    right: Table,
    key_columns: Sequence[str],
    capacity: int | None = None,
    dedup_left: bool = True,
) -> Table:
    """Rows of left whose key does not appear in right (set difference leg).

    Exact under hash collisions: membership is established by joining the
    deduplicated keys and scattering hit marks back to left rows.
    """
    lu = local_unique(left, key_columns) if dedup_left else left
    ru = local_unique(right, key_columns)
    ls, _, _ = _sorted_by_key_hash(lu, key_columns)
    # Join the deduplicated keys (collision-exact thanks to emit-verify in
    # local_join) and scatter hit marks back onto left rows by row index.
    # Both sides are deduplicated, so the pair count is bounded by
    # ls.capacity — no overflow possible.
    member = jnp.zeros((ls.capacity,), bool)
    pairs, _ = local_join(
        Table({n: ls.columns[n] for n in key_columns} | {"__lidx": jnp.arange(ls.capacity, dtype=jnp.int32)}, ls.nvalid),
        Table({n: ru.columns[n] for n in key_columns}, ru.nvalid),
        key_columns,
        capacity=ls.capacity,
    )
    hit_idx = pairs.columns["__lidx"]
    hit_valid = jnp.arange(pairs.capacity, dtype=jnp.int32) < pairs.nvalid
    member = member.at[jnp.where(hit_valid, hit_idx, ls.capacity)].set(True, mode="drop")
    keep = valid_mask(ls) & ~member
    return compact(ls, keep, capacity=capacity)
