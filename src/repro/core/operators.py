"""Distributed dataframe operators: the paper's parallel processing patterns.

Each function here is the *distributed* promotion of a core local operator
(paper §4, Table 2), composed from the three sub-operator kinds:

    core local op  +  auxiliary ops (partition/compact)  +  communication op

All functions run **inside shard_map** over the row-partition axes and take a
``Communicator``. The host-side planning layer (``patterns.py``) chooses
between pattern variants (hash-shuffle vs broadcast join, combine vs plain
shuffle groupby) with the cost model, mirroring paper §5.4.

Static-shape contract: callers pass ``quota`` (per-destination shuffle slots)
and output ``capacity``; operators return overflow counters that are zero for
well-sized quotas (benchmarks assert this).

Hot-kernel dispatch: the shuffle build side of every operator here
(``hash_partition_ids``) and the segment reductions inside
``local_groupby`` route through the Pallas kernel layer
(``repro.kernels``) when the dispatch registry + cost model select it —
bit-identical to the jnp paths either way (docs/KERNELS.md).
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from .comm.communicator import Communicator
from .dataframe import Table, compact, concat, valid_mask
from .local_ops import (
    _max_sentinel,
    finalize_groupby,
    local_anti_join,
    local_groupby,
    local_join,
    local_sort,
    local_unique,
)
from .partition import hash_partition_ids, range_partition_ids

__all__ = [
    "dist_join_shuffle",
    "dist_join_broadcast",
    "dist_groupby",
    "dist_unique",
    "dist_union",
    "dist_difference",
    "dist_sort",
    "dist_column_agg",
    "dist_window_sum",
    "dist_window_agg",
    "dist_transpose",
    "rebalance",
    "dist_head",
    "dist_length",
]


# -- Shuffle-Compute (paper §5.3.2) --------------------------------------------

def dist_join_shuffle(
    comm: Communicator,
    left: Table,
    right: Table,
    key_columns: Sequence[str],
    quota: int,
    capacity: int,
    num_chunks: int = 1,
) -> tuple[Table, dict]:
    """Hash-shuffle join: co-partition both relations by key hash, then join
    locally. T = O(n) part + O(P) + O((P-1)/P * n) comm + T_core (paper §5.3.2).

    The build side (destination ids for both relations) dispatches to the
    Pallas ``kernels.hash_partition`` when profitable (docs/KERNELS.md).

    Args:
      comm: communicator bound to the row-partition axis (inside shard_map).
      left, right: local partitions of the two relations (same key schema).
      key_columns: equi-join key column names.
      quota: per-destination shuffle slots (static-shape contract).
      capacity: output table capacity (join pairs beyond it overflow).
      num_chunks: shuffle pipeline depth K; K > 1 uses the pipelined chunked
        engine (bit-exact, overlaps transfer with the local join leg).

    Returns:
      (joined table, {"overflow_left", "overflow_right", "overflow_join"})
      — overflow counters are zero for well-sized quota/capacity.
    """
    P = comm.size()
    dl = hash_partition_ids(left, key_columns, P)
    dr = hash_partition_ids(right, key_columns, P)
    lsh, ovl = comm.shuffle(left, dl, quota, num_chunks=num_chunks)
    rsh, ovr = comm.shuffle(right, dr, quota, num_chunks=num_chunks)
    out, ovj = local_join(lsh, rsh, key_columns, capacity)
    return out, {"overflow_left": ovl, "overflow_right": ovr, "overflow_join": ovj}


# -- Broadcast-Compute (paper §5.3.7) -------------------------------------------

def dist_join_broadcast(
    comm: Communicator,
    left: Table,
    right: Table,
    key_columns: Sequence[str],
    capacity: int,
    gather: str = "right",
) -> tuple[Table, dict]:
    """Broadcast join: replicate the small relation on every worker, join
    against the other side's local partition. No shuffle of the big side.

    ``gather`` names the replicated (small) side — the caller's planner
    picks it from row counts. Left/right column roles are preserved either
    way (the output schema never depends on which side was gathered), so
    broadcast and shuffle strategies stay interchangeable."""
    if gather == "left":
        out, ovj = local_join(comm.allgather(left), right, key_columns, capacity)
    else:
        out, ovj = local_join(left, comm.allgather(right), key_columns, capacity)
    return out, {"overflow_join": ovj}


# -- Combine-Shuffle-Reduce (paper §5.3.4) --------------------------------------

def dist_groupby(
    comm: Communicator,
    table: Table,
    key_columns: Sequence[str],
    aggs: Mapping[str, Sequence[str]],
    quota: int,
    capacity: int,
    pre_combine: bool = True,
    num_chunks: int = 1,
    finalize: bool = True,
) -> tuple[Table, dict]:
    """GroupBy-aggregate. pre_combine=True is the Combine-Shuffle-Reduce
    pattern (efficient at low cardinality C); False degenerates to plain
    Shuffle-Compute (better when C ~ 1, paper §5.4.1).

    Both hot kernels inside dispatch to the Pallas layer when profitable:
    the build side via ``kernels.hash_partition`` and the combine/reduce
    legs' segment reductions via ``kernels.segment_reduce``
    (docs/KERNELS.md).

    Args:
      comm: communicator bound to the row-partition axis.
      table: local partition of the grouped relation.
      key_columns: group-key column names.
      aggs: value column -> aggregation ops ("sum"/"count"/"min"/"max"/"mean").
      quota: per-destination shuffle slots.
      capacity: output capacity (>= distinct keys landing on this worker).
      pre_combine: combine locally before the shuffle (paper §5.4.1).
      num_chunks: shuffle pipeline depth K (K > 1 = pipelined chunked engine).
      finalize: compute means and drop helper partials. ``finalize=False``
        emits the mergeable partial-aggregate form (``<col>_sum`` /
        ``<col>_count`` / ...) — the streaming engine's per-batch carry
        state, merged across batches with ``local_groupby(merge=True)``.

    Returns:
      (aggregated table, {"overflow_shuffle": rows dropped at the shuffle,
      "overflow_agg": groups dropped at the reduce-side ``capacity``}).
    """
    P = comm.size()
    if pre_combine:
        partial = local_groupby(table, key_columns, aggs, merge=False)
    else:
        partial = table
    dest = hash_partition_ids(partial, key_columns, P)
    shuf, ov = comm.shuffle(partial, dest, quota, num_chunks=num_chunks)
    red, ov_agg = local_groupby(shuf, key_columns, aggs, capacity=capacity,
                                merge=pre_combine, with_overflow=True)
    out = finalize_groupby(red, aggs) if finalize else red
    return out, {"overflow_shuffle": ov, "overflow_agg": ov_agg}


def dist_unique(
    comm: Communicator,
    table: Table,
    key_columns: Sequence[str],
    quota: int,
    capacity: int,
    pre_combine: bool = True,
    num_chunks: int = 1,
) -> tuple[Table, dict]:
    """Distinct rows by key (Combine-Shuffle-Reduce, paper §5.3.4): local
    dedup (optional), hash-shuffle by key, local dedup of the merged rows.

    Args mirror :func:`dist_groupby`; ``num_chunks`` > 1 pipelines the
    shuffle. Returns (deduplicated table, {"overflow_shuffle",
    "overflow_agg"}) — ``overflow_agg`` counts distinct rows dropped at
    the reduce-side ``capacity``.
    """
    P = comm.size()
    t = local_unique(table, key_columns) if pre_combine else table
    dest = hash_partition_ids(t, key_columns, P)
    shuf, ov = comm.shuffle(t, dest, quota, num_chunks=num_chunks)
    out, ov_agg = local_unique(shuf, key_columns, capacity=capacity,
                               with_overflow=True)
    return out, {"overflow_shuffle": ov, "overflow_agg": ov_agg}


def dist_union(
    comm: Communicator,
    left: Table,
    right: Table,
    key_columns: Sequence[str],
    quota: int,
    capacity: int,
    num_chunks: int = 1,
) -> tuple[Table, dict]:
    """Set union = concat + distributed unique (paper Table 2)."""
    both = concat(left, right)
    return dist_unique(comm, both, key_columns, quota, capacity,
                       num_chunks=num_chunks)


def dist_difference(
    comm: Communicator,
    left: Table,
    right: Table,
    key_columns: Sequence[str],
    quota: int,
    capacity: int,
    num_chunks: int = 1,
) -> tuple[Table, dict]:
    """Set difference: co-partition by key hash, local anti-join."""
    P = comm.size()
    dl = hash_partition_ids(left, key_columns, P)
    dr = hash_partition_ids(right, key_columns, P)
    lsh, ovl = comm.shuffle(left, dl, quota, num_chunks=num_chunks)
    rsh, ovr = comm.shuffle(right, dr, quota, num_chunks=num_chunks)
    out = local_anti_join(lsh, rsh, key_columns, capacity=capacity)
    return out, {"overflow_left": ovl, "overflow_right": ovr}


# -- Sample-Shuffle-Compute (paper §5.3.3) ---------------------------------------

def dist_sort(
    comm: Communicator,
    table: Table,
    key_column: str,
    quota: int,
    capacity: int,
    descending: bool = False,
    samples_per_worker: int | None = None,
    num_chunks: int = 1,
) -> tuple[Table, dict]:
    """Sample sort with regular sampling (Li et al., paper §5.3.3).

    local sort -> regular sample -> allgather samples -> pivots -> range
    partition -> shuffle -> local merge(sort). Output: partition i holds the
    globally i-th key range, locally sorted.

    Args:
      comm: communicator bound to the row-partition axis.
      table: local partition to sort.
      key_column: sort key column name.
      quota: per-destination shuffle slots (range partitions can skew —
        size from sampled histograms).
      capacity: output capacity per partition.
      descending: sort direction.
      samples_per_worker: regular-sampling density (default max(P, 2)).
      num_chunks: shuffle pipeline depth K; K > 1 overlaps the range
        shuffle against the local merge sort.

    Returns:
      (sorted table, {"overflow_shuffle", "pivots"}).
    """
    P = comm.size()
    s = samples_per_worker or max(P, 2)
    st = local_sort(table, [key_column], descending=descending)
    keys = st.columns[key_column]
    n = st.nvalid
    # regular sampling positions over the valid prefix
    pos = ((jnp.arange(s, dtype=jnp.float32) + 0.5) / s * n.astype(jnp.float32)).astype(jnp.int32)
    pos = jnp.clip(pos, 0, jnp.maximum(n - 1, 0))
    samp = keys[pos]
    sentinel = _max_sentinel(keys.dtype) if not descending else _max_sentinel(keys.dtype)
    samp = jnp.where(n > 0, samp, sentinel)
    samp_count = jnp.where(n > 0, s, 0)
    all_samp = comm.allgather_array(samp, tiled=True)          # (P*s,)
    all_counts = comm.allgather_array(samp_count, tiled=False)  # (P,)
    total = jnp.sum(all_counts)
    sort_key = -all_samp if (descending and jnp.issubdtype(all_samp.dtype, jnp.floating)) else (
        jnp.bitwise_not(all_samp) if descending else all_samp)
    all_sorted = all_samp[jnp.argsort(sort_key)]
    # P-1 pivots at regular ranks of the gathered sample
    ranks = (jnp.arange(1, P, dtype=jnp.float32) / P * total.astype(jnp.float32)).astype(jnp.int32)
    ranks = jnp.clip(ranks, 0, P * s - 1)
    pivots = all_sorted[ranks]
    dest = range_partition_ids(st, key_column, pivots, P, descending=descending)
    shuf, ov = comm.shuffle(st, dest, quota, capacity=capacity, num_chunks=num_chunks)
    out = local_sort(shuf, [key_column], descending=descending)
    return out, {"overflow_shuffle": ov, "pivots": pivots}


# -- Globally-Reduce (paper §5.3.5) ----------------------------------------------

def dist_column_agg(comm: Communicator, table: Table, name: str, op: str):
    """Column aggregation -> replicated scalar (local reduce + AllReduce)."""
    from .local_ops import column_aggregate_local

    local_val, local_cnt = column_aggregate_local(table, name, op)
    if op in ("sum", "count"):
        return comm.allreduce(local_val, "sum")
    if op == "mean":
        s = comm.allreduce(local_val, "sum")
        c = comm.allreduce(local_cnt, "sum")
        return s / jnp.maximum(c, 1).astype(s.dtype)
    if op in ("min", "max"):
        return comm.allreduce(local_val, op)
    raise ValueError(op)


def dist_length(comm: Communicator, table: Table):
    """Distributed length utility (paper §5.3.5)."""
    return comm.allreduce(table.nvalid, "sum")


# -- Halo Exchange (paper §5.3.6) -------------------------------------------------

def dist_window_sum(
    comm: Communicator,
    table: Table,
    value_column: str,
    window: int,
) -> tuple[Table, dict]:
    """Rolling-window sum over the global row order (partition order = global
    order). Boundary windows receive the left neighbor's tail via a halo
    exchange. Emits ``<col>_rollsum`` plus ``window_valid`` (False for the
    first window-1 global rows, pandas min_periods semantics).

    Requires every partition to hold >= window-1 valid rows (checked via the
    returned ``halo_short`` flag).
    """
    w = window
    v = table.columns[value_column]
    m = valid_mask(table)
    vz = jnp.where(m, v, jnp.zeros_like(v))
    n = table.nvalid
    cap = table.capacity
    # fixed-size tail buffer: rows [n-(w-1), n)
    tail_idx = jnp.clip(n - (w - 1) + jnp.arange(w - 1, dtype=jnp.int32), 0, cap - 1)
    tail = vz[tail_idx]
    tail = jnp.where(jnp.arange(w - 1, dtype=jnp.int32) >= jnp.maximum(w - 1 - n, 0), tail, jnp.zeros_like(tail))
    halo = comm.shift(tail, offset=1)  # from left neighbor; rank0 gets zeros via ring? ring wraps —
    # mask the wrap for rank 0 (non-wrapping window):
    rank = comm.rank()
    halo = jnp.where(rank > 0, halo, jnp.zeros_like(halo))
    ext = jnp.concatenate([halo, vz])            # (w-1 + cap,)
    cs = jnp.cumsum(ext.astype(jnp.float32))
    upper = cs[w - 1 + jnp.arange(cap)]
    lower = jnp.concatenate([jnp.zeros((1,), cs.dtype), cs])[jnp.arange(cap)]
    roll = upper - lower
    # global validity: first w-1 global rows have incomplete windows
    my_offset = _exclusive_prefix_count(comm, n)
    gidx = my_offset + jnp.arange(cap, dtype=jnp.int32)
    wvalid = (gidx >= (w - 1)) & m
    halo_short = (n < (w - 1)) & (rank > 0)
    out = table.replace(**{f"{value_column}_rollsum": roll, "window_valid": wvalid})
    return out, {"halo_short": halo_short}


def _exclusive_prefix_count(comm: Communicator, n: jax.Array) -> jax.Array:
    counts = comm.allgather_array(n, tiled=False)  # (P,)
    P = counts.shape[0]
    rank = comm.rank()
    return jnp.sum(jnp.where(jnp.arange(P) < rank, counts, 0), dtype=jnp.int32)


# -- Partitioned I/O / rebalance (paper §5.3.8, §8) --------------------------------

def rebalance(comm: Communicator, table: Table, quota: int, capacity: int | None = None,
              num_chunks: int = 1) -> tuple[Table, dict]:
    """Evenly redistribute rows across workers preserving global order.

    This is the paper's §8 "sample-based repartitioning" answer to load
    imbalance / elastic rescale, exact rather than sampled because counts are
    one AllGather away. ``num_chunks`` > 1 pipelines the redistribution
    shuffle.
    """
    P = comm.size()
    n = table.nvalid
    counts = comm.allgather_array(n, tiled=False)
    total = jnp.sum(counts)
    base, rem = total // P, total % P
    targets = base + (jnp.arange(P) < rem).astype(counts.dtype)
    cum_targets = jnp.cumsum(targets)
    my_offset = _exclusive_prefix_count(comm, n)
    gidx = my_offset + jnp.arange(table.capacity, dtype=jnp.int32)
    dest = jnp.searchsorted(cum_targets, gidx, side="right").astype(jnp.int32)
    dest = jnp.where(valid_mask(table), jnp.clip(dest, 0, P - 1), P)
    out, ov = comm.shuffle(table, dest, quota, capacity=capacity, num_chunks=num_chunks)
    return out, {"overflow_shuffle": ov}


def dist_head(comm: Communicator, table: Table, k: int) -> Table:
    """Global head(k): keep rows with global index < k (stays partitioned)."""
    my_offset = _exclusive_prefix_count(comm, table.nvalid)
    gidx = my_offset + jnp.arange(table.capacity, dtype=jnp.int32)
    return compact(table, gidx < k)


def dist_transpose(comm: Communicator, table: Table, capacity: int | None = None) -> Table:
    """Distributed transpose (paper Table 2, shuffle-compute family).

    Row-partitioned (N x c) -> column-major (c x N): every worker receives
    all rows (the paper notes transpose "follows a more nuanced approach" —
    with static shapes the practical form is gather + local transpose) and
    emits c rows of N values under columns r0..r{N-1}. Intended for tables
    whose transposed width fits a partition (feature matrices, not fact
    tables); the planner should gate on N like broadcast-join does.
    """
    gathered = comm.allgather(table, capacity=capacity)
    names = sorted(gathered.columns)
    n = gathered.nvalid
    cap = gathered.capacity
    mat = jnp.stack([gathered.columns[k] for k in names], axis=0)  # (c, cap)
    cols = {f"r{i}": mat[:, i] for i in range(cap)}
    out = Table({"__col": jnp.arange(len(names), dtype=jnp.int32), **{
        k: v for k, v in cols.items()}}, jnp.asarray(len(names), jnp.int32))
    return out


def dist_window_agg(
    comm: Communicator,
    table: Table,
    value_column: str,
    window: int,
    op: str = "sum",
) -> tuple[Table, dict]:
    """Rolling window aggregate over the global row order: sum/mean/min/max
    (paper §5.3.6 halo exchange; §8 lists window operators as the major
    missing surface — implemented here)."""
    w = window
    v = table.columns[value_column]
    m = valid_mask(table)
    n = table.nvalid
    cap = table.capacity
    if op in ("sum", "mean"):
        fill = jnp.zeros((), v.dtype)
    elif op == "min":
        from .local_ops import _max_sentinel
        fill = _max_sentinel(v.dtype)
    else:
        from .local_ops import _min_sentinel
        fill = _min_sentinel(v.dtype)
    vz = jnp.where(m, v, fill)

    tail_idx = jnp.clip(n - (w - 1) + jnp.arange(w - 1, dtype=jnp.int32), 0, cap - 1)
    tail = vz[tail_idx]
    tail = jnp.where(jnp.arange(w - 1, dtype=jnp.int32) >= jnp.maximum(w - 1 - n, 0),
                     tail, jnp.full_like(tail, fill))
    halo = comm.shift(tail, offset=1)
    rank = comm.rank()
    halo = jnp.where(rank > 0, halo, jnp.full_like(halo, fill))
    ext = jnp.concatenate([halo, vz])            # (w-1 + cap,)

    # windowed reduce over the extended buffer
    idx = jnp.arange(cap)[:, None] + jnp.arange(w)[None, :]   # (cap, w)
    windows = ext[idx]
    if op == "sum":
        roll = jnp.sum(windows.astype(jnp.float32), axis=1)
    elif op == "mean":
        roll = jnp.mean(windows.astype(jnp.float32), axis=1)
    elif op == "min":
        roll = jnp.min(windows, axis=1).astype(jnp.float32)
    else:
        roll = jnp.max(windows, axis=1).astype(jnp.float32)

    my_offset = _exclusive_prefix_count(comm, n)
    gidx = my_offset + jnp.arange(cap, dtype=jnp.int32)
    wvalid = (gidx >= (w - 1)) & m
    halo_short = (n < (w - 1)) & (rank > 0)
    out = table.replace(**{f"{value_column}_roll{op}": roll, "window_valid": wvalid})
    return out, {"halo_short": halo_short}
