"""Distributed-memory dataframe partition: fixed-capacity columnar table.

The paper (Cylon) represents a dataframe partition in Apache Arrow columnar
format: per column a (validity bitmap, offsets, data) buffer tuple. Under XLA
all shapes must be static, so the TPU-native adaptation (DESIGN.md §2) is a
struct-of-arrays ``Table`` whose columns share a fixed *capacity*; rows
``[0, nvalid)`` are live and the tail is padding. Every operator is
capacity-bounded and carries validity through ``nvalid`` (and, transiently,
boolean masks). This replaces Arrow's offset buffers while preserving the
paper's row-partitioned distributed dataframe definition (paper §2.1).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Table",
    "from_arrays",
    "empty",
    "concat",
    "compact",
    "head",
    "valid_mask",
    "max_sentinel",
    "min_sentinel",
    "to_numpy",
]


def max_sentinel(dtype) -> jax.Array:
    """Largest representable value of ``dtype`` (+inf for floats).

    The identity element for ``min`` reductions: masked/invalid/padding
    rows carry it so they never win. The single definition here is shared
    by the jnp operator paths (``local_ops``) and the Pallas kernel layer
    (``kernels.segment_reduce``) — bit-parity between those backends
    depends on both using the same sentinel."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


def min_sentinel(dtype) -> jax.Array:
    """Smallest representable value of ``dtype`` (-inf for floats) — the
    identity element for ``max`` reductions; see :func:`max_sentinel`."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(-jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).min, dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    """One row-partition of a distributed dataframe.

    columns: name -> array of shape (capacity, ...) — all share capacity.
    nvalid:  scalar int32 — rows [0, nvalid) are live, the rest padding.
    """

    columns: dict[str, jax.Array]
    nvalid: jax.Array

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.nvalid,)
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        *cols, nvalid = children
        return cls(dict(zip(names, cols)), nvalid)

    # -- metadata -----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def column_names(self) -> tuple[str, ...]:
        return tuple(sorted(self.columns))

    @property
    def num_columns(self) -> int:
        return len(self.columns)

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    def replace(self, **updates) -> "Table":
        cols = dict(self.columns)
        nvalid = self.nvalid
        for k, v in updates.items():
            if k == "nvalid":
                nvalid = v
            else:
                cols[k] = v
        return Table(cols, nvalid)

    def select_columns(self, names: Sequence[str]) -> "Table":
        return Table({n: self.columns[n] for n in names}, self.nvalid)

    def nbytes(self) -> int:
        return sum(int(np.prod(c.shape)) * c.dtype.itemsize for c in self.columns.values())


# -- constructors -----------------------------------------------------------

def from_arrays(columns: Mapping[str, jax.Array], nvalid=None) -> Table:
    """Build a Table from same-capacity arrays; nvalid defaults to capacity."""
    cols = {k: jnp.asarray(v) for k, v in columns.items()}
    caps = {v.shape[0] for v in cols.values()}
    if len(caps) != 1:
        raise ValueError(f"columns disagree on capacity: {caps}")
    cap = caps.pop()
    if nvalid is None:
        nvalid = cap
    return Table(cols, jnp.asarray(nvalid, jnp.int32))


def empty(schema: Mapping[str, jnp.dtype], capacity: int) -> Table:
    """All-padding Table (nvalid=0) with the given schema and capacity."""
    cols = {k: jnp.zeros((capacity,), dtype=d) for k, d in schema.items()}
    return Table(cols, jnp.asarray(0, jnp.int32))


# -- core row-level helpers ---------------------------------------------------

def valid_mask(table: Table) -> jax.Array:
    """(capacity,) bool — True for live rows."""
    return jnp.arange(table.capacity, dtype=jnp.int32) < table.nvalid


def compact(table: Table, keep: jax.Array, capacity: int | None = None) -> Table:
    """Stable-move rows with ``keep & valid`` to the front; new nvalid = count.

    This is the paper's compaction auxiliary operator; under static shapes it
    is an argsort-gather (stable, so row order among kept rows is preserved).
    """
    keep = keep & valid_mask(table)
    cap_out = table.capacity if capacity is None else capacity
    # stable argsort of (not keep): kept rows (False) sort to the front.
    order = jnp.argsort(~keep, stable=True)
    if cap_out <= table.capacity:
        order = order[:cap_out]
        cols = {k: v[order] for k, v in table.columns.items()}
    else:
        pad = cap_out - table.capacity
        cols = {
            k: jnp.concatenate([v[order], jnp.zeros((pad,) + v.shape[1:], v.dtype)])
            for k, v in table.columns.items()
        }
    n = jnp.minimum(jnp.sum(keep, dtype=jnp.int32), cap_out)
    return Table(cols, n)


def head(table: Table, n: int) -> Table:
    """First n rows of the local partition (capacity shrinks to n)."""
    cols = {k: v[:n] for k, v in table.columns.items()}
    return Table(cols, jnp.minimum(table.nvalid, n))


def concat(a: Table, b: Table, capacity: int | None = None) -> Table:
    """Concatenate live rows of two partitions (same schema). Output capacity
    defaults to cap_a + cap_b; result is compacted (live rows first)."""
    if set(a.columns) != set(b.columns):
        raise ValueError("schema mismatch in concat")
    cap_out = (a.capacity + b.capacity) if capacity is None else capacity
    cols = {k: jnp.concatenate([a.columns[k], b.columns[k]]) for k in a.columns}
    keep = jnp.concatenate([valid_mask(a), valid_mask(b)])
    t = Table(cols, jnp.asarray(a.capacity + b.capacity, jnp.int32))
    # keep already encodes validity of both sides
    order = jnp.argsort(~keep, stable=True)[:cap_out]
    cols = {k: v[order] for k, v in t.columns.items()}
    n = jnp.minimum(jnp.sum(keep, dtype=jnp.int32), cap_out)
    return Table(cols, n)


def gather_rows(table: Table, idx: jax.Array, nvalid) -> Table:
    cols = {k: v[idx] for k, v in table.columns.items()}
    return Table(cols, jnp.asarray(nvalid, jnp.int32))


def map_rows(table: Table, fn: Callable[[dict[str, jax.Array]], dict[str, jax.Array]]) -> Table:
    """Embarrassingly-parallel map over columns (paper §5.3.1)."""
    out = fn(table.columns)
    return Table(dict(out), table.nvalid)


# -- host-side helpers (tests / examples) -------------------------------------

def to_numpy(table: Table) -> dict[str, np.ndarray]:
    """Live rows only, as numpy (host). For tests and examples."""
    n = int(table.nvalid)
    return {k: np.asarray(v)[:n] for k, v in table.columns.items()}


def from_numpy(data: Mapping[str, np.ndarray], capacity: int | None = None) -> Table:
    n = len(next(iter(data.values())))
    cap = n if capacity is None else capacity
    cols = {}
    for k, v in data.items():
        v = np.asarray(v)
        buf = np.zeros((cap,) + v.shape[1:], v.dtype)
        buf[:n] = v[:cap]
        cols[k] = jnp.asarray(buf)
    return Table(cols, jnp.asarray(min(n, cap), jnp.int32))
