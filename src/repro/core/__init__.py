"""The paper's core: distributed dataframe parallel processing patterns.

Public surface:
- ``Table`` — one fixed-capacity columnar row partition (Arrow adaptation)
- ``DDF`` / ``DDFContext`` — the distributed dataframe + execution env
- ``operators`` — in-shard_map distributed operators (the 8 patterns)
- ``cost_model`` — Hockney-model costs (paper Tables 3-4, §5.3) + strategy
  selection (§5.4)
- ``comm`` — the communication model (communicator / collectives / channels)
"""

from . import comm, cost_model, local_ops, operators, partition, patterns  # noqa: F401
from .api import DDF, DDFContext  # noqa: F401
from .dataframe import Table, from_arrays, from_numpy, to_numpy  # noqa: F401
